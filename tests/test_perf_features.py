"""Hillclimb features are exact-semantics transforms — prove it per feature:
vocab padding, chunked attention at model level, chunked WKV at model level.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.models.layers import padded_vocab


def _lm_batch(cfg, b=2, t=32, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0, cfg.vocab_size).astype(jnp.int32)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}


def test_padded_vocab_sizes():
    cfg = get_config("granite-3-8b")
    assert padded_vocab(cfg) == 49155  # exact when padding off
    cfg_p = dataclasses.replace(cfg, vocab_pad_multiple=256)
    assert padded_vocab(cfg_p) == 49408
    assert padded_vocab(cfg_p) % 256 == 0
    # whisper's odd vocab
    w = dataclasses.replace(get_config("whisper-medium"), vocab_pad_multiple=256)
    assert padded_vocab(w) % 256 == 0 and padded_vocab(w) >= 51865


def test_vocab_padding_preserves_semantics():
    """Padded model with the unpadded weights embedded: identical logits on
    real rows, -inf on padded rows, identical loss, argmax < V."""
    base = get_config("qwen2-7b").reduced()  # vocab 256
    padded = dataclasses.replace(base, vocab_pad_multiple=100)  # -> 300
    m0, m1 = get_model(base), get_model(padded)
    p1 = m1.init(jax.random.PRNGKey(0))
    # carve the exact-vocab params out of the padded ones
    p0 = jax.tree.map(lambda x: x, p1)
    p0["embed"] = p1["embed"][: base.vocab_size]
    p0["lm_head"] = p1["lm_head"][:, : base.vocab_size]
    batch = _lm_batch(base)
    l0, _ = m0.forward(p0, batch)
    l1, _ = m1.forward(p1, batch)
    assert l1.shape[-1] == 300
    np.testing.assert_allclose(
        np.asarray(l1[..., : base.vocab_size]), np.asarray(l0), rtol=1e-5, atol=1e-5
    )
    assert float(jnp.max(l1[..., base.vocab_size :])) <= -1e29
    loss0, _ = m0.loss(p0, batch)
    loss1, _ = m1.loss(p1, batch)
    assert float(loss0) == pytest.approx(float(loss1), rel=1e-5)
    assert int(jnp.max(jnp.argmax(l1, -1))) < base.vocab_size


@pytest.mark.parametrize("arch", ["qwen2-7b", "phi3-medium-14b", "olmoe-1b-7b"])
def test_attn_chunk_model_equivalence(arch):
    """cfg.attn_chunk: flash-style path == full attention, end to end."""
    base = get_config(arch).reduced()
    chunked = dataclasses.replace(base, attn_chunk=8)
    m0, m1 = get_model(base), get_model(chunked)
    params = m0.init(jax.random.PRNGKey(1))
    batch = _lm_batch(base, t=32, seed=2)
    l0, _ = m0.forward(params, batch)
    l1, _ = m1.forward(params, batch)
    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l0, np.float32), rtol=2e-3, atol=2e-3
    )
    # gradients too (train path)
    def loss(m):
        return lambda p: m.loss(p, batch)[0]
    g0 = jax.grad(loss(m0))(params)
    g1 = jax.grad(loss(m1))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=5e-3, atol=5e-3
        )


def test_wkv_chunked_model_equivalence():
    """cfg.wkv_chunked: GEMM-form WKV == faithful per-token scan."""
    base = get_config("rwkv6-1.6b").reduced()
    chunked = dataclasses.replace(base, wkv_chunked=True, wkv_chunk=8)
    m0, m1 = get_model(base), get_model(chunked)
    params = m0.init(jax.random.PRNGKey(3))
    batch = _lm_batch(base, t=32, seed=4)
    l0, _ = m0.forward(params, batch)
    l1, _ = m1.forward(params, batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=2e-4, atol=2e-4)
    # prefill state handoff must also agree (serving correctness)
    _, s0 = m0.prefill(params, batch)
    _, s1 = m1.prefill(params, batch)
    np.testing.assert_allclose(
        np.asarray(s0["wkv"]), np.asarray(s1["wkv"]), rtol=2e-4, atol=2e-4
    )


def test_wkv_chunked_trains():
    from repro.optim import constant
    from repro.train.train_step import init_train_state, make_train_step

    cfg = dataclasses.replace(
        get_config("rwkv6-1.6b").reduced(), wkv_chunked=True, wkv_chunk=8
    )
    model = get_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, constant(1e-3)))
    batch = _lm_batch(cfg, t=16, seed=5)
    _, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]) and float(metrics["grad_norm"]) > 0
