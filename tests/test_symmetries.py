"""The paper's symmetry claims + the symmetric-product early readout."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.mesh_array import simulate_mesh
from repro.core.scramble import sigma_table
from repro.core.symmetries import (
    check_antidiagonal_structure,
    check_mirror_rows,
    check_row1_diagonal,
    general_readout_steps,
    mirror_cell,
    paper_symmetric_bound,
    symmetric_readout_schedule,
    symmetric_readout_steps,
)


@pytest.mark.parametrize("n", list(range(2, 20)))
def test_row1_carries_diagonal(n):
    assert check_row1_diagonal(n)


@pytest.mark.parametrize("n", list(range(2, 20)))
def test_mirror_rows(n):
    """Rows r and n+2-r are reverse+transpose images (paper's mirror rule);
    covers the even-n middle-row self-symmetry as the r = n/2+1 case."""
    assert check_mirror_rows(n)


@pytest.mark.parametrize("n", list(range(2, 20)))
def test_antidiagonal_fixed_subscript(n):
    assert check_antidiagonal_structure(n)


def test_even_middle_row_self_symmetry():
    """Paper: 'for even n the middle row (n/2+1) has self symmetry'."""
    for n in (4, 6, 8, 10):
        tab = sigma_table(n)
        mid = n // 2  # 0-indexed row n/2+1
        row = tab[mid]
        for j in range(n):
            p, q = row[j]
            mp, mq = row[n - 1 - j]
            assert (p, q) == (mq, mp)


def test_paper_6_to_7_transition_new_cells():
    """The paper derives the 7x7 table from 6x6 'by inspection'; only the
    anti-diagonals d = 8 (length 7) cells are genuinely new.  Check the new
    bold values follow the alternating fixed-subscript + zig-zag rule."""
    tab = sigma_table(7)
    d = 8  # main anti-diagonal, m = 7, fixed value = 7
    cells = [(i, d - i) for i in range(1, 8)]
    got = [tab[i - 1][j - 1] for i, j in cells]
    # d even -> first subscript fixed at 7; zig-zag 7,5,3,1,2,4,6 on the other
    assert got == [(7, 7), (7, 5), (7, 3), (7, 1), (7, 2), (7, 4), (7, 6)]


def test_mirror_cell_involution():
    n = 9
    for i in range(2, n + 1):
        for j in range(1, n + 1):
            mi, mj = mirror_cell(n, i, j)
            assert mirror_cell(n, mi, mj) == (i, j)


# --- symmetric-product early readout ----------------------------------------


@pytest.mark.parametrize("n", list(range(2, 33)))
def test_symmetric_readout_within_paper_bound(n):
    """Paper: all significant values by <= n + 1 + n/2 steps (vs 2n-1)."""
    steps = symmetric_readout_steps(n)
    assert steps <= paper_symmetric_bound(n)
    assert steps <= general_readout_steps(n) == 2 * n - 1
    if n >= 4:  # strict saving kicks in
        assert steps < 2 * n - 1


def test_symmetric_readout_values_correct(rng):
    """Reading c_qp from the mirror cell at its (earlier) completion step
    gives the right value when C is symmetric (Gram product A Aᵀ)."""
    n = 8
    a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    gram_b = a.T
    res = simulate_mesh(a, gram_b, record_history=True)
    hist = np.asarray(res.history)
    c = np.asarray(a @ gram_b)
    sched = symmetric_readout_schedule(n)
    horizon = symmetric_readout_steps(n)
    for (p, q), ((i, j), t) in sched.items():
        assert t <= horizon
        np.testing.assert_allclose(hist[t - 1, i - 1, j - 1], c[p - 1, q - 1], rtol=1e-4, atol=1e-4)


def test_early_readout_fails_for_general_products(rng):
    """Sanity: the early readout is a *symmetric-product* property — for a
    general product the mirror cell holds c_qp != c_pq."""
    n = 6
    a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    res = simulate_mesh(a, b, record_history=True)
    hist = np.asarray(res.history)
    c = np.asarray(a @ b)
    sched = symmetric_readout_schedule(n)
    mismatched = 0
    for (p, q), ((i, j), t) in sched.items():
        if not np.allclose(hist[t - 1, i - 1, j - 1], c[p - 1, q - 1], rtol=1e-3):
            mismatched += 1
    assert mismatched > 0


@given(st.integers(min_value=2, max_value=64))
@settings(max_examples=20, deadline=None)
def test_readout_steps_closed_form(n):
    """Empirical law recorded in DESIGN.md: readout horizon == floor(3n/2)
    for n >= 2 under the anti-diagonal start model."""
    assert symmetric_readout_steps(n) == (3 * n) // 2
