"""Resilience subsystem tests (ISSUE 6, DESIGN.md §11).

Every named fault site gets a fault-injection test proving its degradation
path: plan-build backend fallback, execution-time degrade (bitwise-equal to
the fallback backend run directly, per structure), the non-finite guard's
three policies (eager and under an enclosing jit), autotune cache quarantine
and VMEM-model entry validation, checkpoint-write retry/backoff and error
surfacing, sharded collective degradation to the replicated schedule, and
the serve per-request skip loop.  Plus the harness itself (deterministic
trigger accounting, innermost-plan-wins), the degradation ledger, and the
σ-scramble period property (Rangineni, arXiv:1102.4579).

The multi-device collective check re-execs in an 8-virtual-CPU-device
subprocess on the 1-device tier-1 runner (same pattern as
test_sharded_plan.py).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import api
from repro.kernels.api import GemmSpec
from repro.resilience import faults, ledger
from repro.resilience.policy import NonFiniteError, retry_call

B = 8
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_state():
    api.clear_plan_cache()
    ledger.clear()
    yield
    api.clear_plan_cache()
    ledger.clear()


def _mats(m=2 * B, k=B, n=B, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    return a, b


# --- the harness itself ------------------------------------------------------


def test_faultspec_times_after_accounting():
    plan = faults.FaultPlan({"s": faults.FaultSpec(times=2, after=1)})
    with faults.inject(plan):
        faults.check("s")  # `after`: first matching call passes
        with pytest.raises(faults.FaultError):
            faults.check("s")
        with pytest.raises(faults.FaultError):
            faults.check("s")
        faults.check("s")  # dormant after `times` fires
    assert plan.fired("s") == 2
    faults.check("s")  # disarmed outside the with-block


def test_faultspec_match_filters_context():
    with faults.inject({"s": faults.FaultSpec(match={"backend": "xla"})}):
        faults.check("s", backend="ref")  # no match: doesn't count or fire
        with pytest.raises(faults.FaultError):
            faults.check("s", backend="xla")


def test_innermost_plan_wins_per_site():
    with faults.inject({"s": faults.FaultSpec(times=5)}) as outer:
        # inner plan names the site with times=0 -> shadows the outer plan
        with faults.inject({"s": faults.FaultSpec(times=0)}):
            faults.check("s")
        assert outer.fired("s") == 0
        with pytest.raises(faults.FaultError):
            faults.check("s")


def test_poison_corrupts_one_element():
    x = jnp.ones((4, 4))
    with faults.inject({"v": faults.FaultSpec(poison="nan")}):
        y = np.asarray(faults.poison("v", x))
    assert np.isnan(y[0, 0]) and np.isfinite(y.ravel()[1:]).all()
    # a poison-less spec at a value site raises, like `check`
    with faults.inject({"v": faults.FaultSpec(error=OSError)}):
        with pytest.raises(OSError):
            faults.poison("v", x)


def test_env_plan_validation(monkeypatch):
    # Detach an already-armed env plan (chaos tier) WITHOUT resetting its
    # trigger accounting, and restore the same object afterwards.
    saved = list(faults._ENV_INSTALLED)
    for p in saved:
        faults.uninstall_env_plan()
    try:
        monkeypatch.setenv(faults.ENV_PLAN, "no-such-plan")
        with pytest.raises(ValueError, match="canned fault plan"):
            faults.install_env_plan()
        monkeypatch.delenv(faults.ENV_PLAN)
        assert faults.install_env_plan() is None
    finally:
        for p in saved:
            faults._ENV_INSTALLED.append(p)
            with faults._STACK_LOCK:
                faults._STACK.insert(0, p)


def test_ci_default_plan_covers_all_documented_sites():
    want = {
        "plan.build",
        "plan.execute",
        "kernel.output",
        "autotune.cache_load",
        "collective.step",
        "checkpoint.write",
        "serve.request",
        "serve.admit",
        "serve.step",
        "kv.page_alloc",
    }
    assert set(faults.CANNED_PLANS["ci-default"]) == want


# --- ledger ------------------------------------------------------------------


def test_ledger_records_summarizes_and_clears():
    assert "no degradation events" in ledger.format_summary()
    e = ledger.record("site.a", cause="boom", fallback="xla", backend="pallas_mesh")
    ledger.record("site.a", cause="boom", fallback="xla")
    ledger.record("site.b", cause="drip", fallback="retry#1")
    assert e.seq == 1 and e.as_dict()["detail"] == {"backend": "'pallas_mesh'"}
    assert ledger.count() == 3 and ledger.count("site.a") == 2
    assert ledger.summary() == {
        "site.a": {"xla": 2},
        "site.b": {"retry#1": 1},
    }
    text = ledger.format_summary("[t]")
    assert "3 degradation event(s)" in text and "site.a" in text
    ledger.clear()
    assert ledger.count() == 0 and ledger.record("x", cause="c", fallback="f").seq == 1


# --- plan build fallback -----------------------------------------------------


def test_plan_build_falls_back_down_the_chain():
    a, b = _mats()
    spec = GemmSpec.from_operands(a, b, blocks=(B, B, B))
    with faults.inject(
        {"plan.build": faults.FaultSpec(match={"backend": "pallas_mesh"})}
    ):
        p = api.plan(spec, backend="pallas_mesh")
    assert p.backend == "xla"  # next in FALLBACK_ORDER after pallas_mesh
    health = p.describe()["health"]
    assert health["degraded"] and health["active_backend"] == "xla"
    (ev,) = p.health
    assert ev.site == "plan.build" and ev.fallback == "xla"
    assert ledger.events("plan.build")
    # the degraded plan IS the fallback backend's executor: bitwise equal
    want = api.plan(spec, backend="xla")(a, b)
    np.testing.assert_array_equal(np.asarray(p(a, b)), np.asarray(want))


def test_plan_build_fallback_false_raises():
    a, b = _mats(n=2 * B, seed=1)
    spec = GemmSpec.from_operands(a, b, blocks=(B, B, B))
    with faults.inject({"plan.build": faults.FaultSpec()}):
        with pytest.raises(faults.FaultError):
            api.plan(spec, fallback=False)


def test_spec_validation_errors_never_fall_back():
    # caller bugs every backend would reject: PlanValidationError surfaces
    # (still a ValueError) and no fallback build is attempted
    spec = GemmSpec(m=B + 1, k=B, n=B + 1, structure="scrambled", blocks=(B, B, B))
    with pytest.raises(api.PlanValidationError):
        api.plan(spec)
    assert isinstance(api.PlanValidationError("x"), ValueError)
    assert ledger.count() == 0


def test_fallback_chain_order_and_exhaustion():
    a, b = _mats(seed=2)
    spec = GemmSpec.from_operands(a, b, blocks=(B, B, B))
    # every backend's build fails -> the LAST error surfaces
    with faults.inject({"plan.build": faults.FaultSpec(times=99)}):
        with pytest.raises(faults.FaultError):
            api.plan(spec)
    # one ledger event per failed candidate that had a successor
    assert ledger.count("plan.build") >= 2


# --- execution-time degrade (bitwise parity per structure) -------------------


def _spec_and_args(structure, seed=0):
    if structure == "grouped":
        rng = np.random.default_rng(seed)
        g, rpg, k, n = 4, 16, 24, 20
        tokens = jnp.asarray(rng.normal(size=(g * rpg, k)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(g, k, n)).astype(np.float32))
        sizes = jnp.asarray(rng.integers(0, rpg + 1, size=g), jnp.int32)
        valid = (jnp.arange(rpg)[None, :] < sizes[:, None]).reshape(-1, 1)
        tokens = tokens * valid
        off = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(sizes)]).astype(
            jnp.int32
        )
        return api.GemmSpec.for_groups(api.GroupSpec(g, rpg), k, n), (tokens, off, w)
    if structure == "symmetric":
        a, _ = _mats(m=2 * B, k=2 * B, seed=seed)
        spec = GemmSpec.from_operands(a, a.T, structure="symmetric", blocks=(B, B, B))
        return spec, (a, a.T)
    a, b = _mats(m=2 * B, k=B, n=2 * B, seed=seed) if structure == "general" else _mats(
        m=B, k=B, n=B, seed=seed
    )
    spec = GemmSpec.from_operands(a, b, structure=structure, blocks=(B, B, B))
    return spec, (a, b)


@pytest.mark.parametrize("structure", ["general", "symmetric", "scrambled", "grouped"])
def test_execute_degrade_bitwise_equals_direct_fallback(structure):
    spec, args = _spec_and_args(structure)
    p = api.plan(spec, backend="pallas_mesh")
    with faults.inject({"plan.execute": faults.FaultSpec(times=1)}):
        got = p(*args)
    assert p.active_backend != "pallas_mesh"
    ev = next(e for e in p.health if e.site == "plan.execute")
    assert ev.fallback == p.active_backend
    # the ISSUE's bitwise contract: a DegradationEvent-recorded fallback
    # produces exactly what the fallback backend produces when run directly
    want = api.plan(spec, backend=p.active_backend)(*args)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the swap is permanent: the next call reuses the fallback, no new events
    n_ev = len(p.health)
    np.testing.assert_array_equal(np.asarray(p(*args)), np.asarray(want))
    assert len(p.health) == n_ev


def test_execute_degrade_chain_exhaustion_raises():
    a, b = _mats(seed=3)
    p = api.plan(GemmSpec.from_operands(a, b, blocks=(B, B, B)), backend="pallas_mesh")
    with faults.inject({"plan.execute": faults.FaultSpec(times=99)}):
        with pytest.raises(RuntimeError, match="exhausted"):
            p(a, b)
    # one degradation event per attempted fallback
    assert len([e for e in p.health if e.site == "plan.execute"]) >= 2


# --- guard_nonfinite ---------------------------------------------------------


def test_guard_zero_and_record_scrubs_eagerly():
    a, b = _mats(seed=4)
    spec = GemmSpec.from_operands(a, b, blocks=(B, B, B))
    p = api.plan(spec, backend="xla", guard_nonfinite="zero-and-record")
    with faults.inject({"kernel.output": faults.FaultSpec(poison="nan")}):
        out = np.asarray(p(a, b))
    assert np.isfinite(out).all() and out[0, 0] == 0.0
    ev = next(e for e in p.health if e.site == "guard.nonfinite")
    assert ev.fallback == "zero"
    # untouched elements pass through bit-for-bit
    want = np.asarray(api.plan(spec, backend="xla")(a, b))
    np.testing.assert_array_equal(out.ravel()[1:], want.ravel()[1:])


def test_guard_raise_policy():
    a, b = _mats(seed=5)
    p = api.plan(
        GemmSpec.from_operands(a, b, blocks=(B, B, B)),
        backend="xla",
        guard_nonfinite="raise",
    )
    with faults.inject({"kernel.output": faults.FaultSpec(poison="inf")}):
        with pytest.raises(NonFiniteError, match="non-finite"):
            p(a, b)
    p(a, b)  # clean outputs pass the guard


def test_guard_fallback_policy_switches_backend():
    a, b = _mats(seed=6)
    spec = GemmSpec.from_operands(a, b, blocks=(B, B, B))
    p = api.plan(spec, backend="pallas_mesh", guard_nonfinite="fallback")
    with faults.inject(
        {"kernel.output": faults.FaultSpec(poison="nan", match={"backend": "pallas_mesh"})}
    ):
        out = p(a, b)
    assert p.active_backend != "pallas_mesh"
    assert np.isfinite(np.asarray(out)).all()
    want = api.plan(spec, backend=p.active_backend)(a, b)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_guard_under_jit_zero_and_record_scrubs_traced():
    a, b = _mats(seed=7)
    spec = GemmSpec.from_operands(a, b, blocks=(B, B, B))
    p = api.plan(spec, backend="xla", guard_nonfinite="zero_and_record")
    with faults.inject({"kernel.output": faults.FaultSpec(poison="nan")}):
        out = np.asarray(jax.jit(lambda x, y: p(x, y))(a, b))
    assert np.isfinite(out).all()


def test_guard_under_jit_raise_records_unchecked_gap():
    a, b = _mats(seed=8)
    spec = GemmSpec.from_operands(a, b, blocks=(B, B, B))
    p = api.plan(spec, backend="xla", guard_nonfinite="raise")
    with faults.inject({"kernel.output": faults.FaultSpec(poison="nan")}):
        out = np.asarray(jax.jit(lambda x, y: p(x, y))(a, b))
    # values are unknown under the trace: the poison passes through, and the
    # coverage gap is RECORDED rather than silently ignored
    assert np.isnan(out[0, 0])
    ev = next(e for e in p.health if e.site == "guard.nonfinite")
    assert ev.fallback == "unchecked"


def test_guard_sample_and_policy_validation():
    a, b = _mats(seed=9)
    spec = GemmSpec.from_operands(a, b, blocks=(B, B, B))
    with pytest.raises(ValueError, match="guard policy"):
        api.plan(spec, guard_nonfinite="explode")
    # sampling keys a distinct cache entry and still catches element 0
    p = api.plan(spec, backend="xla", guard_nonfinite="raise", guard_sample=4)
    assert p is not api.plan(spec, backend="xla", guard_nonfinite="raise")
    with faults.inject({"kernel.output": faults.FaultSpec(poison="nan")}):
        with pytest.raises(NonFiniteError):
            p(a, b)


# --- autotune cache quarantine ----------------------------------------------


def test_autotune_corrupt_cache_quarantined_and_moved_aside(tmp_path):
    from repro.kernels.autotune import AutotuneCache

    path = tmp_path / "cache.json"
    path.write_text("{corrupt json!")
    with pytest.warns(UserWarning, match="unreadable"):
        assert AutotuneCache(path).get("whatever") is None
    assert not path.exists()
    assert (tmp_path / "cache.json.corrupt").read_text() == "{corrupt json!"
    evs = ledger.events("autotune.cache_load")
    assert evs and evs[-1].fallback == "quarantine"


def test_autotune_cache_load_fault_site(tmp_path):
    from repro.kernels.autotune import AutotuneCache

    path = tmp_path / "cache.json"
    path.write_text('{"version": 2, "entries": {}}')
    with faults.inject({"autotune.cache_load": faults.FaultSpec(error=OSError)}):
        with pytest.warns(UserWarning, match="unreadable"):
            assert AutotuneCache(path).get("x") is None
    assert ledger.count("autotune.cache_load") == 1


def test_autotune_vmem_model_validates_entries(tmp_path):
    import json

    from repro.kernels.autotune import AutotuneCache, cache_key, vmem_bytes

    good_key = cache_key(128, 128, 128, "float32", "pallas_mesh", platform="cpu")
    bad_key = cache_key(4096, 4096, 4096, "float32", "pallas_mesh", platform="cpu")
    good = {"blocks": [8, 8, 8], "source": "seed", "ms": None}
    bad = {"blocks": [2048, 2048, 2048], "source": "seed", "ms": None}
    budget = 12 * 1024 * 1024
    assert vmem_bytes(2048, 2048, 2048, jnp.float32) > budget  # sanity
    path = tmp_path / "cache.json"
    path.write_text(
        json.dumps({"version": 2, "entries": {good_key: good, bad_key: bad}})
    )
    cache = AutotuneCache(path, vmem_budget=budget)
    with pytest.warns(UserWarning, match="failed block/VMEM-model validation"):
        assert cache.get(bad_key) is None  # dropped: cannot fit the budget
    assert cache.get(good_key) == (8, 8, 8)  # validated entries survive
    evs = ledger.events("autotune.cache_load")
    assert evs and evs[-1].fallback == "retune"
    assert bad_key in dict(evs[-1].detail)["keys"]


def test_autotune_first_run_missing_file_is_silent(tmp_path, recwarn):
    from repro.kernels.autotune import AutotuneCache

    assert AutotuneCache(tmp_path / "never-written.json").get("k") is None
    assert not any("autotune" in str(w.message) for w in recwarn.list)
    assert ledger.count("autotune.cache_load") == 0


# --- retry/backoff -----------------------------------------------------------


def test_retry_call_backs_off_records_and_recovers():
    calls, sleeps = [], []

    def fn():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("disk blip")
        return "ok"

    out = retry_call(
        fn,
        retries=3,
        base_delay=0.05,
        max_delay=1.0,
        retry_on=(OSError,),
        site="t.retry",
        sleep=sleeps.append,
    )
    assert out == "ok" and len(calls) == 3
    assert sleeps == [0.05, 0.1]  # exponential backoff
    assert [e.fallback for e in ledger.events("t.retry")] == ["retry#1", "retry#2"]


def test_retry_call_exhaustion_reraises_last_error():
    def fn():
        raise OSError("permanent")

    with pytest.raises(OSError, match="permanent"):
        retry_call(fn, retries=1, base_delay=0.0, site="t.retry2", sleep=lambda s: None)
    assert ledger.count("t.retry2") == 1  # the final raise is not a "retry"


def test_retry_call_does_not_catch_unlisted_errors():
    def fn():
        raise KeyError("not retryable")

    with pytest.raises(KeyError):
        retry_call(fn, retries=5, retry_on=(OSError,), sleep=lambda s: None)
    assert ledger.count() == 0


# --- checkpoint async writer -------------------------------------------------


def test_async_writer_retries_transient_write_fault(tmp_path):
    from repro.checkpoint.async_writer import AsyncCheckpointer
    from repro.checkpoint.manager import CheckpointManager

    mgr = CheckpointManager(str(tmp_path))
    with faults.inject({"checkpoint.write": faults.FaultSpec(times=1, error=OSError)}):
        with AsyncCheckpointer(mgr, backoff=0.0) as ck:
            ck.submit(3, {"w": jnp.arange(4.0)})
            ck.wait()  # transient failure absorbed by the bounded retry
    assert mgr.latest_step() == 3
    evs = ledger.events("checkpoint.write")
    assert evs and evs[-1].fallback == "retry#1"


def test_async_writer_surfaces_permanent_failure_on_close(tmp_path):
    from repro.checkpoint.async_writer import AsyncCheckpointer
    from repro.checkpoint.manager import CheckpointManager

    ck = AsyncCheckpointer(CheckpointManager(str(tmp_path)), retries=1, backoff=0.0)
    with faults.inject({"checkpoint.write": faults.FaultSpec(times=9, error=OSError)}):
        ck.submit(1, {"w": jnp.zeros(2)})
        with pytest.raises(RuntimeError, match="checkpoint write failed"):
            ck.close()
    assert not ck._thread.is_alive()  # worker stopped BEFORE the raise
    with pytest.raises(RuntimeError, match="closed"):
        ck.submit(2, {"w": jnp.zeros(2)})


def test_async_writer_exit_preserves_body_exception(tmp_path):
    from repro.checkpoint.async_writer import AsyncCheckpointer
    from repro.checkpoint.manager import CheckpointManager

    with pytest.raises(ValueError, match="body error"):
        with faults.inject(
            {"checkpoint.write": faults.FaultSpec(times=9, error=OSError)}
        ):
            with AsyncCheckpointer(
                CheckpointManager(str(tmp_path)), retries=0, backoff=0.0
            ) as ck:
                ck.submit(1, {"w": jnp.zeros(2)})
                ck._q.join()  # write has failed by now
                raise ValueError("body error")  # must NOT be masked by close()


# --- serve request isolation -------------------------------------------------


def test_serve_requests_skip_failing_request():
    from repro.configs import get_config
    from repro.launch.serve import serve_requests
    from repro.models import get_model

    cfg = get_config("rwkv6-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [
        jax.random.randint(jax.random.PRNGKey(i), (2, 8), 0, cfg.vocab_size).astype(
            jnp.int32
        )
        for i in (1, 2)
    ]
    with faults.inject({"serve.request": faults.FaultSpec(times=1)}):
        results = serve_requests(model, params, prompts, gen_len=3)
    assert results[0] is None  # injected failure: reported + skipped
    out, rate = results[1]  # the next request still serves
    assert out.shape == (2, 3) and rate > 0
    (ev,) = ledger.events("serve.request")
    assert ev.fallback == "skip" and dict(ev.detail)["request"] == "0"


def test_serve_requests_isolate_arbitrary_errors():
    from repro.launch.serve import serve_requests

    # generate() itself exploding (model=None) is contained per request too
    results = serve_requests(None, None, [jnp.zeros((1, 4), jnp.int32)], gen_len=2)
    assert results == [None]
    assert ledger.count("serve.request") == 1


# --- sharded collective degradation (multi-device) ---------------------------


def _run_in_8dev_subprocess(fn_name: str) -> None:
    from repro.launch.mesh import forced_device_env

    env = forced_device_env(8, pythonpath=("src", "tests"))
    env.pop(faults.ENV_PLAN, None)  # the check arms its own fault plan
    out = subprocess.run(
        [
            sys.executable,
            "-c",
            f"import test_resilience as m; m.{fn_name}(); print('SUBPROC_OK')",
        ],
        capture_output=True,
        text=True,
        env=env,
        cwd=_REPO,
        timeout=600,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    assert "SUBPROC_OK" in out.stdout


def _check_collective_fault_degrades_to_replicated():
    from repro.kernels.api import ShardSpec
    from repro.launch.mesh import make_local_mesh

    api.clear_plan_cache()
    ledger.clear()
    mesh = make_local_mesh((4,), ("x",))
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-4, 5, size=(24, 16)).astype(np.float32))
    b = jnp.asarray(rng.integers(-4, 5, size=(16, 12)).astype(np.float32))
    want = api.plan(GemmSpec.from_operands(a, b, blocks=(B, B, B)))(a, b)
    for schedule in ("ring_k", "reduce_scatter_k", "allgather_a"):
        api.clear_plan_cache()
        shard = ShardSpec.from_mesh(
            mesh,
            k="x" if schedule != "allgather_a" else None,
            m="x" if schedule == "allgather_a" else None,
            schedule=schedule,
        )
        spec = GemmSpec.from_operands(a, b, blocks=(B, B, B), shard=shard)
        p = api.plan(spec, mesh=mesh)
        assert p.schedule == schedule
        with faults.inject({"collective.step": faults.FaultSpec(times=1)}):
            got = p(a, b)
        # integer-valued operands: replicated execution is bitwise-identical
        assert np.array_equal(np.asarray(got), np.asarray(want)), schedule
        assert p.active_backend == "replicated"
        ev = next(e for e in p.health if e.fallback == "replicated")
        assert dict(ev.detail)["schedule"] == repr(schedule)
        # permanent: the next call reuses the replicated executor silently
        n_ev = len(p.health)
        assert np.array_equal(np.asarray(p(a, b)), np.asarray(want))
        assert len(p.health) == n_ev


def test_collective_fault_degrades_to_replicated():
    if jax.device_count() >= 8:
        _check_collective_fault_degrades_to_replicated()
    else:
        _run_in_8dev_subprocess("_check_collective_fault_degrades_to_replicated")


# --- σ-scramble period (Rangineni, arXiv:1102.4579) --------------------------


def test_scramble_period_matches_rangineni():
    from repro.core.scramble import power_perm, scramble_order, scramble_perm

    # the published orders: S_3 and S_4 have period 7, S_5 has period 20
    assert [scramble_order(n) for n in (3, 4, 5)] == [7, 7, 20]
    for n in range(3, 9):
        S = scramble_perm(n)
        order = scramble_order(n)
        ident = np.arange(n * n)
        assert np.array_equal(power_perm(S, order), ident), n
        # true period, not merely a multiple: no proper divisor fixes S^d = I
        for d in range(1, order):
            if order % d == 0:
                assert not np.array_equal(power_perm(S, d), ident), (n, d)


def test_iterated_scramble_returns_to_standard_arrangement():
    from repro.core.scramble import apply_scramble, scramble_order

    for n in (3, 5):
        x = jnp.arange(float(n * n)).reshape(n, n)
        y = x
        for _ in range(scramble_order(n)):
            y = apply_scramble(y)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
        # ... and at no intermediate step (the scrambled arrangements are
        # all distinct from the standard one until the full period)
        y = apply_scramble(x)
        for _ in range(scramble_order(n) - 2):
            assert not np.array_equal(np.asarray(y), np.asarray(x))
            y = apply_scramble(y)
