"""Paged gather-attention: impl parity, masking, and the capability door.

The load-bearing property is BITWISE parity of the xla_gather impl with the
dense `_sdpa` decode path — the continuous-batching scheduler's correctness
contract (a request served through pages equals legacy `generate()`) rests
on it, and test_scheduler.py builds on the model-level version checked here.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import paged_attention as pa
from repro.kernels.api import CapabilityError
from repro.models import ShardCtx, get_model
from repro.models.attention import _sdpa


def _setup(rng, *, s=3, h=4, kvh=2, hd=16, ps=8, n_pages=4):
    pool_pages = 1 + s * n_pages
    q = jnp.asarray(rng.standard_normal((s, h, hd)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal((pool_pages, ps, kvh, hd)), jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((pool_pages, ps, kvh, hd)), jnp.float32)
    # Non-contiguous per-slot page sets, every id >= 1 (0 is scratch).
    tables = rng.permutation(np.arange(1, pool_pages))[: s * n_pages]
    bt = jnp.asarray(tables.reshape(s, n_pages), jnp.int32)
    lengths = jnp.asarray([5, 17, s * n_pages * ps // s], jnp.int32)
    return q, k_pool, v_pool, bt, lengths


def test_xla_gather_bitwise_matches_sdpa(rng):
    q, k_pool, v_pool, bt, lengths = _setup(rng)
    out = pa.paged_attention_xla(q, k_pool, v_pool, bt, lengths)
    # The dense reference: gather the same pages into a contiguous cache and
    # run the legacy decode attention at the same valid lengths.
    k = pa.gather_pages(k_pool, bt)
    v = pa.gather_pages(v_pool, bt)
    ref = _sdpa(q[:, None], k, v, causal=False, kv_valid_len=lengths[:, None])
    assert bool(jnp.all(out == ref[:, 0]))


def test_pallas_interpret_matches_xla(rng):
    q, k_pool, v_pool, bt, lengths = _setup(rng)
    out_x = pa.paged_attention_xla(q, k_pool, v_pool, bt, lengths)
    out_p = pa.paged_attention_pallas(q, k_pool, v_pool, bt, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_x), atol=2e-6)


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (6, 1)])
def test_gqa_head_ratios(rng, h, kvh):
    q, k_pool, v_pool, bt, lengths = _setup(rng, h=h, kvh=kvh)
    out_x = pa.paged_attention_xla(q, k_pool, v_pool, bt, lengths)
    out_p = pa.paged_attention_pallas(q, k_pool, v_pool, bt, lengths, interpret=True)
    assert out_x.shape == q.shape
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_x), atol=2e-6)


def test_length_masking_ignores_tail_and_unused_pages(rng):
    """Poisoning every pool row past `lengths` (and page 0) must not change
    the output — the paged masking never reads them."""
    q, k_pool, v_pool, bt, lengths = _setup(rng)
    lengths = jnp.asarray([1, 9, 12], jnp.int32)  # mid-page cutoffs
    base = pa.paged_attention_xla(q, k_pool, v_pool, bt, lengths)

    ps = k_pool.shape[1]
    k2, v2 = np.array(k_pool), np.array(v_pool)
    for slot in range(bt.shape[0]):
        ln = int(lengths[slot])
        for pidx in range(bt.shape[1]):
            page = int(bt[slot, pidx])
            start = pidx * ps
            for off in range(ps):
                if start + off >= ln:
                    k2[page, off] = 7e5  # large-but-finite garbage
                    v2[page, off] = -7e5
    k2[0] = 9e5  # scratch page
    v2[0] = 9e5
    poisoned = pa.paged_attention_xla(q, jnp.asarray(k2), jnp.asarray(v2), bt, lengths)
    assert bool(jnp.all(base == poisoned))


def test_pallas_skips_pages_past_length(rng):
    q, k_pool, v_pool, bt, lengths = _setup(rng)
    lengths = jnp.asarray([3, 8, 21], jnp.int32)
    out_x = pa.paged_attention_xla(q, k_pool, v_pool, bt, lengths)
    out_p = pa.paged_attention_pallas(q, k_pool, v_pool, bt, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_x), atol=2e-6)


def test_shape_validation(rng):
    q, k_pool, v_pool, bt, lengths = _setup(rng)
    with pytest.raises(ValueError, match="head_dim"):
        pa.paged_attention_pallas(q[..., :8], k_pool, v_pool, bt, lengths, interpret=True)
    with pytest.raises(ValueError, match="k/v pool"):
        pa.paged_attention_pallas(q, k_pool, v_pool[:4], bt, lengths, interpret=True)
    with pytest.raises(ValueError, match="slots"):
        pa.paged_attention_pallas(q, k_pool, v_pool, bt[:2], lengths, interpret=True)


# -- capability door ---------------------------------------------------------


def test_door_resolves_by_capability():
    on_tpu = jax.default_backend() == "tpu"
    assert pa.resolve_paged_impl() == ("pallas_paged" if on_tpu else "xla_gather")
    assert pa.resolve_paged_impl(interpret=True) == "pallas_paged"
    assert pa.resolve_paged_impl("xla_gather") == "xla_gather"


@pytest.mark.skipif(jax.default_backend() == "tpu", reason="pallas runs natively on TPU")
def test_door_explicit_unsupported_raises_capability_error():
    with pytest.raises(CapabilityError):
        pa.resolve_paged_impl("pallas_paged")


def test_door_unknown_impl_raises():
    with pytest.raises(ValueError, match="unknown paged impl"):
        pa.resolve_paged_impl("nope")


def test_door_duplicate_registration_guard():
    with pytest.raises(ValueError, match="already registered"):
        pa.register_paged_impl("xla_gather", pa.paged_attention_xla, interpret=True)
    # override is the explicit escape hatch (re-register the same impl)
    pa.register_paged_impl(
        "xla_gather", pa.paged_attention_xla, interpret=True, override=True
    )


def test_paged_dispatch_entrypoint(rng):
    q, k_pool, v_pool, bt, lengths = _setup(rng)
    out = pa.paged_attention(q, k_pool, v_pool, bt, lengths)  # door-resolved
    ref = pa.paged_attention_xla(q, k_pool, v_pool, bt, lengths)
    if jax.default_backend() != "tpu":
        assert bool(jnp.all(out == ref))


# -- model-level paged decode -----------------------------------------------


@pytest.mark.parametrize("arch", ["mesh-paper", "olmoe-1b-7b"])
def test_lm_decode_paged_bitwise_matches_lm_decode(arch):
    """Full-model paged decode == dense-cache decode, bit for bit, when the
    paged capacity equals the legacy cache capacity (same masked softmax)."""
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ctx = ShardCtx()
    t, ps, n_pages = 8, 8, 2  # capacity 16 == prompt + 8 decode steps
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (1, t), 0, cfg.vocab_size
    ).astype(jnp.int32)

    logits, caches = model.prefill(params, {"tokens": prompts, "labels": prompts}, ctx)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    state = jax.tree.map(
        lambda c: jnp.pad(c, [(0, 0), (0, 0), (0, n_pages * ps - t)] + [(0, 0)] * (c.ndim - 3)),
        caches,
    )

    s_slots = 3  # the tracked row sits in a wider slot batch on the paged side
    pool_pages = 1 + s_slots * n_pages
    pools = {
        name: jnp.zeros(sd.shape, sd.dtype)
        for name, sd in model.paged_pool_specs(pool_pages, ps).items()
    }
    pages = jnp.asarray([3, 5], jnp.int32)  # non-contiguous, non-leading
    kvh, hd = cfg.num_kv_heads, cfg.head_dim_
    layers = cfg.num_layers

    def put(pool, c):
        return pool.at[:, pages].set(
            c[:, 0].reshape(layers, 1, ps, kvh, hd).astype(pool.dtype)
        )

    pools = {"k": put(pools["k"], caches["k"]), "v": put(pools["v"], caches["v"])}
    bt = jnp.zeros((s_slots, n_pages), jnp.int32).at[1].set(pages)
    tok_p = tok

    for i in range(8):
        lg_d, state = model.decode(params, tok[:, None], state, jnp.int32(t + i), ctx)
        toks = jnp.zeros((s_slots, 1), jnp.int32).at[1, 0].set(tok_p[0])
        positions = jnp.zeros((s_slots,), jnp.int32).at[1].set(t + i)
        lg_p, pools = model.paged_decode(params, toks, pools, bt, positions, ctx)
        assert bool(jnp.all(lg_p[1, -1] == lg_d[0, -1])), f"step {i} diverged"
        tok = jnp.argmax(lg_d[:, -1, :], axis=-1).astype(jnp.int32)
        tok_p = jnp.argmax(lg_p[1:2, -1, :], axis=-1).astype(jnp.int32)


def test_paged_decode_rejects_multi_token():
    cfg = get_config("mesh-paper").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pools = {
        name: jnp.zeros(sd.shape, sd.dtype)
        for name, sd in model.paged_pool_specs(4, 8).items()
    }
    with pytest.raises(ValueError, match="single-token"):
        model.paged_decode(
            params,
            jnp.zeros((2, 3), jnp.int32),
            pools,
            jnp.zeros((2, 2), jnp.int32),
            jnp.zeros((2,), jnp.int32),
        )


def test_unsupported_family_has_no_paged_path():
    cfg = get_config("rwkv6-1.6b").reduced()
    model = get_model(cfg)
    assert not model.supports_paged
    with pytest.raises(NotImplementedError, match="paged"):
        model.paged_pool_specs(4, 8)
