"""The paper's scrambling transformation S: tables, symmetries, cycles, order.

Every table the paper prints (n = 3..7) is transcribed verbatim below and
checked cell-by-cell against the closed-form sigma_n.  The one known typo
(7x7 cell (2,7), printed `76`, forced to `67` by the paper's own mirror rule)
is asserted AS CORRECTED and flagged in DESIGN.md §Paper-fidelity.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import scramble
from repro.core.scramble import (
    apply_scramble,
    apply_scramble_power,
    cycle_decomposition,
    inverse_perm,
    power_perm,
    scramble_order,
    scramble_perm,
    sigma,
    sigma_table,
    unscramble,
)

# --- the paper's printed tables (1-indexed (p, q) written as "pq") ----------

PAPER_TABLES = {
    4: """
    11 22 33 44
    12 31 24 43
    32 14 41 23
    34 42 13 21
    """,
    5: """
    11 22 33 44 55
    12 31 24 53 45
    32 14 51 25 43
    34 52 15 41 23
    54 35 42 13 21
    """,
    6: """
    11 22 33 44 55 66
    12 31 24 53 46 65
    32 14 51 26 63 45
    34 52 16 61 25 43
    54 36 62 15 41 23
    56 64 35 42 13 21
    """,
    # (2,7) corrected 76 -> 67 (paper typo; see DESIGN.md §Paper-fidelity)
    7: """
    11 22 33 44 55 66 77
    12 31 24 53 46 75 67
    32 14 51 26 73 47 65
    34 52 16 71 27 63 45
    54 36 72 17 61 25 43
    56 74 37 62 15 41 23
    76 57 64 35 42 13 21
    """,
}

# the 3x3 arrangement from the paper's S^1 scrambling figure
PAPER_TABLES[3] = """
    11 22 33
    12 31 23
    32 13 21
    """


def _parse(text):
    rows = [r.split() for r in text.strip().splitlines()]
    return [[(int(c[0]), int(c[1])) for c in row] for row in rows]


@pytest.mark.parametrize("n", sorted(PAPER_TABLES))
def test_sigma_matches_paper_tables(n):
    expect = _parse(PAPER_TABLES[n])
    got = sigma_table(n)
    for i in range(n):
        for j in range(n):
            assert got[i][j] == expect[i][j], (
                f"n={n} cell ({i+1},{j+1}): closed form {got[i][j]} "
                f"!= paper {expect[i][j]}"
            )


# --- cycle structure / order (paper: S has period 7, 7, 20 for n=3,4,5) -----


@pytest.mark.parametrize("n,order", [(3, 7), (4, 7), (5, 20)])
def test_paper_cycle_orders(n, order):
    assert scramble_order(n) == order


def test_paper_n4_cycle_shapes():
    # paper: (11) (42) (12 22 31 32 14 44 21) (13 33 41 34 23 24 43)
    lens = sorted(len(c) for c in cycle_decomposition(4))
    assert lens == [1, 1, 7, 7]


def test_paper_n3_cycle_shapes():
    # paper: (11) (23) (12 22 31 32 13 33 21)
    lens = sorted(len(c) for c in cycle_decomposition(3))
    assert lens == [1, 1, 7]


def test_paper_n5_cycle_shapes():
    # paper: one 20-cycle, one 4-cycle, one fixed point
    lens = sorted(len(c) for c in cycle_decomposition(5))
    assert lens == [1, 4, 20]


def test_paper_n5_four_cycle_members():
    # paper: (13 33 51 54)
    cycles = cycle_decomposition(5)
    four = next(c for c in cycles if len(c) == 4)
    assert set(four) == {(1, 3), (3, 3), (5, 1), (5, 4)}


def test_order_equals_lcm_of_cycles():
    for n in range(2, 12):
        lens = [len(c) for c in cycle_decomposition(n)]
        assert scramble_order(n) == math.lcm(*lens)


# --- permutation algebra (property tests) ------------------------------------


@given(st.integers(min_value=2, max_value=16))
@settings(max_examples=15, deadline=None)
def test_sigma_is_a_bijection(n):
    seen = {sigma(n, i, j) for i in range(1, n + 1) for j in range(1, n + 1)}
    assert len(seen) == n * n


@given(st.integers(min_value=2, max_value=12), st.integers(min_value=-30, max_value=30))
@settings(max_examples=30, deadline=None)
def test_power_perm_matches_repeated_composition(n, k):
    base = scramble_perm(n)
    # repeated composition (k mod order times)
    order = scramble_order(n)
    kk = k % order
    ref = np.arange(n * n)
    for _ in range(kk):
        ref = base[ref]
    assert np.array_equal(power_perm(base, k), ref)


@given(st.integers(min_value=2, max_value=12))
@settings(max_examples=15, deadline=None)
def test_inverse_perm(n):
    p = scramble_perm(n)
    inv = inverse_perm(p)
    assert np.array_equal(p[inv], np.arange(n * n))
    assert np.array_equal(inv[p], np.arange(n * n))


@given(st.integers(min_value=2, max_value=10))
@settings(max_examples=12, deadline=None)
def test_scramble_power_order_is_identity(n):
    x = np.arange(n * n, dtype=np.float32).reshape(n, n)
    out = apply_scramble(jnp.asarray(x), scramble_order(n))
    np.testing.assert_array_equal(np.asarray(out), x)


def test_apply_unscramble_roundtrip():
    rng = np.random.default_rng(1)
    for n in (3, 4, 5, 8):
        x = jnp.asarray(rng.normal(size=(2, n, n)).astype(np.float32))
        np.testing.assert_allclose(np.asarray(unscramble(apply_scramble(x))), np.asarray(x))


def test_apply_scramble_power_traced_key():
    """Keyed scrambler: traced k selects S^k from the precomputed table."""
    n = 5
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    for k in (0, 1, 7, 19, 20, 33):
        got = apply_scramble_power(x, jnp.int32(k), n)
        want = apply_scramble(x, k % scramble_order(n))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_scramble_identity_shows_S():
    """C = A @ I lands in the scrambled arrangement — the paper's Figure 4."""
    from repro.core.mesh_array import simulate_mesh

    n = 4
    a = jnp.asarray(np.arange(n * n, dtype=np.float32).reshape(n, n))
    out = simulate_mesh(a, jnp.eye(n, dtype=jnp.float32)).output
    np.testing.assert_allclose(np.asarray(out), np.asarray(apply_scramble(a)))


def test_scrambled_cell_of_inverts_sigma():
    for n in (3, 4, 7):
        for p in range(1, n + 1):
            for q in range(1, n + 1):
                i, j = scramble.scrambled_cell_of(n, p, q)
                assert sigma(n, i, j) == (p, q)
