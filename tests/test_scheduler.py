"""Continuous-batching scheduler: correctness, overload ladder, chaos.

Two contracts (DESIGN.md §12):
  1. Correctness — every request that is not evicted decodes BITWISE equal
     to the legacy single-batch `generate()` path, at any admission order,
     slot occupancy, and page placement.
  2. Robustness — overload and injected faults (serve.admit / serve.step /
     kv.page_alloc, the `ci-default` plan) are absorbed as deterministic
     shed / timeout / preempt ledger events, never a crash.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import api
from repro.launch.scheduler import (
    ContinuousBatchingServer,
    PageAllocator,
    PagesExhausted,
    Request,
    RequestResult,
    ServeConfig,
)
from repro.launch.serve import generate, serving_steps
from repro.models import ShardCtx, get_model
from repro.resilience import faults, ledger


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("mesh-paper").reduced()
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _prompt(i, t=8, vocab=256):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(100 + i), (t,), 0, vocab), np.int32
    )


def _legacy_tokens(model, params, prompt, gen):
    out, _ = generate(model, params, jnp.asarray(prompt)[None], gen_len=gen)
    return [int(x) for x in np.asarray(out[0])]


# -- page allocator ----------------------------------------------------------


def test_allocator_reserves_scratch_page():
    alloc = PageAllocator(4)
    pages = alloc.alloc(3, reason="admit")
    assert sorted(pages) == [1, 2, 3]  # page 0 never handed out
    assert alloc.free_count == 0


def test_allocator_exhaustion_and_reuse():
    alloc = PageAllocator(4)
    pages = alloc.alloc(2, reason="admit")
    with pytest.raises(PagesExhausted):
        alloc.alloc(2, reason="grow")
    alloc.free(pages)
    assert alloc.free_count == 3


def test_allocator_double_free_rejected():
    alloc = PageAllocator(4)
    pages = alloc.alloc(1, reason="admit")
    alloc.free(pages)
    with pytest.raises(ValueError, match="double free"):
        alloc.free(pages)
    with pytest.raises(ValueError, match="out of range"):
        alloc.free([0])


def test_serve_config_validation():
    with pytest.raises(ValueError, match="max_slots"):
        ServeConfig(max_slots=0)
    with pytest.raises(ValueError, match="num_pages"):
        ServeConfig(num_pages=1)


# -- correctness: bitwise parity with generate() -----------------------------


def test_staggered_requests_bitwise_equal_legacy(dense):
    """Five requests arriving one tick apart through two slots: admission
    order, slot reuse, and page placement never change any request's
    tokens relative to the legacy single-batch path."""
    model, params = dense
    prompts = [_prompt(i) for i in range(5)]
    scfg = ServeConfig(
        max_slots=2, page_size=8, num_pages=9, max_pages_per_seq=2, queue_capacity=8
    )
    server = ContinuousBatchingServer(model, params, scfg)
    reqs = [
        Request(rid=f"r{i}", prompt=p, max_new_tokens=8, arrival=i)
        for i, p in enumerate(prompts)
    ]
    results = server.run(reqs)
    assert server.counters["served"] == 5
    for i, p in enumerate(prompts):
        assert results[f"r{i}"].status == "ok"
        assert results[f"r{i}"].tokens == _legacy_tokens(model, params, p, 8)


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "pixtral-12b"])
def test_moe_and_vlm_bitwise_equal_legacy(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    t, gen = 8, 6
    total = t + gen + (cfg.num_stub_patches if cfg.family == "vlm" else 0)
    pages = -(-total // 8)
    prompts = [_prompt(i, t=t, vocab=cfg.vocab_size) for i in range(3)]
    scfg = ServeConfig(
        max_slots=2,
        page_size=8,
        num_pages=1 + 2 * pages,
        max_pages_per_seq=pages,
        queue_capacity=4,
    )
    server = ContinuousBatchingServer(model, params, scfg)
    reqs = [
        Request(rid=f"r{i}", prompt=p, max_new_tokens=gen, arrival=i)
        for i, p in enumerate(prompts)
    ]
    results = server.run(reqs)
    for i, p in enumerate(prompts):
        assert results[f"r{i}"].status == "ok"
        assert results[f"r{i}"].tokens == _legacy_tokens(model, params, p, gen)


def test_ssm_stacked_state_bitwise_equal_legacy():
    """Recurrent family: O(1) state rides per slot; no pages involved."""
    cfg = get_config("rwkv6-1.6b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [_prompt(i, vocab=cfg.vocab_size) for i in range(3)]
    server = ContinuousBatchingServer(
        model, params, ServeConfig(max_slots=2, queue_capacity=4)
    )
    server.warmup()
    reqs = [
        Request(rid=f"s{i}", prompt=p, max_new_tokens=6, arrival=i)
        for i, p in enumerate(prompts)
    ]
    results = server.run(reqs)
    for i, p in enumerate(prompts):
        assert results[f"s{i}"].status == "ok"
        assert results[f"s{i}"].tokens == _legacy_tokens(model, params, p, 6)


def test_unschedulable_families_rejected():
    cfg = get_config("zamba2-1.2b").reduced()
    with pytest.raises(NotImplementedError, match="not schedulable"):
        ContinuousBatchingServer(get_model(cfg), None, ServeConfig())


# -- overload ladder: shed / timeout / preempt -------------------------------


def test_queue_overflow_sheds_deterministically(dense):
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=1, page_size=8, num_pages=5, max_pages_per_seq=2, queue_capacity=2
    )
    server = ContinuousBatchingServer(model, params, scfg)
    reqs = [
        Request(rid=f"q{i}", prompt=_prompt(i), max_new_tokens=4) for i in range(5)
    ]
    for r in reqs:
        server.submit(r)
    # Admission happens at step(), so the queue (capacity 2) holds q0, q1 and
    # q2..q4 are shed at submit — exactly three deterministic shed events.
    shed = [e for e in ledger.events("serve.shed") if e.cause == "queue_full"]
    assert [dict(e.detail)["rid"] for e in shed] == ["'q2'", "'q3'", "'q4'"]
    server.drain()
    assert {r: server.results[r].status for r in ("q0", "q1")} == {
        "q0": "ok", "q1": "ok"
    }
    assert server.results["q2"].status == "shed"
    assert server.counters["shed"] == 3 and server.counters["served"] == 2


def test_never_fits_request_shed_up_front(dense):
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=1, page_size=8, num_pages=5, max_pages_per_seq=2, queue_capacity=4
    )
    server = ContinuousBatchingServer(model, params, scfg)
    server.submit(Request(rid="big", prompt=_prompt(0), max_new_tokens=64))
    assert server.results["big"].status == "shed"
    assert "too_long" in server.results["big"].reason
    assert server.pending == 0
    assert ledger.count("serve.shed") == 1


def test_deadline_evicts_running_sequence(dense):
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=1, page_size=8, num_pages=9, max_pages_per_seq=4, queue_capacity=4
    )
    server = ContinuousBatchingServer(model, params, scfg)
    server.submit(Request(rid="slow", prompt=_prompt(0), max_new_tokens=24, deadline=5))
    server.drain()
    res = server.results["slow"]
    assert res.status == "timeout" and 0 < len(res.tokens) < 24
    (ev,) = ledger.events("serve.timeout")
    assert dict(ev.detail)["rid"] == "'slow'" and ev.fallback == "evict"
    # pages reclaimed on eviction
    assert server.alloc.free_count == scfg.num_pages - 1


def test_deadline_expires_queued_request(dense):
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=1, page_size=8, num_pages=9, max_pages_per_seq=2, queue_capacity=4
    )
    server = ContinuousBatchingServer(model, params, scfg)
    server.submit(Request(rid="hog", prompt=_prompt(0), max_new_tokens=8))
    server.submit(Request(rid="late", prompt=_prompt(1), max_new_tokens=4, deadline=3))
    server.drain()
    assert server.results["hog"].status == "ok"
    assert server.results["late"].status == "timeout"
    assert server.results["late"].reason == "deadline_queued"


def test_preemption_evicts_lowest_priority(dense):
    """Two sequences growing into a pool that holds only one: the
    lower-priority one is preempted (partial tokens returned), the survivor
    finishes bitwise-correct, and the event is ledgered."""
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=2, page_size=8, num_pages=6, max_pages_per_seq=3, queue_capacity=4
    )
    server = ContinuousBatchingServer(model, params, scfg)
    reqs = [
        Request(rid=f"p{i}", prompt=_prompt(i), max_new_tokens=16, priority=i)
        for i in range(2)
    ]
    results = server.run(reqs)
    assert results["p0"].status == "preempted" and 0 < len(results["p0"].tokens) < 16
    assert results["p1"].status == "ok"
    assert results["p1"].tokens == _legacy_tokens(model, params, _prompt(1), 16)
    (ev,) = ledger.events("serve.preempt")
    assert dict(ev.detail)["rid"] == "'p0'" and ev.cause == "pages_exhausted"
    assert server.alloc.free_count == scfg.num_pages - 1  # all pages returned


def test_self_preemption_when_requester_is_lowest_priority(dense):
    """When the sequence requesting growth IS the lowest-priority one (the
    higher-priority peer grabbed the last page first), it evicts itself —
    the loop can never deadlock waiting for pages it cannot take."""
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=2, page_size=8, num_pages=6, max_pages_per_seq=3, queue_capacity=4
    )
    server = ContinuousBatchingServer(model, params, scfg)
    reqs = [
        Request(rid=f"v{i}", prompt=_prompt(i), max_new_tokens=16, priority=1 - i)
        for i in range(2)  # v0 outranks v1; v0 also grows first
    ]
    results = server.run(reqs)
    assert results["v1"].status == "preempted" and 0 < len(results["v1"].tokens) < 16
    assert results["v0"].status == "ok"
    assert results["v0"].tokens == _legacy_tokens(model, params, _prompt(0), 16)
    (ev,) = ledger.events("serve.preempt")
    detail = dict(ev.detail)
    assert detail["rid"] == "'v1'" and detail["for_rid"] == "'v1'"  # self-evict
    assert server.alloc.free_count == scfg.num_pages - 1


def test_self_preemption_first_in_order_still_grows_survivors(dense):
    """Regression: three sequences hit a page turn on the same tick with the
    pool exhausted; the FIRST one in iteration order self-evicts (it is the
    lowest priority).  The two survivors must still claim their growth pages
    before the tick decodes — an early exit here would let them write the
    boundary token's KV through scratch page 0 and silently diverge."""
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=3, page_size=8, num_pages=7, max_pages_per_seq=3, queue_capacity=4
    )
    server = ContinuousBatchingServer(model, params, scfg)

    # Deterministic invariant spy: at every decode, each non-stalled active
    # sequence must already hold the page its next write lands in.  (The
    # token-level assertion below can pass by luck — a zeroed KV entry does
    # not always flip the argmax in a reduced random model — this cannot.)
    orig_decode_tick = server._decode_tick

    def checked_decode_tick():
        for s in server._active:
            if not s.stalled:
                assert len(s.pages) >= s.pos // scfg.page_size + 1, (
                    f"{s.req.rid} decoding at pos={s.pos} with only "
                    f"{len(s.pages)} pages: KV would land in scratch page 0"
                )
        orig_decode_tick()

    server._decode_tick = checked_decode_tick

    # w0 admitted first (iterates first) and lowest priority -> self-evicts.
    reqs = [
        Request(rid=f"w{i}", prompt=_prompt(i), max_new_tokens=16,
                priority=0 if i == 0 else 1)
        for i in range(3)
    ]
    results = server.run(reqs)
    assert results["w0"].status == "preempted" and 0 < len(results["w0"].tokens) < 16
    for i in (1, 2):
        assert results[f"w{i}"].status == "ok"
        assert results[f"w{i}"].tokens == _legacy_tokens(model, params, _prompt(i), 16)
    (ev,) = ledger.events("serve.preempt")
    detail = dict(ev.detail)
    assert detail["rid"] == "'w0'" and detail["for_rid"] == "'w0'"
    assert server.alloc.free_count == scfg.num_pages - 1


def test_preemption_victim_later_in_snapshot_does_not_leak_pages(dense):
    """Regression: the victim evicted for an earlier sequence's growth also
    appears later in the iteration snapshot.  A retired sequence must be
    skipped there — processing it would alloc fresh pages onto a dead
    sequence (leaked forever) and, with the pool dry, preempt a LIVE one."""
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=3, page_size=8, num_pages=7, max_pages_per_seq=3, queue_capacity=4
    )
    server = ContinuousBatchingServer(model, params, scfg)
    # u2 is lowest priority but iterates LAST; u0's growth evicts it first.
    reqs = [
        Request(rid=f"u{i}", prompt=_prompt(i), max_new_tokens=16,
                priority=0 if i == 2 else 1)
        for i in range(3)
    ]
    results = server.run(reqs)
    assert results["u2"].status == "preempted" and 0 < len(results["u2"].tokens) < 16
    for i in (0, 1):
        assert results[f"u{i}"].status == "ok"
        assert results[f"u{i}"].tokens == _legacy_tokens(model, params, _prompt(i), 16)
    # exactly ONE preemption: the dead victim never preempted a live peer
    (ev,) = ledger.events("serve.preempt")
    assert dict(ev.detail)["rid"] == "'u2'"
    assert server.counters["preempted"] == 1
    # and no pages leaked onto the retired sequence
    assert server.alloc.free_count == scfg.num_pages - 1


def test_explicit_zero_deadline_expires_immediately(dense):
    """Regression: deadline=0 is an explicit 'expire now', not falsy sugar
    for the 512-tick default."""
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=1, page_size=8, num_pages=9, max_pages_per_seq=2, queue_capacity=4
    )
    server = ContinuousBatchingServer(model, params, scfg)
    server.submit(Request(rid="z0", prompt=_prompt(0), max_new_tokens=4, deadline=0))
    server.step()
    res = server.results["z0"]
    assert res.status == "timeout" and res.reason == "deadline_queued"
    assert res.tokens == []
    assert server.pending == 0


# -- fault sites (the ci-default triggers) ----------------------------------


def test_serve_admit_fault_sheds_exactly_one_request(dense):
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=2, page_size=8, num_pages=9, max_pages_per_seq=2, queue_capacity=8
    )
    server = ContinuousBatchingServer(model, params, scfg)
    reqs = [
        Request(rid=f"a{i}", prompt=_prompt(i), max_new_tokens=4) for i in range(3)
    ]
    with faults.inject({"serve.admit": faults.FaultSpec(times=1)}):
        results = server.run(reqs)
    assert results["a0"].status == "shed"  # first admission attempt fired
    assert results["a1"].status == "ok" and results["a2"].status == "ok"
    assert results["a1"].tokens == _legacy_tokens(model, params, _prompt(1), 4)
    shed = ledger.events("serve.shed")
    assert len(shed) == 1 and "injected fault" in shed[0].cause


def test_serve_step_fault_skips_tick_not_server(dense):
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=1, page_size=8, num_pages=5, max_pages_per_seq=2, queue_capacity=4
    )
    server = ContinuousBatchingServer(model, params, scfg)
    with faults.inject({"serve.step": faults.FaultSpec(times=1)}):
        results = server.run(
            [Request(rid="s0", prompt=_prompt(0), max_new_tokens=4)]
        )
    assert results["s0"].status == "ok"
    assert results["s0"].tokens == _legacy_tokens(model, params, _prompt(0), 4)
    assert server.counters["skipped_ticks"] == 1
    (ev,) = ledger.events("serve.step")
    assert ev.fallback == "skip_tick"


def test_page_alloc_fault_at_admission_defers_one_tick(dense):
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=1, page_size=8, num_pages=5, max_pages_per_seq=2, queue_capacity=4
    )
    server = ContinuousBatchingServer(model, params, scfg)
    with faults.inject(
        {"kv.page_alloc": faults.FaultSpec(times=1, match={"reason": "admit"})}
    ):
        results = server.run(
            [Request(rid="d0", prompt=_prompt(0), max_new_tokens=4)]
        )
    assert results["d0"].status == "ok"  # deferred, then admitted and served
    assert results["d0"].tokens == _legacy_tokens(model, params, _prompt(0), 4)
    (ev,) = ledger.events("kv.page_alloc")
    assert ev.fallback == "defer_admission"


def test_page_alloc_fault_at_growth_stalls_not_evicts(dense):
    model, params = dense
    ledger.clear()
    scfg = ServeConfig(
        max_slots=1, page_size=8, num_pages=5, max_pages_per_seq=3, queue_capacity=4
    )
    server = ContinuousBatchingServer(model, params, scfg)
    with faults.inject(
        {"kv.page_alloc": faults.FaultSpec(times=1, match={"reason": "grow"})}
    ):
        results = server.run(
            [Request(rid="g0", prompt=_prompt(0), max_new_tokens=10)]
        )
    assert results["g0"].status == "ok"  # stalled one tick at the page turn
    assert results["g0"].tokens == _legacy_tokens(model, params, _prompt(0), 10)
    (ev,) = ledger.events("kv.page_alloc")
    assert ev.fallback == "stall"
    assert server.counters["preempted"] == 0


def test_ci_default_oversubscribed_run_survives(dense):
    """The acceptance scenario: the full ci-default plan armed and more work
    than the pool can hold — the run completes, overload lands in the
    ledger, and every non-evicted request is bitwise-equal to legacy."""
    model, params = dense
    ledger.clear()
    api.clear_plan_cache()  # fresh process semantics: warmup builds the canary
    scfg = ServeConfig(
        max_slots=2,
        page_size=8,
        num_pages=7,
        max_pages_per_seq=2,
        queue_capacity=3,
        default_deadline=60,
        warmup_prompt_lens=(8,),
    )
    server = ContinuousBatchingServer(model, params, scfg)
    prompts = [_prompt(i) for i in range(6)]
    reqs = [
        Request(rid=f"c{i}", prompt=p, max_new_tokens=8, arrival=0)
        for i, p in enumerate(prompts)
    ]
    with faults.inject(dict(faults.CANNED_PLANS["ci-default"])):
        server.warmup()
        results = server.run(reqs)

    assert len(results) == 6  # nobody vanished
    statuses = {r.status for r in results.values()}
    assert statuses <= {"ok", "shed", "timeout", "preempted"}
    assert any(r.status == "ok" for r in results.values())
    assert any(r.status != "ok" for r in results.values())  # overload was real
    for i, p in enumerate(prompts):
        if results[f"c{i}"].status == "ok":
            assert results[f"c{i}"].tokens == _legacy_tokens(model, params, p, 8)
    # the serve-side triggers all fired and were absorbed
    assert ledger.count("serve.step") == 1
    assert ledger.count("serve.shed") >= 1
    assert ledger.count("kv.page_alloc") == 1


# -- warmup, tracing, drain --------------------------------------------------


def test_warmup_consumes_poison_outside_serving_traces(dense):
    """An armed kernel.output NaN poison lands in the guarded warmup canary,
    never inside the decode-step trace: served tokens stay legacy-equal."""
    model, params = dense
    ledger.clear()
    api.clear_plan_cache()
    scfg = ServeConfig(
        max_slots=1, page_size=8, num_pages=5, max_pages_per_seq=2,
        queue_capacity=4, warmup_prompt_lens=(8,),
    )
    server = ContinuousBatchingServer(model, params, scfg)
    with faults.inject(
        {"kernel.output": faults.FaultSpec(times=1, poison="nan")}
    ):
        server.warmup()
        results = server.run(
            [Request(rid="w0", prompt=_prompt(0), max_new_tokens=6)]
        )
    assert results["w0"].tokens == _legacy_tokens(model, params, _prompt(0), 6)
    assert ledger.count("guard.nonfinite") == 1  # the canary absorbed it


def test_decode_step_traced_once_across_occupancy(dense):
    """Slot occupancy changes every tick of a staggered run; the fixed
    (max_slots,) batch shape means ONE decode trace serves them all."""
    model, params = dense
    scfg = ServeConfig(
        max_slots=2, page_size=8, num_pages=9, max_pages_per_seq=2, queue_capacity=8
    )
    server = ContinuousBatchingServer(model, params, scfg)
    reqs = [
        Request(rid=f"t{i}", prompt=_prompt(i), max_new_tokens=6, arrival=2 * i)
        for i in range(4)
    ]
    server.run(reqs)
    assert server._decode._cache_size() == 1


def test_generate_trace_count_flat_across_requests(dense):
    """Satellite: the per-(model, ctx) step cache means request N replays
    request 0's traces — trace counts stay at one per shape."""
    model, params = dense
    ctx = ShardCtx()
    prefill, serve = serving_steps(model, ctx)
    generate(model, params, jnp.asarray(_prompt(0))[None], gen_len=4, ctx=ctx)
    base_p, base_s = prefill._cache_size(), serve._cache_size()
    for i in range(1, 4):
        generate(model, params, jnp.asarray(_prompt(i))[None], gen_len=4, ctx=ctx)
    assert serving_steps(model, ctx) == (prefill, serve)  # cache hit, same objects
    assert prefill._cache_size() == base_p
    assert serve._cache_size() == base_s


def test_step_cache_is_bounded():
    """Regression: the per-(model, ctx) step cache is a bounded LRU.  The
    jitted closures capture their model strongly, so an unbounded cache in a
    long-lived process that keeps constructing models grows memory forever;
    least-recently-served entries must be dropped instead."""
    from repro.launch import serve as serve_mod

    saved = dict(serve_mod._STEP_CACHE)
    try:
        serve_mod._STEP_CACHE.clear()
        ctx = ShardCtx()
        models = [object() for _ in range(serve_mod._STEP_CACHE_MAX + 3)]
        for m in models:
            serving_steps(m, ctx)  # steps are built lazily; never traced here
        assert len(serve_mod._STEP_CACHE) == serve_mod._STEP_CACHE_MAX
        # the most recent model is still cached: a hit returns the same pair
        pair = serving_steps(models[-1], ctx)
        assert serving_steps(models[-1], ctx) == pair
        # the oldest was evicted: its key is gone from the cache
        assert all(entry[0] is not models[0]
                   for entry in serve_mod._STEP_CACHE.values())
    finally:
        serve_mod._STEP_CACHE.clear()
        serve_mod._STEP_CACHE.update(saved)


def test_generate_degenerate_timing_reports_zero(dense):
    """Satellite: gen_len=1 decodes zero steps; the rate must be 0.0 (a
    finite, JSON-safe value), never inf."""
    model, params = dense
    _, rate = generate(model, params, jnp.asarray(_prompt(0))[None], gen_len=1)
    assert rate == 0.0


def test_duplicate_rid_rejected(dense):
    model, params = dense
    server = ContinuousBatchingServer(
        model, params,
        ServeConfig(max_slots=1, page_size=8, num_pages=5, max_pages_per_seq=2),
    )
    server.submit(Request(rid="dup", prompt=_prompt(0), max_new_tokens=4))
    with pytest.raises(ValueError, match="duplicate"):
        server.submit(Request(rid="dup", prompt=_prompt(1), max_new_tokens=4))
    server.drain()


def test_context_manager_drains_on_exit(dense):
    model, params = dense
    scfg = ServeConfig(
        max_slots=1, page_size=8, num_pages=5, max_pages_per_seq=2, queue_capacity=4
    )
    with ContinuousBatchingServer(model, params, scfg) as server:
        server.submit(Request(rid="cm", prompt=_prompt(0), max_new_tokens=4))
    assert server.results["cm"].status == "ok"
    assert server.pending == 0
