"""End-to-end integration: training convergence, grad accumulation,
generation, and the launch drivers' public APIs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.serve import generate
from repro.launch.train import build_trainer
from repro.models import get_model
from repro.optim import AdamWConfig, constant
from repro.train.train_step import init_train_state, make_train_step


def test_loss_decreases_on_structured_stream():
    """~60 steps on the Markov-ish synthetic corpus: loss must clearly drop."""
    cfg = get_config("qwen2-7b").reduced()
    step_fn, state, data = build_trainer(cfg, batch=8, seq=64, lr=1e-3, total_steps=60)
    first, last = None, None
    for i in range(60):
        state, metrics = step_fn(state, next(data))
        if i == 4:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 0.3, (first, last)


def test_grad_accum_matches_full_batch():
    """grad_accum=2 must produce (numerically) the same update as accum=1."""
    cfg = get_config("qwen2-7b").reduced()
    model = get_model(cfg)
    state1 = init_train_state(model, jax.random.PRNGKey(0))
    state2 = jax.tree.map(jnp.copy, state1)
    toks = jax.random.randint(jax.random.PRNGKey(5), (8, 32), 0, cfg.vocab_size).astype(jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    acfg = AdamWConfig(clip_norm=0.0)  # clip uses pre-mean norms; disable for exactness
    s1 = jax.jit(make_train_step(model, constant(1e-3), acfg, grad_accum=1))
    s2 = jax.jit(make_train_step(model, constant(1e-3), acfg, grad_accum=2))
    new1, m1 = s1(state1, batch)
    new2, m2 = s2(state2, batch)
    assert m1["loss"] == pytest.approx(float(jnp.mean(m2["loss"])), rel=1e-5)
    for a, b in zip(jax.tree.leaves(new1["params"]), jax.tree.leaves(new2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("arch", ["qwen2-7b", "rwkv6-1.6b", "zamba2-1.2b", "olmoe-1b-7b"])
def test_generate_runs_all_decode_families(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size).astype(jnp.int32)
    out, rate = generate(model, params, prompts, gen_len=5)
    assert out.shape == (2, 5)
    assert rate > 0
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_generate_greedy_matches_forward_argmax():
    """First generated token == argmax of the forward logits at the last
    prompt position (greedy decoding is exact)."""
    cfg = get_config("granite-3-8b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(2), (3, 10), 0, cfg.vocab_size).astype(jnp.int32)
    out, _ = generate(model, params, prompts, gen_len=2)
    logits, _ = model.forward(params, {"tokens": prompts, "labels": prompts})
    want = jnp.argmax(logits[:, -1, :], axis=-1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(want))


def test_trainer_cli_smoke(tmp_path):
    """launch.train main(): 6 steps with checkpointing + resume."""
    from repro.launch.train import main

    ckpt_dir = str(tmp_path / "ck")
    main([
        "--arch", "qwen2-7b", "--reduced", "--steps", "4", "--batch", "2",
        "--seq", "16", "--ckpt-dir", ckpt_dir, "--ckpt-every", "2", "--log-every", "2",
    ])
    # resume continues from step 4 to 6
    main([
        "--arch", "qwen2-7b", "--reduced", "--steps", "6", "--batch", "2",
        "--seq", "16", "--ckpt-dir", ckpt_dir, "--ckpt-every", "2",
        "--resume", "auto", "--log-every", "2",
    ])
    from repro.checkpoint.manager import CheckpointManager

    assert CheckpointManager(ckpt_dir).latest_step() == 6


def test_serve_cli_smoke(capsys):
    from repro.launch.serve import main

    main(["--arch", "rwkv6-1.6b", "--reduced", "--batch", "2", "--prompt-len", "8", "--gen", "4"])
    out = capsys.readouterr().out
    assert "decode steps/s" in out


def test_mesh_kernel_backend_trains():
    """cfg.use_mesh_kernel: the paper's Pallas GEMM backend in a real
    train step (interpret mode on CPU), gradients flowing through the
    custom VJP."""
    import dataclasses

    cfg = dataclasses.replace(get_config("mesh-paper").reduced(), use_mesh_kernel=True)
    model = get_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, constant(1e-3)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size).astype(jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["grad_norm"]) > 0


def test_scramble_privacy_transform():
    """The paper's scrambling system as an activation privacy transform:
    the stack computes on S-permuted block grids (so logits DIFFER from the
    plain run — that is the point), stays finite, and trains."""
    import dataclasses

    base = get_config("mesh-paper").reduced()
    # (T=256, D=128) -> 2x1 grid is non-square; use T=D=256 for a 2x2 S grid
    cfg_off = dataclasses.replace(base, scramble_privacy=False, d_model=256, head_dim=64)
    cfg_on = dataclasses.replace(base, scramble_privacy=True, d_model=256, head_dim=64)
    m_off, m_on = get_model(cfg_off), get_model(cfg_on)
    params = m_off.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 256), 0, cfg_off.vocab_size).astype(jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    l_off, _ = m_off.forward(params, batch)
    l_on, _ = m_on.forward(params, batch)
    assert bool(jnp.all(jnp.isfinite(l_on.astype(jnp.float32))))
    # the permutation genuinely re-routes information through the stack
    assert float(jnp.max(jnp.abs(l_on - l_off))) > 1e-4
    # and the scrambled model still trains (gradients flow through S/S^-1)
    state = init_train_state(m_on, jax.random.PRNGKey(2))
    step = jax.jit(make_train_step(m_on, constant(1e-3)))
    _, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]) and float(metrics["grad_norm"]) > 0
