"""Optional-hypothesis shim: property tests degrade gracefully without it.

`hypothesis` is not part of the baked container image, so importing it at
module scope broke collection of 7 test modules.  Test files import
`given / settings / st` from here instead:

  * hypothesis installed -> the real thing, unchanged semantics.
  * hypothesis missing   -> a minimal deterministic fallback that runs each
    property test over `max_examples` seeded pseudo-random samples drawn from
    the same strategy shapes (integers / floats / booleans / sampled_from).
    Weaker than real shrinking/coverage, but the properties still execute on
    minimal environments instead of the whole module failing collection.
"""

from __future__ import annotations

try:  # pragma: no cover - trivially version-dependent
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools

    import numpy as _np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = -(2**16) if min_value is None else min_value
            hi = 2**16 if max_value is None else max_value
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(min_value=None, max_value=None, **_kw):
            lo = -1e6 if min_value is None else min_value
            hi = 1e6 if max_value is None else max_value
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

    st = _Strategies()

    def settings(max_examples=10, **_kw):
        def deco(fn):
            fn._shim_settings = {"max_examples": max_examples}
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # NOTE: no functools.wraps — copying __wrapped__ would make pytest
            # see the original signature and demand fixtures for drawn args.
            def wrapper(*args, **kwargs):
                cfg = getattr(wrapper, "_shim_settings", None) or getattr(
                    fn, "_shim_settings", {}
                )
                n = cfg.get("max_examples", 10)
                rng = _np.random.default_rng(0)
                for _ in range(n):
                    drawn = [s.sample(rng) for s in arg_strategies]
                    drawn_kw = {k: s.sample(rng) for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
