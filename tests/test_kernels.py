"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes/dtypes per the deliverable: every kernel asserts allclose
against repro.kernels.ref for each (shape, dtype, schedule-flag) cell.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scramble import scramble_order
from repro.kernels import ref
from repro.kernels.mesh_matmul import mesh_matmul_pallas
from repro.kernels.ops import matmul, scramble_blocks
from repro.kernels.scramble_kernel import scramble_blocks_pallas

B = 8  # small block for CPU-interpret sweeps


def _mk(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


# --- mesh matmul kernel -------------------------------------------------------

SHAPES = [
    (B, B, B),
    (2 * B, 3 * B, 4 * B),
    (4 * B, 2 * B, B),
    (3 * B, 5 * B, 2 * B),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("stagger", [True, False])
def test_mesh_matmul_vs_oracle(m, k, n, dtype, stagger):
    a = _mk((m, k), dtype, m + k)
    b = _mk((k, n), dtype, k + n)
    got = mesh_matmul_pallas(
        a, b, block_m=B, block_n=B, block_k=B, stagger=stagger, interpret=True
    )
    want = ref.matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("g", [2, 3, 4, 5])
@pytest.mark.parametrize("stagger", [True, False])
def test_mesh_matmul_scrambled_output(g, stagger):
    """Cell-block (i,j) holds standard block sigma(i,j) — zero-cost fusion."""
    m = n = g * B
    k = 2 * B
    a = _mk((m, k), jnp.float32, g)
    b = _mk((k, n), jnp.float32, g + 1)
    got = mesh_matmul_pallas(
        a, b, block_m=B, block_n=B, block_k=B, stagger=stagger,
        scramble_out=True, interpret=True,
    )
    want = ref.mesh_matmul_ref(a, b, block_m=B, block_n=B)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_mesh_matmul_rejects_bad_shapes():
    a = jnp.zeros((B + 1, B))
    b = jnp.zeros((B, B))
    with pytest.raises(ValueError):
        mesh_matmul_pallas(a, b, block_m=B, block_n=B, block_k=B, interpret=True)
    with pytest.raises(ValueError):
        mesh_matmul_pallas(
            jnp.zeros((2 * B, B)), jnp.zeros((B, B)),
            block_m=B, block_n=B, block_k=B, scramble_out=True, interpret=True,
        )


@given(
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=1, max_value=4),
)
@settings(max_examples=12, deadline=None)
def test_mesh_matmul_property_grid(gm, gk, gn):
    """Property: for any block grid, staggered == standard == oracle."""
    rng = np.random.default_rng(gm * 16 + gk * 4 + gn)
    a = jnp.asarray(rng.normal(size=(gm * B, gk * B)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(gk * B, gn * B)).astype(np.float32))
    want = ref.matmul_ref(a, b)
    for stagger in (True, False):
        got = mesh_matmul_pallas(
            a, b, block_m=B, block_n=B, block_k=B, stagger=stagger, interpret=True
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# --- scramble kernel ---------------------------------------------------------


@pytest.mark.parametrize("g", [2, 3, 4, 6])
@pytest.mark.parametrize("k", [1, 2, -1, 5])
def test_scramble_kernel_vs_oracle(g, k):
    x = _mk((g * B, g * B), jnp.float32, g * 10 + k)
    got = scramble_blocks_pallas(x, block_m=B, block_n=B, k=k, interpret=True)
    want = x
    fn = ref.scramble_blocks_ref if k >= 0 else ref.unscramble_blocks_ref
    for _ in range(abs(k)):
        want = fn(want, block_m=B, block_n=B)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_scramble_kernel_order_identity():
    g = 4
    x = _mk((g * B, g * B), jnp.float32, 7)
    k = scramble_order(g)
    got = scramble_blocks_pallas(x, block_m=B, block_n=B, k=k, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_scramble_kernel_batched():
    g = 3
    x = _mk((2, 5, g * B, g * B), jnp.float32, 9)
    got = scramble_blocks_pallas(x, block_m=B, block_n=B, k=1, interpret=True)
    want = ref.scramble_blocks_ref(x, block_m=B, block_n=B)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- ops.matmul dispatch layer -------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas_mesh"])
def test_ops_matmul_padding_and_batching(backend):
    """Arbitrary (non-block-multiple) shapes + leading batch dims."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.normal(size=(2, 3, 37, 19)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(19, 23)).astype(np.float32))
    got = matmul(a, b, backend=backend, block_m=B, block_n=B, block_k=B)
    want = jnp.einsum("bcmk,kn->bcmn", a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ops_matmul_fully_batched():
    rng = np.random.default_rng(12)
    a = jnp.asarray(rng.normal(size=(4, 17, 9)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(4, 9, 21)).astype(np.float32))
    got = matmul(a, b, backend="pallas_mesh", block_m=B, block_n=B, block_k=B)
    want = jnp.einsum("bmk,bkn->bmn", a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ops_matmul_grad_matches_xla():
    """custom_vjp: kernel-backend gradients == XLA-backend gradients."""
    rng = np.random.default_rng(13)
    a = jnp.asarray(rng.normal(size=(2 * B, 3 * B)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(3 * B, B)).astype(np.float32))

    def loss(backend):
        def f(a, b):
            return jnp.sum(
                matmul(a, b, backend=backend, block_m=B, block_n=B, block_k=B) ** 2
            )
        return f

    ga_x, gb_x = jax.grad(loss("xla"), argnums=(0, 1))(a, b)
    ga_p, gb_p = jax.grad(loss("pallas_mesh"), argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga_p), np.asarray(ga_x), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb_p), np.asarray(gb_x), rtol=1e-4, atol=1e-4)


def test_ops_matmul_grad_scrambled_backend():
    """d/dA sum(S(AB)) == d/dA sum(AB) since S only permutes positions."""
    rng = np.random.default_rng(14)
    g = 3
    a = jnp.asarray(rng.normal(size=(g * B, 2 * B)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2 * B, g * B)).astype(np.float32))

    def f_scr(a, b):
        return jnp.sum(
            matmul(a, b, backend="pallas_mesh_scrambled", block_m=B, block_n=B, block_k=B)
        )

    def f_xla(a, b):
        return jnp.sum(matmul(a, b, backend="xla"))

    ga_s = jax.grad(f_scr)(a, b)
    ga_x = jax.grad(f_xla)(a, b)
    np.testing.assert_allclose(np.asarray(ga_s), np.asarray(ga_x), rtol=1e-4, atol=1e-4)


def test_ops_scramble_blocks_grad_roundtrip():
    """VJP of S^k is S^-k: grad of sum(S(x) * w) must equal S^-1(w)."""
    rng = np.random.default_rng(15)
    g = 3
    x = jnp.asarray(rng.normal(size=(g * B, g * B)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(g * B, g * B)).astype(np.float32))

    def f(x):
        return jnp.sum(scramble_blocks(x, block_m=B, block_n=B, k=1) * w)

    gx = jax.grad(f)(x)
    want = scramble_blocks(w, block_m=B, block_n=B, k=-1)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_scrambled_backend_equals_core_S():
    """kernel-fused S == core apply_scramble at block granularity."""
    from repro.kernels.ref import scramble_blocks_ref

    rng = np.random.default_rng(16)
    g = 4
    a = jnp.asarray(rng.normal(size=(g * B, g * B)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(g * B, g * B)).astype(np.float32))
    got = matmul(a, b, backend="pallas_mesh_scrambled", block_m=B, block_n=B, block_k=B)
    want = scramble_blocks_ref(ref.matmul_ref(a, b), block_m=B, block_n=B)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
