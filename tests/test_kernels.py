"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes/dtypes per the deliverable: every kernel asserts allclose
against repro.kernels.ref for each (shape, dtype, schedule-flag) cell.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.scramble import scramble_order
from repro.kernels import ref
from repro.kernels.mesh_matmul import (
    ACTIVATIONS,
    mesh_matmul_pallas,
    mesh_matmul_pallas_batched,
)
from repro.kernels.ops import matmul, scramble_blocks
from repro.kernels.scramble_kernel import scramble_blocks_pallas

B = 8  # small block for CPU-interpret sweeps


def _mk(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


# --- mesh matmul kernel -------------------------------------------------------

SHAPES = [
    (B, B, B),
    (2 * B, 3 * B, 4 * B),
    (4 * B, 2 * B, B),
    (3 * B, 5 * B, 2 * B),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("stagger", [True, False])
def test_mesh_matmul_vs_oracle(m, k, n, dtype, stagger):
    a = _mk((m, k), dtype, m + k)
    b = _mk((k, n), dtype, k + n)
    got = mesh_matmul_pallas(
        a, b, block_m=B, block_n=B, block_k=B, stagger=stagger, interpret=True
    )
    want = ref.matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("g", [2, 3, 4, 5])
@pytest.mark.parametrize("stagger", [True, False])
def test_mesh_matmul_scrambled_output(g, stagger):
    """Cell-block (i,j) holds standard block sigma(i,j) — zero-cost fusion."""
    m = n = g * B
    k = 2 * B
    a = _mk((m, k), jnp.float32, g)
    b = _mk((k, n), jnp.float32, g + 1)
    got = mesh_matmul_pallas(
        a, b, block_m=B, block_n=B, block_k=B, stagger=stagger,
        scramble_out=True, interpret=True,
    )
    want = ref.mesh_matmul_ref(a, b, block_m=B, block_n=B)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_mesh_matmul_rejects_bad_shapes():
    a = jnp.zeros((B + 1, B))
    b = jnp.zeros((B, B))
    with pytest.raises(ValueError):
        mesh_matmul_pallas(a, b, block_m=B, block_n=B, block_k=B, interpret=True)
    with pytest.raises(ValueError):
        mesh_matmul_pallas(
            jnp.zeros((2 * B, B)), jnp.zeros((B, B)),
            block_m=B, block_n=B, block_k=B, scramble_out=True, interpret=True,
        )


@given(
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=1, max_value=4),
)
@settings(max_examples=12, deadline=None)
def test_mesh_matmul_property_grid(gm, gk, gn):
    """Property: for any block grid, staggered == standard == oracle."""
    rng = np.random.default_rng(gm * 16 + gk * 4 + gn)
    a = jnp.asarray(rng.normal(size=(gm * B, gk * B)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(gk * B, gn * B)).astype(np.float32))
    want = ref.matmul_ref(a, b)
    for stagger in (True, False):
        got = mesh_matmul_pallas(
            a, b, block_m=B, block_n=B, block_k=B, stagger=stagger, interpret=True
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# --- scramble kernel ---------------------------------------------------------


@pytest.mark.parametrize("g", [2, 3, 4, 6])
@pytest.mark.parametrize("k", [1, 2, -1, 5])
def test_scramble_kernel_vs_oracle(g, k):
    x = _mk((g * B, g * B), jnp.float32, g * 10 + k)
    got = scramble_blocks_pallas(x, block_m=B, block_n=B, k=k, interpret=True)
    want = x
    fn = ref.scramble_blocks_ref if k >= 0 else ref.unscramble_blocks_ref
    for _ in range(abs(k)):
        want = fn(want, block_m=B, block_n=B)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_scramble_kernel_order_identity():
    g = 4
    x = _mk((g * B, g * B), jnp.float32, 7)
    k = scramble_order(g)
    got = scramble_blocks_pallas(x, block_m=B, block_n=B, k=k, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_scramble_kernel_batched():
    g = 3
    x = _mk((2, 5, g * B, g * B), jnp.float32, 9)
    got = scramble_blocks_pallas(x, block_m=B, block_n=B, k=1, interpret=True)
    want = ref.scramble_blocks_ref(x, block_m=B, block_n=B)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- ops.matmul dispatch layer -------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas_mesh"])
def test_ops_matmul_padding_and_batching(backend):
    """Arbitrary (non-block-multiple) shapes + leading batch dims."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.normal(size=(2, 3, 37, 19)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(19, 23)).astype(np.float32))
    got = matmul(a, b, backend=backend, block_m=B, block_n=B, block_k=B)
    want = jnp.einsum("bcmk,kn->bcmn", a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ops_matmul_fully_batched():
    rng = np.random.default_rng(12)
    a = jnp.asarray(rng.normal(size=(4, 17, 9)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(4, 9, 21)).astype(np.float32))
    got = matmul(a, b, backend="pallas_mesh", block_m=B, block_n=B, block_k=B)
    want = jnp.einsum("bmk,bkn->bmn", a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ops_matmul_grad_matches_xla():
    """custom_vjp: kernel-backend gradients == XLA-backend gradients."""
    rng = np.random.default_rng(13)
    a = jnp.asarray(rng.normal(size=(2 * B, 3 * B)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(3 * B, B)).astype(np.float32))

    def loss(backend):
        def f(a, b):
            return jnp.sum(
                matmul(a, b, backend=backend, block_m=B, block_n=B, block_k=B) ** 2
            )
        return f

    ga_x, gb_x = jax.grad(loss("xla"), argnums=(0, 1))(a, b)
    ga_p, gb_p = jax.grad(loss("pallas_mesh"), argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga_p), np.asarray(ga_x), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb_p), np.asarray(gb_x), rtol=1e-4, atol=1e-4)


def test_ops_matmul_grad_scrambled_backend():
    """d/dA sum(S(AB)) == d/dA sum(AB) since S only permutes positions."""
    rng = np.random.default_rng(14)
    g = 3
    a = jnp.asarray(rng.normal(size=(g * B, 2 * B)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2 * B, g * B)).astype(np.float32))

    def f_scr(a, b):
        return jnp.sum(
            matmul(a, b, backend="pallas_mesh_scrambled", block_m=B, block_n=B, block_k=B)
        )

    def f_xla(a, b):
        return jnp.sum(matmul(a, b, backend="xla"))

    ga_s = jax.grad(f_scr)(a, b)
    ga_x = jax.grad(f_xla)(a, b)
    np.testing.assert_allclose(np.asarray(ga_s), np.asarray(ga_x), rtol=1e-4, atol=1e-4)


def test_ops_scramble_blocks_grad_roundtrip():
    """VJP of S^k is S^-k: grad of sum(S(x) * w) must equal S^-1(w)."""
    rng = np.random.default_rng(15)
    g = 3
    x = jnp.asarray(rng.normal(size=(g * B, g * B)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(g * B, g * B)).astype(np.float32))

    def f(x):
        return jnp.sum(scramble_blocks(x, block_m=B, block_n=B, k=1) * w)

    gx = jax.grad(f)(x)
    want = scramble_blocks(w, block_m=B, block_n=B, k=-1)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_scrambled_backend_equals_core_S():
    """kernel-fused S == core apply_scramble at block granularity."""
    from repro.kernels.ref import scramble_blocks_ref

    rng = np.random.default_rng(16)
    g = 4
    a = jnp.asarray(rng.normal(size=(g * B, g * B)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(g * B, g * B)).astype(np.float32))
    got = matmul(a, b, backend="pallas_mesh_scrambled", block_m=B, block_n=B, block_k=B)
    want = scramble_blocks_ref(ref.matmul_ref(a, b), block_m=B, block_n=B)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# --- fused epilogue -----------------------------------------------------------


def _epilogue_ref(a, b, bias=None, activation=None, residual=None):
    z = jnp.dot(a, b, preferred_element_type=jnp.float32)
    if bias is not None:
        z = z + bias.astype(jnp.float32)
    if activation not in (None, "none"):
        z = ACTIVATIONS[activation](z)
    if residual is not None:
        z = z + residual.astype(jnp.float32)
    return z


@pytest.mark.parametrize("activation", [None, "relu", "gelu", "silu", "sigmoid", "tanh"])
@pytest.mark.parametrize("with_residual", [False, True])
def test_fused_epilogue_vs_unfused_reference(activation, with_residual):
    """acceptance: fused bias+activation matches unfused reference @ 1e-4."""
    m, k, n = 2 * B, 3 * B, 2 * B
    a = _mk((m, k), jnp.float32, 21)
    b = _mk((k, n), jnp.float32, 22)
    bias = _mk((n,), jnp.float32, 23)
    res = _mk((m, n), jnp.float32, 24) if with_residual else None
    got = mesh_matmul_pallas(
        a, b, bias=bias, residual=res, activation=activation,
        block_m=B, block_n=B, block_k=B, interpret=True,
    )
    want = _epilogue_ref(a, b, bias, activation, res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_fused_epilogue_scrambled_applies_before_placement():
    """epilogue acts on the standard block, then sigma places it."""
    g = 3
    m = n = g * B
    a = _mk((m, 2 * B), jnp.float32, 25)
    b = _mk((2 * B, n), jnp.float32, 26)
    bias = _mk((n,), jnp.float32, 27)
    got = mesh_matmul_pallas(
        a, b, bias=bias, activation="relu", scramble_out=True,
        block_m=B, block_n=B, block_k=B, interpret=True,
    )
    want = ref.scramble_blocks_ref(
        _epilogue_ref(a, b, bias, "relu"), block_m=B, block_n=B
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_fused_epilogue_rejects_bad_shapes():
    a = jnp.zeros((2 * B, B))
    b = jnp.zeros((B, 2 * B))
    with pytest.raises(ValueError):
        mesh_matmul_pallas(
            a, b, bias=jnp.zeros((B,)),  # wrong bias length
            block_m=B, block_n=B, block_k=B, interpret=True,
        )
    with pytest.raises(ValueError):
        mesh_matmul_pallas(
            a, b, activation="swish-ish",  # unknown activation
            block_m=B, block_n=B, block_k=B, interpret=True,
        )


def test_ops_fused_epilogue_with_padding():
    """Fused path through ops.matmul on non-block-multiple shapes."""
    rng = np.random.default_rng(31)
    a = jnp.asarray(rng.normal(size=(19, 13)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(13, 11)).astype(np.float32))
    bias = jnp.asarray(rng.normal(size=(11,)).astype(np.float32))
    got = matmul(
        a, b, backend="pallas_mesh", block_m=B, block_n=B, block_k=B,
        bias=bias, activation="gelu",
    )
    want = _epilogue_ref(a, b, bias, "gelu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("activation", ["relu", "gelu", "silu", "sigmoid", "tanh"])
def test_fused_epilogue_grads_match_xla(activation):
    """Extended VJP: grads of act(AB + bias) + residual == XLA-backend grads."""
    rng = np.random.default_rng(32)
    a = jnp.asarray(rng.normal(size=(2 * B, 3 * B)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(3 * B, B)).astype(np.float32))
    bias = jnp.asarray(rng.normal(size=(B,)).astype(np.float32))
    res = jnp.asarray(rng.normal(size=(2 * B, B)).astype(np.float32))

    def loss(backend):
        def f(a, b, bias, res):
            y = matmul(
                a, b, backend=backend, block_m=B, block_n=B, block_k=B,
                bias=bias, activation=activation, residual=res,
            )
            return jnp.sum(y**2)
        return f

    gx = jax.grad(loss("xla"), argnums=(0, 1, 2, 3))(a, b, bias, res)
    gp = jax.grad(loss("pallas_mesh"), argnums=(0, 1, 2, 3))(a, b, bias, res)
    for want, got in zip(gx, gp):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )


def test_fused_epilogue_grads_scrambled_backend():
    rng = np.random.default_rng(33)
    g = 3
    a = jnp.asarray(rng.normal(size=(g * B, 2 * B)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2 * B, g * B)).astype(np.float32))
    bias = jnp.asarray(rng.normal(size=(g * B,)).astype(np.float32))

    def f_scr(a, b, bias):
        y = matmul(
            a, b, backend="pallas_mesh_scrambled", block_m=B, block_n=B,
            block_k=B, bias=bias, activation="silu",
        )
        return jnp.sum(y**2)

    def f_xla(a, b, bias):
        return jnp.sum(matmul(a, b, backend="xla", bias=bias, activation="silu") ** 2)

    gs = jax.grad(f_scr, argnums=(0, 1, 2))(a, b, bias)
    gx = jax.grad(f_xla, argnums=(0, 1, 2))(a, b, bias)
    # sum-of-squares is permutation-invariant, so grads agree exactly
    for want, got in zip(gx, gs):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )


# --- batched (b, i, j, k) grid ------------------------------------------------


def test_batched_kernel_vs_oracle():
    nb = 4
    a = _mk((nb, 2 * B, 3 * B), jnp.float32, 41)
    b = _mk((nb, 3 * B, 2 * B), jnp.float32, 42)
    got = mesh_matmul_pallas_batched(
        a, b, block_m=B, block_n=B, block_k=B, interpret=True
    )
    want = jnp.einsum("bmk,bkn->bmn", a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_batched_kernel_fused_epilogue():
    nb = 3
    a = _mk((nb, 2 * B, B), jnp.float32, 43)
    b = _mk((nb, B, 2 * B), jnp.float32, 44)
    bias = _mk((2 * B,), jnp.float32, 45)  # shared across the batch
    res = _mk((nb, 2 * B, 2 * B), jnp.float32, 46)
    got = mesh_matmul_pallas_batched(
        a, b, bias=bias, residual=res, activation="silu",
        block_m=B, block_n=B, block_k=B, interpret=True,
    )
    want = jax.vmap(lambda ai, bi, ri: _epilogue_ref(ai, bi, bias, "silu", ri))(a, b, res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ops_batched_is_single_pallas_call():
    """acceptance: batched inputs trace to ONE pallas_call with a (b,i,j,k)
    grid — no vmapped per-element launch."""
    import re

    a = _mk((4, 2 * B, B), jnp.float32, 47)
    b = _mk((4, B, B), jnp.float32, 48)
    jaxpr = str(
        jax.make_jaxpr(
            lambda a, b: matmul(a, b, backend="pallas_mesh", block_m=B, block_n=B, block_k=B)
        )(a, b)
    )
    assert len([ln for ln in jaxpr.splitlines() if "pallas_call" in ln]) == 1
    grids = re.findall(r"grid=\(([^)]*)\)", jaxpr)
    assert grids and len(grids[0].split(",")) == 4, grids  # (b, i, j, k)
    assert grids[0].split(",")[0].strip() == "4"  # leading batch axis


def test_batched_grads_match_xla():
    a = _mk((3, 2 * B, B), jnp.float32, 49)
    b = _mk((3, B, 2 * B), jnp.float32, 50)
    bias = _mk((2 * B,), jnp.float32, 51)

    def loss(backend):
        def f(a, b, bias):
            y = matmul(
                a, b, backend=backend, block_m=B, block_n=B, block_k=B,
                bias=bias, activation="gelu",
            )
            return jnp.sum(y**2)
        return f

    gx = jax.grad(loss("xla"), argnums=(0, 1, 2))(a, b, bias)
    gp = jax.grad(loss("pallas_mesh"), argnums=(0, 1, 2))(a, b, bias)
    for want, got in zip(gx, gp):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )
