"""Plan/execute operator API: typed specs, the capability-based backend
registry, the plan cache, the compat shim's deprecation path, and cross-
backend error parity (DESIGN.md §8)."""

import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import api, ops
from repro.kernels import ref
from repro.kernels.api import (
    BackendCapabilities,
    CapabilityError,
    Epilogue,
    GemmSpec,
)
from repro.kernels.mesh_matmul import mesh_matmul_pallas

B = 8


def _mk(shape, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32), dtype=dtype)


@pytest.fixture(autouse=True)
def _clean_dispatch_state():
    """Each test sees a fresh plan cache, auto default, and warning slate."""
    api.clear_plan_cache()
    api.set_default(None)
    ops._WARNED.clear()
    saved_legacy = (ops._LEGACY_DEFAULT, ops._LEGACY_EPOCH)
    yield
    ops._LEGACY_DEFAULT, ops._LEGACY_EPOCH = saved_legacy
    api.set_default(None)
    api.clear_plan_cache()
    ops._WARNED.clear()


# --- GemmSpec / Epilogue ------------------------------------------------------


def test_spec_from_operands_shapes_and_dtypes():
    a = _mk((2, 3, 4 * B, 2 * B), 0, jnp.bfloat16)
    w = _mk((2 * B, B), 1)
    spec = GemmSpec.from_operands(a, w)
    assert (spec.m, spec.k, spec.n) == (4 * B, 2 * B, B)
    assert spec.batch == (2, 3) and not spec.batched_b
    assert spec.dtype_a == "bfloat16" and spec.dtype_b == "float32"
    assert spec.eff_m == 6 * 4 * B  # leading dims fold into M when b is 2D
    b3 = _mk((2, 3, 2 * B, B), 2)
    spec3 = GemmSpec.from_operands(a, b3)
    assert spec3.batched_b and spec3.eff_m == 4 * B


def test_spec_rejects_malformed_operands():
    with pytest.raises(ValueError, match="contraction mismatch"):
        GemmSpec.from_operands(jnp.zeros((B, B)), jnp.zeros((2 * B, B)))
    with pytest.raises(ValueError, match="batch dims mismatch"):
        GemmSpec.from_operands(jnp.zeros((2, B, B)), jnp.zeros((3, B, B)))
    with pytest.raises(ValueError, match="structure must be one of"):
        GemmSpec(m=B, k=B, n=B, structure="diagonal")


def test_epilogue_validates_activation_like_kernels():
    with pytest.raises(ValueError, match="activation must be one of"):
        Epilogue(activation="swishh")
    assert Epilogue(activation="none").activation is None
    assert Epilogue().is_identity and not Epilogue(bias=True).is_identity


def test_spec_is_hashable_cache_key():
    s1 = GemmSpec(m=B, k=B, n=B, blocks=(B, B, B))
    s2 = GemmSpec(m=B, k=B, n=B, blocks=(B, B, B))
    assert s1 == s2 and hash(s1) == hash(s2)
    assert s1 != GemmSpec(m=B, k=B, n=B, blocks=(B, B, B), structure="scrambled")


# --- registry -----------------------------------------------------------------


def test_duplicate_registration_rejected():
    def impl(plan, a, b, bias, residual):
        return jnp.zeros((plan.spec.m, plan.spec.n))

    api.register_backend("dup_test", impl, {"structures": {"general"}})
    try:
        with pytest.raises(ValueError, match="already registered"):
            api.register_backend("dup_test", impl, {"structures": {"general"}})
        api.register_backend(  # override is the explicit escape hatch
            "dup_test", impl, {"structures": {"general"}}, override=True
        )
    finally:
        api.unregister_backend("dup_test")
    assert "dup_test" not in api.backend_names()


def test_unknown_capability_rejected():
    with pytest.raises(ValueError, match="unknown capabilities.*teleport"):
        api.register_backend(
            "bogus_caps",
            lambda *a: None,
            {"structures": {"general"}, "teleport": True},
        )
    with pytest.raises(ValueError, match="unknown structures"):
        BackendCapabilities(structures=frozenset({"general", "diagonal"}))
    assert "bogus_caps" not in api.backend_names()


def test_plan_rejects_unknown_backend():
    spec = GemmSpec(m=B, k=B, n=B)
    with pytest.raises(ValueError, match="unknown backend"):
        api.plan(spec, backend="not_a_backend")


def test_capability_mismatch_rejected():
    scrambled = GemmSpec(m=B, k=B, n=B, structure="scrambled", blocks=(B, B, B))
    with pytest.raises(CapabilityError, match="does not support structure"):
        api.plan(scrambled, backend="xla")

    # a TPU-only double is rejected on this CPU host
    api.register_backend(
        "tpu_only_double",
        lambda plan, a, b, bias, residual: a @ b,
        {"structures": {"general"}, "interpret": False},
    )
    try:
        with pytest.raises(CapabilityError, match="requires TPU"):
            api.plan(GemmSpec(m=B, k=B, n=B), backend="tpu_only_double")
    finally:
        api.unregister_backend("tpu_only_double")

    # batched operands against a 2D-only double
    api.register_backend(
        "no_batch_double",
        lambda plan, a, b, bias, residual: a @ b,
        {"structures": {"general"}, "batching": False},
    )
    try:
        spec3 = GemmSpec(m=B, k=B, n=B, batch=(4,), batched_b=True)
        with pytest.raises(CapabilityError, match="fully-batched"):
            api.plan(spec3, backend="no_batch_double")
    finally:
        api.unregister_backend("no_batch_double")


def test_test_double_registers_uniformly_and_executes():
    calls = []

    def impl(plan, a, b, bias, residual):
        calls.append(plan.spec)
        return jnp.full((plan.spec.m, plan.spec.n), 7.0)

    api.register_backend("double", impl, {"structures": {"general"}})
    try:
        spec = GemmSpec(m=B, k=B, n=B)
        p = api.plan(spec, backend="double")
        out = p(jnp.zeros((B, B)), jnp.zeros((B, B)))
        assert float(out[0, 0]) == 7.0 and calls == [spec]
    finally:
        api.unregister_backend("double")


# --- backend choice / defaults ------------------------------------------------


def test_auto_choice_prefers_xla_then_capable_backend():
    assert api.plan(GemmSpec(m=B, k=B, n=B)).backend == "xla"
    scrambled = GemmSpec(m=B, k=B, n=B, structure="scrambled", blocks=(B, B, B))
    assert api.plan(scrambled).backend == "pallas_mesh"  # xla can't scramble


def test_default_backend_context_manager():
    spec = GemmSpec(m=B, k=B, n=B, blocks=(B, B, B))
    with api.default_backend("pallas_mesh"):
        assert api.plan(spec).backend == "pallas_mesh"
    assert api.plan(spec).backend == "xla"
    with pytest.raises(ValueError, match="unknown backend"):
        with api.default_backend("nope"):
            pass


# --- plan cache ---------------------------------------------------------------


def test_plan_reuse_returns_identical_callable():
    spec = GemmSpec(m=B, k=B, n=B, blocks=(B, B, B))
    p1 = api.plan(spec, backend="pallas_mesh")
    p2 = api.plan(spec, backend="pallas_mesh")
    p3 = api.plan(  # equal spec built independently
        GemmSpec(m=B, k=B, n=B, blocks=(B, B, B)), backend="pallas_mesh"
    )
    assert p1 is p2 is p3
    info = api.plan_cache_info()
    assert info["size"] == 1 and info["hits"] == 2 and info["misses"] == 1
    # a different structure is a different plan
    assert api.plan(spec) is not p1  # auto-choice resolves to xla


def test_plan_provenance_and_tables():
    a = _mk((3 * B, 2 * B), 3)
    b = _mk((2 * B, 3 * B), 4)
    spec = GemmSpec.from_operands(a, b, structure="scrambled", blocks=(B, B, B))
    p = api.plan(spec)
    assert p.backend == "pallas_mesh" and p.blocks == (B, B, B)
    assert p.flops == 2 * 3 * B * 2 * B * 3 * B
    assert p.vmem_bytes and p.vmem_bytes > 0
    assert p.sigma_table is not None and p.sigma_table.shape == (9,)
    assert p.stagger_table is not None and p.stagger_table.shape == (3, 3)
    json.dumps(p.describe())  # provenance is JSON-able as-is


def test_plan_execution_matches_oracles_per_backend():
    a = _mk((2 * B, 3 * B), 5)
    b = _mk((3 * B, 2 * B), 6)
    bias = _mk((2 * B,), 7)
    spec = GemmSpec.from_operands(
        a, b, epilogue=Epilogue(bias=True, activation="gelu"), blocks=(B, B, B)
    )
    want = None
    for backend in ("xla", "ref", "pallas_mesh"):
        got = api.plan(spec, backend=backend)(a, b, bias=bias)
        if want is None:
            want = got
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )


def test_scrambled_structure_bit_for_bit_vs_fused_kernel():
    """structure='scrambled' reproduces the old pallas_mesh_scrambled output
    exactly (same kernel, same opts — zero numeric drift)."""
    g = 3
    a = _mk((g * B, 2 * B), 8)
    b = _mk((2 * B, g * B), 9)
    want = mesh_matmul_pallas(
        a, b, block_m=B, block_n=B, block_k=B, scramble_out=True, interpret=True
    )
    spec = GemmSpec.from_operands(a, b, structure="scrambled", blocks=(B, B, B))
    got_plan = api.plan(spec)(a, b)
    got_compat = ops.matmul(
        a, b, backend="pallas_mesh_scrambled", block_m=B, block_n=B, block_k=B
    )
    np.testing.assert_array_equal(np.asarray(got_plan), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_compat), np.asarray(want))
    # and the ref backend agrees numerically (allclose, not bitwise)
    got_ref = api.plan(spec, backend="ref")(a, b)
    np.testing.assert_allclose(
        np.asarray(got_ref), np.asarray(want), rtol=1e-4, atol=1e-4
    )


def test_scrambled_alignment_validated_at_plan_time():
    spec = GemmSpec(m=B + 1, k=B, n=B + 1, structure="scrambled", blocks=(B, B, B))
    with pytest.raises(ValueError, match="block-aligned"):
        api.plan(spec)
    rect = GemmSpec(m=2 * B, k=B, n=3 * B, structure="scrambled", blocks=(B, B, B))
    with pytest.raises(ValueError, match="square block grid"):
        api.plan(rect)


def test_symmetric_structure_requires_square_and_executes():
    with pytest.raises(ValueError, match="square product"):
        api.plan(GemmSpec(m=B, k=B, n=2 * B, structure="symmetric"))
    a = _mk((2 * B, B), 10)
    spec = GemmSpec.from_operands(a, a.T, structure="symmetric", blocks=(B, B, B))
    got = api.plan(spec, backend="pallas_mesh")(a, a.T)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a @ a.T), rtol=1e-4, atol=1e-4
    )


# --- execution-time validation / error parity ---------------------------------


def test_epilogue_contract_mismatch_rejected():
    a = _mk((B, B), 11)
    p = api.plan(GemmSpec.from_operands(a, a))
    with pytest.raises(ValueError, match="built without bias"):
        p(a, a, bias=jnp.zeros((B,)))
    p2 = api.plan(GemmSpec.from_operands(a, a, epilogue=Epilogue(bias=True)))
    with pytest.raises(ValueError, match="built with bias"):
        p2(a, a)


@pytest.mark.parametrize("backend", ["xla", "ref", "pallas_mesh"])
def test_epilogue_shape_errors_identical_on_all_backends(backend):
    """The xla path used to skip `_check_epilogue`'s shape validation — every
    backend now rejects malformed bias/residual with the same error."""
    a = _mk((2 * B, B), 12)
    b = _mk((B, 2 * B), 13)
    spec_bias = GemmSpec.from_operands(
        a, b, epilogue=Epilogue(bias=True), blocks=(B, B, B)
    )
    with pytest.raises(ValueError) as bias_err:
        api.plan(spec_bias, backend=backend)(a, b, bias=jnp.zeros((3,)))
    assert str(bias_err.value) == f"bias must have shape ({2 * B},), got (3,)"

    spec_res = GemmSpec.from_operands(
        a, b, epilogue=Epilogue(residual=True), blocks=(B, B, B)
    )
    with pytest.raises(ValueError) as res_err:
        api.plan(spec_res, backend=backend)(a, b, residual=jnp.zeros((B, B)))
    assert (
        str(res_err.value)
        == f"residual must have shape ({2 * B}, {2 * B}), got ({B}, {B})"
    )


def test_operand_shape_mismatch_rejected():
    a = _mk((B, B), 14)
    p = api.plan(GemmSpec.from_operands(a, a))
    with pytest.raises(ValueError, match="do not match plan spec"):
        p(jnp.zeros((2 * B, B)), a)


# --- compat shim / deprecation path -------------------------------------------


def test_compat_deprecation_warning_fires_exactly_once():
    a = _mk((B, B), 15)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ops.matmul(a, a, backend="xla")
        ops.matmul(a, a, backend="xla")
        ops.matmul(a, a, backend="pallas_mesh", block_m=B, block_n=B, block_k=B)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1 and "backend= strings" in str(dep[0].message)

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ops.matmul(a, a)  # no string backend: nothing to warn about
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]


def test_set_default_backend_deprecated_but_functional():
    a = _mk((B, B), 16)
    try:
        with pytest.deprecated_call():
            ops.set_default_backend("pallas_mesh")
        assert ops.get_default_backend() == "pallas_mesh"
        out = ops.matmul(a, a, block_m=B, block_n=B, block_k=B)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(a @ a), rtol=1e-4, atol=1e-4
        )
        [entry] = api.plan_cache_info()["plans"]
        assert entry["backend"] == "pallas_mesh"
        with pytest.raises(ValueError, match="backend must be one of"):
            ops.set_default_backend("bogus")
    finally:
        ops._LEGACY_DEFAULT = None
        api.set_default(None)


def test_scoped_default_backend_reaches_compat_shim():
    """api.default_backend(...) — the documented replacement for the global
    setter — must steer legacy ops.matmul call sites too."""
    a = _mk((B, B), 20)
    with api.default_backend("pallas_mesh"):
        ops.matmul(a, a, block_m=B, block_n=B, block_k=B)
    [entry] = api.plan_cache_info()["plans"]
    assert entry["backend"] == "pallas_mesh"
    assert ops.get_default_backend() == "xla"  # scope ended


def test_invalid_backend_string_does_not_consume_warning():
    a = _mk((B, B), 21)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        with pytest.raises(ValueError, match="backend must be one of"):
            ops.matmul(a, a, backend="typo")
        ops.matmul(a, a, backend="xla")  # the one-shot warning still fires
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1


def test_scoped_default_supersedes_stale_legacy_scrambled_default():
    """A newer api default (scope or set_default) wins over the legacy
    setter's string — including its scrambled structure."""
    g = 3
    a = _mk((g * B, g * B), 22)
    try:
        with pytest.deprecated_call():
            ops.set_default_backend("pallas_mesh_scrambled")
        with api.default_backend("pallas_mesh"):
            got = ops.matmul(a, a, block_m=B, block_n=B, block_k=B)
        np.testing.assert_allclose(  # plain product, NOT scrambled
            np.asarray(got), np.asarray(a @ a), rtol=1e-4, atol=1e-4
        )
        api.set_default(None)  # explicit auto-choice also supersedes
        assert ops.get_default_backend() == "xla"
    finally:
        ops._LEGACY_DEFAULT = None
        ops._LEGACY_EPOCH = None
        api.set_default(None)


def test_plan_call_rejects_dtype_mismatch():
    a = _mk((B, B), 23)
    p = api.plan(GemmSpec.from_operands(a, a))
    with pytest.raises(ValueError, match="dtypes .* do not match plan spec"):
        p(a.astype(jnp.bfloat16), a.astype(jnp.bfloat16))


def test_spec_rejects_malformed_blocks_tuple():
    with pytest.raises(ValueError, match="bm, bn, bk"):
        GemmSpec(m=B, k=B, n=B, blocks=(B, B))


def test_reregistration_evicts_only_that_backends_plans():
    spec = GemmSpec(m=B, k=B, n=B)
    p_xla = api.plan(spec, backend="xla")
    api.register_backend(
        "evict_double",
        lambda plan, a, b, bias, residual: a @ b,
        {"structures": {"general"}},
    )
    try:
        p_d1 = api.plan(spec, backend="evict_double")
        api.register_backend(
            "evict_double",
            lambda plan, a, b, bias, residual: a @ b + 1,
            {"structures": {"general"}},
            override=True,
        )
        assert api.plan(spec, backend="xla") is p_xla  # untouched backend kept
        assert api.plan(spec, backend="evict_double") is not p_d1  # stale gone
        sizes = api.plan_cache_info()["size"]
        assert sizes == 2  # no stranded entries from the old registration
    finally:
        api.unregister_backend("evict_double")
    assert api.plan_cache_info()["size"] == 1  # double's plan evicted with it


def test_legacy_scrambled_default_backend_still_routes():
    g = 3
    a = _mk((g * B, g * B), 17)
    try:
        with pytest.deprecated_call():
            ops.set_default_backend("pallas_mesh_scrambled")
        got = ops.matmul(a, a, block_m=B, block_n=B, block_k=B)
        want = ref.scramble_blocks_ref(
            ref.matmul_ref(a, a), block_m=B, block_n=B
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )
    finally:
        ops._LEGACY_DEFAULT = None
        api.set_default(None)


def test_no_string_dispatch_tuple_left():
    """Acceptance: the hard-coded _VALID tuple is gone — backend names come
    from the registry."""
    assert not hasattr(ops, "_VALID")
    assert set(api.backend_names()) >= {"xla", "pallas_mesh", "ref"}


# --- layers integration: one plan per (spec, backend) pair --------------------


def test_layers_gemm_plans_once_per_spec():
    from repro.models.layers import gemm

    class Cfg:
        use_mesh_kernel = True
        mesh_block_m = B
        mesh_block_n = B
        mesh_block_k = B
        fused_dense_epilogue = True

    x = _mk((4, 2 * B), 18)
    w = _mk((2 * B, B), 19)
    y1 = gemm(x, w, Cfg(), activation="silu")
    size_after_first = api.plan_cache_info()["size"]
    y2 = gemm(x, w, Cfg(), activation="silu")
    info = api.plan_cache_info()
    assert info["size"] == size_after_first == 1  # one plan, reused
    assert info["hits"] >= 1
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=0, atol=0)
