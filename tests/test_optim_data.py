"""Optimizer, schedules, data pipeline, compression unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.data.pipeline import DataConfig, SyntheticLM, pack_documents
from repro.optim import AdamWConfig, constant, warmup_cosine
from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm, global_norm
from repro.optim.zero import zero1_rules
from repro.parallel.sharding import DEFAULT_RULES, logical_to_physical


# --- AdamW -------------------------------------------------------------------


def _toy_params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(8,)).astype(np.float32)),
    }


def test_adamw_descends_quadratic():
    params = _toy_params()
    target = jax.tree.map(lambda p: jnp.ones_like(p), params)
    opt = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=0.0)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2) for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    l0 = float(loss(params))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, opt, _ = adamw_update(grads, opt, params, jnp.float32(0.05), cfg)
    assert float(loss(params)) < 0.01 * l0


def test_adamw_weight_decay_only_on_matrices():
    params = _toy_params()
    opt = adamw_init(params)
    zero_grads = jax.tree.map(jnp.zeros_like, params)
    cfg = AdamWConfig(weight_decay=0.5, clip_norm=0.0)
    new_params, _, _ = adamw_update(zero_grads, opt, params, jnp.float32(0.1), cfg)
    # matrices decay toward zero; vectors (b) untouched by decay
    assert float(jnp.max(jnp.abs(new_params["w"]))) < float(jnp.max(jnp.abs(params["w"])))
    np.testing.assert_allclose(np.asarray(new_params["b"]), np.asarray(params["b"]), atol=1e-6)


@given(st.floats(min_value=0.1, max_value=10.0))
@settings(max_examples=10, deadline=None)
def test_clip_by_global_norm(max_norm):
    grads = {"a": jnp.full((4,), 3.0), "b": jnp.full((2, 2), -4.0)}
    clipped, norm = clip_by_global_norm(grads, max_norm)
    new_norm = float(global_norm(clipped))
    assert new_norm <= max_norm * (1 + 1e-4) or new_norm <= float(norm)


def test_bias_correction_first_step_magnitude():
    """After one step with unit grads, update ~= lr (Adam bias correction)."""
    params = {"w": jnp.zeros((4, 4), jnp.float32)}
    opt = adamw_init(params)
    grads = {"w": jnp.ones((4, 4), jnp.float32)}
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=0.0)
    new_params, _, _ = adamw_update(grads, opt, params, jnp.float32(0.1), cfg)
    np.testing.assert_allclose(np.asarray(new_params["w"]), -0.1, rtol=1e-3)


# --- schedules -----------------------------------------------------------------


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, 10, 100, final_frac=0.1)
    xs = [float(fn(jnp.int32(s))) for s in (0, 5, 10, 55, 100, 200)]
    assert xs[0] == 0.0
    assert xs[1] == pytest.approx(0.5)
    assert xs[2] == pytest.approx(1.0, rel=1e-3)
    assert xs[3] < xs[2]
    assert xs[4] == pytest.approx(0.1, rel=1e-2)
    assert xs[5] == pytest.approx(0.1, rel=1e-2)  # clamped after total_steps


def test_constant_schedule():
    assert float(constant(3e-4)(jnp.int32(7))) == pytest.approx(3e-4)


# --- ZeRO-1 rules ---------------------------------------------------------------


def test_zero1_rules_shard_embed_over_dp():
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh((1, 1), ("data", "model"))
    rules = zero1_rules(DEFAULT_RULES)
    spec = logical_to_physical(("embed", "mlp"), mesh, rules)
    assert spec == jax.sharding.PartitionSpec("data", "model")
    # param rules unchanged for activations under DEFAULT_RULES
    spec2 = logical_to_physical(("embed", "mlp"), mesh, DEFAULT_RULES)
    assert spec2 == jax.sharding.PartitionSpec(None, "model")


# --- data pipeline ----------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=8, seed=42)
    it1 = SyntheticLM(cfg)
    batches = [next(it1) for _ in range(5)]
    # restore to step 2 reproduces batch 2 bit-exactly
    it2 = SyntheticLM(cfg)
    it2.restore(2)
    b2 = next(it2)
    np.testing.assert_array_equal(b2["tokens"], batches[2]["tokens"])
    np.testing.assert_array_equal(b2["labels"], batches[2]["labels"])


def test_data_host_sharding_partitions_batch():
    """Union of host shards == the single-host global batch, in order."""
    base = DataConfig(vocab_size=64, seq_len=16, global_batch=8, seed=7)
    full = next(SyntheticLM(base))
    shards = []
    for host in range(4):
        c = DataConfig(
            vocab_size=64, seq_len=16, global_batch=8, seed=7, num_hosts=4, host_id=host
        )
        shards.append(next(SyntheticLM(c)))
    # per-host streams must be disjoint deterministic functions of host_id
    assert all(s["tokens"].shape == (2, 16) for s in shards)
    flat = np.concatenate([s["tokens"] for s in shards])
    assert len({arr.tobytes() for arr in flat}) == len(flat)  # all rows distinct
    # labels are next-token targets
    np.testing.assert_array_equal(full["labels"][:, :-1], full["tokens"][:, 1:])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=24, global_batch=4, seed=1)
    b = next(SyntheticLM(cfg))
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_data_rejects_bad_host_split():
    with pytest.raises(ValueError):
        DataConfig(vocab_size=10, seq_len=8, global_batch=7, num_hosts=2)


def test_pack_documents():
    docs = [np.arange(5), np.arange(3), np.arange(9), np.arange(2)]
    out = pack_documents(docs, seq_len=8, pad_id=0)
    assert out["tokens"].shape[1] == 8
    assert out["segment_ids"].shape == out["tokens"].shape
    # first row: doc0 (5) + doc1 (3) exactly fills
    np.testing.assert_array_equal(out["segment_ids"][0], [1, 1, 1, 1, 1, 2, 2, 2])
    # over-long docs are truncated to seq_len
    assert (out["segment_ids"] >= 0).all()


@given(st.integers(min_value=1, max_value=200))
@settings(max_examples=20, deadline=None)
def test_data_step_purity(step):
    """Any step's batch is a pure function of (seed, step) — elastic resume."""
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2, seed=5)
    a = SyntheticLM(cfg, step=step)
    b = SyntheticLM(cfg)
    b.restore(step)
    np.testing.assert_array_equal(next(a)["tokens"], next(b)["tokens"])
