"""Observability subsystem (ISSUE 9, DESIGN.md §14).

Contracts under test:
  1. Overhead — a disabled `span()` adds <2% to a ~10us workload (the
     single-attribute-check fast path), so tracing can stay in hot paths.
  2. Correctness — nesting/parent links, thread safety, bounded ring,
     tracer-aware suppression (a span can NEVER fire inside a jitted trace).
  3. Exports — Chrome-trace documents load (schema), Prometheus text parses
     (format + cumulative-bucket invariants), JSONL sinks own their handle.
  4. Bridge — ledger events mirror into the degradation counter EXACTLY
     (the chaos CI job asserts the same equality under fault injection),
     and warm plan.execute spans become cost-model calibration records.
"""

import json
import re
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import bridge as obs_bridge
from repro.obs import trace as obs_trace
from repro.resilience import ledger


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with obs fully off and empty."""
    obs.uninstall()
    obs.disable()
    obs.clear_spans()
    obs.reset_metrics()
    yield
    obs.uninstall()
    obs.disable()
    obs.clear_spans()
    obs.reset_metrics()


# -- overhead contract -------------------------------------------------------


def test_disabled_overhead_under_2pct():
    """Contract: a disabled span adds <2% to the cheapest realistically
    traced body (~tens of µs: a scheduler tick, a plan-cache hit).
    Measured as direct-per-call cost over body-per-iteration — differencing
    two long loops drowns a ~200ns effect in scheduler noise on a loaded
    test runner."""

    def workload():
        return sum(range(5000))

    def bare(iters=10_000):
        for _ in range(iters):
            workload()

    def spans_only(iters=10_000):
        for _ in range(iters):
            with obs.span("t.overhead", i=0):
                pass

    assert not obs.is_enabled()
    bare(), spans_only()  # warm both paths
    best = lambda fn: min(_timed(fn) for _ in range(5))
    per_call = best(spans_only) / 10_000  # incl. loop + with overhead
    body = best(bare) / 10_000
    overhead = per_call / body
    assert overhead < 0.02, (
        f"disabled span costs {per_call * 1e9:.0f}ns per call = "
        f"{overhead:.2%} of a {body * 1e6:.0f}us body (contract: <2%)"
    )
    assert obs.stats()["finished"] == 0  # nothing recorded while disabled


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# -- span mechanics ----------------------------------------------------------


def test_span_nesting_and_attrs():
    obs.enable()
    with obs.span("outer.op", a=1) as outer:
        with obs.span("inner.op") as inner:
            inner.set("found", "x")
        outer.set("late", True)
    got = {s.name: s for s in obs.spans()}
    assert set(got) == {"outer.op", "inner.op"}
    assert got["inner.op"].parent == got["outer.op"].seq
    assert got["outer.op"].parent is None
    assert got["outer.op"].attrs == {"a": 1, "late": True}
    assert got["inner.op"].attrs == {"found": "x"}
    assert got["inner.op"].duration_s <= got["outer.op"].duration_s


def test_span_records_error_and_unwinds():
    obs.enable()
    with pytest.raises(ValueError):
        with obs.span("t.fail"):
            raise ValueError("boom")
    (sp,) = obs.spans("t.fail")
    assert "ValueError: boom" in sp.attrs["error"]
    # the stack unwound: a new span is a root again
    with obs.span("t.after"):
        pass
    assert obs.spans("t.after")[0].parent is None


def test_traced_decorator():
    calls = []

    @obs.traced("t.deco", kind="unit")
    def fn(x):
        calls.append(x)
        return x + 1

    assert fn(1) == 2  # disabled: no span, function still runs
    assert obs.spans("t.deco") == []
    obs.enable()
    assert fn(2) == 3
    (sp,) = obs.spans("t.deco")
    assert sp.attrs == {"kind": "unit"}
    assert calls == [1, 2]


def test_ring_is_bounded_and_counts_drops():
    obs.enable(capacity=8)
    try:
        for i in range(20):
            with obs.span("t.ring", i=i):
                pass
        st = obs.stats()
        assert st["retained"] == 8 and st["dropped"] == 12
        # newest survive
        assert [s.attrs["i"] for s in obs.spans("t.ring")] == list(range(12, 20))
    finally:
        obs.configure(capacity=obs_trace.DEFAULT_CAPACITY)


def test_threads_get_independent_stacks():
    obs.enable()
    errs = []

    def worker(k):
        try:
            for i in range(50):
                with obs.span(f"t.outer{k}"):
                    with obs.span(f"t.inner{k}", i=i):
                        pass
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(4)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    assert not errs
    assert obs.stats()["finished"] == 4 * 50 * 2
    for k in range(4):
        inners = obs.spans(f"t.inner{k}")
        outers = {s.seq: s for s in obs.spans(f"t.outer{k}")}
        assert len(inners) == 50
        for sp in inners:  # every inner's parent is one of ITS thread's outers
            assert sp.parent in outers and outers[sp.parent].tid == sp.tid


def test_no_span_inside_jit():
    """The tracer-aware guard: a span in jitted code must not record (it
    would measure trace time and fire per-compile, not per-execution)."""
    obs.enable()

    @jax.jit
    def f(x):
        with obs.span("t.in_jit"):
            return x * 2

    np.testing.assert_allclose(np.asarray(f(jnp.ones(4))), 2.0)
    f(jnp.ones(4))  # cached-trace call: no python at all
    assert obs.spans("t.in_jit") == []
    assert obs.stats()["suppressed_in_trace"] >= 1


def test_tracing_scope_restores_prior_state():
    assert not obs.is_enabled()
    with obs.tracing():
        assert obs.is_enabled()
        with obs.span("t.scoped"):
            pass
    assert not obs.is_enabled()
    assert len(obs.spans("t.scoped")) == 1


# -- metrics -----------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    c = obs.counter("t_total", "help", labels=("site",))
    c.inc(site="a"), c.inc(2, site="a"), c.inc(site="b")
    assert c.value(site="a") == 3 and c.total() == 4
    with pytest.raises(ValueError):
        c.inc(-1, site="a")
    with pytest.raises(ValueError):
        c.inc(site="a", extra="x")  # undeclared label

    g = obs.gauge("t_gauge")
    g.set(5), g.inc(-2)
    assert g.value() == 3

    h = obs.histogram("t_lat_seconds")
    for v in (1e-5, 1e-5, 1e-3, 0.1):
        h.observe(v)
    assert h.count() == 4 and h.sum() == pytest.approx(0.10102)
    q50 = h.quantile(0.5)
    assert 1e-6 < q50 < 1e-3
    assert h.quantile(1.0) >= 0.05


def test_registry_is_idempotent_and_kind_checked():
    a = obs.counter("t_same", labels=("x",))
    assert obs.counter("t_same", labels=("x",)) is a
    with pytest.raises(TypeError):
        obs.gauge("t_same", labels=("x",))
    with pytest.raises(TypeError):
        obs.counter("t_same", labels=("y",))


# -- exports -----------------------------------------------------------------


def test_chrome_trace_schema(tmp_path):
    obs.enable()
    with obs.span("outer.op", k="v"):
        with obs.span("inner.op"):
            pass
    path = tmp_path / "trace.json"
    obs.write_chrome_trace(str(path), metadata={"run": "test"})
    doc = json.loads(path.read_text())  # must round-trip as strict JSON
    evs = doc["traceEvents"]
    assert evs[0]["ph"] == "M"  # process_name metadata event
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"outer.op", "inner.op"}
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0  # µs offsets from the epoch
        assert e["cat"] == e["name"].split(".")[0]
        assert isinstance(e["args"]["seq"], int)
    inner = next(e for e in xs if e["name"] == "inner.op")
    outer = next(e for e in xs if e["name"] == "outer.op")
    assert inner["args"]["parent"] == outer["args"]["seq"]
    assert doc["otherData"]["run"] == "test"


_PROM_LINE = re.compile(
    r"^(# (HELP|TYPE) .*"
    r"|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? ([0-9.e+-]+|\+Inf))$"
)


def test_prometheus_text_format():
    obs.counter("t_req_total", "requests", labels=("status",)).inc(status="ok")
    h = obs.histogram("t_dur_seconds", "durations")
    h.observe(0.001), h.observe(0.5)
    text = obs.prometheus_text()
    for line in text.strip().splitlines():
        assert _PROM_LINE.match(line), f"malformed exposition line: {line!r}"
    assert 't_req_total{status="ok"} 1' in text
    # cumulative buckets: +Inf bucket equals _count, buckets never decrease
    bucket_vals = [
        float(m.group(1))
        for m in re.finditer(r't_dur_seconds_bucket\{le="[^"]+"\} (\S+)', text)
    ]
    assert bucket_vals == sorted(bucket_vals)
    count = float(re.search(r"t_dur_seconds_count (\S+)", text).group(1))
    assert bucket_vals[-1] == count == 2


def test_jsonl_sink_owns_handle(tmp_path):
    path = tmp_path / "m.jsonl"
    with obs.JsonlSink(str(path)) as sink:
        sink.write({"a": 1})
        assert not sink.closed
    assert sink.closed
    with pytest.raises(ValueError):
        sink.write({"b": 2})
    assert json.loads(path.read_text()) == {"a": 1}


def test_metrics_logger_closes_sink(tmp_path):
    from repro.train.metrics import MetricsLogger

    path = tmp_path / "train.jsonl"
    with MetricsLogger(str(path)) as lg:
        lg.log(1, {"loss": 2.5})
        lg.summary({"final_step": 1})
        assert not lg.closed
    assert lg.closed
    recs = [json.loads(x) for x in path.read_text().splitlines()]
    assert recs[0]["step"] == 1 and recs[0]["loss"] == 2.5
    assert recs[1] == {"summary": {"final_step": 1}}
    MetricsLogger().close()  # pathless logger: close is a no-op


# -- bridge: ledger -> counter ----------------------------------------------


def test_ledger_events_mirror_to_counter_exactly():
    ledger.clear()
    try:
        ledger.record("t.site_a", cause="ValueError: x", fallback="skip")
        ledger.record("t.site_a", cause="ValueError: y", fallback="skip")
        obs.install()  # backfills the two pre-install events
        ledger.record("t.site_b", cause="KeyError: z", fallback="retry")
        c = obs_bridge.degradation_counter()
        assert c.total() == ledger.count() == 3
        assert c.value(site="t.site_a", cause="ValueError") == 2
        assert c.value(site="t.site_b", cause="KeyError") == 1
        # per-site sums match the ledger summary (the chaos CI assertion)
        per_site = {}
        for (site, _), v in c.series().items():
            per_site[site] = per_site.get(site, 0) + v
        want = {s: sum(d.values()) for s, d in ledger.summary().items()}
        assert per_site == want
    finally:
        ledger.clear()


def test_install_is_idempotent():
    ledger.clear()
    try:
        obs.install()
        obs.install()  # second install must not double-subscribe
        ledger.record("t.once", cause="E: e", fallback="f")
        assert obs_bridge.degradation_counter().value(site="t.once", cause="E") == 1
    finally:
        ledger.clear()


# -- bridge: spans -> calibration --------------------------------------------


def test_plan_execute_spans_feed_calibration(tmp_path, monkeypatch):
    from repro.costmodel.calibrate import CalibrationCache, clear_coefficients_memo
    from repro.kernels import api

    cache_path = tmp_path / "costmodel.json"
    monkeypatch.setenv("REPRO_COSTMODEL_CACHE", str(cache_path))
    clear_coefficients_memo()
    obs.enable()
    obs.install()
    try:
        a = jnp.ones((16, 16), jnp.float32)
        p = api.plan(api.GemmSpec.from_operands(a, a, blocks=(16, 16, 16)))
        jax.block_until_ready(p(a, a))  # cold: compile-inclusive, discarded
        jax.block_until_ready(p(a, a))  # warm: becomes a calibration record
        pend = obs.pending_calibration_records()
        assert len(pend) == 1
        assert pend[0]["source"] == "obs" and pend[0]["ms"] > 0
        assert pend[0]["terms"]["flops"] == 2 * 16**3
        n = obs.flush_calibration(refit=False)
        assert n == 1 and obs.pending_calibration_records() == []
        recs = CalibrationCache(str(cache_path)).records(jax.default_backend())
        assert len(recs) == 1 and recs[0]["source"] == "obs"
        stamp = obs.calibration_stamp()
        assert stamp["cache_path"] == str(cache_path)
    finally:
        clear_coefficients_memo()


def test_flush_of_invalid_records_never_raises():
    ledger.clear()
    try:
        obs.submit_calibration([{"terms": "not-a-dict", "ms": -1}])
        assert obs.flush_calibration() == 0  # invalid batch: dropped, no raise
        assert obs.pending_calibration_records() == []
    finally:
        ledger.clear()


# -- scheduler + serve integration -------------------------------------------


@pytest.fixture(scope="module")
def dense():
    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config("mesh-paper").reduced()
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _mk_server(dense, slots=2):
    from repro.launch.scheduler import ContinuousBatchingServer, ServeConfig

    model, params = dense
    cfg = ServeConfig(
        max_slots=slots, page_size=8, num_pages=1 + slots * 4,
        max_pages_per_seq=4, queue_capacity=8, warmup_prompt_lens=(8,),
    )
    return ContinuousBatchingServer(model, params, cfg)


def test_scheduler_ticks_emit_spans_and_metrics(dense, tmp_path, monkeypatch):
    from repro.launch.scheduler import Request

    # drain() flushes bridged calibration records; keep the persist off the
    # repo's calibration cache
    monkeypatch.setenv("REPRO_COSTMODEL_CACHE", str(tmp_path / "cal.json"))
    obs.enable()
    obs.install()
    server = _mk_server(dense)
    server.warmup()
    prompt = np.zeros(8, np.int32)
    for r in range(3):
        server.submit(Request(rid=f"r{r}", prompt=prompt, max_new_tokens=4))
    server.drain()
    ticks = obs.spans("serve.tick")
    assert len(ticks) == server.counters["ticks"]
    tick_seqs = {s.seq for s in ticks}
    decodes = obs.spans("serve.decode")
    assert decodes and all(s.parent in tick_seqs for s in decodes)
    prefills = obs.spans("serve.prefill")
    assert {s.attrs["rid"] for s in prefills} >= {"r0", "r1", "r2"}
    # metrics agree with the scheduler's own accounting
    assert obs.counter("serve_requests_total", labels=("status",)).value(
        status="served"
    ) == 3
    assert obs.counter("serve_decode_tokens_total").value() == float(
        server.counters["decode_tokens"]
    )
    h = obs.histogram("serve_ttft_seconds")
    assert h.count() == 3 and h.quantile(0.5) > 0
    assert obs.histogram("serve_tpot_seconds").count() == len(decodes)


def test_serve_main_obs_export_end_to_end(tmp_path, capsys, monkeypatch):
    from repro.launch import serve

    # the exit-time calibration flush persists; keep it off the repo's cache
    monkeypatch.setenv("REPRO_COSTMODEL_CACHE", str(tmp_path / "cal.json"))
    out = tmp_path / "trace.json"
    serve.main([
        "--arch", "mesh-paper", "--reduced", "--batch", "1",
        "--prompt-len", "8", "--gen", "2", "--requests", "2",
        "--plan-stats", "--obs-export", str(out),
    ])
    text = capsys.readouterr().out
    assert "obs export:" in text
    doc = json.loads(out.read_text())
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs}
    # plan() here only runs inside the jitted step traces, where spans are
    # correctly suppressed — so the timeline holds the request spans (the
    # scheduler path, exercised above and in CI, adds tick/plan spans)
    assert "serve.request" in names
    assert sum(e["name"] == "serve.request" for e in evs) == 2
    st = obs.stats()
    assert st["suppressed_in_trace"] > 0  # the in-jit plan spans were suppressed
    assert "source" in doc["otherData"]["calibration"]
    # the .prom and .jsonl sidecars parse
    (tmp_path / "trace.json.prom").read_text()
    lines = (tmp_path / "trace.json.jsonl").read_text().splitlines()
    assert lines and all(json.loads(x)["name"] for x in lines)
