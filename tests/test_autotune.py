"""Autotuner subsystem: candidate pruning, cache round-trip/versioning/legacy
migration, warm start, search modes, and the ops.matmul integration."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels.autotune import (
    CACHE_VERSION,
    AutotuneCache,
    cache_key,
    candidate_blocks,
    model_score,
    vmem_bytes,
)


@pytest.fixture()
def cache(tmp_path):
    return AutotuneCache(tmp_path / "cache.json")


# --- key format / vmem model --------------------------------------------------


def test_cache_key_formalizes_legacy_format():
    key = cache_key(4096, 4096, 4096, jnp.bfloat16, "pallas_mesh", platform="cpu")
    assert key == "4096x4096x4096|bfloat16|pallas_mesh|sym0|cpu"
    key = cache_key(2048, 16384, 2048, "bfloat16", "pallas_mesh", symmetry=1, platform="tpu")
    assert key == "2048x16384x2048|bfloat16|pallas_mesh|sym1|tpu"


def test_vmem_model_counts_tiles_and_acc():
    # A-tile + B-tile in dtype + f32 accumulator
    assert vmem_bytes(128, 128, 128, jnp.bfloat16) == (128 * 128 * 2) * 2 + 128 * 128 * 4
    assert vmem_bytes(128, 128, 128, jnp.float32) == (128 * 128 * 4) * 2 + 128 * 128 * 4
    plain = vmem_bytes(128, 128, 128, jnp.bfloat16)
    assert vmem_bytes(128, 128, 128, jnp.bfloat16, has_residual=True) > plain
    assert vmem_bytes(128, 128, 128, jnp.bfloat16, has_bias=True) > plain


def test_candidates_are_aligned_and_within_budget():
    cands = candidate_blocks(4096, 4096, 4096, jnp.bfloat16)
    assert cands, "no candidates survived"
    for bm, bn, bk in cands:
        assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0
        assert vmem_bytes(bm, bn, bk, jnp.bfloat16) <= autotune.DEFAULT_VMEM_BUDGET
    # a tight budget prunes the large blocks
    tight = candidate_blocks(4096, 4096, 4096, jnp.bfloat16, vmem_budget=300 * 1024)
    assert max(max(c) for c in tight) <= 256
    assert len(tight) < len(cands)


def test_candidates_never_overhang_small_dims():
    cands = candidate_blocks(100, 4096, 100, jnp.bfloat16)
    for bm, bn, bk in cands:
        assert bm == 128 and bn == 128  # 100 pads to one 128 block at most


def test_model_score_prefers_utilization():
    # A block that exactly tiles the shape beats one that pads 4096 -> 5120.
    fits = model_score(4096, 4096, 4096, (512, 512, 128), jnp.bfloat16)
    pads = model_score(4096 + 128, 4096, 4096, (512, 512, 128), jnp.bfloat16)
    assert fits > pads


# --- cache persistence --------------------------------------------------------


def test_cache_round_trip(cache):
    key = cache_key(512, 512, 512, jnp.bfloat16, "pallas_mesh", platform="cpu")
    assert cache.get(key) is None
    cache.put(key, (256, 256, 128), source="timed", ms=1.25)
    cache.save()
    reloaded = AutotuneCache(cache.path)
    assert reloaded.get(key) == (256, 256, 128)
    raw = json.loads(cache.path.read_text())
    assert raw["version"] == CACHE_VERSION
    assert raw["entries"][key]["source"] == "timed"


def test_cache_migrates_legacy_v1_flat_dict(tmp_path):
    path = tmp_path / "legacy.json"
    legacy_key = "4096x4096x4096|bfloat16|pallas_mesh|sym0|cpu"
    path.write_text(json.dumps({legacy_key: [512, 512, 128]}))
    cache = AutotuneCache(path)
    assert cache.get(legacy_key) == (512, 512, 128)
    cache.save()  # rewritten as v2
    raw = json.loads(path.read_text())
    assert raw["version"] == CACHE_VERSION
    assert raw["entries"][legacy_key]["blocks"] == [512, 512, 128]
    assert raw["entries"][legacy_key]["source"] == "seed"


def test_cache_discards_unknown_version_and_corrupt_files(tmp_path):
    key = "512x512x512|bfloat16|pallas_mesh|sym0|cpu"
    future = tmp_path / "future.json"
    future.write_text(json.dumps({"version": 99, "entries": {key: {"blocks": [64, 64, 64]}}}))
    assert AutotuneCache(future).get(key) is None
    corrupt = tmp_path / "corrupt.json"
    corrupt.write_text("{not json")
    assert AutotuneCache(corrupt).get(key) is None
    bad_blocks = tmp_path / "bad.json"
    bad_blocks.write_text(json.dumps({key: [512, 512]}))  # wrong arity
    assert AutotuneCache(bad_blocks).get(key) is None


# --- search -------------------------------------------------------------------


def test_cache_hit_never_searches(cache):
    key = cache_key(512, 512, 512, jnp.bfloat16, "pallas_mesh")
    cache.put(key, (256, 256, 128), source="timed")

    def explode(*a, **k):  # measure must not be called on a hit
        raise AssertionError("searched despite cache hit")

    got = autotune.autotune(
        512, 512, 512, jnp.bfloat16, "pallas_mesh", cache=cache, mode="time", measure=explode
    )
    assert got == (256, 256, 128)


def test_timed_search_picks_fastest_and_persists(cache):
    fake_ms = {(128, 128, 128): 3.0, (256, 256, 128): 1.0}

    def measure(m, k, n, dtype, backend, blocks):
        return fake_ms.get(blocks, 10.0)

    got = autotune.autotune(
        512,
        512,
        512,
        jnp.bfloat16,
        "pallas_mesh",
        cache=cache,
        mode="time",
        measure=measure,
        max_timed=64,  # cover the full candidate list so the fake times decide
    )
    assert got == (256, 256, 128)
    # persisted: a fresh instance over the same file hits without searching
    reloaded = AutotuneCache(cache.path)
    key = cache_key(512, 512, 512, jnp.bfloat16, "pallas_mesh")
    assert reloaded.get(key) == (256, 256, 128)


def test_warm_start_is_tried_first(cache):
    import jax

    platform = jax.default_backend()
    near = cache_key(1024, 1024, 1024, jnp.bfloat16, "pallas_mesh", platform=platform)
    cache.put(near, (256, 128, 128), source="timed")
    order = []

    def measure(m, k, n, dtype, backend, blocks):
        order.append(blocks)
        return 1.0

    autotune.autotune(
        2048, 2048, 2048, jnp.bfloat16, "pallas_mesh", cache=cache, mode="time", measure=measure
    )
    assert order[0] == (256, 128, 128)


def test_model_mode_runs_nothing_and_caches(cache):
    got = autotune.autotune(4096, 4096, 4096, jnp.bfloat16, "pallas_mesh", cache=cache, mode="model")
    assert all(x % 128 == 0 for x in got)
    key = cache_key(4096, 4096, 4096, jnp.bfloat16, "pallas_mesh")
    assert cache.get(key) == got


# --- ops.matmul integration ---------------------------------------------------


def test_ops_matmul_resolves_blocks_via_autotuner(tmp_path, monkeypatch):
    from repro.kernels.ops import matmul

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "cache.json"))
    autotune._DEFAULT_CACHE = None  # force re-read of the env var
    autotune.clear_resolve_memo()
    try:
        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.normal(size=(48, 32)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(32, 24)).astype(np.float32))
        got = matmul(a, b, backend="pallas_mesh")  # no explicit blocks
        np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b), rtol=1e-4, atol=1e-4)
        cache = autotune.default_cache()
        key = cache_key(48, 32, 24, jnp.float32, "pallas_mesh")
        assert cache.get(key) is not None, "autotuner was not consulted"
        # second call: memo + cache hit, still correct
        got2 = matmul(a, b, backend="pallas_mesh")
        np.testing.assert_allclose(np.asarray(got2), np.asarray(got), rtol=0, atol=0)
    finally:
        autotune._DEFAULT_CACHE = None
        autotune.clear_resolve_memo()


def test_scrambled_backend_candidates_respect_square_grid(cache):
    """Scrambled dispatch rejects padding + non-square grids — the search
    must only propose compatible blocks (regression: 384x384 crashed)."""
    got = autotune.autotune(384, 384, 384, jnp.float32, "pallas_mesh_scrambled",
                            cache=cache, mode="model")
    bm, bn, _ = got
    assert 384 % bm == 0 and 384 % bn == 0 and 384 // bm == 384 // bn

    from repro.kernels.ops import matmul

    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.normal(size=(384, 384)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(384, 384)).astype(np.float32))
    out = matmul(a, b, backend="pallas_mesh_scrambled",
                 block_m=bm, block_n=bn, block_k=got[2])
    assert out.shape == (384, 384)


def test_activation_validated_on_every_backend():
    """Same ValueError for a typo'd activation on xla and pallas backends."""
    from repro.kernels.ops import matmul

    a = jnp.zeros((8, 8))
    for backend in ("xla", "pallas_mesh"):
        with pytest.raises(ValueError, match="activation must be one of"):
            matmul(a, a, backend=backend, block_m=8, block_n=8, block_k=8,
                   activation="swishh")
