"""Grouped-GEMM planner tests (ISSUE 5, DESIGN.md §10).

Covers: grouped plans vs the dense one-hot / per-group oracles on every
backend (bitwise on drop-free configs), empty-group and single-expert edge
cases, plan-cache keying on GroupSpec, capability rejection for backends
that don't declare `grouped`, gradients through the Pallas ragged kernel,
the `expert` collective schedule, and the MoE refactor's drop-free
equivalence with the pre-refactor dense dispatch.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import api
from repro.kernels.ref import grouped_matmul_ref
from repro.models.layers import NO_SHARD, init_params
from repro.models.moe import moe_block, moe_specs

BACKENDS = ("xla", "ref", "pallas_mesh")


def _case(g=4, rpg=16, k=24, n=20, seed=0, sizes=None):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.normal(size=(g * rpg, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(g, k, n)).astype(np.float32))
    if sizes is None:
        sizes = rng.integers(0, rpg + 1, size=g)
    sizes = jnp.asarray(np.asarray(sizes), jnp.int32)
    # contract: padding rows are zero (the MoE scatter produces exactly this)
    valid = (jnp.arange(rpg)[None, :] < sizes[:, None]).reshape(-1, 1)
    tokens = tokens * valid
    off = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(sizes)]).astype(
        jnp.int32
    )
    return tokens, sizes, off, w


@pytest.fixture(autouse=True)
def _fresh_cache():
    api.clear_plan_cache()
    yield
    api.clear_plan_cache()


@pytest.mark.parametrize("backend", BACKENDS)
def test_grouped_plan_matches_oracle(backend):
    tokens, sizes, off, w = _case()
    spec = api.GemmSpec.for_groups(api.GroupSpec(4, 16), 24, 20)
    p = api.plan(spec, backend=backend)
    assert isinstance(p, api.GroupedPlan)
    out = p(tokens, off, w)
    want = grouped_matmul_ref(tokens, sizes, w)
    # drop-free of reduction-order ambiguity at K <= one block: bitwise
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("backend", BACKENDS)
def test_grouped_epilogue_parity(backend):
    tokens, sizes, off, w = _case(seed=1)
    rng = np.random.default_rng(2)
    bias = jnp.asarray(rng.normal(size=(4, 20)).astype(np.float32))
    res = jnp.asarray(rng.normal(size=(64, 20)).astype(np.float32))
    spec = api.GemmSpec.for_groups(
        api.GroupSpec(4, 16), 24, 20,
        epilogue=api.Epilogue(bias=True, activation="gelu", residual=True),
    )
    out = api.plan(spec, backend=backend)(tokens, off, w, bias=bias, residual=res)
    # reference: per-group epilogue then the segment mask (contract: padding
    # rows are zero even when a residual is attached)
    z = jnp.einsum(
        "grk,gkn->grn", tokens.reshape(4, 16, 24), w,
        preferred_element_type=jnp.float32,
    )
    z = api.ACTIVATIONS["gelu"](z + bias[:, None, :]) + res.reshape(4, 16, 20)
    valid = jnp.arange(16)[None, :] < sizes[:, None]
    want = jnp.where(valid[..., None], z, 0.0).reshape(64, 20)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=1e-6, atol=1e-6
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_grouped_empty_groups(backend):
    """All-empty and partially-empty groups produce zero rows."""
    tokens, sizes, off, w = _case(sizes=[0, 0, 0, 0])
    spec = api.GemmSpec.for_groups(api.GroupSpec(4, 16), 24, 20)
    out = api.plan(spec, backend=backend)(tokens, off, w)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((64, 20), np.float32))
    tokens, sizes, off, w = _case(sizes=[16, 0, 3, 0], seed=3)
    out = api.plan(spec, backend=backend)(tokens, off, w)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(grouped_matmul_ref(tokens, sizes, w))
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_grouped_single_group(backend):
    """num_groups=1 degenerates to a plain (masked) GEMM."""
    tokens, sizes, off, w = _case(g=1, rpg=32, sizes=[20], seed=4)
    spec = api.GemmSpec.for_groups(api.GroupSpec(1, 32), 24, 20)
    out = api.plan(spec, backend=backend)(tokens, off, w)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(grouped_matmul_ref(tokens, sizes, w))
    )


def test_grouped_plan_cache_keys_on_groupspec():
    spec_a = api.GemmSpec.for_groups(api.GroupSpec(4, 16), 24, 20)
    spec_b = api.GemmSpec.for_groups(api.GroupSpec(8, 8), 24, 20)  # same m!
    assert spec_a.m == spec_b.m
    p_a = api.plan(spec_a)
    p_b = api.plan(spec_b)
    assert p_a is not p_b  # GroupSpec is part of the cache key
    assert api.plan(spec_a) is p_a  # identical object on reuse
    info = api.plan_cache_info()
    assert info["size"] == 2 and info["hits"] == 1 and info["misses"] == 2
    assert all(p["grouped"] for p in info["plans"])


def test_grouped_capability_rejection():
    """Backends that don't declare `grouped` reject grouped specs; declaring
    it without a grouped_impl is rejected at registration."""
    api.register_backend(
        "nogrouped_double",
        lambda p, a, b, bias, res: jnp.matmul(a, b),
        {"structures": {"general"}},
    )
    try:
        spec = api.GemmSpec.for_groups(api.GroupSpec(4, 16), 24, 20)
        with pytest.raises(api.CapabilityError, match="grouped"):
            api.plan(spec, backend="nogrouped_double")
        with pytest.raises(ValueError, match="grouped_impl"):
            api.register_backend(
                "half_grouped",
                lambda p, a, b, bias, res: jnp.matmul(a, b),
                {"structures": {"general"}, "grouped": True},
            )
    finally:
        api.unregister_backend("nogrouped_double")


def test_grouped_spec_validation():
    with pytest.raises(ValueError, match="for_groups"):
        api.GemmSpec(m=65, k=24, n=20, group=api.GroupSpec(4, 16))
    with pytest.raises(ValueError, match="general"):
        api.GemmSpec(
            m=64, k=24, n=20, group=api.GroupSpec(4, 16), structure="scrambled"
        )
    with pytest.raises(ValueError, match="batch"):
        api.GemmSpec(
            m=64, k=24, n=20, group=api.GroupSpec(4, 16), batch=(2,)
        )
    with pytest.raises(ValueError, match="positive"):
        api.GroupSpec(0, 16)


def test_grouped_operand_validation():
    tokens, sizes, off, w = _case()
    p = api.plan(api.GemmSpec.for_groups(api.GroupSpec(4, 16), 24, 20))
    with pytest.raises(ValueError, match="group_offsets"):
        p(tokens, off[:-1], w)
    with pytest.raises(ValueError, match="integer"):
        p(tokens, off.astype(jnp.float32), w)
    with pytest.raises(ValueError, match="do not match"):
        p(tokens[:, :-1], off, w)
    with pytest.raises(ValueError, match="without bias"):
        p(tokens, off, w, bias=jnp.zeros((4, 20)))


def test_grouped_grads_match_reference():
    """The custom VJP through the Pallas ragged kernel equals autodiff
    through the pure-jnp oracle — tokens AND stacked weights."""
    tokens, sizes, off, w = _case(seed=5)
    spec = api.GemmSpec.for_groups(api.GroupSpec(4, 16), 24, 20)
    p = api.plan(spec, backend="pallas_mesh")

    def loss_kernel(t, ww):
        return jnp.sum(p(t, off, ww) ** 2)

    def loss_ref(t, ww):
        return jnp.sum(grouped_matmul_ref(t, sizes, ww) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1))(tokens, w)
    gr = jax.grad(loss_ref, argnums=(0, 1))(tokens, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_grouped_autotuned_block_m_divides_rows():
    """block_m is clamped to divide the rows_per_group bound (the ragged
    grid needs whole row blocks per group)."""
    spec = api.GemmSpec.for_groups(api.GroupSpec(4, 24), 32, 32)
    p = api.plan(spec, backend="pallas_mesh")
    bm = p.blocks[0]
    assert 24 % bm == 0
    tokens, sizes, off, w = _case(g=4, rpg=24, k=32, n=32, sizes=[24, 5, 0, 17])
    np.testing.assert_allclose(
        np.asarray(p(tokens, off, w)),
        np.asarray(grouped_matmul_ref(tokens, sizes, w)),
        rtol=1e-6,
        atol=1e-6,
    )


# ---------------------------------------------------------------------------
# Sharded grouped plans: the `expert` schedule
# ---------------------------------------------------------------------------


def test_grouped_sharded_trivial_mesh_bitwise():
    """A size-1 axis_g routes through the ShardedGroupedPlan path and
    reproduces the unsharded GroupedPlan bit for bit."""
    from repro.launch.mesh import make_local_mesh

    tokens, sizes, off, w = _case(seed=6)
    mesh = make_local_mesh((1,), ("model",))
    base = api.plan(api.GemmSpec.for_groups(api.GroupSpec(4, 16), 24, 20))(
        tokens, off, w
    )
    spec = api.GemmSpec.for_groups(
        api.GroupSpec(4, 16), 24, 20,
        shard=api.ShardSpec.from_mesh(mesh, g="model"),
    )
    p = api.plan(spec, mesh=mesh)
    assert isinstance(p, api.ShardedGroupedPlan)
    assert p.schedule == "replicated" and p.bytes_moved == 0
    np.testing.assert_array_equal(np.asarray(p(tokens, off, w)), np.asarray(base))

    # the epilogue shards with its operands, so a sharded grouped plan with
    # bias+activation reproduces the unsharded one bit for bit too
    epi = api.Epilogue(bias=True, activation="gelu")
    bias = jnp.ones((4, 20), jnp.float32)
    base_e = api.plan(api.GemmSpec.for_groups(api.GroupSpec(4, 16), 24, 20, epilogue=epi))(
        tokens, off, w, bias=bias
    )
    spec_e = api.GemmSpec.for_groups(
        api.GroupSpec(4, 16), 24, 20, epilogue=epi,
        shard=api.ShardSpec.from_mesh(mesh, g="model"),
    )
    p_e = api.plan(spec_e, mesh=mesh)
    np.testing.assert_array_equal(
        np.asarray(p_e(tokens, off, w, bias=bias)), np.asarray(base_e)
    )


@pytest.mark.skipif(
    jax.device_count() < 8, reason="expert schedule needs 8 devices in-process"
)
@pytest.mark.parametrize("backend", ["xla", "pallas_mesh"])
def test_grouped_expert_schedule_bitwise(backend):
    """Group dim sharded over 8 devices: same bits as the unsharded plan,
    bytes-moved provenance populated."""
    from repro.launch.mesh import make_local_mesh

    tokens, sizes, off, w = _case(g=8, rpg=16, seed=7)
    mesh = make_local_mesh((8,), ("model",))
    base = api.plan(
        api.GemmSpec.for_groups(api.GroupSpec(8, 16), 24, 20), backend=backend
    )(tokens, off, w)
    spec = api.GemmSpec.for_groups(
        api.GroupSpec(8, 16), 24, 20,
        shard=api.ShardSpec.from_mesh(mesh, g="model"),
    )
    p = api.plan(spec, backend=backend, mesh=mesh)
    assert p.schedule == "expert"
    assert p.bytes_moved > 0 and p.collective_phases == 7
    np.testing.assert_array_equal(np.asarray(p(tokens, off, w)), np.asarray(base))
    rl = _roofline_record(p)
    assert rl["grouped"]["per_group_flops"] > 0


def _roofline_record(p):
    from repro.launch.roofline import analyze_plan

    return analyze_plan(p.describe())


def test_roofline_understands_grouped_plans():
    spec = api.GemmSpec.for_groups(api.GroupSpec(4, 16), 24, 20)
    p = api.plan(spec)
    rec = _roofline_record(p)
    assert rec["grouped"]["num_groups"] == 4
    assert rec["grouped"]["per_group_flops"] == 2 * 16 * 24 * 20
    assert rec["grouped"]["dispatch_bytes"] == p.describe()["grouped"]["dispatch_bytes"]
    assert rec["t_compute_s"] > 0 and rec["dominant"] in (
        "compute", "memory", "collective",
    )


# ---------------------------------------------------------------------------
# MoE on the grouped planner
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "qwen2-moe-a2.7b"])
def test_moe_block_matches_onehot_reference_dropfree(arch):
    """At a drop-free (_EXACT_GROUP) shape the grouped-planner moe_block
    reproduces the dense one-hot dispatch — outputs and aux losses — to f32
    reduction-order precision (the computation graphs reduce in different
    orders, so agreement is ulp-level, not bitwise)."""
    # the single in-tree copy of the pre-refactor dense dispatch lives next
    # to the benchmark that times it
    from benchmarks.bench_moe import onehot_moe_reference

    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), moe_specs(cfg), cfg.pdtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), cfg.adtype)
    y_ref, aux_ref = onehot_moe_reference(params, x, cfg)
    y_new, aux_new = moe_block(params, x, cfg, NO_SHARD)
    np.testing.assert_allclose(
        np.asarray(y_new, np.float32), np.asarray(y_ref, np.float32),
        rtol=1e-6, atol=1e-8,
    )
    for key in aux_ref:
        np.testing.assert_allclose(
            float(aux_new[key]), float(aux_ref[key]), rtol=1e-5
        )


def test_moe_block_one_grouped_plan_per_expert_shape():
    """One grouped plan per logical expert shape (wi and wo), however many
    layers/calls run — the acceptance-criteria cache check."""
    cfg = get_config("olmoe-1b-7b").reduced()
    params = init_params(jax.random.PRNGKey(0), moe_specs(cfg), cfg.pdtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), cfg.adtype)
    for _ in range(3):  # repeated layers/steps reuse the same two plans
        moe_block(params, x, cfg, NO_SHARD)
    grouped = [p for p in api.plan_cache_info()["plans"] if p.get("grouped")]
    assert len(grouped) == 2  # wi: d -> 2f, wo: f -> d
    shapes = {p["mkn"] for p in grouped}
    assert len(shapes) == 2


def test_moe_block_grouped_trains():
    """Gradients flow through sort/scatter/grouped-plan/gather end to end,
    on the Pallas backend too."""
    cfg = dataclasses.replace(
        get_config("olmoe-1b-7b").reduced(), use_mesh_kernel=True
    )
    params = init_params(jax.random.PRNGKey(0), moe_specs(cfg), cfg.pdtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), cfg.adtype)

    def loss(pp):
        y, aux = moe_block(pp, x, cfg, NO_SHARD)
        return jnp.sum(y.astype(jnp.float32) ** 2) + aux["lb_loss"]

    grads = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    assert float(jnp.max(jnp.abs(grads["wi"]))) > 0
    assert float(jnp.max(jnp.abs(grads["wo"]))) > 0
