"""Checkpointing + fault-tolerant loop: atomicity, resume, crash recovery,
async writer, straggler accounting, elastic re-mesh restore."""

import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.async_writer import AsyncCheckpointer
from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import get_model
from repro.optim import constant
from repro.train.loop import LoopConfig, train_loop
from repro.train.metrics import MetricsLogger
from repro.train.train_step import init_train_state, make_train_step


def _tiny_setup(tmp_path, vocab=64, steps_data_seed=0):
    cfg = get_config("qwen2-7b").reduced()
    model = get_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, constant(1e-3)))
    data = SyntheticLM(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4, seed=steps_data_seed)
    )
    ckpt = CheckpointManager(str(tmp_path), keep_n=2)
    return model, state, step_fn, data, ckpt


# --- manager -------------------------------------------------------------------


def test_save_restore_roundtrip(tmp_path):
    _, state, _, _, ckpt = _tiny_setup(tmp_path)
    ckpt.save(3, state, {"data_step": 3})
    restored = ckpt.restore(3, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.meta(3)["data_step"] == 3


def test_bf16_leaves_roundtrip(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(8, dtype=jnp.bfloat16) / 3, "n": jnp.int32(7)}
    ckpt.save(1, tree)
    out = ckpt.restore(1, tree)
    np.testing.assert_array_equal(
        np.asarray(out["w"], np.float32), np.asarray(tree["w"], np.float32)
    )
    assert out["w"].dtype == jnp.bfloat16


def test_keep_n_gc(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep_n=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        ckpt.save(s, tree)
    assert ckpt.all_steps() == [3, 4]


def test_shape_mismatch_raises(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, {"x": jnp.zeros((2,))})
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(1, {"x": jnp.zeros((3,))})


def test_missing_leaf_raises(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, {"x": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        ckpt.restore(1, {"x": jnp.zeros((2,)), "y": jnp.zeros((1,))})


def test_crashed_write_never_looks_complete(tmp_path):
    """A .tmp_save_* dir (simulated crash) is invisible to all_steps()."""
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(5, {"x": jnp.zeros((2,))})
    os.makedirs(os.path.join(str(tmp_path), ".tmp_save_crashed"), exist_ok=True)
    with open(os.path.join(str(tmp_path), ".tmp_save_crashed", "arrays.npz"), "w") as f:
        f.write("partial")
    assert ckpt.all_steps() == [5]
    assert ckpt.latest_step() == 5


def test_overwrite_same_step_atomic(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, {"x": jnp.zeros((2,))})
    ckpt.save(1, {"x": jnp.ones((2,))})
    out = ckpt.restore(1, {"x": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(out["x"]), np.ones((2,)))


# --- checksummed reads ----------------------------------------------------------


def test_save_records_content_digest(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    path = ckpt.save(1, {"x": jnp.arange(4.0)})
    digest = ckpt.meta(1)["digest"]
    assert digest.startswith("sha256:")
    from repro.checkpoint.manager import _file_digest

    assert digest == _file_digest(os.path.join(path, "arrays.npz"))


def test_corrupt_checkpoint_quarantined_and_skipped(tmp_path):
    """Flipped bytes in arrays.npz -> CorruptCheckpointError, the step dir is
    renamed to .corrupt (so all_steps() stops offering it for resume), and the
    degradation lands in the resilience ledger."""
    from repro.checkpoint.manager import CorruptCheckpointError
    from repro.resilience import ledger

    ledger.clear()
    ckpt = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(4.0)}
    ckpt.save(1, tree)
    ckpt.save(2, tree)
    arrays = os.path.join(str(tmp_path), "step_00000001", "arrays.npz")
    with open(arrays, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        f.write(bytes([f.read(1)[0] ^ 0xFF]))
    with pytest.raises(CorruptCheckpointError, match="digest"):
        ckpt.restore(1, tree)
    assert os.path.isdir(os.path.join(str(tmp_path), "step_00000001.corrupt"))
    assert ckpt.all_steps() == [2]  # resume falls through to the good step
    out = ckpt.restore(2, tree)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(4.0))
    (ev,) = ledger.events("checkpoint.read")
    assert ev.fallback == "quarantine" and "digest mismatch" in ev.cause


def test_predigest_checkpoint_restores_unverified(tmp_path):
    """Checkpoints written before digests existed have no recorded digest —
    they restore without verification instead of being rejected."""
    ckpt = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(3.0)}
    ckpt.save(1, tree)
    meta_path = os.path.join(str(tmp_path), "step_00000001", "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    del meta["digest"]
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    out = ckpt.restore(1, tree)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(3.0))


# --- async writer ---------------------------------------------------------------


def test_async_checkpointer(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    writer = AsyncCheckpointer(ckpt)
    tree = {"x": jnp.arange(4, dtype=jnp.float32)}
    for s in (1, 2, 3):
        writer.submit(s, jax.tree.map(lambda t: t + s, tree), {"data_step": s})
    writer.wait()
    assert ckpt.all_steps() == [1, 2, 3]
    out = ckpt.restore(2, tree)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(4) + 2)
    writer.close()


def test_async_checkpointer_snapshot_semantics(tmp_path):
    """The tree is snapshotted at submit() — later mutation can't corrupt it."""
    ckpt = CheckpointManager(str(tmp_path))
    writer = AsyncCheckpointer(ckpt)
    arr = np.zeros(4, np.float32)
    writer.submit(1, {"x": arr})
    arr += 99  # mutate after submit
    writer.wait()
    out = ckpt.restore(1, {"x": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(out["x"]), np.zeros(4))
    writer.close()


# --- fault-tolerant loop -----------------------------------------------------------


def test_loop_runs_and_checkpoints(tmp_path):
    model, state, step_fn, data, ckpt = _tiny_setup(tmp_path)
    cfg = LoopConfig(total_steps=12, ckpt_every=5, log_every=100)
    final = train_loop(step_fn, state, data, cfg, ckpt=ckpt)
    assert int(final["step"]) == 12
    assert 10 in ckpt.all_steps() and 12 in ckpt.all_steps()
    assert ckpt.meta(10)["data_step"] == 10


def test_loop_crash_recovery_bit_exact(tmp_path):
    """Inject a crash at step 7; loop must restore step 5 and finish, and the
    final params must equal a crash-free run (deterministic data replay)."""
    model, state0, step_fn, data, ckpt = _tiny_setup(tmp_path)
    crashed = {"done": False}

    def bomb(step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    cfg = LoopConfig(total_steps=10, ckpt_every=5, max_restarts=2, log_every=100)
    final = train_loop(step_fn, state0, data, cfg, ckpt=ckpt, failure_hook=bomb)
    assert crashed["done"]
    assert int(final["step"]) == 10

    # crash-free reference run (same init, same data)
    model2, state2, step2, data2, ckpt2 = _tiny_setup(str(tmp_path) + "_ref")
    cfg2 = LoopConfig(total_steps=10, ckpt_every=100, log_every=100)
    ref = train_loop(step2, state2, data2, cfg2)
    for a, b in zip(jax.tree.leaves(final["params"]), jax.tree.leaves(ref["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_loop_gives_up_after_max_restarts(tmp_path):
    model, state, step_fn, data, ckpt = _tiny_setup(tmp_path)

    def always_bomb(step):
        if step >= 3:
            raise RuntimeError("persistent failure")

    cfg = LoopConfig(total_steps=10, ckpt_every=2, max_restarts=2, log_every=100)
    with pytest.raises(RuntimeError, match="persistent"):
        train_loop(step_fn, state, data, cfg, ckpt=ckpt, failure_hook=always_bomb)


def test_loop_straggler_accounting(tmp_path, capsys):
    import time

    model, state, step_fn, data, ckpt = _tiny_setup(tmp_path)
    slow = {"n": 0}

    def laggy(step):
        if step == 2:
            slow["n"] += 1
            time.sleep(0.05)

    cfg = LoopConfig(total_steps=4, ckpt_every=100, step_deadline_s=0.04, log_every=100)
    logger = MetricsLogger()
    train_loop(step_fn, state, data, cfg, logger=logger, failure_hook=laggy)
    out = capsys.readouterr().out
    assert "straggler" in out


# --- elastic re-mesh restore ----------------------------------------------------


def test_elastic_restore_onto_mesh(tmp_path):
    """Checkpoints store logical arrays; restore device_puts onto any mesh."""
    from repro.launch.mesh import make_local_mesh
    from repro.parallel.sharding import DEFAULT_RULES, tree_shardings

    cfg = get_config("qwen2-7b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save(1, params)

    mesh = make_local_mesh((1, 1), ("data", "model"))
    shardings = tree_shardings(model.logical_axes(), mesh, DEFAULT_RULES, params)
    restored = ckpt.restore(1, model.abstract_params(), shardings=shardings)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding.mesh.shape == {"data": 1, "model": 1}
