"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override is exclusive to launch/dryrun.py)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    # Framework targets bf16/f32; keep default f32 semantics.
    yield


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running (subprocess compile) tests")


def pytest_report_header(config):
    return f"jax {jax.__version__} devices={jax.devices()}"
