"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override is exclusive to launch/dryrun.py)."""
import os
import warnings

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    # Framework targets bf16/f32; keep default f32 semantics.
    yield


@pytest.fixture(scope="session", autouse=True)
def _chaos_env_plan(tmp_path_factory):
    """Chaos tier (REPRO_FAULT_PLAN set, e.g. the CI `chaos` job): arm the
    canned fault plan and consume every trigger up front by driving each
    site's degradation path once, in a controlled order.  The ordinary suite
    then runs with the (now dormant) plan still armed — the whole suite
    passing under this fixture is the proof that one injected fault per site
    degrades gracefully instead of crashing the process."""
    from repro.resilience import faults

    if not os.environ.get(faults.ENV_PLAN):
        yield
        return

    import jax.numpy as jnp

    from repro.checkpoint.async_writer import AsyncCheckpointer
    from repro.checkpoint.manager import CheckpointManager
    from repro.kernels import api
    from repro.kernels.autotune import AutotuneCache
    from repro.launch.serve import serve_requests
    from repro.resilience import ledger

    plan = faults.install_env_plan()
    tmp = tmp_path_factory.mktemp("chaos-warmup")

    # autotune.cache_load FIRST, against a scratch path — the injected read
    # error must quarantine a throwaway file, not the repo-level cache.
    scratch = tmp / "autotune.json"
    scratch.write_text("{}")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        AutotuneCache(scratch).get("warmup")

    # plan.build + plan.execute + kernel.output: one guarded plan walks the
    # build fallback chain, the execution degrade, and the NaN scrub.
    a = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)).astype(np.float32))
    p = api.plan(
        api.GemmSpec.from_operands(a, a, blocks=(8, 8, 8)),
        guard_nonfinite="zero_and_record",
    )
    assert bool(jnp.isfinite(p(a, a)).all())

    # checkpoint.write: one transient failure absorbed by the bounded retry.
    with AsyncCheckpointer(CheckpointManager(str(tmp / "ck")), backoff=0.0) as ck:
        ck.submit(0, {"w": np.zeros(2, np.float32)})

    # serve.request: the per-request skip (fires before the model is touched,
    # so no model is needed).
    assert serve_requests(None, None, [None], gen_len=1) == [None]

    # collective.step fires inside shard_map'd ring helpers; the 1-device
    # tier has no sharded plan to degrade, so consume the trigger at the raw
    # site (the degradation path itself is proven by test_resilience.py's
    # multi-device check).
    try:
        faults.check("collective.step", schedule="warmup")
    except faults.FaultError:
        pass

    # serve.admit / serve.step / kv.page_alloc fire inside the continuous-
    # batching scheduler loop; consume the session-plan triggers at the raw
    # sites so scheduler tests see a dormant plan (their degradation paths
    # are proven with test-local plans in tests/test_scheduler.py, and end
    # to end by the chaos CI scheduler smoke).
    for site in ("serve.admit", "serve.step", "kv.page_alloc"):
        try:
            faults.check(site, warmup=True)
        except faults.FaultError:
            pass

    unfired = [s for s in plan.sites() if plan.fired(s) < 1]
    assert not unfired, f"chaos warmup left sites unfired: {unfired}"
    assert ledger.count() > 0  # the degradations were recorded, not silent
    api.clear_plan_cache()
    ledger.clear()
    yield


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running (subprocess compile) tests")


def pytest_report_header(config):
    return f"jax {jax.__version__} devices={jax.devices()}"
