"""Distributed building blocks on small multi-device CPU meshes.

Runs under the default 1-CPU runtime by building meshes over however many
devices exist (1 is fine: shard_map still exercises the collective code
paths; ppermute/psum become identities).  For real multi-device coverage,
tests that NEED >1 device spawn a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=4 — keeping the main test
process at 1 device per the harness contract.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_local_mesh
from repro.parallel.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    logical_to_physical,
    named_sharding,
    tree_shardings,
)
from repro.parallel.pipeline import bubble_fraction, pipeline_ticks
from repro.parallel.systolic import phase_counts


def _run_subprocess(body: str, n_dev: int = 4) -> str:
    """Run a snippet under a forced n-device CPU runtime; returns stdout."""
    prog = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + f" --xla_force_host_platform_device_count={n_dev}"
    )
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    return out.stdout


# --- sharding rules -----------------------------------------------------------


def test_logical_to_physical_basic():
    mesh = make_local_mesh((1, 1), ("data", "model"))
    spec = logical_to_physical(("batch", "seq", "embed"), mesh, DEFAULT_RULES)
    assert spec == jax.sharding.PartitionSpec("data", None, None)
    spec = logical_to_physical(("embed", "mlp"), mesh, DEFAULT_RULES)
    assert spec == jax.sharding.PartitionSpec(None, "model")


def test_duplicate_physical_axis_dropped():
    """A mesh axis may appear once per spec: later logical dims go replicated."""
    mesh = make_local_mesh((1, 1), ("data", "model"))
    rules = ShardingRules.make({"seq": "data"})  # batch also maps to data
    spec = logical_to_physical(("batch", "seq", "embed"), mesh, rules)
    assert spec == jax.sharding.PartitionSpec("data", None, None)


def test_missing_mesh_axis_dropped():
    """'pod' rules are harmless on a single-pod mesh."""
    mesh = make_local_mesh((1, 1), ("data", "model"))
    spec = logical_to_physical(("batch",), mesh, DEFAULT_RULES)  # ('pod','data')
    assert spec == jax.sharding.PartitionSpec("data")


def test_indivisible_dim_falls_back_to_replicated():
    mesh = make_local_mesh((1, 1), ("data", "model"))
    # vocab=49155 not divisible by model axis (1 divides everything — use a
    # fake 2-wide check through the helper's arithmetic instead)
    from repro.parallel.sharding import _drop_indivisible

    spec = jax.sharding.PartitionSpec("model", None)

    class FakeMesh:
        shape = {"data": 4, "model": 16}

    out = _drop_indivisible(spec, (49155, 128), FakeMesh())
    assert out == jax.sharding.PartitionSpec(None, None)
    out2 = _drop_indivisible(spec, (49152, 128), FakeMesh())
    assert out2 == jax.sharding.PartitionSpec("model", None)


def test_tree_shardings_structure():
    mesh = make_local_mesh((1, 1), ("data", "model"))
    tree = {"w": ("embed", "mlp"), "b": None}
    avals = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32), "b": jax.ShapeDtypeStruct((), jnp.float32)}
    sh = tree_shardings(tree, mesh, DEFAULT_RULES, avals)
    assert sh["w"].spec == jax.sharding.PartitionSpec(None, "model")
    assert sh["b"].spec == jax.sharding.PartitionSpec()


# --- paper phase counts --------------------------------------------------------


def test_systolic_phase_counts_track_paper():
    """switched-torus Cannon: p+1 phases (2n-1 regime) vs naive 2p-1 (3n-2)."""
    for p in (2, 4, 8, 16):
        pc = phase_counts(p)
        assert pc["switched_phases"] == p + 1
        assert pc["naive_phases"] == 2 * p - 1
        assert pc["paper_mesh_steps"] == 2 * p - 1
        assert pc["paper_standard_steps"] == 3 * p - 2
        # the mesh/standard saving and the switched/naive saving agree ~2/3
        if p > 2:  # p=2: both schedules already minimal (3 phases)
            assert pc["switched_phases"] < pc["naive_phases"]


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert bubble_fraction(1, 8) == 0.0
    # 1F1B runs fwd+bwd (2M work units) but fills/drains each phase: the
    # bubble FRACTION matches GPipe exactly — the win is peak in-flight
    assert bubble_fraction(4, 12, schedule="1f1b") == pytest.approx(3 / 15)


def test_pipeline_ticks_fill_steady_drain():
    g = pipeline_ticks(4, 12)
    assert (g["fill"], g["steady"], g["drain"]) == (3, 9, 3)
    assert g["total"] == 15 and g["bubble"] == 3 and g["peak_in_flight"] == 12
    f = pipeline_ticks(4, 12, schedule="1f1b")
    assert (f["fill"], f["steady"], f["drain"]) == (3, 24, 3)
    assert f["total"] == 30 and f["bubble"] == 6
    # 1F1B's point: bounded in-flight microbatches (min(stages, micro))
    assert f["peak_in_flight"] == 4
    assert pipeline_ticks(4, 2, schedule="1f1b")["peak_in_flight"] == 2
    # identities: total = fill + steady + drain; bubble/total = fraction
    for d in (g, f):
        assert d["total"] == d["fill"] + d["steady"] + d["drain"]
        assert d["bubble_fraction"] == pytest.approx(d["bubble"] / d["total"])
    # degenerate single stage: no bubble at all
    assert pipeline_ticks(1, 8)["bubble"] == 0
    with pytest.raises(ValueError, match="schedule"):
        pipeline_ticks(4, 12, schedule="interleaved")
    with pytest.raises(ValueError):
        pipeline_ticks(0, 12)


# --- multi-device behaviour (subprocess with 4 CPU devices) -------------------


@pytest.mark.slow
def test_systolic_matmul_4dev():
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_local_mesh
        from repro.parallel.systolic import systolic_matmul
        mesh = make_local_mesh((2, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(8, 12)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(12, 16)).astype(np.float32))
        # K must divide both mesh axes (2): 12 ok; M=8, N=16 ok
        out = systolic_matmul(a, b, mesh=mesh, axes=("data", "model"))
        np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b), rtol=1e-4, atol=1e-4)
        print("OK")
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_ring_collective_matmuls_4dev():
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_local_mesh
        from repro.parallel.collectives import ring_allgather_matmul, matmul_ring_reducescatter
        from repro.parallel.sharding import shard_map
        mesh = make_local_mesh((4,), ("model",))
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(8, 12)).astype(np.float32))
        # ring all-gather matmul: X row-sharded, W replicated
        f = shard_map(
            lambda xb, wb: ring_allgather_matmul(xb, wb, "model"),
            mesh=mesh, in_specs=(P("model", None), P()), out_specs=P(), check_vma=False,
        )
        np.testing.assert_allclose(np.asarray(f(x, w))[:16], np.asarray(x @ w), rtol=1e-4, atol=1e-4)
        # matmul + ring reduce-scatter: X col-sharded, W row-sharded
        g = shard_map(
            lambda xb, wb: matmul_ring_reducescatter(xb, wb, "model"),
            mesh=mesh, in_specs=(P(None, "model"), P("model", None)), out_specs=P("model", None), check_vma=False,
        )
        np.testing.assert_allclose(np.asarray(g(x, w)), np.asarray(x @ w), rtol=1e-4, atol=1e-4)
        print("OK")
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_overlapped_collectives_bitwise_4dev():
    """Every double-buffered helper (overlap=True / ring_pipeline_matmul)
    reproduces its serial twin bit for bit on integer-valued f32 operands."""
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_local_mesh
        from repro.parallel.collectives import (
            ring_allgather_matmul, matmul_ring_reducescatter,
            ring_pipeline_matmul)
        from repro.parallel.systolic import ring_systolic_kpass
        from repro.parallel.sharding import shard_map
        mesh = make_local_mesh((4,), ("model",))
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.integers(-4, 5, size=(16, 8)).astype(np.float32))
        w = jnp.asarray(rng.integers(-4, 5, size=(8, 12)).astype(np.float32))
        ref = np.asarray(x) @ np.asarray(w)

        def run(fn, in_specs, out_specs):
            f = shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
            return np.asarray(f(x, w))

        ag_in = (P("model", None), P())
        serial = run(lambda xb, wb: ring_allgather_matmul(xb, wb, "model"),
                     ag_in, P())
        overlap = run(lambda xb, wb: ring_allgather_matmul(
            xb, wb, "model", overlap=True), ag_in, P())
        assert np.array_equal(serial, overlap), "allgather overlap != serial"
        assert np.array_equal(overlap, ref)

        rs_in = (P(None, "model"), P("model", None))
        serial = run(lambda xb, wb: matmul_ring_reducescatter(
            xb, wb, "model"), rs_in, P("model", None))
        overlap = run(lambda xb, wb: matmul_ring_reducescatter(
            xb, wb, "model", overlap=True), rs_in, P("model", None))
        assert np.array_equal(serial, overlap), "reducescatter overlap != serial"
        assert np.array_equal(overlap, ref)

        serial = run(lambda ab, bb: ring_systolic_kpass(
            ab, bb, axis="model"), rs_in, P())
        overlap = run(lambda ab, bb: ring_systolic_kpass(
            ab, bb, axis="model", overlap=True), rs_in, P())
        assert np.array_equal(serial, overlap), "kpass overlap != serial"
        assert np.array_equal(overlap, ref)

        # 1F1B microbatched ring: 8 microbatches = 2 chains of 4 on p=4
        pipe = run(lambda xb, wb: ring_pipeline_matmul(
            xb, wb, "model", microbatches=8), rs_in, P("model", None))
        assert np.array_equal(pipe, ref), "pipeline != reference"
        print("OK")
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_compressed_allreduce_4dev():
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_local_mesh
        from repro.parallel.compression import compressed_psum_mean, init_error_state
        from repro.parallel.sharding import shard_map
        mesh = make_local_mesh((4,), ("data",))
        rng = np.random.default_rng(2)
        g = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))  # per-dev rows
        e = jnp.zeros((4, 64), jnp.float32)
        f = shard_map(
            lambda gb, eb: compressed_psum_mean(gb[0], eb[0], ("data",)),
            mesh=mesh, in_specs=(P("data", None), P("data", None)),
            out_specs=(P(), P("data")), check_vma=False,
        )
        mean, new_e = f(g, e)
        true_mean = np.asarray(g).mean(0)
        err = np.abs(np.asarray(mean) - true_mean).max()
        scale = np.abs(np.asarray(g)).max() / 127.0
        assert err <= 4 * scale + 1e-6, (err, scale)
        # error feedback: residual equals quantization error exactly
        print("OK")
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_pipeline_parallel_4dev():
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_local_mesh
        from repro.parallel.pipeline import pipeline_apply
        mesh = make_local_mesh((4,), ("stage",))
        rng = np.random.default_rng(3)
        ws = jnp.asarray(rng.normal(size=(4, 8, 8)).astype(np.float32)) * 0.5
        x = jnp.asarray(rng.normal(size=(6, 2, 8)).astype(np.float32))  # (micro, mb, d)
        def stage_fn(w, h):
            return jnp.tanh(h @ w)
        out = pipeline_apply(stage_fn, ws, x, mesh=mesh, axis="stage")
        # reference: sequential application of all 4 stages
        ref = x
        for s in range(4):
            ref = jnp.tanh(ref @ ws[s])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)
        print("OK")
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_dp_train_step_compressed_4dev():
    """int8 error-feedback DP training converges on a toy problem."""
    out = _run_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_local_mesh
        from repro.models import get_model
        from repro.optim import constant
        from repro.train.train_step import (
            init_dp_train_state_compressed, make_dp_train_step_compressed)
        mesh = make_local_mesh((4,), ("data",))
        cfg = get_config("qwen2-7b").reduced()
        model = get_model(cfg)
        state = init_dp_train_state_compressed(model, jax.random.PRNGKey(0), mesh)
        step = make_dp_train_step_compressed(model, constant(3e-3), mesh, dp_axes=("data",))
        step = jax.jit(step)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks.astype(jnp.int32), "labels": jnp.roll(toks, -1, 1).astype(jnp.int32)}
        losses = []
        for i in range(15):  # overfit one batch: compressed grads must descend
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses
        print("OK", losses[0], losses[-1])
        """
    )
    assert "OK" in out
