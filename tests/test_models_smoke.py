"""Per-architecture smoke tests (all 10 assigned archs + paper demo config).

Each arch instantiates its REDUCED config (same family/code paths, tiny dims)
and runs:
  * forward + loss: output shapes, no NaNs,
  * one real train step: loss/grad-norm finite, params actually change,
  * prefill -> decode consistency: stepwise decode logits must match the
    teacher-forced forward logits at the same positions (validates every
    family's cache/state carry — KV caches, WKV state, SSD state, conv state,
    cross-attention caches).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import get_model
from repro.optim import AdamWConfig, constant
from repro.train.train_step import init_train_state, make_train_step

ALL_ARCHS = list(ASSIGNED_ARCHS) + ["mesh-paper"]


def _batch_for(cfg, b=2, t=16, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab_size).astype(jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "audio":
        t_enc = t * cfg.dec_ratio
        batch = {
            "frames": jax.random.normal(key, (b, t_enc, cfg.d_model), cfg.adtype),
            "tokens": toks,
            "labels": jnp.roll(toks, -1, axis=1),
        }
    elif cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.num_stub_patches, cfg.d_model), cfg.adtype
        )
    return batch


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            model = get_model(cfg)
            params = model.init(jax.random.PRNGKey(1))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch, models):
    cfg, model, params = models(arch)
    batch = _batch_for(cfg)
    logits, aux = model.forward(params, batch)
    b, t = batch["tokens"].shape
    assert logits.shape == (b, t, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, metrics = model.loss(params, batch)
    assert jnp.isfinite(loss)
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch, models):
    cfg, model, params = models(arch)
    state = init_train_state(model, jax.random.PRNGKey(2))
    step = jax.jit(make_train_step(model, constant(1e-3), AdamWConfig()))
    batch = _batch_for(cfg, seed=3)
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]) and jnp.isfinite(metrics["grad_norm"])
    assert float(metrics["grad_norm"]) > 0
    # params changed
    diff = jax.tree.reduce(
        lambda acc, x: acc + float(jnp.sum(jnp.abs(x[0] - x[1]))),
        jax.tree.map(lambda a, b: (a, b), new_state["params"], state["params"]),
        0.0,
    )
    assert diff > 0
    assert int(new_state["step"]) == 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch, models):
    """Teacher-forced forward logits == prefill+stepwise-decode logits."""
    cfg, model, params = models(arch)
    if arch == "mesh-paper":
        # the demo config scrambles activations (square grids only) — the
        # reduced dims make scrambling a no-op, so the test still applies
        pass
    b, t_pre, t_gen = 2, 8, 4
    batch = _batch_for(cfg, b=b, t=t_pre + t_gen, seed=5)
    full_logits, _ = model.forward(params, batch)

    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :t_pre]
    pre_batch["labels"] = batch["labels"][:, :t_pre]
    logits_pre, state = model.prefill(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(full_logits[:, :t_pre], np.float32),
        rtol=2e-2, atol=2e-2,
    )

    # grow KV caches for families that carry per-position caches
    offset = cfg.num_stub_patches if cfg.family == "vlm" else 0
    if cfg.family in ("dense", "moe", "vlm"):
        state = jax.tree.map(
            lambda c: jnp.pad(c, [(0, 0), (0, 0), (0, t_gen)] + [(0, 0)] * (c.ndim - 3)),
            state,
        )
    elif cfg.family in ("hybrid", "audio"):
        state = {
            k: (
                jnp.pad(v, [(0, 0), (0, 0), (0, t_gen)] + [(0, 0)] * (v.ndim - 3))
                if k in ("kv_k", "kv_v", "k", "v")
                else v
            )
            for k, v in state.items()
        }
    for i in range(t_gen):
        pos = t_pre + i + offset
        tok = batch["tokens"][:, t_pre + i : t_pre + i + 1]
        logits_i, state = model.decode(params, tok, state, jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits_i[:, 0], np.float32),
            np.asarray(full_logits[:, t_pre + i], np.float32),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode step {i} diverges from forward",
        )


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "zamba2-1.2b"])
def test_long_context_state_is_constant_size(arch, models):
    """The long_500k families must carry O(1)-per-token decode state."""
    cfg, model, params = models(arch)
    s1 = model.decode_state_specs(2, 64)
    s2 = model.decode_state_specs(2, 128)
    if cfg.family == "ssm":
        assert jax.tree.map(lambda x: x.shape, s1) == jax.tree.map(lambda x: x.shape, s2)
    else:  # hybrid: SSM states constant; only shared-attn KV grows
        assert s1["h"].shape == s2["h"].shape
        assert s1["conv"].shape == s2["conv"].shape


def test_moe_router_aux_losses(models):
    cfg, model, params = models("olmoe-1b-7b")
    batch = _batch_for(cfg, seed=7)
    _, aux = model.forward(params, batch)
    assert float(aux["lb_loss"]) > 0.0  # load-balance loss is active
    loss_with, _ = model.loss(params, batch)
    assert jnp.isfinite(loss_with)


def test_whisper_enc_dec_shapes(models):
    cfg, model, params = models("whisper-medium")
    b, t_dec = 2, 8
    batch = _batch_for(cfg, b=b, t=t_dec)
    logits, _ = model.forward(params, batch)
    assert logits.shape == (b, t_dec, cfg.vocab_size)


def test_vlm_patch_prefix_changes_logits(models):
    """Pixtral: image patches must actually condition the text logits."""
    cfg, model, params = models("pixtral-12b")
    batch = _batch_for(cfg, seed=9)
    logits_a, _ = model.forward(params, batch)
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"] + 1.0
    logits_b, _ = model.forward(params, batch2)
    assert float(jnp.max(jnp.abs(logits_a - logits_b))) > 1e-4


@pytest.mark.parametrize("arch", ["granite-3-8b", "olmoe-1b-7b"])
def test_full_configs_match_assignment(arch):
    """Spot-check the FULL (non-reduced) configs against the assignment table."""
    cfg = get_config(arch)
    if arch == "granite-3-8b":
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads) == (40, 4096, 32, 8)
        assert (cfg.d_ff, cfg.vocab_size) == (12800, 49155)
    else:
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads) == (16, 2048, 16)
        assert (cfg.num_experts, cfg.num_experts_per_tok, cfg.vocab_size) == (64, 8, 50304)


def test_all_ten_archs_registered():
    assert len(ASSIGNED_ARCHS) == 10
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        assert cfg.arch_id == arch
