"""Sharded plan/execute surface (DESIGN.md §9): ShardSpec construction and
validation, the one-planner degenerate path, collective-schedule numerics
bit-for-bit vs the unsharded Plan, divisibility rejection, plan-cache keying
on mesh identity, and the satellite guards (mesh validation, indivisible-drop
warning, parallel exports).

Multi-device checks run in-process when the runtime already has >= 8 devices
(the CI distributed job sets XLA_FLAGS) and otherwise re-exec themselves in
an 8-virtual-CPU-device subprocess, keeping the tier-1 process at 1 device
per the harness contract.
"""

import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import api
from repro.kernels.api import Epilogue, GemmSpec, ShardedPlan, ShardSpec
from repro.launch.mesh import make_local_mesh

B = 8

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_plan_cache():
    api.clear_plan_cache()
    yield
    api.clear_plan_cache()


def _int_mat(shape, seed):
    """Integer-valued f32 operands: every partial product and sum is exact,
    so all collective summation orders agree bit for bit."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-4, 5, size=shape).astype(np.float32))


def _run_in_8dev_subprocess(fn_name: str) -> None:
    """Re-exec a module-level `_check_*` function under 8 CPU devices."""
    from repro.launch.mesh import forced_device_env

    env = forced_device_env(8, pythonpath=("src", "tests"))
    out = subprocess.run(
        [
            sys.executable,
            "-c",
            f"import test_sharded_plan as m; m.{fn_name}(); print('SUBPROC_OK')",
        ],
        capture_output=True,
        text=True,
        env=env,
        cwd=_REPO,
        timeout=600,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    assert "SUBPROC_OK" in out.stdout


def _multi_or_subprocess(fn, fn_name: str) -> None:
    if jax.device_count() >= 8:
        fn()
    else:
        _run_in_8dev_subprocess(fn_name)


# --- ShardSpec construction / validation (1 device) ---------------------------


def test_shardspec_validates_axes_and_schedule():
    mesh = make_local_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="not a mesh axis"):
        ShardSpec.from_mesh(mesh, m="rows")
    with pytest.raises(ValueError, match="schedule must be 'auto' or one of"):
        ShardSpec.from_mesh(mesh, k="model", schedule="cannon")
    with pytest.raises(ValueError, match="partitions more than one GEMM dim"):
        ShardSpec.from_mesh(mesh, m="model", n="model")
    with pytest.raises(ValueError, match="axis_k must be a single mesh axis"):
        ShardSpec.from_mesh(mesh, k=("data", "model"))
    # tuple axes are allowed on the local dims and length-1 tuples unwrap
    # (axis_k included — only MULTI-axis K is rejected)
    s = ShardSpec.from_mesh(mesh, m=("data", "model"), n=None)
    assert s.axis_m == ("data", "model") and s.axis_size(s.axis_m) == 1
    assert ShardSpec.from_mesh(mesh, m=("data",)).axis_m == "data"
    assert ShardSpec.from_mesh(mesh, k=("model",)).axis_k == "model"
    assert ShardSpec.unsharded(mesh).is_trivial


def test_shardspec_is_hashable_spec_field():
    mesh = make_local_mesh((1,), ("model",))
    s1 = GemmSpec(m=B, k=B, n=B, shard=ShardSpec.from_mesh(mesh, m="model"))
    s2 = GemmSpec(m=B, k=B, n=B, shard=ShardSpec.from_mesh(mesh, m="model"))
    assert s1 == s2 and hash(s1) == hash(s2)
    assert s1 != GemmSpec(m=B, k=B, n=B)
    with pytest.raises(TypeError, match="shard must be a ShardSpec"):
        GemmSpec(m=B, k=B, n=B, shard="model")


def test_shardspec_from_rules_maps_logical_axes():
    from repro.parallel.sharding import DEFAULT_RULES

    mesh = make_local_mesh((1, 1), ("data", "model"))
    s = ShardSpec.from_rules(mesh, DEFAULT_RULES, m="batch", n="mlp")
    # 'batch' -> ('pod','data') with 'pod' absent on this mesh; 'mlp' -> model
    assert s.axis_m == "data" and s.axis_n == "model" and s.axis_k is None
    # 'seq' maps to None -> dim stays whole
    assert ShardSpec.from_rules(mesh, DEFAULT_RULES, k="seq").axis_k is None


def test_plan_requires_matching_mesh_and_shardspec():
    mesh = make_local_mesh((1, 1), ("data", "model"))
    spec = GemmSpec(m=B, k=B, n=B, shard=ShardSpec.unsharded(mesh))
    with pytest.raises(ValueError, match="pass the device mesh"):
        api.plan(spec)
    # mesh= WITHOUT a ShardSpec auto-shards (cost model) instead of raising
    auto = api.plan(GemmSpec(m=B, k=B, n=B), mesh=mesh)
    assert isinstance(auto, ShardedPlan) and auto.spec.shard is not None
    assert auto.describe()["decision"]["sharding"]["chosen"]
    other = make_local_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="built for mesh axes"):
        api.plan(spec, mesh=other)


def test_sharding_capability_gates_backends():
    mesh = make_local_mesh((1,), ("model",))
    spec = GemmSpec(m=B, k=B, n=B, shard=ShardSpec.unsharded(mesh))
    api.register_backend(
        "no_shard_double",
        lambda plan, a, b, bias, residual: a @ b,
        {"structures": {"general"}, "sharding": False},
    )
    try:
        with pytest.raises(api.CapabilityError, match="sharding"):
            api.plan(spec, backend="no_shard_double", mesh=mesh)
    finally:
        api.unregister_backend("no_shard_double")
    caps = api.get_capabilities("xla")
    assert caps.sharding and api.get_capabilities("pallas_mesh").sharding


# --- one planner: the degenerate ShardSpec path (1 device) --------------------


@pytest.mark.parametrize("backend", ["xla", "pallas_mesh", "ref"])
def test_unsharded_shardspec_matches_plain_plan_bitwise(backend):
    mesh = make_local_mesh((1, 1), ("data", "model"))
    a, b = _int_mat((2 * B, B), 0), _int_mat((B, 3 * B), 1)
    bias = _int_mat((3 * B,), 2)
    epi = Epilogue(bias=True, activation="gelu")
    want = api.plan(
        GemmSpec.from_operands(a, b, epilogue=epi, blocks=(B, B, B)),
        backend=backend,
    )(a, b, bias=bias)
    spec = GemmSpec.from_operands(
        a, b, epilogue=epi, blocks=(B, B, B), shard=ShardSpec.unsharded(mesh)
    )
    p = api.plan(spec, backend=backend, mesh=mesh)
    assert isinstance(p, ShardedPlan) and p.schedule == "replicated"
    np.testing.assert_array_equal(np.asarray(p(a, b, bias=bias)), np.asarray(want))
    # cached: the identical object comes back, and the per-shard local plan
    # is itself a cached ordinary Plan (one planner, not two)
    assert api.plan(spec, backend=backend, mesh=mesh) is p
    assert p.local is api.plan(p.local.spec, backend=backend)


def test_sharded_plan_describe_provenance_and_roofline():
    import json

    from repro.launch.roofline import analyze_plan

    mesh = make_local_mesh((1,), ("model",))
    spec = GemmSpec(m=2 * B, k=B, n=B, shard=ShardSpec.unsharded(mesh))
    d = api.plan(spec, mesh=mesh).describe()
    json.dumps(d)
    sh = d["sharding"]
    assert sh["mesh"] == [["model", 1]] and sh["schedule"] == "replicated"
    assert sh["per_shard_mkn"] == [2 * B, B, B]
    assert sh["per_shard_flops"] == 2 * 2 * B * B * B and sh["bytes_moved"] == 0
    assert d["fused_epilogue"] is False
    rl = analyze_plan(d)
    assert rl["t_collective_s"] == 0.0 and rl["dominant"] in ("compute", "memory")
    # unsharded describe() flows through the same arithmetic
    rl2 = analyze_plan(api.plan(GemmSpec(m=B, k=B, n=B)).describe())
    assert rl2["schedule"] is None and rl2["collective_bytes"] == 0
    # batched_b byte counts scale with batch, matching the batch-full FLOPs
    rl3 = analyze_plan(
        api.plan(GemmSpec(m=B, k=B, n=B, batch=(4,), batched_b=True)).describe()
    )
    assert rl3["hbm_bytes"] == 4 * rl2["hbm_bytes"]
    assert rl3["per_shard_flops"] == 4 * rl2["per_shard_flops"]


def test_scrambled_structure_rejected_with_shard():
    mesh = make_local_mesh((1,), ("model",))
    spec = GemmSpec(
        m=B, k=B, n=B, structure="scrambled", blocks=(B, B, B),
        shard=ShardSpec.unsharded(mesh),
    )
    with pytest.raises(ValueError, match="scrambled.*does not compose"):
        api.plan(spec, mesh=mesh)


def test_schedule_resolution_and_bytes_moved_model():
    """_resolve_sharding is pure arithmetic over the spec — the comm model
    (bytes per device per call) and auto schedule choice are unit-testable
    without devices."""
    axes = (("x", 4),)
    spec_k = GemmSpec(m=16, k=32, n=8, shard=ShardSpec(axes, axis_k="x"))
    sched, local, bytes_moved, phases, decision = api._resolve_sharding(spec_k)
    assert sched == "reduce_scatter_k"  # auto: M % 4 == 0
    assert decision is not None and decision["chosen"] == "reduce_scatter_k"
    assert (local.m, local.k, local.n) == (4, 8, 8)
    assert local.epilogue.is_identity and local.shard is None
    assert bytes_moved == 3 * 4 * 8 * 4 and phases == 3

    spec_ring = GemmSpec(m=6, k=32, n=8, shard=ShardSpec(axes, axis_k="x"))
    sched, local, bytes_moved, _, _ = api._resolve_sharding(spec_ring)
    assert sched == "ring_k"  # auto: M=6 not divisible by 4
    assert (local.m, local.k) == (6, 8) and bytes_moved == 3 * 6 * 8 * 4

    spec_ag = GemmSpec(
        m=16, k=32, n=8,
        shard=ShardSpec(axes, axis_m="x", schedule="allgather_a"),
        dtype_a="bfloat16",
    )
    sched, local, bytes_moved, _, _ = api._resolve_sharding(spec_ag)
    assert sched == "allgather_a" and local.m == 4
    # f32 RESULT chunks hop the ring (each device computes its rows once);
    # input dtype no longer enters the byte model
    assert bytes_moved == 3 * 4 * 8 * 4

    # overlap twins: same byte model, column-half local kernels (ln = n/2)
    for ov_sched, kw, want_local, want_bytes, want_phases in [
        ("allgather_a_overlap", {"axis_m": "x"}, (4, 32, 4), 3 * 4 * 8 * 4, 3),
        ("reduce_scatter_k_overlap", {"axis_k": "x"}, (4, 8, 8), 3 * 4 * 8 * 4, 3),
        ("ring_k_overlap", {"axis_k": "x"}, (16, 8, 4), 3 * 16 * 8 * 4, 3),
        # eff_m=16, pk=4 -> mb=4 (even) -> 2 chains of 4 microbatches;
        # phases = micro - micro/pk = 6, one kernel call per microbatch
        ("pipeline", {"axis_k": "x"}, (2, 8, 8), 3 * 4 * 8 * 4, 6),
    ]:
        spec_ov = GemmSpec(
            m=16, k=32, n=8, shard=ShardSpec(axes, schedule=ov_sched, **kw)
        )
        sched, local, bytes_moved, phases, _ = api._resolve_sharding(spec_ov)
        assert sched == ov_sched
        assert (local.m, local.k, local.n) == want_local, (ov_sched, local)
        assert bytes_moved == want_bytes and phases == want_phases, ov_sched

    # the column-half variants need an even N
    for ov_sched, kw in [
        ("allgather_a_overlap", {"axis_m": "x"}),
        ("ring_k_overlap", {"axis_k": "x"}),
    ]:
        with pytest.raises(ValueError, match="must be even"):
            api._resolve_sharding(
                GemmSpec(m=16, k=32, n=9,
                         shard=ShardSpec(axes, schedule=ov_sched, **kw))
            )

    with pytest.raises(ValueError, match="cannot shard K"):
        api._resolve_sharding(
            GemmSpec(m=16, k=32, n=8,
                     shard=ShardSpec(axes, axis_k="x", schedule="replicated"))
        )
    with pytest.raises(ValueError, match="requires axis_k"):
        api._resolve_sharding(
            GemmSpec(m=16, k=32, n=8,
                     shard=ShardSpec(axes, axis_m="x", schedule="ring_k"))
        )
    with pytest.raises(ValueError, match="shards only K"):
        api._resolve_sharding(
            GemmSpec(m=16, k=32, n=8,
                     shard=ShardSpec((("x", 4), ("y", 2)), axis_k="x", axis_n="y",
                                     schedule="ring_k"))
        )
    # auto must not blame a schedule the caller never chose
    with pytest.raises(ValueError, match="no collective schedule combines"):
        api._resolve_sharding(
            GemmSpec(m=16, k=32, n=8,
                     shard=ShardSpec((("x", 4), ("y", 2)), axis_m="y", axis_k="x"))
        )
    with pytest.raises(ValueError, match="no batch dims"):
        api._resolve_sharding(
            GemmSpec(m=16, k=32, n=8, shard=ShardSpec(axes, axis_batch="x"))
        )


def test_layers_gemm_routes_shard(monkeypatch):
    from repro.models.layers import gemm

    class Cfg:
        use_mesh_kernel = False
        fused_dense_epilogue = True

    mesh = make_local_mesh((1, 1), ("data", "model"))
    x, w = _int_mat((2 * B, B), 3), _int_mat((B, B), 4)
    want = gemm(x, w, Cfg())
    got = gemm(x, w, Cfg(), mesh=mesh, shard=ShardSpec.unsharded(mesh))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    [desc] = [
        p for p in api.plan_cache_info()["plans"] if p.get("sharding")
    ]
    assert desc["sharding"]["schedule"] == "replicated"


def test_serve_report_prints_sharding_column(capsys):
    from repro.launch.serve import report_plan_cache

    mesh = make_local_mesh((1,), ("model",))
    spec = GemmSpec(m=B, k=B, n=B, shard=ShardSpec.unsharded(mesh))
    api.plan(spec, mesh=mesh)
    info = report_plan_cache(prefix="[t]")
    out = capsys.readouterr().out
    assert "shard=replicated@1" in out and info["size"] >= 1


# --- satellites (1 device) ----------------------------------------------------


def test_make_local_mesh_validates_device_count():
    with pytest.raises(ValueError, match="exceeds the .* available"):
        make_local_mesh((64, 64), ("data", "model"))
    with pytest.raises(ValueError, match="equal rank"):
        make_local_mesh((1, 1), ("data",))


def test_drop_indivisible_warns_once_per_spec():
    from jax.sharding import PartitionSpec as P

    from repro.parallel import sharding as shmod

    class FakeMesh:
        shape = {"data": 4, "model": 16}

    shmod._WARNED_DROPS.clear()
    spec = P("model", None)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = shmod._drop_indivisible(spec, (49155, 128), FakeMesh())
        shmod._drop_indivisible(spec, (49155, 128), FakeMesh())  # same spec
    assert out == P(None, None)
    drops = [w for w in rec if "fell back to replicated" in str(w.message)]
    assert len(drops) == 1  # once per (spec, shape, mesh)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        shmod._drop_indivisible(spec, (40, 128), FakeMesh())  # different shape
        assert shmod._drop_indivisible(spec, (49152, 128), FakeMesh()) == spec
    drops = [w for w in rec if "fell back to replicated" in str(w.message)]
    assert len(drops) == 1  # new spec warns; divisible spec never warns


def test_parallel_package_exports_public_names():
    import repro.parallel as par

    for name in (
        "ShardingRules",
        "named_sharding",
        "constrain",
        "shard_map",
        "ring_systolic_kpass",
    ):
        assert hasattr(par, name) and name in par.__all__, name


def test_phase_counts_cover_kpass_schedules():
    from repro.parallel.systolic import phase_counts

    for p in (2, 4, 8):
        pc = phase_counts(p)
        # ring-systolic K-pass: partials flow through neighbours (2n-1 regime)
        # vs psum'd partials returning to a central point (3n-2 regime)
        assert pc["kpass_ring_phases"] == p - 1
        assert pc["kpass_psum_phases"] == 2 * (p - 1)
        assert pc["kpass_ring_phases"] < pc["kpass_psum_phases"]


# --- multi-device checks (8 virtual CPU devices) ------------------------------


def _check_numerics_all_schedules():
    """Every collective schedule x {xla, pallas_mesh} reproduces the
    unsharded Plan bit for bit, epilogue included."""
    api.clear_plan_cache()
    M, K, N = 24, 16, 12
    a, b = _int_mat((M, K), 0), _int_mat((K, N), 1)
    bias = _int_mat((N,), 2)
    epi = Epilogue(bias=True, activation="gelu")
    mesh1d = make_local_mesh((4,), ("x",))
    mesh2d = make_local_mesh((4, 2), ("x", "y"))
    for backend in ("xla", "pallas_mesh"):
        want = api.plan(
            GemmSpec.from_operands(a, b, epilogue=epi, blocks=(B, B, B)),
            backend=backend,
        )(a, b, bias=bias)
        cases = [
            (mesh2d, ShardSpec.from_mesh(mesh2d, m="x", n="y"), "replicated"),
            (mesh1d, ShardSpec.from_mesh(mesh1d, m="x", schedule="allgather_a"),
             "allgather_a"),
            (mesh1d, ShardSpec.from_mesh(mesh1d, m="x",
                                         schedule="allgather_a_overlap"),
             "allgather_a_overlap"),
            (mesh1d, ShardSpec.from_mesh(mesh1d, k="x", schedule="reduce_scatter_k"),
             "reduce_scatter_k"),
            (mesh1d, ShardSpec.from_mesh(mesh1d, k="x",
                                         schedule="reduce_scatter_k_overlap"),
             "reduce_scatter_k_overlap"),
            (mesh1d, ShardSpec.from_mesh(mesh1d, k="x", schedule="ring_k"), "ring_k"),
            (mesh1d, ShardSpec.from_mesh(mesh1d, k="x", schedule="ring_k_overlap"),
             "ring_k_overlap"),
            (mesh1d, ShardSpec.from_mesh(mesh1d, k="x", schedule="pipeline"),
             "pipeline"),
            (mesh1d, ShardSpec.from_mesh(mesh1d, k="x"), "reduce_scatter_k"),  # auto
        ]
        # per-DEVICE work provenance at p=4: reduce-scatter runs one kernel
        # per ring step plus the resident chunk; the column-half overlap
        # twins run two half-width kernels; pipeline runs one per microbatch
        # (eff_m=24, mb=6 even -> 2 chains x 4 = 8)
        want_invs = {
            "replicated": 1, "allgather_a": 1, "allgather_a_overlap": 2,
            "reduce_scatter_k": 4, "reduce_scatter_k_overlap": 4,
            "ring_k": 1, "ring_k_overlap": 2, "pipeline": 8,
        }
        want_phases = {"replicated": 0, "pipeline": 6}
        for mesh, shard, want_sched in cases:
            spec = GemmSpec.from_operands(
                a, b, epilogue=epi, blocks=(B, B, B), shard=shard
            )
            p = api.plan(spec, backend=backend, mesh=mesh)
            assert p.schedule == want_sched, (backend, p.schedule, want_sched)
            got = p(a, b, bias=bias)
            assert np.array_equal(np.asarray(got), np.asarray(want)), (
                backend,
                want_sched,
            )
            assert p.collective_phases == want_phases.get(want_sched, 3)
            sh = p.describe()["sharding"]
            assert sh["kernel_invocations"] == want_invs[want_sched], want_sched
            assert sh["overlap"] == (
                want_sched.endswith("_overlap") or want_sched == "pipeline"
            )
            if want_sched.startswith("allgather_a"):
                # result-gather: every device computes only ITS rows (the
                # input-rotation form paid p x this)
                assert sh["per_shard_flops"] == p.flops // 4

    # batch handling: 2D b folds batch into the M partition; 3D b replicates
    a3 = _int_mat((2, 4, K), 3)
    want = api.plan(GemmSpec.from_operands(a3, b))(a3, b)
    p = api.plan(
        GemmSpec.from_operands(a3, b, shard=ShardSpec.from_mesh(mesh1d, m="x")),
        mesh=mesh1d,
    )
    assert np.array_equal(np.asarray(p(a3, b)), np.asarray(want))
    b3 = _int_mat((4, K, N), 4)
    ab3 = _int_mat((4, 6, K), 5)
    want = api.plan(GemmSpec.from_operands(ab3, b3))(ab3, b3)
    p = api.plan(
        GemmSpec.from_operands(
            ab3, b3, shard=ShardSpec.from_mesh(mesh2d, batch="x", n="y")
        ),
        mesh=mesh2d,
    )
    assert np.array_equal(np.asarray(p(ab3, b3)), np.asarray(want))


def _check_divisibility_and_cache_keying():
    api.clear_plan_cache()
    mesh1d = make_local_mesh((4,), ("x",))

    def expect(msg, **spec_kw):
        try:
            api.plan(GemmSpec(**spec_kw), mesh=mesh1d)
        except ValueError as e:
            assert msg in str(e), (msg, str(e))
        else:
            raise AssertionError(f"expected rejection: {msg}")

    expect("M=10 is not divisible",
           m=10, k=16, n=12, shard=ShardSpec.from_mesh(mesh1d, m="x"))
    expect("K=18 is not divisible",
           m=8, k=18, n=12, shard=ShardSpec.from_mesh(mesh1d, k="x",
                                                      schedule="ring_k"))
    expect("M=6 is not divisible",
           m=6, k=16, n=12, shard=ShardSpec.from_mesh(mesh1d, k="x",
                                                      schedule="reduce_scatter_k"))
    expect("N=10 is not divisible",
           m=8, k=16, n=10, shard=ShardSpec.from_mesh(mesh1d, n="x"))

    # cache keys on mesh identity: equal meshes share, disjoint devices don't
    import jax.sharding as shd

    m1 = make_local_mesh((4,), ("x",))
    m2 = make_local_mesh((4,), ("x",))
    m3 = shd.Mesh(np.array(jax.devices()[4:8]), ("x",))
    spec = GemmSpec(m=8, k=16, n=12, shard=ShardSpec.from_mesh(m1, k="x"))
    p1 = api.plan(spec, mesh=m1)
    assert api.plan(spec, mesh=m2) is p1
    assert api.plan(spec, mesh=m3) is not p1
    # and the two sharded plans share the cached per-shard local plan
    assert api.plan(spec, mesh=m3).local is p1.local


def _check_overlap_fault_degrades_to_replicated():
    """A `collective.step` fault injected MID-double-buffer (step match, so
    the first ppermute round already ran) degrades the ShardedPlan to
    replicated execution with identical outputs and a ledger event."""
    from repro.resilience import faults, ledger

    M, K, N = 24, 16, 12
    a, b = _int_mat((M, K), 0), _int_mat((K, N), 1)
    mesh1d = make_local_mesh((4,), ("x",))
    for sched in ("reduce_scatter_k_overlap", "ring_k_overlap",
                  "allgather_a_overlap", "pipeline"):
        api.clear_plan_cache()
        ledger.clear()
        want = api.plan(GemmSpec.from_operands(a, b))(a, b)
        kw = {"m": "x"} if sched.startswith("allgather") else {"k": "x"}
        p = api.plan(
            GemmSpec.from_operands(
                a, b, shard=ShardSpec.from_mesh(mesh1d, schedule=sched, **kw)
            ),
            mesh=mesh1d,
        )
        step = (0, 1) if sched == "pipeline" else 1
        with faults.inject(
            {"collective.step": faults.FaultSpec(
                times=1, match={"schedule": sched, "step": step})}
        ):
            got = p(a, b)
        assert np.array_equal(np.asarray(got), np.asarray(want)), sched
        assert p._active == "replicated", sched
        (ev,) = [e for e in ledger.events("plan.execute")
                 if e.fallback == "replicated"]
        assert dict(ev.detail)["schedule"] == repr(sched)
        # degraded plans keep serving the replicated executor bitwise
        assert np.array_equal(np.asarray(p(a, b)), np.asarray(want)), sched


def _check_async_dispatch_overlaps_plans():
    """`Plan.dispatch` returns without forcing the value; `execute_async`
    drains a batch with ONE sync and matches per-plan sync execution
    bitwise — including sharded overlap plans."""
    api.clear_plan_cache()
    M, K, N = 24, 16, 12
    a, b = _int_mat((M, K), 0), _int_mat((K, N), 1)
    mesh1d = make_local_mesh((4,), ("x",))
    p_plain = api.plan(GemmSpec.from_operands(a, b))
    p_ov = api.plan(
        GemmSpec.from_operands(
            a, b,
            shard=ShardSpec.from_mesh(mesh1d, k="x",
                                      schedule="ring_k_overlap"),
        ),
        mesh=mesh1d,
    )
    h = p_plain.dispatch(a, b)
    assert isinstance(h, api.AsyncResult)
    assert np.array_equal(np.asarray(h.block()), np.asarray(p_plain(a, b)))
    outs = api.execute_async([(p_plain, (a, b)), (p_ov, (a, b))])
    assert len(outs) == 2
    assert np.array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


@pytest.mark.slow
def test_sharded_numerics_bitwise_8dev():
    _multi_or_subprocess(_check_numerics_all_schedules, "_check_numerics_all_schedules")


@pytest.mark.slow
def test_overlap_fault_degrades_to_replicated_8dev():
    _multi_or_subprocess(
        _check_overlap_fault_degrades_to_replicated,
        "_check_overlap_fault_degrades_to_replicated",
    )


@pytest.mark.slow
def test_async_dispatch_8dev():
    _multi_or_subprocess(
        _check_async_dispatch_overlaps_plans, "_check_async_dispatch_overlaps_plans"
    )


@pytest.mark.slow
def test_divisibility_and_cache_keying_8dev():
    _multi_or_subprocess(
        _check_divisibility_and_cache_keying, "_check_divisibility_and_cache_keying"
    )
