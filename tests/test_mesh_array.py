"""Cycle-accurate mesh/standard array simulators vs the paper's step counts.

Paper claims validated here:
  * mesh array multiplies n x n in 2n-1 steps (Fig. 1: n=4 -> 7 steps),
  * standard array takes 3n-2 steps (Fig. 2: n=3 -> 7 steps),
  * mesh output is C = AB in the scrambled arrangement sigma_n,
  * node (i, j)'s accumulator is FROZEN after its completion step
    (completion_times is exact, not an upper bound).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.mesh_array import (
    mesh_completion_times,
    mesh_matmul_reference,
    mesh_start_times,
    simulate_mesh,
    simulate_standard,
    standard_completion_times,
)
from repro.core.scramble import unscramble


def _rand(n, rng, dtype=np.float32):
    return jnp.asarray(rng.normal(size=(n, n)).astype(dtype))


@pytest.mark.parametrize("n", [2, 3, 4, 5, 7, 8, 16])
def test_mesh_steps_and_correctness(n, rng):
    a, b = _rand(n, rng), _rand(n, rng)
    res = simulate_mesh(a, b)
    assert res.steps == 2 * n - 1  # the paper's headline claim
    np.testing.assert_allclose(
        np.asarray(unscramble(res.output)), np.asarray(a @ b), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("n", [2, 3, 4, 5, 8])
def test_standard_steps_and_correctness(n, rng):
    a, b = _rand(n, rng), _rand(n, rng)
    res = simulate_standard(a, b)
    assert res.steps == 3 * n - 2
    np.testing.assert_allclose(np.asarray(res.output), np.asarray(a @ b), rtol=1e-4, atol=1e-4)


def test_fig1_fig2_step_counts():
    """Paper's intro: mesh on 4x4 takes 7 steps = standard on 3x3."""
    assert simulate_mesh(jnp.eye(4), jnp.eye(4)).steps == 7
    assert simulate_standard(jnp.eye(3), jnp.eye(3)).steps == 7


@pytest.mark.parametrize("model", ["antidiagonal", "corner"])
def test_both_start_models_give_2n_minus_1(model, rng):
    for n in (3, 4, 6):
        a, b = _rand(n, rng), _rand(n, rng)
        res = simulate_mesh(a, b, model=model)
        assert int(mesh_completion_times(n, model).max()) == 2 * n - 1
        np.testing.assert_allclose(
            np.asarray(unscramble(res.output)), np.asarray(a @ b), rtol=1e-4, atol=1e-4
        )


def test_node_accumulators_freeze_at_completion(rng):
    """History check: each node's value is final at its completion step and
    every node performs exactly n MACs — the paper's Fig. 3 node semantics."""
    n = 5
    a, b = _rand(n, rng), _rand(n, rng)
    res = simulate_mesh(a, b, record_history=True)
    hist = np.asarray(res.history)  # (steps, n, n)
    comp = res.completion_times  # 1-indexed steps
    final = np.asarray(res.output)
    for i in range(n):
        for j in range(n):
            t = comp[i, j]
            np.testing.assert_allclose(hist[t - 1, i, j], final[i, j], rtol=1e-5)
            if t < res.steps:
                # frozen afterwards
                np.testing.assert_allclose(hist[-1, i, j], final[i, j], rtol=1e-5)


def test_start_times_structure():
    n = 6
    st_anti = mesh_start_times(n, "antidiagonal")
    st_corner = mesh_start_times(n, "corner")
    std = standard_completion_times(n)
    # no-padding feeding: node (1,1) starts at step 1 in both mesh models
    assert st_anti[0, 0] == 1 and st_corner[0, 0] == 1
    # standard array's last node finishes at 3n-2
    assert std.max() == 3 * n - 2
    # mesh completion horizon is 2n-1 under both models
    assert (st_anti + n - 1).max() == 2 * n - 1
    assert (st_corner + n - 1).max() == 2 * n - 1


@given(st.integers(min_value=2, max_value=10))
@settings(max_examples=10, deadline=None)
def test_reference_equals_simulator(n):
    rng = np.random.default_rng(n)
    a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(mesh_matmul_reference(a, b)),
        np.asarray(simulate_mesh(a, b).output),
        rtol=1e-4,
        atol=1e-4,
    )


def test_reference_batched(rng):
    a = jnp.asarray(rng.normal(size=(3, 4, 4)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(3, 4, 4)).astype(np.float32))
    out = mesh_matmul_reference(a, b)
    for i in range(3):
        np.testing.assert_allclose(
            np.asarray(out[i]), np.asarray(mesh_matmul_reference(a[i], b[i])), rtol=1e-5
        )


def test_integer_exactness():
    """Integer inputs: simulator must be bit-exact vs the gather reference."""
    n = 6
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(-5, 5, size=(n, n)).astype(np.int32))
    b = jnp.asarray(rng.integers(-5, 5, size=(n, n)).astype(np.int32))
    res = simulate_mesh(a, b)
    assert np.array_equal(np.asarray(unscramble(res.output)), np.asarray(a @ b))
