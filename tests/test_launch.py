"""launch/: input specs, hlo_stats parsing, roofline math, production mesh.

The 512-device production mesh is exercised in a subprocess (XLA_FLAGS must
be set before jax init; the main test process stays at 1 device).
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
from repro.launch.hlo_stats import collective_stats
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, analyze_artifact


# --- input_specs ---------------------------------------------------------------


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_input_specs_all_cells(arch):
    """Every applicable (arch x shape) cell produces abstract input specs."""
    from repro.launch.dryrun import _cell_applicable, input_specs

    cfg = get_config(arch)
    for shape_name, shape in SHAPES.items():
        if _cell_applicable(cfg, shape):
            continue  # documented skip
        specs = input_specs(arch, shape_name)
        leaves = jax.tree.leaves(specs)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves if hasattr(l, "shape"))
        if shape.kind in ("train", "prefill"):
            assert specs["batch"]["tokens"].shape[0] == shape.global_batch
        else:
            assert specs["tokens"].shape == (shape.global_batch, 1)


def test_long_500k_skips_exactly_the_full_attention_archs():
    from repro.launch.dryrun import _cell_applicable

    skipped = {
        a for a in ASSIGNED_ARCHS
        if _cell_applicable(get_config(a), SHAPES["long_500k"])
    }
    assert skipped == {
        "olmoe-1b-7b", "qwen2-moe-a2.7b", "granite-3-8b", "phi3-medium-14b",
        "qwen2-7b", "mistral-large-123b", "whisper-medium", "pixtral-12b",
    }
    assert {"rwkv6-1.6b", "zamba2-1.2b"}.isdisjoint(skipped)


# --- hlo_stats ------------------------------------------------------------------

HLO_SAMPLE = """
  %x = f32[16,1024]{1,0} parameter(0)
  %ar = f32[16,1024]{1,0} all-reduce(f32[16,1024]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[64,256]{1,0} all-gather(bf16[16,256]{1,0} %y), replica_groups={{0,1},{2,3}}, dimensions={0}
  %rs = f32[4,128]{1,0} reduce-scatter(f32[16,128]{1,0} %z), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[8,8]{1,0} collective-permute(f32[8,8]{1,0} %w), source_target_pairs={{0,1},{1,0}}
"""


def test_collective_stats_parsing():
    stats = collective_stats(HLO_SAMPLE)
    assert stats["all-reduce"]["count"] == 1
    # all-reduce: 2 * (n-1)/n * payload, n=4, payload=16*1024*4
    assert stats["all-reduce"]["link_bytes"] == pytest.approx(2 * 0.75 * 16 * 1024 * 4)
    # all-gather result bf16[64,256] -> 2 bytes, n=2 -> 0.5 multiplier
    assert stats["all-gather"]["link_bytes"] == pytest.approx(0.5 * 64 * 256 * 2)
    assert stats["reduce-scatter"]["count"] == 1
    assert stats["collective-permute"]["link_bytes"] == pytest.approx(8 * 8 * 4)


def test_collective_stats_skips_done_ops():
    text = "%d = f32[4]{0} all-reduce-done(f32[4]{0} %s)\n"
    assert collective_stats(text) == {}


# --- roofline math ----------------------------------------------------------------


def test_analyze_artifact_terms():
    art = {
        "status": "ok",
        "arch": "x",
        "shape": "train_4k",
        "mesh": "pod16x16",
        "kind": "train",
        "n_devices": 256,
        "flops_per_device": 1e12,
        "bytes_per_device": 1e11,
        "collective_link_bytes": 5e9,
        "n_active_params": 1e9,
        "n_params": 1e9,
        "tokens_per_step": 1_000_000,
    }
    r = analyze_artifact(art)
    assert r["t_compute_s"] == pytest.approx(1e12 / PEAK_FLOPS)
    assert r["t_memory_s"] == pytest.approx(1e11 / HBM_BW)
    assert r["t_collective_s"] == pytest.approx(5e9 / LINK_BW)
    assert r["dominant"] == "memory"
    assert r["model_flops"] == pytest.approx(6e15)
    assert r["roofline_fraction"] == pytest.approx(
        (6e15 / (256 * PEAK_FLOPS)) / r["t_memory_s"]
    )


def test_analyze_artifact_prefers_corrected():
    art = {
        "status": "ok", "arch": "x", "shape": "decode_32k", "mesh": "m", "kind": "decode",
        "n_devices": 256, "flops_per_device": 1.0, "bytes_per_device": 1.0,
        "collective_link_bytes": 1.0,
        "flops_per_device_corrected": 10.0, "bytes_per_device_corrected": 20.0,
        "collective_link_bytes_corrected": 30.0, "recurrence_bytes_analytic": 5.0,
        "n_active_params": 1, "tokens_per_step": 1,
    }
    r = analyze_artifact(art)
    assert r["t_compute_s"] == pytest.approx(10.0 / PEAK_FLOPS)
    assert r["t_memory_s"] == pytest.approx(25.0 / HBM_BW)
    assert r["t_collective_s"] == pytest.approx(30.0 / LINK_BW)


def test_analyze_artifact_skipped_is_none():
    assert analyze_artifact({"status": "skipped"}) is None


# --- production mesh (512 fake devices, subprocess) ------------------------------


@pytest.mark.slow
def test_production_meshes_subprocess():
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}, m1.shape
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}, m2.shape
        assert m2.size == 512
        print("OK")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_dryrun_cell_on_small_production_slice():
    """Full dry-run machinery on a 4x4=16-device mesh (fast CI analogue of
    the 256-chip pod): lower + compile + artifact fields present."""
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import json
        import repro.launch.mesh as mesh_mod
        import jax
        real = mesh_mod.make_production_mesh
        mesh_mod.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
            (4, 4), ("data", "model"))
        from repro.launch.dryrun import run_cell
        art = run_cell("rwkv6-1.6b", "decode_32k", probe=False, verbose=False)
        assert art["status"] == "ok", art
        for k in ("flops_per_device", "bytes_per_device", "collective_link_bytes",
                  "memory_analysis", "tokens_per_step"):
            assert k in art, k
        print("OK")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=560,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_all_sweep_artifacts_ok_or_documented_skip():
    """If the artifact sweep has been run, every cell must be ok/skipped."""
    base = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "artifacts")
    found = 0
    for mesh_dir in ("pod16x16", "pod2x16x16"):
        d = os.path.join(base, mesh_dir)
        if not os.path.isdir(d):
            continue
        for f in os.listdir(d):
            if not f.endswith(".json"):
                continue
            art = json.load(open(os.path.join(d, f)))
            assert art["status"] in ("ok", "skipped"), (f, art.get("error"))
            found += 1
    if found:
        assert found >= 80  # 40 cells x 2 meshes


@pytest.mark.slow
def test_probe_correction_matches_ground_truth():
    """The scan-cost probe (L=2,4 unrolled -> slope -> extrapolate) must
    reproduce the TRUE cost of a fully-unrolled model at full depth.
    Run on a 4x2=8-device mesh with a 6-layer reduced config."""
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax
        import repro.launch.mesh as mesh_mod
        mesh_mod.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
            (4, 2), ("data", "model"))
        from repro.configs import SHAPES, get_config
        from repro.launch.dryrun import (
            _cost_triple, _rules_for, build_lowered, probe_corrected_costs)

        cfg = dataclasses.replace(
            get_config("qwen2-7b").reduced(), num_layers=6)
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
        mesh = mesh_mod.make_production_mesh()
        rules = _rules_for(cfg, shape, mesh)
        probe = probe_corrected_costs(cfg, shape, mesh, rules)
        truth = _cost_triple(
            build_lowered(
                dataclasses.replace(cfg, scan_unroll=True), shape, mesh, rules
            ).compile()
        )
        rel = abs(probe["flops"] - truth["flops"]) / truth["flops"]
        assert rel < 0.05, (probe["flops"], truth["flops"], rel)
        rel_b = abs(probe["bytes"] - truth["bytes"]) / truth["bytes"]
        assert rel_b < 0.15, (probe["bytes"], truth["bytes"], rel_b)
        print("OK", rel, rel_b)
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_multipod_mesh_cell_with_pod_axis():
    """'pod' axis rules compose: lower+compile a decode cell on a tiny
    (pod=2, data=2, model=2) mesh."""
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import repro.launch.mesh as mesh_mod
        mesh_mod.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
            (2, 2, 2), ("pod", "data", "model"))
        from repro.launch.dryrun import run_cell
        art = run_cell("zamba2-1.2b", "decode_32k", multi_pod=True,
                       probe=False, verbose=False)
        assert art["status"] == "ok", art
        print("OK")
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
