"""SSD (Mamba2) chunked-vs-sequential equivalence + property tests.

ssd_chunked is the matmul-rich (MXU-friendly) form used for training;
ssd_scan is the sequential oracle.  They must agree for any shapes, chunk
boundaries, and decay magnitudes (the log-space trick keeps every exponent
<= 0, so no overflow for extreme dt/a values).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models.ssm import ssd_chunked, ssd_scan, ssd_step


def _inputs(b, t, h, p, n, seed, dt_scale=1.0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, t, h, p)).astype(np.float32))
    dt = jnp.asarray((rng.random((b, t, h)) * dt_scale + 0.01).astype(np.float32))
    a_log = jnp.asarray(rng.normal(size=(h,)).astype(np.float32) * 0.5)
    bmat = jnp.asarray(rng.normal(size=(b, t, n)).astype(np.float32))
    cmat = jnp.asarray(rng.normal(size=(b, t, n)).astype(np.float32))
    d_skip = jnp.asarray(rng.normal(size=(h,)).astype(np.float32))
    h0 = jnp.asarray(rng.normal(size=(b, h, p, n)).astype(np.float32) * 0.1)
    return x, dt, a_log, bmat, cmat, d_skip, h0


@pytest.mark.parametrize("t,chunk", [(16, 4), (16, 16), (20, 8), (7, 4), (64, 16)])
def test_chunked_equals_scan(t, chunk):
    args = _inputs(2, t, 3, 4, 5, seed=t * 31 + chunk)
    y_seq, h_seq = ssd_scan(*args)
    y_chk, h_chk = ssd_chunked(*args, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_seq), rtol=2e-4, atol=2e-4)


@given(
    b=st.integers(1, 3),
    t=st.integers(1, 24),
    h=st.integers(1, 4),
    p=st.integers(1, 6),
    n=st.integers(1, 6),
    chunk=st.sampled_from([2, 4, 8]),
)
@settings(max_examples=25, deadline=None)
def test_chunked_equals_scan_property(b, t, h, p, n, chunk):
    args = _inputs(b, t, h, p, n, seed=b + t * 7 + h * 11 + p * 13 + n * 17)
    y_seq, h_seq = ssd_scan(*args)
    y_chk, h_chk = ssd_chunked(*args, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_seq), rtol=5e-4, atol=5e-4)


def test_extreme_decay_no_overflow():
    """Large dt * a: decays underflow to 0 but never overflow/NaN."""
    args = _inputs(1, 32, 2, 3, 4, seed=0, dt_scale=50.0)
    y_chk, h_chk = ssd_chunked(*args, chunk=8)
    assert bool(jnp.all(jnp.isfinite(y_chk)))
    assert bool(jnp.all(jnp.isfinite(h_chk)))
    y_seq, h_seq = ssd_scan(*args)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq), rtol=1e-3, atol=1e-3)


def test_step_matches_scan_per_token():
    """Decode path: T applications of ssd_step == one ssd_scan."""
    b, t, h, p, n = 2, 6, 2, 3, 4
    x, dt, a_log, bmat, cmat, d_skip, h0 = _inputs(b, t, h, p, n, seed=5)
    y_seq, h_seq = ssd_scan(x, dt, a_log, bmat, cmat, d_skip, h0)
    hcur = h0
    ys = []
    for i in range(t):
        y_i, hcur = ssd_step(hcur, x[:, i], dt[:, i], a_log, bmat[:, i], cmat[:, i], d_skip)
        ys.append(y_i)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(ys, axis=1)), np.asarray(y_seq), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(np.asarray(hcur), np.asarray(h_seq), rtol=1e-4, atol=1e-4)


def test_state_carry_across_calls():
    """Splitting a sequence across two chunked calls == one call (streaming)."""
    b, t, h, p, n = 1, 24, 2, 4, 3
    x, dt, a_log, bmat, cmat, d_skip, h0 = _inputs(b, t, h, p, n, seed=9)
    y_full, h_full = ssd_chunked(x, dt, a_log, bmat, cmat, d_skip, h0, chunk=8)
    cut = 16
    y1, h1 = ssd_chunked(x[:, :cut], dt[:, :cut], a_log, bmat[:, :cut], cmat[:, :cut], d_skip, h0, chunk=8)
    y2, h2 = ssd_chunked(x[:, cut:], dt[:, cut:], a_log, bmat[:, cut:], cmat[:, cut:], d_skip, h1, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), rtol=2e-4, atol=2e-4)
