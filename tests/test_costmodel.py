"""Cost-model subsystem tests (DESIGN.md §13): model arithmetic, calibration
cache resilience, decision provenance, and the auto-resolution properties —
legality, determinism for a fixed calibration file, and bitwise parity with
explicitly pinned schedules."""

import dataclasses
import importlib
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# the package re-exports the calibrate() FUNCTION, which shadows the
# submodule attribute — import the module itself for its internals
cal = importlib.import_module("repro.costmodel.calibrate")
from repro.costmodel import choose as choose_mod
from repro.costmodel.model import (
    COST_MODEL_VERSION,
    CostCoefficients,
    default_coefficients,
    predict,
    predict_blocks_ms,
    repeat_amortization,
    structure_step_factor,
    terms_from_describe,
)
from repro.kernels import api
from repro.kernels.api import Epilogue, GemmSpec, GroupSpec, ShardSpec

from tests._hyp import given, settings, st

B = 8


@pytest.fixture(autouse=True)
def _isolated_costmodel_cache(tmp_path, monkeypatch):
    """Every test reads/writes a scratch calibration file — the repo-level
    `.costmodel_cache.json` must never be created or consulted by tests."""
    monkeypatch.setenv("REPRO_COSTMODEL_CACHE", str(tmp_path / "costmodel.json"))
    cal.clear_coefficients_memo()
    choose_mod.clear_decision_memo()
    yield
    cal.clear_coefficients_memo()
    choose_mod.clear_decision_memo()


def _axes(p=4):
    return (("x", p),)


# --- model arithmetic ---------------------------------------------------------


def test_coefficients_round_trip_and_unknown_keys():
    co = default_coefficients("cpu")
    d = co.as_dict()
    assert isinstance(d["backend_efficiency"], dict)  # JSON-friendly mapping
    back = CostCoefficients.from_dict({**d, "not_a_field": 1})
    assert back == co
    assert co.efficiency("xla") == 1.0
    assert co.efficiency("never_registered") == co.default_efficiency


def test_structure_step_factor_matches_exact_symmetric_counts():
    from repro.core.symmetries import symmetric_readout_steps

    assert structure_step_factor("general", 64) == 1.0
    assert structure_step_factor("scrambled", 64) == 1.0
    for n in (4, 16, 64, 128):
        assert structure_step_factor("symmetric", n) == (
            symmetric_readout_steps(n) / (2 * n - 1)
        )
    # beyond the exact range: the floor(3n/2) closed form, still < 1
    assert 0 < structure_step_factor("symmetric", 1024) < 0.76


def test_repeat_amortization_limits():
    n = 64
    assert repeat_amortization(1, n) == 1.0
    vals = [repeat_amortization(r, n) for r in (1, 2, 4, 8, 64)]
    assert vals == sorted(vals, reverse=True)  # monotone toward n/(2n-1)
    assert vals[-1] < 0.52


def test_terms_match_real_describe_records_and_roofline():
    from repro.launch.roofline import analyze_plan

    plans = [
        api.plan(GemmSpec(m=2 * B, k=B, n=B)),
        api.plan(GemmSpec(m=B, k=B, n=B, batch=(4,), batched_b=True)),
        api.plan(GemmSpec.for_groups(GroupSpec(4, B), k=B, n=B)),
    ]
    for p in plans:
        d = p.describe()
        t = terms_from_describe(d)
        rl = analyze_plan(d)
        # roofline consumes the SAME terms (single arithmetic owner)
        assert rl["terms"] == t
        assert rl["hbm_bytes"] == t["hbm_bytes"]
        assert rl["per_shard_flops"] == t["flops"]
        json.dumps(t)


def test_predict_prices_paper_structures():
    co = default_coefficients("cpu")
    n = 64
    gen = terms_from_describe(api.plan(GemmSpec(m=n, k=n, n=n)).describe())
    sym = dict(gen, structure="symmetric")
    assert predict(sym, co)["t_compute_s"] < predict(gen, co)["t_compute_s"]
    # repeats amortize compute AND the resident-B stream
    rep = dict(gen, repeats=8)
    assert predict(rep, co)["total_s"] < predict(gen, co)["total_s"]
    # the collective term is additive on top of max(compute, memory)
    coll = dict(gen, collective_bytes=10**9)
    out = predict(coll, co)
    assert out["t_collective_s"] == 10**9 / co.link_bytes_per_s
    assert out["total_s"] == pytest.approx(
        max(out["t_compute_s"], out["t_memory_s"]) + out["t_collective_s"]
    )


def test_predict_blocks_ms_prefers_divisible_blocks():
    co = default_coefficients("cpu")
    # exact tiling beats a pathological overhang (padded dead FLOPs)
    assert predict_blocks_ms(256, 256, 256, (128, 128, 128), co) < predict_blocks_ms(
        256, 256, 256, (129, 129, 129), co
    )


# --- calibration cache resilience --------------------------------------------


def test_calibration_cache_quarantines_corrupt_file(tmp_path):
    path = tmp_path / "cal.json"
    path.write_text("{not json")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cache = cal.CalibrationCache(path)
        assert cache.coefficients("cpu") is None
    assert any("unreadable" in str(x.message) for x in w)
    assert (tmp_path / "cal.json.corrupt").exists()
    # the quarantined store still works
    cache.set_coefficients(default_coefficients("cpu"))
    cache.save()
    assert cal.CalibrationCache(path).coefficients("cpu") is not None


def test_calibration_cache_drops_invalid_records_and_versions(tmp_path):
    path = tmp_path / "cal.json"
    good = {"terms": {"flops": 1000}, "ms": 1.0, "source": "probe"}
    path.write_text(
        json.dumps(
            {
                "version": cal.CALIBRATION_VERSION,
                "model_version": COST_MODEL_VERSION,
                "coefficients": {"cpu": {"flops_per_s": -1}},  # invalid
                "records": {"cpu": [good, {"ms": -3}, "junk"]},
            }
        )
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        cache = cal.CalibrationCache(path)
        assert cache.coefficients("cpu") is None  # invalid coefficients dropped
        assert cache.records("cpu") == [good]
    # unknown version: start clean, never steer plans with stale fits
    path.write_text(json.dumps({"version": 999, "coefficients": {"cpu": {}}}))
    assert cal.CalibrationCache(path).coefficients("cpu") is None


def test_fit_coefficients_is_deterministic_and_reduces_error():
    terms = terms_from_describe(api.plan(GemmSpec(m=64, k=64, n=64)).describe())
    # synthesize measurements from a ground truth 4x slower than defaults
    truth = dataclasses.replace(
        default_coefficients("cpu"), flops_per_s=2.5e10, hbm_bytes_per_s=5e9
    )
    records = []
    for scale in (1, 2, 4, 8):
        t = dict(terms)
        t["flops"] *= scale**3
        t["a_bytes"] *= scale**2
        t["b_bytes"] *= scale**2
        t["out_bytes"] *= scale**2
        t["hbm_bytes"] *= scale**2
        records.append({"terms": t, "ms": predict(t, truth)["total_s"] * 1e3})
    init = default_coefficients("cpu")
    fit1 = cal.fit_coefficients(records, init=init)
    fit2 = cal.fit_coefficients(records, init=init)
    assert fit1 == fit2 and fit1.source == "calibrated"
    assert cal._fit_error(records, fit1) < cal._fit_error(records, init)


def test_calibrate_round_trip_installs_coefficients(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_COSTMODEL_CACHE", str(tmp_path / "cc.json"))
    cal.clear_coefficients_memo()
    assert cal.current_coefficients().source == "default"
    got = cal.calibrate(shapes=((16, 16, 16), (64, 64, 64)))
    assert got.source == "calibrated"
    assert cal.current_coefficients() == got  # memo refreshed
    cal.clear_coefficients_memo()
    assert cal.current_coefficients() == got  # persisted + reloaded


def test_hillclimb_gemm_variant_writes_ingestible_records(tmp_path):
    from repro.launch.hillclimb import run_gemm_variant

    rec = run_gemm_variant("G0_tiny", out_dir=str(tmp_path), reps=1)
    assert cal._valid_record(rec) and rec["source"] == "hillclimb"
    on_disk = json.loads((tmp_path / "gemm__G0_tiny.json").read_text())
    assert cal._valid_record(on_disk)
    assert cal.ingest([rec]) == 1
    assert cal.current_coefficients().source == "calibrated"


# --- decisions ---------------------------------------------------------------


def test_auto_schedule_decision_provenance():
    spec = GemmSpec(m=16, k=32, n=8, shard=ShardSpec(_axes(4), axis_k="x"))
    sched, dec = choose_mod.decide_schedule(spec)
    assert sched == "reduce_scatter_k"
    d = dec.as_dict()
    json.dumps(d)
    assert d["chosen"] == "reduce_scatter_k"
    assert d["calibration"]["model_version"] == COST_MODEL_VERSION
    by_name = {c["name"]: c for c in d["candidates"]}
    assert by_name["reduce_scatter_k"]["legal"]
    assert by_name["ring_k"]["legal"]
    # rs moves 1/p of ring's bytes -> strictly cheaper prediction
    assert (
        by_name["reduce_scatter_k"]["predicted_s"] < by_name["ring_k"]["predicted_s"]
    )
    assert not by_name["replicated"]["legal"]
    assert not by_name["allgather_a"]["legal"]


def test_auto_matches_legacy_heuristic_with_default_coefficients():
    # the shipped zero-latency coefficients reproduce the legacy rule exactly
    cases = [
        (GemmSpec(m=16, k=32, n=8, shard=ShardSpec(_axes(4), axis_k="x")),
         "reduce_scatter_k"),
        (GemmSpec(m=6, k=32, n=8, shard=ShardSpec(_axes(4), axis_k="x")),
         "ring_k"),
        (GemmSpec(m=16, k=32, n=8, shard=ShardSpec(_axes(4), axis_m="x")),
         "replicated"),
        (GemmSpec(m=16, k=32, n=8, shard=ShardSpec(_axes(1))), "replicated"),
    ]
    for spec, want in cases:
        sched, *_ = api._resolve_sharding(spec)
        assert sched == want, (spec, sched, want)


def test_calibrated_latency_steers_auto_schedule(tmp_path, monkeypatch):
    """A calibration file with a large per-launch overhead flips the choice
    to ring_k (1 kernel invocation) over reduce_scatter_k (p invocations) —
    and the resolution is deterministic for the fixed file."""
    spec = GemmSpec(m=16, k=32, n=8, shard=ShardSpec(_axes(4), axis_k="x"))
    path = tmp_path / "steer.json"
    co = dataclasses.replace(default_coefficients("cpu"), launch_overhead_s=1.0)
    cache = cal.CalibrationCache(path)
    cache.set_coefficients(co)
    cache.save()
    monkeypatch.setenv("REPRO_COSTMODEL_CACHE", str(path))
    cal.clear_coefficients_memo()
    choose_mod.clear_decision_memo()
    picks = set()
    for _ in range(3):
        sched, dec = choose_mod.decide_schedule(spec)
        picks.add(sched)
        assert dec.as_dict()["calibration"]["source"] == "calibrated"
    assert picks == {"ring_k"}


def test_calibrated_link_admits_overlap_schedules(tmp_path, monkeypatch):
    """Under shipped (default) coefficients the overlap family never enters
    auto resolution; a CALIBRATED slow link flips the choice to the
    double-buffered twin, with the §15 max(compute, comm) pricing recorded
    in the decision provenance."""
    spec = GemmSpec(m=16, k=32, n=8, shard=ShardSpec(_axes(4), axis_k="x"))
    _, dec = choose_mod.decide_schedule(spec)
    names = [c["name"] for c in dec.as_dict()["candidates"]]
    assert not any(c.endswith("_overlap") or c == "pipeline" for c in names)

    path = tmp_path / "slowlink.json"
    co = dataclasses.replace(default_coefficients("cpu"), link_bytes_per_s=1e6)
    cache = cal.CalibrationCache(path)
    cache.set_coefficients(co)
    cache.save()
    monkeypatch.setenv("REPRO_COSTMODEL_CACHE", str(path))
    cal.clear_coefficients_memo()
    choose_mod.clear_decision_memo()
    sched, dec = choose_mod.decide_schedule(spec)
    # the collective term dominates; hiding it behind the kernel wins, and
    # reduce_scatter's byte model beats ring/pipeline at equal pricing
    assert sched == "reduce_scatter_k_overlap"
    cands = {c["name"]: c for c in dec.as_dict()["candidates"] if c.get("legal")}
    win, serial = cands[sched], cands["reduce_scatter_k"]
    assert win["overlap"] is True and serial["overlap"] is False
    assert win["pricing"] == "max(compute,memory,collective)+latency"
    assert serial["pricing"] == "max(compute,memory)+collective+latency"
    assert win["predicted_s"] < serial["predicted_s"]

    # the planner's auto path records the same chosen schedule
    api.clear_plan_cache()
    got, _, _, _, decision = api._resolve_sharding(spec)
    assert got == "reduce_scatter_k_overlap"
    assert decision["chosen"] == "reduce_scatter_k_overlap"


def test_decide_backend_ranks_capable_set():
    spec = GemmSpec(m=B, k=B, n=B)
    chosen, dec = choose_mod.decide_backend(
        spec, [("xla", 0), ("pallas_mesh", 1), ("ref", 2)]
    )
    assert chosen == "xla"  # efficiency 1.0 beats 0.05 / 0.01 on cpu
    names = [c["name"] for c in dec.as_dict()["candidates"]]
    assert names == ["xla", "pallas_mesh", "ref"]


def test_plan_records_backend_decision():
    api.clear_plan_cache()
    p = api.plan(GemmSpec(m=B, k=B, n=B))
    assert p.backend == "xla"
    d = p.describe()
    json.dumps(d)
    dec = d["decision"]["backend"]
    assert dec["chosen"] == "xla" and len(dec["candidates"]) >= 2
    # explicit backend: no decision to record
    assert api.plan(GemmSpec(m=B, k=B, n=B), backend="ref").decision is None


def test_spec_repeats_validated_and_in_provenance():
    with pytest.raises(ValueError, match="repeats"):
        GemmSpec(m=B, k=B, n=B, repeats=0)
    p = api.plan(GemmSpec(m=B, k=B, n=B, repeats=8), backend="xla")
    d = p.describe()
    assert d["repeats"] == 8
    assert terms_from_describe(d)["repeats"] == 8


# --- auto resolution properties ----------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=12),
    k_mult=st.integers(min_value=1, max_value=6),
    n=st.integers(min_value=1, max_value=12),
    p=st.sampled_from([2, 3, 4]),
    dim=st.sampled_from(["m", "k", "n"]),
)
def test_auto_never_selects_illegal_schedule(m, k_mult, n, p, dim):
    """Whatever (spec, shard axes) is drawn, auto either resolves to a
    schedule that passes the full divisibility validation when explicitly
    pinned, or raises PlanValidationError itself — it never silently picks
    an illegal schedule."""
    axes = (("x", p),)
    shard = ShardSpec(axes, **{f"axis_{dim}": "x"})
    spec = GemmSpec(m=m, k=k_mult * p, n=n, shard=shard)
    try:
        sched, local, bytes_moved, phases, _ = api._resolve_sharding(spec)
    except api.PlanValidationError:
        return  # no legal schedule for this draw: raising IS the contract
    assert sched in api.SCHEDULES
    pinned = dataclasses.replace(
        spec, shard=dataclasses.replace(shard, schedule=sched)
    )
    got = api._resolve_sharding(pinned)  # must not raise
    assert got[0] == sched and got[1] == local and got[2] == bytes_moved


def test_auto_plan_bitwise_equals_explicit_schedule():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    a = jnp.asarray(
        np.random.default_rng(0).normal(size=(2 * B, B)).astype(np.float32)
    )
    b = jnp.asarray(np.random.default_rng(1).normal(size=(B, B)).astype(np.float32))
    auto_spec = GemmSpec.from_operands(a, b, shard=ShardSpec.from_mesh(mesh, m="x"))
    p_auto = api.plan(auto_spec, mesh=mesh)
    chosen = p_auto.schedule
    explicit = GemmSpec.from_operands(
        a, b, shard=ShardSpec.from_mesh(mesh, m="x", schedule=chosen)
    )
    p_exp = api.plan(explicit, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(p_auto(a, b)), np.asarray(p_exp(a, b)))
    # provenance: the auto plan carries the decision, the pinned one doesn't
    assert (p_auto.describe().get("decision") or {}).get("schedule")
    assert (p_exp.describe().get("decision") or {}).get("schedule") is None


def test_auto_shard_is_deterministic_and_memoized():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
    spec = GemmSpec(m=2 * B, k=B, n=B)
    s1, d1 = choose_mod.decide_sharding(spec, mesh)
    s2, d2 = choose_mod.decide_sharding(spec, mesh)
    assert s1 == s2 and d1 is d2  # memo hit
    choose_mod.clear_decision_memo()
    s3, d3 = choose_mod.decide_sharding(spec, mesh)
    assert s3 == s1 and d3.as_dict()["chosen"] == d1.as_dict()["chosen"]


# --- roofline formatting (satellite) -----------------------------------------


def test_fmt_s_unit_ranges():
    from repro.launch.roofline import _fmt_s

    assert _fmt_s(2.5) == "2.50s"
    assert _fmt_s(1.0) == "1.00s"
    assert _fmt_s(0.0042) == "4.20ms"
    assert _fmt_s(1e-3) == "1.00ms"
    assert _fmt_s(2e-5) == "20.0us"
    assert _fmt_s(0.0) == "0.0us"


def test_render_markdown_rows_and_skips():
    from repro.launch.roofline import render_markdown

    rows = [
        {
            "arch": "a1", "shape": "s1", "t_compute_s": 0.5, "t_memory_s": 2e-3,
            "t_collective_s": 3e-6, "dominant": "compute", "useful_ratio": 0.5,
            "roofline_fraction": 0.25,
        },
        {"skip": True, "arch": "a2", "shape": "s2", "status": "oom",
         "reason": "too big"},
    ]
    md = render_markdown(rows, title="T")
    lines = md.strip().splitlines()
    assert lines[0] == "### T"
    assert lines[2].startswith("| arch | shape |")  # blank line after title
    assert "500.00ms" in md and "2.00ms" in md and "3.0us" in md
    assert "**compute**" in md
    assert "OOM" in md and "too big" in md
    # no title -> header first
    assert render_markdown(rows).startswith("| arch ")
