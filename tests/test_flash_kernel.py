"""Pallas flash-attention kernel vs the SDPA oracle (interpret=True).

Sweeps shapes, GQA ratios, block shapes, dtypes, causal on/off; also checks
the jnp chunked path (models/attention._sdpa_chunked) against the same
oracle — three implementations, one semantics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import _sdpa, _sdpa_chunked


def _qkv(b, t, h, kv, hd, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, t, h, hd)).astype(np.float32), dtype)
    k = jnp.asarray(rng.normal(size=(b, t, kv, hd)).astype(np.float32), dtype)
    v = jnp.asarray(rng.normal(size=(b, t, kv, hd)).astype(np.float32), dtype)
    return q, k, v


CASES = [
    # b, t, h, kv, hd, bq, bk
    (2, 64, 4, 4, 16, 16, 16),   # MHA
    (2, 64, 4, 2, 16, 16, 32),   # GQA rep=2
    (1, 128, 6, 2, 32, 32, 64),  # GQA rep=3
    (2, 64, 8, 1, 16, 64, 16),   # MQA
]


@pytest.mark.parametrize("b,t,h,kv,hd,bq,bk", CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel_vs_oracle(b, t, h, kv, hd, bq, bk, causal):
    q, k, v = _qkv(b, t, h, kv, hd, jnp.float32, seed=t + h)
    got = flash_attention_pallas(
        q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True
    )
    want = _sdpa(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_kernel_bf16():
    q, k, v = _qkv(2, 64, 4, 2, 16, jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=16, block_k=16, interpret=True)
    want = _sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2
    )


def test_flash_kernel_rejects_bad_blocks():
    q, k, v = _qkv(1, 64, 2, 2, 16, jnp.float32)
    with pytest.raises(ValueError):
        flash_attention_pallas(q, k, v, block_q=48, block_k=16, interpret=True)


@given(
    t_blocks=st.integers(1, 4),
    h=st.sampled_from([2, 4]),
    kv=st.sampled_from([1, 2]),
    causal=st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_flash_kernel_property(t_blocks, h, kv, causal):
    if h % kv:
        kv = 1
    t = 32 * t_blocks
    q, k, v = _qkv(1, t, h, kv, 16, jnp.float32, seed=t_blocks * 7 + h)
    got = flash_attention_pallas(
        q, k, v, causal=causal, block_q=32, block_k=32, interpret=True
    )
    want = _sdpa(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_chunked_jnp_path_vs_oracle(chunk):
    q, k, v = _qkv(2, 64, 4, 2, 16, jnp.float32, seed=chunk)
    for causal in (True, False):
        got = _sdpa_chunked(q, k, v, causal=causal, chunk=chunk)
        want = _sdpa(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_three_impls_agree_gqa():
    q, k, v = _qkv(2, 128, 8, 2, 32, jnp.float32, seed=9)
    a = _sdpa(q, k, v, causal=True)
    b = _sdpa_chunked(q, k, v, causal=True, chunk=32)
    c = flash_attention_pallas(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(a), rtol=2e-5, atol=2e-5)
