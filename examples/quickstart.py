"""Quickstart: the paper's mesh array in 60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.mesh_array import simulate_mesh, simulate_standard
from repro.core.scramble import (
    apply_scramble,
    cycle_decomposition,
    format_table,
    scramble_order,
    unscramble,
)
from repro.core.symmetries import paper_symmetric_bound, symmetric_readout_steps
from repro.kernels.mesh_matmul import mesh_matmul_pallas

n = 4
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
b = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))

# 1. the mesh array multiplies in 2n-1 steps (standard: 3n-2)
mesh = simulate_mesh(a, b)
std = simulate_standard(a, b)
print(f"mesh array steps: {mesh.steps} (2n-1)   standard: {std.steps} (3n-2)")

# 2. the output lands in the scrambled arrangement sigma_n (paper table):
print("\nsigma_4 arrangement (node (i,j) holds c_pq):")
print(format_table(4))
assert np.allclose(np.asarray(unscramble(mesh.output)), np.asarray(a @ b), atol=1e-5)
print("\nunscramble(mesh output) == A @ B  ✓")

# 3. S as a scrambling system: period 7 for n=4 (paper)
print(f"\norder(S_4) = {scramble_order(4)}; cycles: "
      f"{[len(c) for c in cycle_decomposition(4)]}")
x = jnp.arange(16, dtype=jnp.float32).reshape(4, 4)
assert np.allclose(np.asarray(apply_scramble(x, 7)), np.asarray(x))
print("S^7 = identity  ✓")

# 4. symmetric products finish early (paper: <= n+1+n/2)
for m in (4, 8, 16):
    print(f"n={m:3d}: symmetric readout at step {symmetric_readout_steps(m)}"
          f" (bound {paper_symmetric_bound(m)}, general {2*m-1})")

# 5. the TPU kernel (Pallas; interpret mode on CPU) — staggered k-schedule +
#    zero-cost scrambled output fused into the BlockSpec index_map
B = 8
a2 = jnp.asarray(rng.normal(size=(4 * B, 2 * B)).astype(np.float32))
b2 = jnp.asarray(rng.normal(size=(2 * B, 4 * B)).astype(np.float32))
out = mesh_matmul_pallas(a2, b2, block_m=B, block_n=B, block_k=B,
                         scramble_out=True, interpret=True)
from repro.kernels.ref import mesh_matmul_ref

assert np.allclose(np.asarray(out), np.asarray(mesh_matmul_ref(a2, b2, block_m=B, block_n=B)), atol=1e-4)
print("\nPallas mesh-matmul kernel (scrambled output) == oracle  ✓")

# 6. the plan/execute operator API: describe the GEMM once (typed spec,
#    including the paper regime via `structure`), plan once (backend chosen by
#    capability, blocks autotuned, σ table precomputed host-side), execute
#    per request via the cached jitted callable
from repro.kernels import api

spec = api.GemmSpec.from_operands(a2, b2, structure="scrambled",
                                  blocks=(B, B, B))
p = api.plan(spec)                      # picks a scramble-capable backend
print(f"\nplanned: backend={p.backend} blocks={p.blocks} "
      f"flops={p.flops:.2e} vmem={p.vmem_bytes}B")
assert np.array_equal(np.asarray(p(a2, b2)), np.asarray(out))
assert api.plan(spec) is p              # plan cache: same spec, same object
print("plan/execute (structure='scrambled') == fused kernel, plan cached  ✓")
