"""Batched serving demo: prefill + greedy decode across model families
(dense KV cache, MoE, RWKV O(1) state, Zamba2 hybrid state).

Every projection runs through the plan/execute API: the first trace of each
family plans its GEMM shapes once, later requests (and repeat shapes across
families) hit the process-wide plan cache — the report at the end shows one
plan per (spec, backend) pair.

  PYTHONPATH=src python examples/serve_demo.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.serve import generate, report_plan_cache
from repro.models import get_model

for arch in ("qwen2-7b", "olmoe-1b-7b", "rwkv6-1.6b", "zamba2-1.2b"):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size
    ).astype(jnp.int32)
    out, rate = generate(model, params, prompts, gen_len=8)
    print(f"{arch:16s} family={cfg.family:7s} generated {out.shape} "
          f"@ {rate:6.1f} steps/s — row0: {list(map(int, out[0]))}")

report_plan_cache(prefix="[demo]")
