"""End-to-end driver: train a small LM for a few hundred steps on CPU with
the full production stack (config system, data pipeline, AdamW + schedule,
atomic checkpointing, fault-tolerant loop, auto-resume).

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import tempfile

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.launch.train import build_trainer
from repro.train.loop import LoopConfig, train_loop
from repro.train.metrics import MetricsLogger


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen2-7b")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    step_fn, state, data = build_trainer(
        cfg, batch=16, seq=128, lr=1e-3, total_steps=args.steps
    )
    ckpt_dir = tempfile.mkdtemp(prefix="mesh_repro_ckpt_")
    ckpt = CheckpointManager(ckpt_dir, keep_n=2)
    logger = MetricsLogger()
    print(f"training {args.arch} (reduced) for {args.steps} steps; ckpts -> {ckpt_dir}")

    state = train_loop(
        step_fn,
        state,
        data,
        LoopConfig(total_steps=args.steps, ckpt_every=100, log_every=25),
        ckpt=ckpt,
        logger=logger,
    )
    first = logger.history[0]["loss"]
    last = logger.history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'LEARNING' if last < first else 'NOT LEARNING'})")
    print(f"checkpoints: {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
