"""The mesh array as a scrambling (privacy) system — paper §Scrambling.

Demonstrates:
  * S^k as a keyed permutation cipher on an image-like matrix (key = k,
    key space = Z_order(S)),
  * the paper's period (order) values and how fast order(S_n) grows,
  * wrong-key decryption failing, right-key succeeding,
  * block-granularity scrambling via the Pallas schedule (zero-copy on TPU).

  PYTHONPATH=src python examples/scrambling_demo.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.scramble import (
    apply_scramble,
    apply_scramble_power,
    scramble_order,
    unscramble,
)
from repro.kernels.ops import scramble_blocks

# "image": a 16x16 gradient with a diagonal watermark
n = 16
img = np.add.outer(np.arange(n), np.arange(n)).astype(np.float32)
np.fill_diagonal(img, 99.0)
x = jnp.asarray(img)

order = scramble_order(n)
print(f"order(S_{n}) = {order}  (key space for the keyed scrambler)")
for m in (3, 4, 5, 8, 12, 16, 20, 24):
    print(f"  order(S_{m:2d}) = {scramble_order(m)}")

key = 12345 % order
enc = apply_scramble(x, key)
print(f"\nencrypted with key k={key}: corner 4x4 =\n{np.asarray(enc)[:4, :4]}")

dec_ok = apply_scramble(enc, -key)
dec_bad = apply_scramble(enc, -(key + 1))
print(f"\nright key recovers image: {bool(jnp.all(dec_ok == x))}")
print(f"wrong key recovers image: {bool(jnp.all(dec_bad == x))}")

# runtime-keyed variant (k is a traced value -> serving-friendly)
k_traced = jnp.int32(key)
enc2 = apply_scramble_power(x, k_traced, n)
assert bool(jnp.all(enc2 == enc))
print("traced-key scrambler matches static-key scrambler ✓")

# block-granularity S via the Pallas copy kernel (the TPU-native form: the
# permutation lives in the BlockSpec index_map — zero extra data movement)
g, blk = 4, 8
big = jnp.asarray(np.random.default_rng(0).normal(size=(g * blk, g * blk)).astype(np.float32))
enc_blk = scramble_blocks(big, block_m=blk, block_n=blk, k=3)
dec_blk = scramble_blocks(enc_blk, block_m=blk, block_n=blk, k=-3)
assert bool(jnp.all(dec_blk == big))
print(f"block-granularity S^3 / S^-3 roundtrip on a {g}x{g} grid of "
      f"{blk}x{blk} blocks ✓")

print("\nNOTE (paper + DESIGN.md): S_n alone is a fixed public permutation —"
      "\nthe keyed system uses k in Z_order(S); order grows with n but the"
      "\ncipher remains a permutation cipher (demo, not production crypto).")
