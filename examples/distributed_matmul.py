"""The mesh array on the ICI torus: distributed systolic (Cannon) matmul
with shard_map + ppermute, overlapped ring collectives, the phase-count
arithmetic that mirrors the paper's 2n-1 vs 3n-2 step saving — and the
sharding-aware plan/execute API that packages all of it: a GemmSpec with a
ShardSpec plans to a ShardedPlan whose collective schedule wraps the
per-shard kernel.

Relaunches itself with 4 virtual CPU devices if only 1 is present.

  PYTHONPATH=src python examples/distributed_matmul.py
"""

import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_local_mesh
from repro.parallel.collectives import ring_allgather_matmul
from repro.parallel.sharding import shard_map
from repro.parallel.systolic import phase_counts, systolic_matmul

print(f"devices: {jax.device_count()}")
mesh = make_local_mesh((2, 2), ("data", "model"))
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
b = jnp.asarray(rng.normal(size=(32, 48)).astype(np.float32))

# Cannon's algorithm = the paper's mesh array at block/device granularity;
# on a switched torus the skew alignment is ONE collective-permute.
c = systolic_matmul(a, b, mesh=mesh, axes=("data", "model"))
assert np.allclose(np.asarray(c), np.asarray(a @ b), atol=1e-4)
print("systolic (Cannon) matmul over 2x2 device mesh == A @ B ✓")

for p in (2, 4, 16):
    pc = phase_counts(p)
    print(f"  p={p:2d}: switched-torus phases {pc['switched_phases']:3d} vs naive "
          f"{pc['naive_phases']:3d}   (paper: mesh {pc['paper_mesh_steps']} vs "
          f"standard {pc['paper_standard_steps']})")

# Overlapped ring collective (TP building block): all_gather fused into the
# partial matmuls — the 1D-ring form of the same overlap idea.
mesh1d = make_local_mesh((4,), ("model",))
x = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(8, 12)).astype(np.float32))
f = jax.jit(
    shard_map(
        lambda xb, wb: ring_allgather_matmul(xb, wb, "model"),
        mesh=mesh1d,
        in_specs=(P("model", None), P()),
        out_specs=P(),
        check_vma=False,
    )
)
assert np.allclose(np.asarray(f(x, w)), np.asarray(x @ w), atol=1e-4)
print("ring all-gather matmul (comm/compute overlapped) == X @ W ✓")

# The sharding-aware plan/execute API: one planner covers unsharded and
# sharded specs — a ShardSpec picks the device-mesh partition, plan() picks
# the collective schedule and lowers the per-shard kernel through shard_map.
from repro.kernels import api

a4 = jnp.asarray(rng.integers(-4, 5, size=(64, 32)).astype(np.float32))
b4 = jnp.asarray(rng.integers(-4, 5, size=(32, 48)).astype(np.float32))
baseline = api.plan(api.GemmSpec.from_operands(a4, b4))(a4, b4)
for shard in (
    api.ShardSpec.unsharded(mesh1d),                      # degenerate, same path
    api.ShardSpec.from_mesh(mesh1d, m="model"),           # DP rows, no collective
    api.ShardSpec.from_mesh(mesh1d, m="model", schedule="allgather_a"),
    api.ShardSpec.from_mesh(mesh1d, k="model", schedule="reduce_scatter_k"),
    api.ShardSpec.from_mesh(mesh1d, k="model", schedule="ring_k"),  # 2n-1 feed
):
    p = api.plan(
        api.GemmSpec.from_operands(a4, b4, shard=shard), mesh=mesh1d
    )
    out = p(a4, b4)
    assert np.array_equal(np.asarray(out), np.asarray(baseline))
    sh = p.describe()["sharding"]
    print(
        f"ShardedPlan schedule={p.schedule:17s} phases={sh['collective_phases']}"
        f" bytes_moved={sh['bytes_moved']:6d}  == unsharded plan bit-for-bit ✓"
    )
