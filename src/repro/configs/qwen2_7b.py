"""Qwen2-7B [arXiv:2407.10671; hf] — dense GQA with QKV bias."""

from repro.configs.base import ArchConfig, register


@register
def qwen2_7b() -> ArchConfig:
    return ArchConfig(
        arch_id="qwen2-7b",
        family="dense",
        source="arXiv:2407.10671; hf",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        supports_long_context=False,
    )
