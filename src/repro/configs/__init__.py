"""Published architecture configs (import side-effect: registration)."""

from repro.configs.base import ArchConfig, CONFIGS, SHAPES, ShapeSpec, get_config

# Registration imports — one module per assigned architecture + the paper's own.
from repro.configs import (  # noqa: F401
    granite_3_8b,
    mesh_paper,
    mistral_large_123b,
    olmoe_1b_7b,
    phi3_medium_14b,
    pixtral_12b,
    qwen2_7b,
    qwen2_moe_a27b,
    rwkv6_1b6,
    whisper_medium,
    zamba2_1b2,
)

ASSIGNED_ARCHS = (
    "olmoe-1b-7b",
    "qwen2-moe-a2.7b",
    "granite-3-8b",
    "phi3-medium-14b",
    "qwen2-7b",
    "mistral-large-123b",
    "rwkv6-1.6b",
    "whisper-medium",
    "zamba2-1.2b",
    "pixtral-12b",
)

__all__ = [
    "ArchConfig",
    "ShapeSpec",
    "SHAPES",
    "CONFIGS",
    "get_config",
    "ASSIGNED_ARCHS",
]
