"""Phi-3-medium 14B [arXiv:2404.14219] — dense, RoPE SwiGLU GQA."""

from repro.configs.base import ArchConfig, register


@register
def phi3_medium_14b() -> ArchConfig:
    return ArchConfig(
        arch_id="phi3-medium-14b",
        family="dense",
        source="arXiv:2404.14219; unverified",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=10,
        d_ff=17920,
        vocab_size=100352,
        rope_theta=10_000.0,
        supports_long_context=False,
    )
