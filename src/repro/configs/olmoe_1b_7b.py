"""OLMoE-1B-7B [arXiv:2409.02060; hf] — 64-expert top-8 MoE."""

from repro.configs.base import ArchConfig, register


@register
def olmoe_1b_7b() -> ArchConfig:
    return ArchConfig(
        arch_id="olmoe-1b-7b",
        family="moe",
        source="arXiv:2409.02060; hf",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1024,  # dense d_ff unused (no shared experts); kept for reference
        vocab_size=50304,
        num_experts=64,
        num_experts_per_tok=8,
        num_shared_experts=0,
        moe_d_ff=1024,
        rope_theta=10_000.0,
        supports_long_context=False,
    )
