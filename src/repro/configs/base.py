"""Architecture config system.

One frozen dataclass covers all 10 assigned architectures (dense / MoE / SSM /
hybrid / enc-dec audio / VLM).  Every published config file under
`repro/configs/` instantiates `ArchConfig` with the exact paper/HF numbers and
registers it; `reduced()` derives the CPU-smoke variant used by per-arch tests
(same family and code paths, tiny dims).

Shapes are separate (`ShapeSpec`): the four assigned input-shape cells plus
smoke shapes.  `launch/dryrun.py` iterates CONFIGS x SHAPES.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "register", "get_config", "CONFIGS"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    source: str  # citation tag from the assignment table

    # transformer backbone
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # expert hidden dim (d_ff above = dense fallback/shared)
    router_aux_coef: float = 0.01

    # SSM / RWKV / hybrid
    ssm_state_size: int = 0
    ssm_conv_dim: int = 4
    ssm_num_heads: int = 0  # mamba2 heads (d_inner / head_p)
    ssm_expand: int = 2
    shared_attn_period: int = 0  # zamba2: shared attn block after every k SSM layers

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    dec_layers: int = 0
    dec_ratio: int = 8  # decoder len = enc len // dec_ratio for assigned shapes

    # VLM (pixtral)
    num_stub_patches: int = 0  # stub ViT frontend: precomputed patch embeddings

    # capability flags (drive which shape cells lower — DESIGN.md §5/§6)
    supports_long_context: bool = False  # sub-quadratic path for long_500k
    has_decode: bool = True

    # numerics / schedule levers (hillclimb knobs)
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    remat_policy: str = "dots"  # none | dots | full
    use_mesh_kernel: bool = False  # route GEMMs through the Pallas mesh kernel
    mesh_block_m: int = 0  # kernel block shape overrides; 0 = resolve via the
    mesh_block_n: int = 0  # persistent autotune cache (kernels/autotune.py,
    mesh_block_k: int = 0  # DESIGN.md §3)
    fused_dense_epilogue: bool = True  # bias+activation+residual inside the
    # kernel's final-k flush (DESIGN.md §3); False = separate XLA ops (A/B lever)
    scramble_privacy: bool = False  # apply S to activations (scrambling system)
    scan_unroll: bool = False  # unroll layer scans (cost-probe lowering only:
    # XLA cost_analysis counts a while body ONCE, so roofline probes lower
    # reduced-depth UNROLLED variants and fit the per-layer slope — launch/dryrun.py)
    attn_chunk: int = 0  # >0: flash-style chunked attention (KV-chunk online
    # softmax) for train/prefill — kills the O(S^2) score materialization
    vocab_pad_multiple: int = 0  # pad embedding/lm_head rows so the vocab dim
    # divides the TP axis (padded logits are masked out of loss/argmax)
    wkv_chunked: bool = False  # rwkv6: chunk-parallel GEMM-form WKV (exact)
    # instead of the faithful per-token scan — see models/rwkv._wkv_chunked
    wkv_chunk: int = 16
    grad_accum: int = 1  # microbatch gradient accumulation (train_step scan)
    # — bounds activation/remat residency per pass; used to FIT large cells

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.activation_dtype)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def n_params_dense_blocks(self) -> int:
        """Rough parameter count (reported in DESIGN/EXPERIMENTS tables)."""
        d, L = self.d_model, self.num_layers
        hd = self.head_dim_
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        if self.is_moe:
            ff = 3 * d * self.moe_d_ff * self.num_experts
            ff += 3 * d * self.d_ff * self.num_shared_experts
        else:
            ff = 3 * d * self.d_ff
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ff) + emb

    def n_active_params(self) -> int:
        """Active-per-token params (MoE: only routed top-k + shared)."""
        if not self.is_moe:
            return self.n_params_dense_blocks()
        d, L = self.d_model, self.num_layers
        hd = self.head_dim_
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        ff = 3 * d * self.moe_d_ff * self.num_experts_per_tok
        ff += 3 * d * self.d_ff * self.num_shared_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ff) + emb

    def tuned(self, tp: int = 16) -> "ArchConfig":
        """Beyond-paper production tuning (EXPERIMENTS.md §Perf, applied
        across the board): flash-style chunked attention, vocab padding when
        the vocab doesn't divide TP, chunk-parallel WKV for rwkv.  Sharding
        rule upgrades (FSDP/SP/seq_attn) live in launch/dryrun._rules_for."""
        kw: dict = {}
        if self.family != "ssm":  # every attention-bearing family
            kw["attn_chunk"] = 1024
        if self.vocab_size % tp:
            kw["vocab_pad_multiple"] = 256
        if self.family == "ssm":
            kw["wkv_chunked"] = True
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """CPU-smoke variant: same family/code paths, tiny dims."""
        kv = max(1, min(self.num_kv_heads, 2))
        heads = max(kv, min(self.num_heads, 4))
        return dataclasses.replace(
            self,
            num_layers=min(self.num_layers, 2),
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_experts=min(self.num_experts, 8) if self.is_moe else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2) if self.is_moe else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_d_ff=64 if self.is_moe else 0,
            ssm_state_size=min(self.ssm_state_size, 16) if self.ssm_state_size else 0,
            ssm_num_heads=min(self.ssm_num_heads, 4) if self.ssm_num_heads else 0,
            shared_attn_period=2 if self.shared_attn_period else 0,
            enc_layers=min(self.enc_layers, 2),
            dec_layers=min(self.dec_layers, 2),
            num_stub_patches=min(self.num_stub_patches, 8),
            param_dtype="float32",
            activation_dtype="float32",
            remat_policy="none",
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "long_decode"),
}

CONFIGS: Dict[str, Callable[[], ArchConfig]] = {}


def register(fn: Callable[[], ArchConfig]) -> Callable[[], ArchConfig]:
    cfg = fn()
    CONFIGS[cfg.arch_id] = fn
    return fn


def get_config(arch_id: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (triggers registration of all archs)

    if arch_id not in CONFIGS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(CONFIGS)}")
    return CONFIGS[arch_id]()
