"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892] — attention-free, data-dependent decay.

O(1)-state decode => long_500k runs (DESIGN.md §5).
"""

from repro.configs.base import ArchConfig, register


@register
def rwkv6_1b6() -> ArchConfig:
    return ArchConfig(
        arch_id="rwkv6-1.6b",
        family="ssm",
        source="arXiv:2404.05892; unverified",
        num_layers=24,
        d_model=2048,
        num_heads=32,  # wkv heads = d_model / head_dim(64)
        num_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65536,
        ssm_state_size=64,  # per-head KxV state (head_dim x head_dim)
        supports_long_context=True,
    )
