"""The paper's own 'architecture': a pure mesh-array matmul workload config.

Not one of the 10 assigned archs — used by examples/benchmarks to exercise
the kernel + distributed systolic path at representative GEMM sizes.
"""

import dataclasses

from repro.configs.base import ArchConfig, register


@register
def mesh_paper() -> ArchConfig:
    return ArchConfig(
        arch_id="mesh-paper",
        family="dense",
        source="Kak 2010 (this paper)",
        num_layers=4,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=32768,
        use_mesh_kernel=True,
        scramble_privacy=True,
        supports_long_context=False,
    )
