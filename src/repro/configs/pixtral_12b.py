"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409] — mistral-nemo backbone;
pixtral-ViT frontend stubbed (input_specs supplies precomputed patch embeddings)."""

from repro.configs.base import ArchConfig, register


@register
def pixtral_12b() -> ArchConfig:
    return ArchConfig(
        arch_id="pixtral-12b",
        family="vlm",
        source="hf:mistralai/Pixtral-12B-2409; unverified",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        num_stub_patches=256,  # stub ViT: 256 patch embeddings prepended
        rope_theta=1_000_000.0,
        supports_long_context=False,
    )
