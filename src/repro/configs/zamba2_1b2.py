"""Zamba2-1.2B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attention block.

Hybrid: O(1)-state SSM decode with periodic shared-weight attention blocks
(own KV cache per application) => long_500k runs with seq-sharded KV.
"""

from repro.configs.base import ArchConfig, register


@register
def zamba2_1b2() -> ArchConfig:
    return ArchConfig(
        arch_id="zamba2-1.2b",
        family="hybrid",
        source="arXiv:2411.15242; hf",
        num_layers=38,  # mamba2 blocks
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,  # shared block MLP hidden
        vocab_size=32000,
        ssm_state_size=64,
        ssm_num_heads=64,  # d_inner(4096) / head_p(64)
        ssm_expand=2,
        shared_attn_period=6,  # shared attn block after every 6 mamba layers
        supports_long_context=True,
    )
