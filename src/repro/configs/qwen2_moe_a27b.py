"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 4 shared + 60 routed top-4."""

from repro.configs.base import ArchConfig, register


@register
def qwen2_moe_a27b() -> ArchConfig:
    return ArchConfig(
        arch_id="qwen2-moe-a2.7b",
        family="moe",
        source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5632,  # shared-expert hidden (4 shared experts of 1408 fused = 5632)
        vocab_size=151936,
        num_experts=60,
        num_experts_per_tok=4,
        num_shared_experts=4,
        moe_d_ff=1408,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        supports_long_context=False,
    )
