"""Granite-3 8B [hf:ibm-granite/granite-3.0 family] — dense GQA."""

from repro.configs.base import ArchConfig, register


@register
def granite_3_8b() -> ArchConfig:
    return ArchConfig(
        arch_id="granite-3-8b",
        family="dense",
        source="hf:ibm-granite/granite-3.0-2b-base; hf",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=12800,
        vocab_size=49155,
        rope_theta=10_000.0,
        tie_embeddings=True,
        supports_long_context=False,
    )
