"""Whisper-medium [arXiv:2212.04356] — enc-dec; conv frontend stubbed
(input_specs supplies precomputed frame embeddings, per the assignment)."""

from repro.configs.base import ArchConfig, register


@register
def whisper_medium() -> ArchConfig:
    return ArchConfig(
        arch_id="whisper-medium",
        family="audio",
        source="arXiv:2212.04356; unverified",
        num_layers=24,  # total transformer blocks (12 enc + 12 dec at 'medium' scale x2)
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        is_encoder_decoder=True,
        enc_layers=24,
        dec_layers=24,
        dec_ratio=8,  # assigned shapes: dec_len = seq_len // 8 (enc frames = seq_len)
        rope_theta=10_000.0,  # backbone uses RoPE in this framework (adaptation note)
        supports_long_context=False,
    )
