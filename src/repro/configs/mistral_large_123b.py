"""Mistral-Large 123B [hf:mistralai/Mistral-Large-Instruct-2407] — dense GQA."""

from repro.configs.base import ArchConfig, register


@register
def mistral_large_123b() -> ArchConfig:
    return ArchConfig(
        arch_id="mistral-large-123b",
        family="dense",
        source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
        num_layers=88,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=32768,
        head_dim=128,
        rope_theta=1_000_000.0,
        supports_long_context=False,
    )
