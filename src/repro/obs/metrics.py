"""Typed metrics registry: counters, gauges, and log-spaced histograms.

Replaces the one-off accounting dicts in serve/scheduler with Prometheus-
shaped instruments (DESIGN.md §14).  Everything is stdlib-only, thread-safe
under one registry lock, and cheap enough to stay on unconditionally —
unlike spans, metric increments carry no payload and need no off-switch.

Instruments are label-aware: ``counter("x", labels=("site",)).inc(site="a")``
keeps one series per label-value tuple, exactly the Prometheus data model
`export.prometheus_text` renders.  Histograms use a FIXED log-spaced bucket
ladder (1us .. ~2min, base 2) so latency distributions from different runs
are always bucket-compatible and diffable.
"""

from __future__ import annotations

import bisect
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS_S",
    "Registry",
    "REGISTRY",
    "counter",
    "gauge",
    "histogram",
    "reset",
    "snapshot",
]

# 1us -> ~134s in 28 base-2 rungs: wide enough for a plan dispatch and a
# full drain, fixed so every exported histogram is cross-run comparable.
LATENCY_BUCKETS_S: Tuple[float, ...] = tuple(1e-6 * (2.0 ** i) for i in range(28))


def _label_key(labelnames: Tuple[str, ...], labels: Dict[str, Any]) -> Tuple[str, ...]:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"labels {sorted(labels)} do not match declared {sorted(labelnames)}"
        )
    return tuple(str(labels[n]) for n in labelnames)


class _Instrument:
    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str],
                 lock: threading.Lock):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock

    def _check_compatible(self, kind: str, labelnames: Sequence[str]) -> None:
        if kind != self.kind or tuple(labelnames) != self.labelnames:
            raise TypeError(
                f"metric {self.name!r} already registered as {self.kind}"
                f"{self.labelnames}, requested {kind}{tuple(labelnames)}"
            )


class Counter(_Instrument):
    """Monotonic float counter; one series per label-value tuple."""

    kind = "counter"

    def __init__(self, name, help, labelnames, lock):
        super().__init__(name, help, labelnames, lock)
        self._series: Dict[Tuple[str, ...], float] = {}

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease ({amount})")
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels: Any) -> float:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            return self._series.get(key, 0.0)

    def total(self) -> float:
        with self._lock:
            return sum(self._series.values())

    def series(self) -> Dict[Tuple[str, ...], float]:
        with self._lock:
            return dict(self._series)


class Gauge(_Instrument):
    """Last-write-wins float; `inc` allows signed adjustments."""

    kind = "gauge"

    def __init__(self, name, help, labelnames, lock):
        super().__init__(name, help, labelnames, lock)
        self._series: Dict[Tuple[str, ...], float] = {}

    def set(self, value: float, **labels: Any) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._series[key] = float(value)

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels: Any) -> float:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            return self._series.get(key, 0.0)

    def series(self) -> Dict[Tuple[str, ...], float]:
        with self._lock:
            return dict(self._series)


class Histogram(_Instrument):
    """Fixed-bucket histogram (cumulative counts in exposition, per-bucket
    internally).  `quantile` interpolates within the winning bucket — good
    enough for p50/p99 reporting, exact enough to rank plans."""

    kind = "histogram"

    def __init__(self, name, help, labelnames, lock,
                 buckets: Sequence[float] = LATENCY_BUCKETS_S):
        super().__init__(name, help, labelnames, lock)
        b = tuple(sorted(float(x) for x in buckets))
        if not b:
            raise ValueError("histogram needs at least one bucket bound")
        self.buckets = b
        # per series: [counts per bucket] + [overflow], sum, count
        self._series: Dict[Tuple[str, ...], List[Any]] = {}

    def _check_compatible(self, kind, labelnames):
        super()._check_compatible(kind, labelnames)

    def observe(self, value: float, **labels: Any) -> None:
        key = _label_key(self.labelnames, labels)
        idx = bisect.bisect_left(self.buckets, value)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = [[0] * (len(self.buckets) + 1), 0.0, 0]
            s[0][idx] += 1
            s[1] += value
            s[2] += 1

    def count(self, **labels: Any) -> int:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            s = self._series.get(key)
            return int(s[2]) if s else 0

    def sum(self, **labels: Any) -> float:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            s = self._series.get(key)
            return float(s[1]) if s else 0.0

    def quantile(self, q: float, **labels: Any) -> Optional[float]:
        """Approximate q-quantile (0..1) or None for an empty series."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        key = _label_key(self.labelnames, labels)
        with self._lock:
            s = self._series.get(key)
            if s is None or s[2] == 0:
                return None
            counts, total = list(s[0]), s[2]
        rank = q * total
        seen = 0.0
        for i, c in enumerate(counts):
            seen += c
            if seen >= rank and c:
                hi = self.buckets[i] if i < len(self.buckets) else self.buckets[-1]
                lo = self.buckets[i - 1] if 0 < i <= len(self.buckets) else 0.0
                frac = (rank - (seen - c)) / c
                return lo + (hi - lo) * max(0.0, min(1.0, frac))
        return self.buckets[-1]

    def series(self) -> Dict[Tuple[str, ...], Dict[str, Any]]:
        with self._lock:
            return {
                k: {"buckets": list(s[0]), "sum": s[1], "count": s[2]}
                for k, s in self._series.items()
            }


class Registry:
    """Get-or-create instrument registry (idempotent; kind-checked)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Instrument] = {}

    def _get_or_create(self, cls, name, help, labels, **kw):
        with self._lock:
            got = self._metrics.get(name)
        if got is not None:
            got._check_compatible(cls.kind, labels)
            return got
        inst = cls(name, help, tuple(labels), self._lock, **kw)
        with self._lock:
            # lost a race: keep the first registration
            return self._metrics.setdefault(name, inst)

    def counter(self, name: str, help: str = "", labels: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", labels: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS_S) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels, buckets=buckets)

    def metrics(self) -> List[_Instrument]:
        with self._lock:
            return sorted(self._metrics.values(), key=lambda m: m.name)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """JSON-able view: {name: {kind, labels, series}} with label tuples
        flattened to 'k=v,k=v' strings."""
        out: Dict[str, Dict[str, Any]] = {}
        for m in self.metrics():
            series = {
                ",".join(f"{n}={v}" for n, v in zip(m.labelnames, key)): val
                for key, val in m.series().items()
            }
            out[m.name] = {
                "kind": m.kind,
                "help": m.help,
                "labels": list(m.labelnames),
                "series": series,
            }
        return out

    def reset(self) -> None:
        """Test hook: drop every instrument."""
        with self._lock:
            self._metrics.clear()


REGISTRY = Registry()


def counter(name: str, help: str = "", labels: Sequence[str] = ()) -> Counter:
    return REGISTRY.counter(name, help, labels)


def gauge(name: str, help: str = "", labels: Sequence[str] = ()) -> Gauge:
    return REGISTRY.gauge(name, help, labels)


def histogram(name: str, help: str = "", labels: Sequence[str] = (),
              buckets: Sequence[float] = LATENCY_BUCKETS_S) -> Histogram:
    return REGISTRY.histogram(name, help, labels, buckets)


def snapshot() -> Dict[str, Dict[str, Any]]:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()
