"""Observability subsystem (DESIGN.md §14): spans, metrics, exports, bridge.

Quickstart::

    from repro import obs

    obs.enable()                      # tracing is OFF by default
    with obs.span("plan.execute", backend="xla"):
        ...
    obs.counter("served_total").inc()
    print(obs.prometheus_text())
    obs.write_chrome_trace("trace.json")   # load in chrome://tracing

Span names follow the `layer.verb` convention (plan.build, plan.execute,
autotune.measure, serve.tick, serve.decode, calibrate.ingest, ...).
"""

from repro.obs.bridge import (
    calibration_stamp,
    flush_calibration,
    install,
    pending_calibration_records,
    submit_calibration,
    uninstall,
)
from repro.obs.export import (
    JsonlSink,
    chrome_trace,
    prometheus_text,
    write_chrome_trace,
    write_prometheus,
    write_spans_jsonl,
)
from repro.obs.metrics import (
    LATENCY_BUCKETS_S,
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    Registry,
    counter,
    gauge,
    histogram,
    snapshot,
)
from repro.obs.trace import (
    Span,
    configure,
    disable,
    enable,
    is_enabled,
    on_span_end,
    remove_span_end,
    span,
    spans,
    stats,
    traced,
    tracing,
)
from repro.obs.trace import clear as clear_spans
from repro.obs.metrics import reset as reset_metrics

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "JsonlSink",
    "LATENCY_BUCKETS_S",
    "REGISTRY",
    "Registry",
    "Span",
    "calibration_stamp",
    "chrome_trace",
    "clear_spans",
    "configure",
    "counter",
    "disable",
    "enable",
    "flush_calibration",
    "gauge",
    "histogram",
    "install",
    "is_enabled",
    "on_span_end",
    "pending_calibration_records",
    "prometheus_text",
    "remove_span_end",
    "reset_metrics",
    "snapshot",
    "span",
    "spans",
    "stats",
    "submit_calibration",
    "traced",
    "tracing",
    "uninstall",
    "write_chrome_trace",
    "write_prometheus",
    "write_spans_jsonl",
]
