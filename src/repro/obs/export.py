"""Exporters: Chrome-trace timelines, Prometheus text, and a JSONL sink.

Three render targets for the span ring and the metrics registry
(DESIGN.md §14), all stdlib-only:

  chrome_trace      `chrome://tracing` / Perfetto-loadable JSON: one
                    complete ("ph": "X") event per finished span, grouped
                    by thread, timestamps in microseconds relative to the
                    tracer's monotonic epoch.  Run metadata (calibration
                    stamp, counters) rides in "otherData".
  prometheus_text   the text exposition format (# HELP/# TYPE + samples;
                    histograms as cumulative _bucket{le=...}/_sum/_count).
  JsonlSink         an owned, explicitly closed append-only JSONL file —
                    the sink `train.metrics.MetricsLogger` now writes
                    through (its leaked file handle is fixed by owning the
                    lifecycle here).

Exports are pull-based and must stay OFF the serving tick: callers flush
at drain/exit (see `launch/serve.main` and the obs bridge), never per span.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

__all__ = [
    "JsonlSink",
    "chrome_trace",
    "prometheus_text",
    "write_chrome_trace",
    "write_prometheus",
    "write_spans_jsonl",
]


def _json_safe(v: Any):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, Mapping):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    return repr(v)


def chrome_trace(
    spans: Optional[Sequence["_trace.Span"]] = None,
    *,
    metadata: Optional[Mapping[str, Any]] = None,
) -> Dict[str, Any]:
    """Render spans as a Chrome-trace document (dict; json.dump it)."""
    if spans is None:
        spans = _trace.spans()
    epoch = _trace._STATE.epoch
    pid = os.getpid()
    events: List[Dict[str, Any]] = [
        {
            "ph": "M",
            "pid": pid,
            "tid": 0,
            "name": "process_name",
            "args": {"name": "repro"},
        }
    ]
    for sp in spans:
        events.append(
            {
                "ph": "X",
                "pid": pid,
                "tid": sp.tid,
                "name": sp.name,
                "cat": sp.name.split(".", 1)[0],
                "ts": (sp.t0 - epoch) * 1e6,
                "dur": max(sp.duration_s, 0.0) * 1e6,
                "args": _json_safe(dict(sp.attrs, seq=sp.seq, parent=sp.parent)),
            }
        )
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": _json_safe(dict(metadata or {}, spans=len(spans))),
    }


def write_chrome_trace(
    path: str,
    spans: Optional[Sequence["_trace.Span"]] = None,
    *,
    metadata: Optional[Mapping[str, Any]] = None,
) -> Dict[str, Any]:
    doc = chrome_trace(spans, metadata=metadata)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def _fmt_value(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


def _fmt_labels(names: Sequence[str], values: Sequence[str],
                extra: Sequence[tuple] = ()) -> str:
    pairs = list(zip(names, values)) + list(extra)
    if not pairs:
        return ""
    esc = lambda s: str(s).replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")
    return "{" + ",".join(f'{n}="{esc(v)}"' for n, v in pairs) + "}"


def prometheus_text(registry: Optional["_metrics.Registry"] = None) -> str:
    """Render the registry in the Prometheus text exposition format."""
    registry = registry or _metrics.REGISTRY
    lines: List[str] = []
    for m in registry.metrics():
        lines.append(f"# HELP {m.name} {m.help or m.name}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        if m.kind in ("counter", "gauge"):
            series = m.series() or ({(): 0.0} if not m.labelnames else {})
            for key, val in sorted(series.items()):
                lines.append(
                    f"{m.name}{_fmt_labels(m.labelnames, key)} {_fmt_value(val)}"
                )
        elif m.kind == "histogram":
            for key, s in sorted(m.series().items()):
                cum = 0
                for bound, c in zip(m.buckets, s["buckets"]):
                    cum += c
                    lbl = _fmt_labels(m.labelnames, key, [("le", _fmt_value(bound))])
                    lines.append(f"{m.name}_bucket{lbl} {cum}")
                cum += s["buckets"][-1]
                lbl = _fmt_labels(m.labelnames, key, [("le", "+Inf")])
                lines.append(f"{m.name}_bucket{lbl} {cum}")
                lines.append(
                    f"{m.name}_sum{_fmt_labels(m.labelnames, key)} {repr(float(s['sum']))}"
                )
                lines.append(
                    f"{m.name}_count{_fmt_labels(m.labelnames, key)} {s['count']}"
                )
    return "\n".join(lines) + "\n"


def write_prometheus(path: str, registry: Optional["_metrics.Registry"] = None) -> str:
    text = prometheus_text(registry)
    with open(path, "w") as f:
        f.write(text)
    return text


class JsonlSink:
    """Append-only JSONL file with an owned, explicitly closed handle."""

    def __init__(self, path: str):
        self.path = path
        self._fh = open(path, "a")

    @property
    def closed(self) -> bool:
        return self._fh.closed

    def write(self, record: Mapping[str, Any]) -> None:
        if self._fh.closed:
            raise ValueError(f"JsonlSink({self.path!r}) is closed")
        self._fh.write(json.dumps(_json_safe(dict(record))) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


def write_spans_jsonl(
    path: str, spans: Optional[Sequence["_trace.Span"]] = None
) -> int:
    """Dump finished spans one-per-line; returns the span count."""
    if spans is None:
        spans = _trace.spans()
    with JsonlSink(path) as sink:
        for sp in spans:
            sink.write(sp.as_dict())
    return len(spans)
