"""Structured tracing: nestable spans over a process-wide ring buffer.

The repo's timing story used to be ad-hoc ``time.monotonic()`` pairs
scattered across serve/scheduler/autotune/train; this module replaces them
with *spans* — named, nestable intervals with monotonic wall times and
JSON-able attributes — cheap enough to leave in the hot paths permanently.

Design contract (DESIGN.md §14):

  off by default   tracing is a hard opt-in (`enable()` / the `tracing()`
                   scope).  The DISABLED fast path of `span()` is a single
                   attribute check returning a shared no-op span — the
                   overhead budget (<2% on the 10k-iteration microbench) is
                   asserted in tests and tracked in BENCH_kernels.json["obs"].
  nestable         spans nest via a per-thread stack: `parent` links child
                   spans to the enclosing one, so exports reconstruct a
                   request's life (serve.tick -> serve.decode -> ...).
  bounded          finished spans land in one process-wide ring
                   (deque(maxlen)); old spans are dropped, never grown
                   without bound — `stats()["dropped"]` counts the loss.
  thread-safe      the ring, the seq counter, and the end hooks are guarded
                   by one lock; span stacks are thread-local.
  tracer-aware     a span must never fire inside a jitted trace (the same
                   discipline as the non-finite guard in `kernels/api.py`):
                   under tracing `time.monotonic()` would measure *trace*
                   time and the span would fire once per compile, not per
                   execution.  When jax reports an active trace the span is
                   suppressed (counted in `stats()["suppressed_in_trace"]`).

Zero dependencies: stdlib only; jax is imported lazily and only to ask
"are we inside a trace?" — the module works in processes without jax.
"""

from __future__ import annotations

import collections
import functools
import threading
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = [
    "Span",
    "clear",
    "configure",
    "disable",
    "enable",
    "is_enabled",
    "on_span_end",
    "remove_span_end",
    "span",
    "spans",
    "stats",
    "traced",
    "tracing",
]

DEFAULT_CAPACITY = 65536


class _State:
    """Process-wide tracer state; `enabled` is THE disabled-path check."""

    __slots__ = ("enabled", "capacity", "epoch")

    def __init__(self) -> None:
        self.enabled = False
        self.capacity = DEFAULT_CAPACITY
        # monotonic origin all span times are relative to (stable within a
        # process; exports use it to produce small, diff-friendly offsets)
        self.epoch = time.monotonic()


_STATE = _State()
_LOCK = threading.Lock()
_RING: "collections.deque" = collections.deque(maxlen=DEFAULT_CAPACITY)
_HOOKS: List[Callable[["Span"], None]] = []
_LOCAL = threading.local()
_SEQ = [0]
_STATS = {"started": 0, "finished": 0, "dropped": 0, "suppressed_in_trace": 0}

# Resolved lazily at first enabled span: () -> bool, True when NOT tracing.
_TRACE_CLEAN: Optional[Callable[[], bool]] = None


def _resolve_trace_clean() -> Callable[[], bool]:
    global _TRACE_CLEAN
    if _TRACE_CLEAN is None:
        try:
            from jax.core import trace_state_clean as _clean  # type: ignore

            _TRACE_CLEAN = _clean
        except Exception:  # no jax in this process: never inside a trace
            _TRACE_CLEAN = lambda: True
    return _TRACE_CLEAN


class Span:
    """One finished-or-open interval.  Mutable while open (`set()` adds
    attributes mid-span); append-only once it lands in the ring."""

    __slots__ = ("name", "seq", "parent", "tid", "t0", "t1", "attrs")

    def __init__(self, name: str, seq: int, parent: Optional[int], tid: int,
                 t0: float, attrs: Dict[str, Any]):
        self.name = name
        self.seq = seq
        self.parent = parent
        self.tid = tid
        self.t0 = t0
        self.t1 = t0
        self.attrs = attrs

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def set(self, key: str, value: Any) -> None:
        """Attach an attribute discovered mid-span (e.g. a chosen schedule)."""
        self.attrs[key] = value

    def as_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "seq": self.seq,
            "parent": self.parent,
            "tid": self.tid,
            "t0": self.t0,
            "t1": self.t1,
            "duration_s": self.duration_s,
            "attrs": dict(self.attrs),
        }

    def __repr__(self) -> str:
        return (
            f"Span({self.name!r}, seq={self.seq}, parent={self.parent},"
            f" dur={self.duration_s * 1e3:.3f}ms)"
        )

    # -- context-manager protocol -------------------------------------------

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        _end_span(self, exc)
        return False


class _NullSpan:
    """The disabled/suppressed path: every operation is a no-op."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, key: str, value: Any) -> None:
        pass


_NULL = _NullSpan()


def _stack() -> List[Span]:
    st = getattr(_LOCAL, "stack", None)
    if st is None:
        st = _LOCAL.stack = []
    return st


def span(name: str, **attrs: Any):
    """Open a span; use as ``with span("plan.execute", backend="xla"): ...``.

    Disabled tracing returns a shared no-op span after ONE attribute check.
    Enabled tracing inside a jitted trace is suppressed (tracer-aware guard).
    """
    if not _STATE.enabled:
        return _NULL
    return _begin(name, attrs)


def _begin(name: str, attrs: Dict[str, Any]):
    if not _resolve_trace_clean()():
        with _LOCK:
            _STATS["suppressed_in_trace"] += 1
        return _NULL
    st = _stack()
    parent = st[-1].seq if st else None
    with _LOCK:
        _SEQ[0] += 1
        seq = _SEQ[0]
        _STATS["started"] += 1
    sp = Span(name, seq, parent, threading.get_ident(), time.monotonic(), attrs)
    st.append(sp)
    return sp


def _end_span(sp: Span, exc: Optional[BaseException]) -> None:
    sp.t1 = time.monotonic()
    if exc is not None:
        sp.attrs["error"] = f"{type(exc).__name__}: {exc}"
    st = _stack()
    # Tolerate out-of-order exits (a span leaked across a raise): pop up to
    # and including this span if present, else leave the stack alone.
    if sp in st:
        while st and st.pop() is not sp:
            pass
    with _LOCK:
        _STATS["finished"] += 1
        if _RING.maxlen is not None and len(_RING) == _RING.maxlen:
            _STATS["dropped"] += 1
        _RING.append(sp)
        hooks = list(_HOOKS)
    for fn in hooks:
        try:
            fn(sp)
        except Exception:
            pass  # a broken hook must never take the traced path down


def traced(name_or_fn=None, **attrs: Any):
    """Decorator form: ``@traced`` or ``@traced("layer.verb", key=...)``."""

    def deco(fn: Callable, name: Optional[str] = None) -> Callable:
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _STATE.enabled:
                return fn(*args, **kwargs)
            with _begin(label, dict(attrs)):
                return fn(*args, **kwargs)

        return wrapper

    if callable(name_or_fn):
        return deco(name_or_fn)
    return lambda fn: deco(fn, name_or_fn)


# ---------------------------------------------------------------------------
# Switches + introspection
# ---------------------------------------------------------------------------


def enable(capacity: Optional[int] = None) -> None:
    """Turn tracing on (optionally resizing the ring)."""
    if capacity is not None:
        configure(capacity=capacity)
    _STATE.enabled = True


def disable() -> None:
    _STATE.enabled = False


def is_enabled() -> bool:
    return _STATE.enabled


class tracing:
    """Scoped enable: ``with tracing(): ...`` restores the prior state."""

    def __init__(self, capacity: Optional[int] = None):
        self._capacity = capacity
        self._prior = False

    def __enter__(self) -> "tracing":
        self._prior = _STATE.enabled
        enable(self._capacity)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        _STATE.enabled = self._prior
        return False


def configure(*, capacity: int) -> None:
    """Resize the ring (keeps the newest spans that still fit)."""
    global _RING
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    with _LOCK:
        _STATE.capacity = capacity
        _RING = collections.deque(_RING, maxlen=capacity)


def spans(name: Optional[str] = None) -> List[Span]:
    """Snapshot of finished spans, oldest first (optionally filtered)."""
    with _LOCK:
        got = list(_RING)
    return got if name is None else [s for s in got if s.name == name]


def stats() -> Dict[str, int]:
    with _LOCK:
        d = dict(_STATS)
        d["retained"] = len(_RING)
        d["capacity"] = _STATE.capacity
    return d


def clear() -> None:
    """Test hook: drop finished spans and reset counters (keeps `enabled`)."""
    with _LOCK:
        _RING.clear()
        _SEQ[0] = 0
        for k in _STATS:
            _STATS[k] = 0


def on_span_end(fn: Callable[[Span], None]) -> None:
    """Register a finished-span hook (the obs bridge feeds calibration
    through this).  Hooks run outside the lock; exceptions are swallowed."""
    with _LOCK:
        if fn not in _HOOKS:
            _HOOKS.append(fn)


def remove_span_end(fn: Callable[[Span], None]) -> None:
    with _LOCK:
        if fn in _HOOKS:
            _HOOKS.remove(fn)
