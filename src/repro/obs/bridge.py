"""Bridges between obs and the rest of the system (DESIGN.md §14).

Two one-way feeds, both installed by `install()` (idempotent):

  ledger -> metrics   every `resilience.ledger.DegradationEvent` increments
                      `repro_degradations_total{site, cause}` (cause is the
                      exception-type head of the ledger's free-text cause,
                      so label cardinality stays bounded).  Installation
                      BACKFILLS events recorded before the bridge existed,
                      so the counter equals the ledger exactly — the chaos
                      CI job and `tests/test_obs.py` assert that equality.

  spans -> calibration  finished `plan.execute` spans carrying cost-model
                      `terms` (unsharded AND sharded/collective — the
                      ShardedPlan terms include collective bytes/phases,
                      the multi-device lane ROADMAP 2(a) was missing) are
                      converted into `costmodel.calibrate.ingest()` records
                      and BUFFERED.  Nothing touches the filesystem per
                      span: `flush_calibration()` folds the buffer into the
                      calibration cache at drain/exit, keeping the serving
                      tick I/O-free.  `submit_calibration()` lets benches
                      route their own blocked-and-timed measurements
                      through the same lane (bench_costmodel does).

`calibration_stamp()` reports the coefficients serving the planner right
now — exported timelines embed it so a trace says which calibration
predicted the plans it shows.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

__all__ = [
    "calibration_stamp",
    "degradation_counter",
    "flush_calibration",
    "install",
    "installed",
    "pending_calibration_records",
    "submit_calibration",
    "uninstall",
]

DEGRADATION_COUNTER = "repro_degradations_total"
_MAX_PENDING = 1024  # bounded: a serve run cannot grow the buffer unbounded

_LOCK = threading.Lock()
_INSTALLED = [False]
_PENDING: List[Dict[str, Any]] = []
# keys whose first (cold) execution has been seen and discarded: the first
# p(a, b) for a plan includes jit compilation, and feeding a
# compile-inclusive duration to the fitter would poison the coefficients
_WARM: set = set()


def degradation_counter() -> "_metrics.Counter":
    return _metrics.counter(
        DEGRADATION_COUNTER,
        "resilience.ledger degradation events mirrored by the obs bridge",
        labels=("site", "cause"),
    )


def _cause_head(cause: str) -> str:
    """Bounded-cardinality cause label: the exception-type head."""
    return str(cause).split(":", 1)[0].strip()[:64] or "unknown"


def _mirror_event(ev) -> None:
    degradation_counter().inc(site=ev.site, cause=_cause_head(ev.cause))


def _on_span_end(sp: "_trace.Span") -> None:
    if sp.name != "plan.execute":
        return
    terms = sp.attrs.get("terms")
    if not isinstance(terms, dict):
        return
    ms = sp.duration_s * 1e3
    if ms <= 0:
        return
    key = sp.attrs.get("key")
    rec = {
        "terms": terms,
        "ms": ms,
        "source": "obs",
        "key": key,
    }
    with _LOCK:
        if key not in _WARM:
            _WARM.add(key)  # cold execution: compile-inclusive, discard
            return
        if len(_PENDING) < _MAX_PENDING:
            _PENDING.append(rec)


def install() -> None:
    """Idempotently wire the ledger listener + span-end hook.  Events
    recorded before the bridge existed are BACKFILLED first, so the counter
    equals the ledger at the moment install returns; the listener keeps the
    two in lockstep from there (an event reaches the counter exactly once:
    subscription happens strictly after the backfill snapshot is taken)."""
    from repro.resilience import ledger as _ledger

    with _LOCK:
        was = _INSTALLED[0]
        _INSTALLED[0] = True
    if was:
        return
    for ev in _ledger.events():
        _mirror_event(ev)
    _ledger.add_listener(_mirror_event)
    _trace.on_span_end(_on_span_end)


def uninstall() -> None:
    """Test hook: detach both feeds and drop the pending buffer."""
    from repro.resilience import ledger as _ledger

    _ledger.remove_listener(_mirror_event)
    _trace.remove_span_end(_on_span_end)
    with _LOCK:
        _INSTALLED[0] = False
        _PENDING.clear()
        _WARM.clear()


def installed() -> bool:
    return _INSTALLED[0]


def submit_calibration(records: Sequence[Mapping[str, Any]]) -> int:
    """Buffer externally measured `{"terms", "ms", ...}` records for the
    next flush (the bench lane: blocked-and-timed sharded steps)."""
    added = 0
    with _LOCK:
        for rec in records:
            if len(_PENDING) >= _MAX_PENDING:
                break
            _PENDING.append(dict(rec))
            added += 1
    return added


def pending_calibration_records() -> List[Dict[str, Any]]:
    with _LOCK:
        return [dict(r) for r in _PENDING]


def flush_calibration(
    *,
    platform: Optional[str] = None,
    refit: bool = True,
    persist: bool = True,
) -> int:
    """Fold buffered records into the calibration cache (drain/exit only —
    this is the ONLY filesystem touch on the span->calibration lane).
    Returns the number of records ingested; failures degrade to 0 with a
    ledger record, never raise into a shutdown path."""
    with _LOCK:
        batch, _PENDING[:] = list(_PENDING), []
    if not batch:
        return 0
    try:
        # module-path import: the package re-exports a `calibrate` FUNCTION
        # that shadows the submodule name on attribute access
        from repro.costmodel.calibrate import ingest as _ingest

        return _ingest(batch, platform=platform, refit=refit, persist=persist)
    except Exception as e:
        from repro.resilience import ledger as _ledger

        _ledger.record(
            "obs.flush",
            cause=f"{type(e).__name__}: {e}",
            fallback="drop-batch",
            records=len(batch),
        )
        return 0


def calibration_stamp() -> Dict[str, Any]:
    """The coefficients the planner is using right now, for timeline
    metadata (which calibration predicted the plans this trace shows)."""
    try:
        from repro.costmodel.calibrate import current_coefficients, default_cache

        co = current_coefficients()
        return {
            "platform": co.platform,
            "source": co.source,
            "flops_per_s": co.flops_per_s,
            "link_bytes_per_s": co.link_bytes_per_s,
            "phase_latency_s": co.phase_latency_s,
            "cache_path": str(default_cache().path),
        }
    except Exception:
        return {"source": "unavailable"}
