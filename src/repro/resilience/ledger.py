"""Process-wide degradation ledger (DESIGN.md §11).

Every graceful-degradation decision — a plan build falling down the backend
chain, a sharded schedule dropping to replicated, a quarantined autotune
cache, a guard-scrubbed NaN, a retried checkpoint write — records one
`DegradationEvent` here.  The ledger is the operator's view of how much of
the process is running degraded: `serve --plan-stats` prints `summary()`,
plans carry their own events in `describe()["health"]`, and fault-injection
tests assert on it.

Events are timestamp-free by design (a monotonic `seq` orders them): the
ledger must be byte-stable across runs so CI can diff it.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "DegradationEvent",
    "add_listener",
    "clear",
    "count",
    "events",
    "format_summary",
    "record",
    "remove_listener",
    "summary",
]


@dataclasses.dataclass(frozen=True)
class DegradationEvent:
    """One degradation decision: what failed, why, and what absorbed it.

    seq       process-wide monotonic counter (timestamp-free ordering)
    site      the fault site (same names as `resilience.faults`)
    cause     human-readable failure description ("FaultError: ...")
    fallback  what the process degraded TO ("xla", "replicated", "retry#1",
              "zero", "quarantine", ...)
    detail    sorted (key, value-repr) pairs of extra context
    """

    seq: int
    site: str
    cause: str
    fallback: str
    detail: Tuple[Tuple[str, str], ...] = ()

    def as_dict(self) -> Dict[str, Any]:
        return {
            "seq": self.seq,
            "site": self.site,
            "cause": self.cause,
            "fallback": self.fallback,
            "detail": dict(self.detail),
        }


_EVENTS: List[DegradationEvent] = []
_SEQ = [0]
_LOCK = threading.Lock()
_LISTENERS: List[Any] = []


def add_listener(fn) -> None:
    """Subscribe `fn(event)` to every future `record()` (the obs bridge
    mirrors events into metrics through this).  Listeners run OUTSIDE the
    ledger lock; a raising listener is ignored, never the recorder's
    problem."""
    with _LOCK:
        if fn not in _LISTENERS:
            _LISTENERS.append(fn)


def remove_listener(fn) -> None:
    with _LOCK:
        if fn in _LISTENERS:
            _LISTENERS.remove(fn)


def record(site: str, cause: str, fallback: str, **detail: Any) -> DegradationEvent:
    """Append one event (thread-safe; the checkpoint worker records too)."""
    with _LOCK:
        _SEQ[0] += 1
        ev = DegradationEvent(
            seq=_SEQ[0],
            site=str(site),
            cause=str(cause),
            fallback=str(fallback),
            detail=tuple(sorted((str(k), repr(v)) for k, v in detail.items())),
        )
        _EVENTS.append(ev)
        listeners = list(_LISTENERS)
    for fn in listeners:
        try:
            fn(ev)
        except Exception:
            pass
    return ev


def events(site: Optional[str] = None) -> List[DegradationEvent]:
    with _LOCK:
        evs = list(_EVENTS)
    return evs if site is None else [e for e in evs if e.site == site]


def count(site: Optional[str] = None) -> int:
    return len(events(site))


def summary() -> Dict[str, Dict[str, int]]:
    """{site: {fallback: count}} — the shape `serve --plan-stats` prints."""
    out: Dict[str, Dict[str, int]] = {}
    for e in events():
        out.setdefault(e.site, {})
        out[e.site][e.fallback] = out[e.site].get(e.fallback, 0) + 1
    return out


def format_summary(prefix: str = "[resilience]") -> str:
    """Multi-line printable summary; one line when the ledger is empty."""
    evs = events()
    if not evs:
        return f"{prefix} ledger: no degradation events (all paths healthy)"
    lines = [f"{prefix} ledger: {len(evs)} degradation event(s)"]
    for site, falls in sorted(summary().items()):
        per = ", ".join(f"{fb} x{c}" for fb, c in sorted(falls.items()))
        lines.append(f"{prefix}   {site:22s} -> {per}")
    tail = evs[-5:]
    for e in tail:
        lines.append(
            f"{prefix}   #{e.seq} {e.site}: {e.cause[:80]} -> {e.fallback}"
        )
    return "\n".join(lines)


def clear() -> None:
    """Test hook: drop all events and reset the sequence counter."""
    with _LOCK:
        _EVENTS.clear()
        _SEQ[0] = 0
