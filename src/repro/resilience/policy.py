"""Numeric guardrail policies and bounded retry/backoff (DESIGN.md §11).

Two small, dependency-free primitives the rest of the resilience layer is
built from:

  * `nonfinite_count` / `scrub_nonfinite` — the NaN/Inf detection used by
    the `guard_nonfinite` plan option (`kernels/api.plan`), sampling-aware
    so big outputs can be spot-checked instead of fully reduced;
  * `retry_call` — bounded retry with exponential backoff for the I/O edges
    (checkpoint writes, autotune cache persistence), recording each retry
    in the resilience ledger.
"""

from __future__ import annotations

import time
from typing import Callable, Optional, Tuple, Type, TypeVar

from repro.resilience import ledger

__all__ = [
    "GUARD_POLICIES",
    "NonFiniteError",
    "nonfinite_count",
    "normalize_policy",
    "retry_call",
    "scrub_nonfinite",
]

T = TypeVar("T")

# guard_nonfinite policies (kernels/api.plan):
#   raise            NonFiniteError on any sampled NaN/Inf
#   fallback         re-execute on the next backend in the plan's chain
#   zero_and_record  replace non-finite entries with 0 and record the event
GUARD_POLICIES = ("raise", "fallback", "zero_and_record")


class NonFiniteError(FloatingPointError):
    """A guarded plan produced NaN/Inf under the `raise` policy."""


def normalize_policy(policy: str) -> str:
    """Accept hyphenated spellings ("zero-and-record") for the CLI edge."""
    p = str(policy).replace("-", "_")
    if p not in GUARD_POLICIES:
        raise ValueError(
            f"guard policy must be one of {GUARD_POLICIES}, got {policy!r}"
        )
    return p


def nonfinite_count(x, sample: Optional[int] = None) -> int:
    """Number of non-finite entries in `x` (host-synced — eager arrays only).

    `sample` checks an evenly strided subset of that many elements instead of
    the full array — the guard's cheap spot-check for big outputs.  Sampling
    can miss a poisoned tail; it is a cost/coverage dial, not a proof.
    """
    import jax.numpy as jnp

    flat = jnp.ravel(x)
    if sample is not None and 0 < sample < flat.shape[0]:
        stride = flat.shape[0] // sample
        flat = flat[:: max(stride, 1)]
    return int(jnp.sum(~jnp.isfinite(flat)))


def scrub_nonfinite(x):
    """Replace NaN/Inf with exact zeros (traceable — no host sync)."""
    import jax.numpy as jnp

    return jnp.where(jnp.isfinite(x), x, jnp.zeros((), x.dtype))


def retry_call(
    fn: Callable[[], T],
    *,
    retries: int = 2,
    base_delay: float = 0.05,
    max_delay: float = 1.0,
    retry_on: Tuple[Type[BaseException], ...] = (Exception,),
    site: str = "retry",
    sleep: Callable[[float], None] = time.sleep,
) -> T:
    """Run `fn`, retrying up to `retries` times with exponential backoff.

    Each retry records a DegradationEvent (site, cause, "retry#n") so
    transient I/O failures are visible even when they ultimately succeed.
    After the bounded retries are exhausted the LAST error is re-raised —
    surfacing, not swallowing.
    """
    if retries < 0:
        raise ValueError(f"retries must be >= 0, got {retries}")
    attempt = 0
    while True:
        try:
            return fn()
        except retry_on as e:
            attempt += 1
            if attempt > retries:
                raise
            ledger.record(
                site,
                cause=f"{type(e).__name__}: {e}",
                fallback=f"retry#{attempt}",
                attempts_left=retries - attempt,
            )
            delay = min(base_delay * (2 ** (attempt - 1)), max_delay)
            if delay > 0:
                sleep(delay)
