"""Resilience: fault injection, degradation ledger, numeric guard policies.

The planner (DESIGN.md §11) promises graceful degradation — a failed Pallas
plan build falls down the backend chain, a corrupt autotune cache is
quarantined, an injected collective fault degrades a sharded plan to the
replicated schedule, a NaN-producing kernel is caught by an opt-in guard.
This package makes every one of those promises *testable*:

  faults   deterministic fault-injection harness: arm failures at named
           sites (`plan.build`, `autotune.cache_load`, `collective.step`,
           `kernel.output`, `checkpoint.write`, ...) via a context-manager
           fault plan keyed by site and trigger count
  ledger   process-wide, timestamp-free record of every DegradationEvent
           (site, cause, fallback, monotonic seq) — printed by
           `serve --plan-stats` and inspectable in tests
  policy   numeric guardrail policies (`raise | fallback | zero_and_record`)
           and the bounded retry/backoff helper used on the I/O edges
"""

from repro.resilience import faults, ledger, policy
from repro.resilience.faults import FaultError, FaultSpec, inject
from repro.resilience.ledger import DegradationEvent
from repro.resilience.policy import GUARD_POLICIES, NonFiniteError, retry_call

__all__ = [
    "DegradationEvent",
    "FaultError",
    "FaultSpec",
    "GUARD_POLICIES",
    "NonFiniteError",
    "faults",
    "inject",
    "ledger",
    "policy",
    "retry_call",
]
