"""Deterministic fault-injection harness (DESIGN.md §11).

A degradation path that has never fired is a degradation path that does not
work.  This module lets tests (and the `chaos` CI job) arm failures at named
sites without monkeypatching internals: production code calls `check(site)`
at raising sites and `poison(site, x)` at value sites, and both are
near-free when no plan is armed (one truthiness test on an empty list).

    from repro.resilience import faults

    with faults.inject({"plan.build": faults.FaultSpec(times=1)}):
        p = api.plan(spec)   # first build fails -> backend fallback chain

Triggers are deterministic, not probabilistic: a `FaultSpec` fires for
`times` matching calls after skipping the first `after`, then stays dormant.
When several plans are armed (nested `inject`, or the ambient env plan under
a test-local one), the INNERMOST plan that names the site decides — it fires
or passes, and outer plans are not consulted for that call.

Named sites instrumented across the repo:

  plan.build          `kernels/api.plan` — backend plan construction
                      (ctx: backend)
  plan.execute        Plan.__call__ — first/any execution of a built plan
                      (ctx: backend)
  kernel.output       Plan.__call__ — VALUE site: poisons the kernel output
                      with NaN/Inf instead of raising (ctx: backend)
  autotune.cache_load `kernels/autotune.AutotuneCache._load` (ctx: path)
  collective.step     ring collectives / systolic k-pass under shard_map
                      (ctx: axis, schedule) — fires at trace time
  checkpoint.write    `checkpoint/async_writer` worker, inside the bounded
                      retry loop (ctx: step)
  serve.request       `launch/serve.serve_requests` per-request boundary
                      (ctx: request)
  serve.admit         `launch/scheduler` admission — a fired fault sheds
                      that one request (ctx: rid)
  serve.step          `launch/scheduler` tick boundary — a fired fault
                      skips the tick, never the server (ctx: tick)
  kv.page_alloc       `launch/scheduler.PageAllocator.alloc` — a fired
                      fault defers/stalls the allocation one tick
                      (ctx: reason, rid)

The canned plan registry backs `REPRO_FAULT_PLAN` (the chaos CI job sets
`REPRO_FAULT_PLAN=ci-default`); `install_env_plan()` arms it for the process.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from typing import Any, Dict, Iterator, List, Mapping, Optional, Union

__all__ = [
    "CANNED_PLANS",
    "ENV_PLAN",
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "active_plans",
    "check",
    "fire_counts",
    "inject",
    "install_env_plan",
    "poison",
    "uninstall_env_plan",
]

ENV_PLAN = "REPRO_FAULT_PLAN"


class FaultError(RuntimeError):
    """The default injected failure (sites raise it unless the FaultSpec
    pins another exception type)."""


@dataclasses.dataclass
class FaultSpec:
    """One armed failure at one site.

    times   how many matching calls fire before the spec goes dormant
    after   matching calls to skip first (0 = fire from the first call)
    error   exception *class* raised at `check` sites (ignored by `poison`)
    poison  "nan" | "inf": value sites corrupt the array instead of raising
    match   optional {ctx_key: value} filter — the spec only counts calls
            whose keyword context carries every matching item
    """

    times: int = 1
    after: int = 0
    error: type = FaultError
    poison: Optional[str] = None
    match: Optional[Mapping[str, Any]] = None

    def __post_init__(self):
        if self.times < 0 or self.after < 0:
            raise ValueError(f"times/after must be >= 0, got {self}")
        if self.poison not in (None, "nan", "inf"):
            raise ValueError(f"poison must be None|'nan'|'inf', got {self.poison!r}")

    def matches(self, ctx: Mapping[str, Any]) -> bool:
        return all(ctx.get(k) == v for k, v in (self.match or {}).items())


class FaultPlan:
    """A site -> FaultSpec table with per-site trigger accounting."""

    def __init__(
        self, specs: Mapping[str, Union[FaultSpec, Mapping[str, Any]]], *, name: str = ""
    ):
        self.name = name
        self.specs: Dict[str, FaultSpec] = {}
        for site, spec in specs.items():
            if not isinstance(spec, FaultSpec):
                spec = FaultSpec(**dict(spec))
            self.specs[str(site)] = spec
        self._seen: Dict[str, int] = {}
        self._fired: Dict[str, int] = {}
        self._lock = threading.Lock()

    def sites(self) -> List[str]:
        return list(self.specs)

    def fired(self, site: str) -> int:
        with self._lock:
            return self._fired.get(site, 0)

    def _consume(self, site: str, ctx: Mapping[str, Any]) -> Optional[FaultSpec]:
        """Count one matching call; return the spec iff it fires this call."""
        spec = self.specs.get(site)
        if spec is None or not spec.matches(ctx):
            return None
        with self._lock:
            seen = self._seen.get(site, 0)
            self._seen[site] = seen + 1
            if spec.after <= seen < spec.after + spec.times:
                self._fired[site] = self._fired.get(site, 0) + 1
                return spec
        return None


# The armed-plan stack.  A plain list mutated under a lock: fault plans are a
# test/chaos construct, and the instrumented sites only pay a truthiness test
# on it in production (empty list -> immediate return).
_STACK: List[FaultPlan] = []
_STACK_LOCK = threading.Lock()
_ENV_INSTALLED: List[FaultPlan] = []


def active_plans() -> List[FaultPlan]:
    return list(_STACK)


@contextlib.contextmanager
def inject(
    plan: Union[FaultPlan, Mapping[str, Union[FaultSpec, Mapping[str, Any]]]],
) -> Iterator[FaultPlan]:
    """Arm a fault plan for the dynamic extent of the with-block."""
    if not isinstance(plan, FaultPlan):
        plan = FaultPlan(plan)
    with _STACK_LOCK:
        _STACK.append(plan)
    try:
        yield plan
    finally:
        with _STACK_LOCK:
            _STACK.remove(plan)


def _find(site: str, ctx: Mapping[str, Any]) -> Optional[FaultSpec]:
    # Innermost plan naming the site decides; outer plans keep their triggers.
    for plan in reversed(_STACK):
        if site in plan.specs:
            return plan._consume(site, ctx)
    return None


def check(site: str, **ctx: Any) -> None:
    """Raising site: raises the armed error if a matching spec fires."""
    if not _STACK:
        return
    spec = _find(site, ctx)
    if spec is not None and spec.poison is None:
        raise spec.error(f"injected fault at {site!r} (ctx={ctx})")


def poison(site: str, x, **ctx: Any):
    """Value site: returns `x` with one element poisoned if a spec fires.

    Works on concrete arrays and on tracers (the poison bakes into the traced
    graph when it fires at trace time).  Specs without a `poison` kind raise,
    exactly like `check` — a plan may choose either behavior for the site.
    """
    if not _STACK:
        return x
    spec = _find(site, ctx)
    if spec is None:
        return x
    if spec.poison is None:
        raise spec.error(f"injected fault at {site!r} (ctx={ctx})")
    import jax.numpy as jnp

    bad = jnp.asarray(
        float("nan") if spec.poison == "nan" else float("inf"), dtype=x.dtype
    )
    return x.at[(0,) * x.ndim].set(bad) if x.ndim else bad


def fire_counts() -> Dict[str, int]:
    """Total fires per site across every armed plan (diagnostics)."""
    out: Dict[str, int] = {}
    for plan in _STACK:
        for site in plan.specs:
            out[site] = out.get(site, 0) + plan.fired(site)
    return out


# ---------------------------------------------------------------------------
# Canned plans (REPRO_FAULT_PLAN)
# ---------------------------------------------------------------------------

# One fault per site, once each: the chaos CI job arms this for the whole
# test session and the conftest warmup drives every degradation path through
# it before the ordinary suite runs fault-free.
CANNED_PLANS: Dict[str, Dict[str, FaultSpec]] = {
    "ci-default": {
        "plan.build": FaultSpec(times=1),
        "plan.execute": FaultSpec(times=1),
        "autotune.cache_load": FaultSpec(times=1, error=OSError),
        "collective.step": FaultSpec(times=1),
        "kernel.output": FaultSpec(times=1, poison="nan"),
        "checkpoint.write": FaultSpec(times=1, error=OSError),
        "serve.request": FaultSpec(times=1),
        "serve.admit": FaultSpec(times=1),
        "serve.step": FaultSpec(times=1),
        "kv.page_alloc": FaultSpec(times=1),
    },
}


def install_env_plan() -> Optional[FaultPlan]:
    """Arm the canned plan named by $REPRO_FAULT_PLAN (idempotent).

    Returns the installed plan, or None when the env var is unset.  The plan
    sits at the BOTTOM of the stack, so test-local `inject` blocks shadow it
    site by site.
    """
    name = os.environ.get(ENV_PLAN)
    if not name:
        return None
    if _ENV_INSTALLED:
        return _ENV_INSTALLED[0]
    if name not in CANNED_PLANS:
        raise ValueError(
            f"${ENV_PLAN}={name!r} names no canned fault plan;"
            f" known: {sorted(CANNED_PLANS)}"
        )
    plan = FaultPlan(CANNED_PLANS[name], name=name)
    with _STACK_LOCK:
        _STACK.insert(0, plan)
    _ENV_INSTALLED.append(plan)
    return plan


def uninstall_env_plan() -> None:
    """Disarm the env-installed plan (test teardown)."""
    if _ENV_INSTALLED:
        plan = _ENV_INSTALLED.pop()
        with _STACK_LOCK:
            if plan in _STACK:
                _STACK.remove(plan)
