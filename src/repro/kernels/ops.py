"""Legacy GEMM entry points — a thin compat shim over `repro.kernels.api`.

The real dispatch layer is the plan/execute API (DESIGN.md §8):

    from repro.kernels import api
    spec = api.GemmSpec.from_operands(a, b, epilogue=api.Epilogue(bias=True))
    p = api.plan(spec)          # capability-validated, autotuned, cached
    y = p(a, b, bias=bias)      # reusable jitted executable

`matmul` here keeps every former call shape working: string `backend=`
selection (including the old `pallas_mesh_scrambled` pseudo-backend, now
`structure="scrambled"` on the spec) and the mutable process-global
`set_default_backend` both still function, each emitting a DeprecationWarning
once per process.  New code should build a `GemmSpec` — or use the scoped
`api.default_backend(...)` context manager instead of the global setter.

`scramble_blocks` (S^k at block granularity) is not deprecated; it lives here
unchanged.
"""

from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax

from repro.kernels import api
from repro.kernels.api import Epilogue, GemmSpec, apply_epilogue  # re-exports
from repro.kernels import ref
from repro.kernels.scramble_kernel import scramble_blocks_pallas

__all__ = [
    "apply_epilogue",
    "get_default_backend",
    "matmul",
    "scramble_blocks",
    "set_default_backend",
]

# The old pseudo-backend name: scrambled output is a *structure* now, but the
# string keeps routing for existing callers.
_SCRAMBLED_ALIAS = "pallas_mesh_scrambled"

# Set only by the deprecated set_default_backend; None = defer to the api
# default (the scoped default_backend context manager), then "xla".
# _LEGACY_EPOCH records api.default_epoch() at install time: any later
# set_default/default_backend change supersedes the legacy string entirely.
_LEGACY_DEFAULT: Optional[str] = None
_LEGACY_EPOCH: Optional[int] = None

_WARNED: set = set()


def _warn_once(kind: str, message: str, stacklevel: int = 3) -> None:
    """Deprecation warnings fire once per process per kind, attributed to the
    *external* caller of the public shim function — never to this module, so
    CI's first-party deprecation gate only trips on unmigrated repro code."""
    if kind in _WARNED:
        return
    _WARNED.add(kind)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


def _valid_names() -> tuple:
    return tuple(api.backend_names()) + (_SCRAMBLED_ALIAS,)


def _split_legacy(name: str) -> tuple:
    """Legacy backend string -> (registry backend, structure)."""
    if name == _SCRAMBLED_ALIAS:
        _warn_once(
            "scrambled-pseudo-backend",
            f"backend={_SCRAMBLED_ALIAS!r} is deprecated; use "
            "GemmSpec(structure='scrambled') with the 'pallas_mesh' backend",
            stacklevel=4,  # _warn_once -> here -> matmul -> external caller
        )
        return "pallas_mesh", "scrambled"
    return name, "general"


def set_default_backend(backend: str) -> None:
    """Deprecated: install a process-wide default backend string.

    Prefer the scoped `api.default_backend(name)` context manager, or pass
    `backend=` to `api.plan` explicitly.
    """
    global _LEGACY_DEFAULT, _LEGACY_EPOCH
    if backend not in _valid_names():
        raise ValueError(
            f"backend must be one of {_valid_names()}, got {backend!r}"
        )
    _warn_once(  # after validation: a typo'd call must not consume the warning
        "set-default-backend",
        "set_default_backend is deprecated; use the "
        "repro.kernels.api.default_backend(...) context manager or "
        "plan(spec, backend=...)",
    )
    _LEGACY_DEFAULT = backend
    api.set_default("pallas_mesh" if backend == _SCRAMBLED_ALIAS else backend)
    _LEGACY_EPOCH = api.default_epoch()


def get_default_backend() -> str:
    return _default_name()


def _default_name() -> str:
    """Default resolution for calls without backend=: the legacy string holds
    only while the api default is *still the one set_default_backend
    installed* (epoch check) — so a `pallas_mesh_scrambled` default retains
    its scrambled structure, but any newer api.set_default / default_backend
    scope (including None for auto-choice) supersedes it."""
    if _LEGACY_DEFAULT is not None and _LEGACY_EPOCH == api.default_epoch():
        return _LEGACY_DEFAULT
    return api.get_default() or "xla"


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    backend: Optional[str] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    stagger: bool = True,
    out_dtype=None,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    residual: Optional[jax.Array] = None,
) -> jax.Array:
    """General fused matmul over the trailing two dims: (..., M, K) @ (K, N)
    or batched (..., M, K) @ (..., K, N).

    Compat shim: builds a `GemmSpec` and routes through `api.plan` — the plan
    cache makes repeated calls with the same logical shape cheap.  Epilogue
    contract (all backends): y = act(a @ b + bias) + residual, f32 accumulate,
    cast to out_dtype at the end.  bias is (N,); residual matches the output
    shape.  Block sizes left as None are resolved via `kernels/autotune.py`.
    """
    if backend is not None:
        if backend not in _valid_names():
            raise ValueError(
                f"backend must be one of {_valid_names()}, got {backend!r}"
            )
        _warn_once(  # after validation: a typo'd call must not consume it
            "string-backend",
            "passing backend= strings to ops.matmul is deprecated; build a "
            "GemmSpec and call repro.kernels.api.plan(spec, backend=...)",
        )
    name, structure = _split_legacy(backend or _default_name())
    blocks = (
        None
        if block_m is block_n is block_k is None
        else (block_m, block_n, block_k)
    )
    spec = GemmSpec.from_operands(
        a,
        b,
        structure=structure,
        epilogue=Epilogue(
            bias=bias is not None,
            activation=activation,
            residual=residual is not None,
        ),
        out_dtype=out_dtype,
        blocks=blocks,
        stagger=stagger,
    )
    return api.plan(spec, backend=name)(a, b, bias=bias, residual=residual)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# The permutation's linearization is itself; its transpose is the inverse
# permutation — so S^k's VJP is S^{-k} applied to the cotangent.
@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _scramble_pallas_vjp(x: jax.Array, opts) -> jax.Array:
    block_m, block_n, k, interpret = opts
    return scramble_blocks_pallas(
        x, block_m=block_m, block_n=block_n, k=k, interpret=interpret
    )


def _scr_fwd(x, opts):
    return _scramble_pallas_vjp(x, opts), None


def _scr_bwd(opts, _, g):
    block_m, block_n, k, interpret = opts
    return (_scramble_pallas_vjp(g, (block_m, block_n, -k, interpret)),)


_scramble_pallas_vjp.defvjp(_scr_fwd, _scr_bwd)


def scramble_blocks(
    x: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    k: int = 1,
    use_pallas: bool = True,
) -> jax.Array:
    """S^k at block granularity on the trailing (m, n) dims."""
    if not use_pallas:
        out = x
        fn = ref.scramble_blocks_ref if k >= 0 else ref.unscramble_blocks_ref
        for _ in range(abs(k)):
            out = fn(out, block_m=block_m, block_n=block_n)
        return out
    return _scramble_pallas_vjp(x, (block_m, block_n, k, not _on_tpu()))
