"""Public GEMM / scramble entry points — the framework's matmul dispatch layer.

Every dense layer in `repro.models` routes its projections through
`repro.kernels.ops.matmul`, making the paper's kernel a first-class selectable
GEMM backend:

  backend="xla"          jnp.dot (default for pjit'd full-scale graphs — XLA
                         owns the sharded GEMM + collective schedule there)
  backend="pallas_mesh"  the Pallas mesh-array staggered-k kernel
  backend="pallas_mesh_scrambled"
                         same, with the paper's S fused into the output
                         BlockSpec (square block grids only)

The wrapper pads arbitrary shapes up to block multiples, folds leading batch
dims (fully-batched operands compile to ONE `pallas_call` with a (b, i, j, k)
grid — no per-element vmap launch), and on CPU runs Pallas in interpret mode
automatically (TPU compiles).

Block shapes: explicit `block_m/n/k` are honored as given; any left as None
are resolved through `kernels/autotune.py` (persistent per-shape cache; a hit
never searches).  The fused epilogue (bias + activation + residual — the
contract is y = act(AB + bias) + residual, DESIGN.md §3) is available on
every backend so `models/layers.dense` can call one API; on the Pallas
backends it executes inside the kernel's final-k flush.

A process-wide default backend can be installed with `set_default_backend`
(used by configs' `use_mesh_kernel` flag).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune as _autotune
from repro.kernels import ref
from repro.kernels.mesh_matmul import (
    ACTIVATIONS,
    mesh_matmul_pallas,
    mesh_matmul_pallas_batched,
)
from repro.kernels.scramble_kernel import scramble_blocks_pallas

__all__ = [
    "apply_epilogue",
    "get_default_backend",
    "matmul",
    "scramble_blocks",
    "set_default_backend",
]

_DEFAULT_BACKEND = "xla"
_VALID = ("xla", "pallas_mesh", "pallas_mesh_scrambled")

# d/dz of each fused activation, as a function of the *pre-activation* z
# (recomputed in the backward pass — remat, not an extra forward output).
_ACT_GRADS = {
    "relu": lambda z: (z > 0).astype(z.dtype),
    "silu": lambda z: jax.nn.sigmoid(z) * (1 + z * (1 - jax.nn.sigmoid(z))),
    "sigmoid": lambda z: jax.nn.sigmoid(z) * (1 - jax.nn.sigmoid(z)),
    "tanh": lambda z: 1 - jnp.tanh(z) ** 2,
    "gelu": lambda z: _gelu_grad(z),
}


def _gelu_grad(z):
    """Analytic derivative of ACTIVATIONS['gelu'] (same GELU_C/GELU_A)."""
    from repro.kernels.mesh_matmul import GELU_A, GELU_C

    u = jnp.tanh(GELU_C * (z + GELU_A * z**3))
    return 0.5 * (1 + u) + 0.5 * z * (1 - u**2) * GELU_C * (1 + 3 * GELU_A * z**2)


def set_default_backend(backend: str) -> None:
    global _DEFAULT_BACKEND
    if backend not in _VALID:
        raise ValueError(f"backend must be one of {_VALID}, got {backend!r}")
    _DEFAULT_BACKEND = backend


def get_default_backend() -> str:
    return _DEFAULT_BACKEND


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def apply_epilogue(
    z: jax.Array,
    bias: Optional[jax.Array],
    activation: Optional[str],
    residual: Optional[jax.Array],
) -> jax.Array:
    """The epilogue contract as plain jnp ops (f32 in, f32 out) — the single
    unfused reference used by the XLA backend and the unfused A/B lever."""
    if bias is not None:
        z = z + bias.astype(jnp.float32)
    if activation not in (None, "none"):
        z = ACTIVATIONS[activation](z)
    if residual is not None:
        z = z + residual.astype(jnp.float32)
    return z


def _act_grad(z: jax.Array, activation: str) -> jax.Array:
    fn = _ACT_GRADS[activation]
    return fn(z)


def _mm_impl(a2, b2, bias, residual, opts) -> jax.Array:
    """Mesh-kernel matmul (2D or fully-batched 3D) with padding to block
    multiples and the fused epilogue."""
    block_m, block_n, block_k, stagger, scramble, out_dtype, interpret, act = opts
    batched = a2.ndim == 3
    m, n = a2.shape[-2], b2.shape[-1]
    ap = _pad_to(_pad_to(a2, block_m, -2), block_k, -1)
    bp = _pad_to(_pad_to(b2, block_k, -2), block_n, -1)
    if scramble and (ap.shape[-2] != m or bp.shape[-1] != n):
        raise ValueError(
            "pallas_mesh_scrambled requires block-aligned M and N "
            f"(got M={m}, N={n} with blocks {block_m}x{block_n})"
        )
    bias_p = None if bias is None else _pad_to(bias, block_n, 0)
    res_p = (
        None
        if residual is None
        else _pad_to(_pad_to(residual, block_m, -2), block_n, -1)
    )
    kernel = mesh_matmul_pallas_batched if batched else mesh_matmul_pallas
    out = kernel(
        ap,
        bp,
        bias=bias_p,
        residual=res_p,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        stagger=stagger,
        scramble_out=scramble,
        activation=act,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[..., :m, :n]


# pallas_call has no JVP rule, so training graphs need an explicit VJP.
# Forward: y = act(A @ B + bias) + residual (epilogue fused in-kernel).
# Backward: dresidual = g; dz = g * act'(z) with z recomputed by one plain
# kernel call (remat — no extra forward output); dA = dz Bᵀ and dB = Aᵀ dz are
# two more mesh-kernel matmuls; dbias reduces dz over rows.  For the scrambled
# backend C = S(...), the cotangent is unscrambled (a pure gather — the
# permutation's own transpose) first, putting the whole backward in standard
# arrangement.
@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _mm(a2, b2, bias, residual, opts) -> jax.Array:
    return _mm_impl(a2, b2, bias, residual, opts)


def _mm_fwd(a2, b2, bias, residual, opts):
    # dresidual only needs residual's DTYPE — save a scalar sentinel, not the
    # full output-sized tensor (it would stay live until the backward pass).
    res_sentinel = None if residual is None else jnp.zeros((), residual.dtype)
    return _mm_impl(a2, b2, bias, residual, opts), (a2, b2, bias, res_sentinel)


def _mm_bwd(opts, res, g):
    a2, b2, bias, res_sentinel = res
    block_m, block_n, block_k, stagger, scramble, _, interpret, act = opts
    if scramble:
        g = ref.unscramble_blocks_ref(g, block_m=block_m, block_n=block_n)
    gf = g.astype(jnp.float32)
    dresidual = None if res_sentinel is None else g.astype(res_sentinel.dtype)

    if act in (None, "none"):
        dz = gf
    else:
        # Remat the pre-activation z = A @ B + bias with a plain (no-epilogue,
        # unscrambled) kernel call, then chain through act'.
        opts_z = (block_m, block_n, block_k, stagger, False, jnp.float32, interpret, None)
        z = _mm_impl(
            a2.astype(jnp.float32), b2.astype(jnp.float32), None, None, opts_z
        )
        if bias is not None:
            z = z + bias.astype(jnp.float32)
        dz = gf * _act_grad(z, act)

    opts_a = (block_m, block_k, block_n, stagger, False, jnp.float32, interpret, None)
    opts_b = (block_k, block_n, block_m, stagger, False, jnp.float32, interpret, None)
    bT = jnp.swapaxes(b2, -1, -2).astype(jnp.float32)
    aT = jnp.swapaxes(a2, -1, -2).astype(jnp.float32)
    da = _mm(dz, bT, None, None, opts_a)
    db = _mm(aT, dz, None, None, opts_b)
    dbias = (
        None
        if bias is None
        else jnp.sum(dz, axis=tuple(range(dz.ndim - 1))).astype(bias.dtype)
    )
    return da.astype(a2.dtype), db.astype(b2.dtype), dbias, dresidual


_mm.defvjp(_mm_fwd, _mm_bwd)


def _resolve_blocks(block_m, block_n, block_k, m, k, n, dtype, backend):
    """Fill any block sizes not explicitly passed from the autotune cache."""
    if block_m is not None and block_n is not None and block_k is not None:
        return block_m, block_n, block_k
    bm, bn, bk = _autotune.resolve_blocks(m, k, n, dtype, backend)
    return block_m or bm, block_n or bn, block_k or bk


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    backend: Optional[str] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    stagger: bool = True,
    out_dtype=None,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    residual: Optional[jax.Array] = None,
) -> jax.Array:
    """General fused matmul over the trailing two dims: (..., M, K) @ (K, N)
    or batched (..., M, K) @ (..., K, N).

    Epilogue contract (all backends): y = act(a @ b + bias) + residual, with
    the accumulation and epilogue in float32, cast to out_dtype at the end.
    bias is (N,); residual matches the output shape.  Block sizes left as
    None are resolved via `kernels/autotune.py` (cache hit => no search).
    """
    backend = backend or _DEFAULT_BACKEND
    if backend not in _VALID:
        raise ValueError(f"backend must be one of {_VALID}, got {backend!r}")
    if activation not in ACTIVATIONS:  # same error on every backend
        raise ValueError(
            f"activation must be one of {sorted(k for k in ACTIVATIONS if k)},"
            f" got {activation!r}"
        )
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)

    if backend == "xla":
        z = jnp.matmul(a, b, preferred_element_type=jnp.float32)
        return apply_epilogue(z, bias, activation, residual).astype(out_dtype)

    scramble = backend == "pallas_mesh_scrambled"
    # Effective M for tuning: leading batch dims of `a` fold into M when `b`
    # is 2D; fully-batched calls tune the per-element (M, K, N) GEMM.
    eff_m = math.prod(a.shape[:-1]) if b.ndim == 2 else a.shape[-2]
    block_m, block_n, block_k = _resolve_blocks(
        block_m,
        block_n,
        block_k,
        eff_m,
        a.shape[-1],
        b.shape[-1],
        jnp.result_type(a.dtype, b.dtype),
        backend,
    )
    opts = (
        block_m,
        block_n,
        block_k,
        stagger,
        scramble,
        jnp.dtype(out_dtype),
        not _on_tpu(),
        None if activation in (None, "none") else activation,
    )

    if a.ndim == 2 and b.ndim == 2:
        return _mm(a, b, bias, residual, opts)
    if b.ndim == 2:
        # Fold leading batch dims of `a` into M — still a single 2D kernel.
        lead = a.shape[:-2]
        a2 = a.reshape(-1, a.shape[-1])
        res2 = None if residual is None else residual.reshape(-1, residual.shape[-1])
        out = _mm(a2, b, bias, res2, opts)
        return out.reshape(*lead, a.shape[-2], b.shape[-1])
    # Fully batched: ONE pallas_call with grid (b, i, j, k).
    if a.shape[:-2] != b.shape[:-2]:
        raise ValueError(f"batch dims mismatch: {a.shape} vs {b.shape}")
    lead = a.shape[:-2]
    af = a.reshape(-1, *a.shape[-2:])
    bf = b.reshape(-1, *b.shape[-2:])
    resf = None if residual is None else residual.reshape(-1, *residual.shape[-2:])
    out = _mm(af, bf, bias, resf, opts)
    return out.reshape(*lead, *out.shape[-2:])


# The permutation's linearization is itself; its transpose is the inverse
# permutation — so S^k's VJP is S^{-k} applied to the cotangent.
@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _scramble_pallas_vjp(x: jax.Array, opts) -> jax.Array:
    block_m, block_n, k, interpret = opts
    return scramble_blocks_pallas(
        x, block_m=block_m, block_n=block_n, k=k, interpret=interpret
    )


def _scr_fwd(x, opts):
    return _scramble_pallas_vjp(x, opts), None


def _scr_bwd(opts, _, g):
    block_m, block_n, k, interpret = opts
    return (_scramble_pallas_vjp(g, (block_m, block_n, -k, interpret)),)


_scramble_pallas_vjp.defvjp(_scr_fwd, _scr_bwd)


def scramble_blocks(
    x: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    k: int = 1,
    use_pallas: bool = True,
) -> jax.Array:
    """S^k at block granularity on the trailing (m, n) dims."""
    if not use_pallas:
        out = x
        fn = ref.scramble_blocks_ref if k >= 0 else ref.unscramble_blocks_ref
        for _ in range(abs(k)):
            out = fn(out, block_m=block_m, block_n=block_n)
        return out
    return _scramble_pallas_vjp(x, (block_m, block_n, k, not _on_tpu()))
