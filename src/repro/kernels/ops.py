"""Public GEMM / scramble entry points — the framework's matmul dispatch layer.

Every dense layer in `repro.models` routes its projections through
`repro.kernels.ops.matmul`, making the paper's kernel a first-class selectable
GEMM backend:

  backend="xla"          jnp.dot (default for pjit'd full-scale graphs — XLA
                         owns the sharded GEMM + collective schedule there)
  backend="pallas_mesh"  the Pallas mesh-array staggered-k kernel
  backend="pallas_mesh_scrambled"
                         same, with the paper's S fused into the output
                         BlockSpec (square block grids only)

The wrapper pads arbitrary shapes up to block multiples, folds leading batch
dims, and on CPU runs Pallas in interpret mode automatically (TPU compiles).
A process-wide default backend can be installed with `set_default_backend`
(used by configs' `use_mesh_kernel` flag).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.mesh_matmul import mesh_matmul_pallas
from repro.kernels.scramble_kernel import scramble_blocks_pallas

__all__ = ["matmul", "scramble_blocks", "set_default_backend", "get_default_backend"]

_DEFAULT_BACKEND = "xla"
_VALID = ("xla", "pallas_mesh", "pallas_mesh_scrambled")


def set_default_backend(backend: str) -> None:
    global _DEFAULT_BACKEND
    if backend not in _VALID:
        raise ValueError(f"backend must be one of {_VALID}, got {backend!r}")
    _DEFAULT_BACKEND = backend


def get_default_backend() -> str:
    return _DEFAULT_BACKEND


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _mm_impl(a2: jax.Array, b2: jax.Array, opts) -> jax.Array:
    """2D mesh-kernel matmul with padding to block multiples."""
    block_m, block_n, block_k, stagger, scramble, out_dtype, interpret = opts
    m, _ = a2.shape
    _, n = b2.shape
    ap = _pad_to(_pad_to(a2, block_m, 0), block_k, 1)
    bp = _pad_to(_pad_to(b2, block_k, 0), block_n, 1)
    if scramble and (ap.shape[0] != m or bp.shape[1] != n):
        raise ValueError(
            "pallas_mesh_scrambled requires block-aligned M and N "
            f"(got M={m}, N={n} with blocks {block_m}x{block_n})"
        )
    out = mesh_matmul_pallas(
        ap,
        bp,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        stagger=stagger,
        scramble_out=scramble,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[:m, :n]


# pallas_call has no JVP rule, so training graphs need an explicit VJP:
# the backward of C = A @ B is two more mesh-kernel matmuls
# (dA = g Bᵀ, dB = Aᵀ g); for the scrambled backend C = S(AB), the cotangent
# is unscrambled (a pure gather — the permutation's own transpose) first.
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _mm(a2: jax.Array, b2: jax.Array, opts) -> jax.Array:
    return _mm_impl(a2, b2, opts)


def _mm_fwd(a2, b2, opts):
    return _mm_impl(a2, b2, opts), (a2, b2)


def _mm_bwd(opts, res, g):
    a2, b2 = res
    block_m, block_n, block_k, stagger, scramble, _, interpret = opts
    if scramble:
        g = ref.unscramble_blocks_ref(g, block_m=block_m, block_n=block_n)
    gf = g.astype(jnp.float32)
    opts_a = (block_m, block_k, block_n, stagger, False, jnp.float32, interpret)
    opts_b = (block_k, block_n, block_m, stagger, False, jnp.float32, interpret)
    da = _mm(gf, b2.T.astype(jnp.float32), opts_a)
    db = _mm(a2.T.astype(jnp.float32), gf, opts_b)
    return da.astype(a2.dtype), db.astype(b2.dtype)


_mm.defvjp(_mm_fwd, _mm_bwd)


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    backend: Optional[str] = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    stagger: bool = True,
    out_dtype=None,
) -> jax.Array:
    """General matmul over the trailing two dims: (..., M, K) @ (K, N) or
    batched (..., M, K) @ (..., K, N)."""
    backend = backend or _DEFAULT_BACKEND
    if backend not in _VALID:
        raise ValueError(f"backend must be one of {_VALID}, got {backend!r}")
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)

    if backend == "xla":
        return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)

    scramble = backend == "pallas_mesh_scrambled"
    opts = (block_m, block_n, block_k, stagger, scramble, jnp.dtype(out_dtype), not _on_tpu())

    def one(a2: jax.Array, b2: jax.Array) -> jax.Array:
        return _mm(a2, b2, opts)

    if a.ndim == 2 and b.ndim == 2:
        return one(a, b)
    # Fold leading batch dims of `a`; broadcast or batch `b`.
    if b.ndim == 2:
        lead = a.shape[:-2]
        out = one(a.reshape(-1, a.shape[-1]) if a.ndim > 2 else a, b)
        return out.reshape(*lead, a.shape[-2], b.shape[-1]) if a.ndim > 2 else out
    # Fully batched: vmap over shared leading dims.
    if a.shape[:-2] != b.shape[:-2]:
        raise ValueError(f"batch dims mismatch: {a.shape} vs {b.shape}")
    lead = a.shape[:-2]
    af = a.reshape(-1, *a.shape[-2:])
    bf = b.reshape(-1, *b.shape[-2:])
    out = jax.vmap(one)(af, bf)
    return out.reshape(*lead, *out.shape[-2:])


# The permutation's linearization is itself; its transpose is the inverse
# permutation — so S^k's VJP is S^{-k} applied to the cotangent.
@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _scramble_pallas_vjp(x: jax.Array, opts) -> jax.Array:
    block_m, block_n, k, interpret = opts
    return scramble_blocks_pallas(
        x, block_m=block_m, block_n=block_n, k=k, interpret=interpret
    )


def _scr_fwd(x, opts):
    return _scramble_pallas_vjp(x, opts), None


def _scr_bwd(opts, _, g):
    block_m, block_n, k, interpret = opts
    return (_scramble_pallas_vjp(g, (block_m, block_n, -k, interpret)),)


_scramble_pallas_vjp.defvjp(_scr_fwd, _scr_bwd)


def scramble_blocks(
    x: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    k: int = 1,
    use_pallas: bool = True,
) -> jax.Array:
    """S^k at block granularity on the trailing (m, n) dims."""
    if not use_pallas:
        out = x
        fn = ref.scramble_blocks_ref if k >= 0 else ref.unscramble_blocks_ref
        for _ in range(abs(k)):
            out = fn(out, block_m=block_m, block_n=block_n)
        return out
    return _scramble_pallas_vjp(x, (block_m, block_n, k, not _on_tpu()))
