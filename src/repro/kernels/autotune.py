"""Block-shape autotuner for the mesh-matmul dispatch path (DESIGN.md §3).

The Pallas kernel's performance is set almost entirely by its block triple
(block_m, block_n, block_k): it fixes the VMEM working set per grid cell, the
MXU arithmetic intensity, and the HBM padding waste.  This module owns the
choice so `ops.matmul` callers never hard-code 128³ again:

  candidate_blocks   MXU-aligned triples pruned by a VMEM-budget model of the
                     per-cell working set (A-tile + B-tile + f32 accumulator
                     + optional epilogue tiles)
  autotune           cache lookup -> (timed | model-scored) search over the
                     candidates, warm-started from the nearest cached shape
  AutotuneCache      versioned persistent JSON keyed by
                     (M, K, N, dtype, backend, symmetry, platform) —
                     formalizes the legacy flat-dict `.autotune_cache.json`
                     (migrated transparently on load)
  resolve_blocks     process-memoized entry point used by `ops.matmul`
                     whenever block sizes aren't explicitly passed

Search modes: "time" runs the real kernel per candidate (TPU; interpret mode
on CPU is not a measurement), "model" ranks by the analytic score
intensity x padding-utilization, "auto" picks "time" on TPU and "model"
elsewhere.  A cache hit never searches.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
import warnings
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as _obs
from repro.resilience import faults as _faults
from repro.resilience import ledger as _rledger
from repro.resilience.policy import retry_call as _retry_call

__all__ = [
    "CACHE_VERSION",
    "AutotuneCache",
    "autotune",
    "cache_key",
    "candidate_blocks",
    "default_cache",
    "measure_best_ms",
    "model_score",
    "resolve_blocks",
    "vmem_bytes",
]

CACHE_VERSION = 2
DEFAULT_CACHE_FILENAME = ".autotune_cache.json"
_ENV_CACHE = "REPRO_AUTOTUNE_CACHE"

_LANE = 128  # MXU tile edge — every candidate dimension is a multiple
# Per-core VMEM is ~16 MiB; leave headroom for pipeline double-buffering
# (Pallas keeps two in-flight copies of each input block).
DEFAULT_VMEM_BUDGET = 12 * 1024 * 1024

Blocks = Tuple[int, int, int]


def cache_key(
    m: int,
    k: int,
    n: int,
    dtype,
    backend: str,
    *,
    symmetry: int = 0,
    platform: Optional[str] = None,
) -> str:
    """`"MxKxN|dtype|backend|symS|platform"` — the legacy key format, kept."""
    platform = platform or jax.default_backend()
    return f"{m}x{k}x{n}|{jnp.dtype(dtype).name}|{backend}|sym{symmetry}|{platform}"


def vmem_bytes(
    bm: int,
    bn: int,
    bk: int,
    dtype,
    *,
    has_bias: bool = False,
    has_residual: bool = False,
) -> int:
    """Per-grid-cell VMEM working set: A-tile + B-tile + f32 acc (+ epilogue)."""
    ds = jnp.dtype(dtype).itemsize
    total = (bm * bk + bk * bn) * ds + bm * bn * 4
    if has_bias:
        total += bn * 4
    if has_residual:
        total += bm * bn * ds
    return total


def _dim_candidates(dim: int, aligns: Tuple[int, ...]) -> List[int]:
    """Aligned block sizes that don't exceed the dim padded up to alignment."""
    ceil_dim = max(dim, aligns[0])
    out = [a for a in aligns if a <= ((ceil_dim + aligns[0] - 1) // aligns[0]) * aligns[0]]
    return out or [aligns[0]]


def candidate_blocks(
    m: int,
    k: int,
    n: int,
    dtype,
    *,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    aligns: Tuple[int, ...] = (_LANE, 2 * _LANE, 4 * _LANE),
    has_bias: bool = False,
    has_residual: bool = False,
) -> List[Blocks]:
    """MXU-aligned (bm, bn, bk) triples whose working set fits the budget."""
    cands = [
        (bm, bn, bk)
        for bm in _dim_candidates(m, aligns)
        for bn in _dim_candidates(n, aligns)
        for bk in _dim_candidates(k, aligns)
        if vmem_bytes(bm, bn, bk, dtype, has_bias=has_bias, has_residual=has_residual)
        <= vmem_budget
    ]
    if not cands:  # budget smaller than the minimal tile: fall back anyway
        cands = [(aligns[0], aligns[0], aligns[0])]
    return cands


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def model_score(m: int, k: int, n: int, blocks: Blocks, dtype) -> float:
    """Analytic desirability: MXU intensity x padding utilization.

    intensity   = FLOPs per HBM byte streamed for one (bm, bn, bk) phase —
                  rewards large blocks (the roofline x-axis).
    utilization = useful fraction of the padded iteration space — penalizes
                  blocks that overhang M/N/K (wasted MXU issue slots).
    """
    bm, bn, bk = blocks
    ds = jnp.dtype(dtype).itemsize
    intensity = (2 * bm * bn * bk) / ((bm * bk + bk * bn) * ds)
    padded = (
        _ceil_div(m, bm) * bm * _ceil_div(n, bn) * bn * _ceil_div(k, bk) * bk
    )
    utilization = (m * n * k) / padded
    return intensity * utilization


class AutotuneCache:
    """Versioned persistent JSON cache of chosen block triples.

    On-disk format (v2):
        {"version": 2, "entries": {key: {"blocks": [bm, bn, bk],
                                         "source": "timed|model|seed",
                                         "ms": float|null}}}
    A legacy v1 file (flat {key: [bm, bn, bk]} — the orphaned
    `.autotune_cache.json` this formalizes) is migrated in memory on load and
    rewritten as v2 on the next save.  Any other/unknown version is discarded
    rather than trusted.

    Resilience (DESIGN.md §11): an unreadable/corrupt cache file is
    QUARANTINED — warned about once (with the path), moved aside to
    `<path>.corrupt`, and recorded in the resilience ledger — never crashed
    on and never silently retuned-forever.  Individual entries are validated
    against the VMEM model on load: an entry whose working set cannot fit the
    budget (a corrupt or hand-edited cache) is dropped with a ledger record,
    and the next `autotune` miss rebuilds it.
    """

    def __init__(
        self,
        path: Optional[os.PathLike] = None,
        *,
        vmem_budget: int = DEFAULT_VMEM_BUDGET,
    ):
        self.path = Path(
            path or os.environ.get(_ENV_CACHE, DEFAULT_CACHE_FILENAME)
        )
        self.vmem_budget = vmem_budget
        self._entries: Optional[Dict[str, dict]] = None

    # -- persistence ---------------------------------------------------------

    def _entry_fits_vmem(self, key: str, blocks) -> bool:
        """VMEM-model validation: the (worst-case epilogue) working set of a
        cached triple must fit the budget candidates were pruned by.  Keys
        whose dtype field doesn't parse are conservatively kept."""
        try:
            dtype = jnp.dtype(key.split("|")[1])
        except (IndexError, TypeError):
            return True
        bm, bn, bk = (int(x) for x in blocks)
        return (
            vmem_bytes(bm, bn, bk, dtype, has_bias=True, has_residual=True)
            <= self.vmem_budget
        )

    def _quarantine_file(self, err: BaseException) -> None:
        """Move the unreadable cache aside as `<path>.corrupt` so the bad
        file is diagnosable (and never re-read), then record + warn once."""
        corrupt = Path(str(self.path) + ".corrupt")
        moved = False
        try:
            os.replace(self.path, corrupt)
            moved = True
        except OSError:
            pass
        _warn_once(
            f"autotune cache {self.path} is unreadable"
            f" ({type(err).__name__}: {err});"
            + (f" moved aside to {corrupt};" if moved else "")
            + " retuning from scratch"
        )
        _rledger.record(
            "autotune.cache_load",
            cause=f"{type(err).__name__}: {err}",
            fallback="quarantine",
            path=str(self.path),
            moved_to=str(corrupt) if moved else None,
        )

    def _load(self) -> Dict[str, dict]:
        if self._entries is not None:
            return self._entries
        self._entries = {}
        try:
            _faults.check("autotune.cache_load", path=str(self.path))
            raw = json.loads(self.path.read_text())
        except FileNotFoundError:
            return self._entries  # first run: nothing to load, nothing to warn
        except (OSError, json.JSONDecodeError, _faults.FaultError) as e:
            self._quarantine_file(e)
            return self._entries
        dropped = []
        if isinstance(raw, dict) and "version" not in raw:
            # v1 legacy: flat {key: [bm, bn, bk]}
            for key, blocks in raw.items():
                if _valid_blocks(blocks) and self._entry_fits_vmem(key, blocks):
                    self._entries[key] = {
                        "blocks": [int(x) for x in blocks],
                        "source": "seed",
                        "ms": None,
                    }
                else:
                    dropped.append(key)
        elif isinstance(raw, dict) and raw.get("version") == CACHE_VERSION:
            for key, ent in raw.get("entries", {}).items():
                if (
                    isinstance(ent, dict)
                    and _valid_blocks(ent.get("blocks"))
                    and self._entry_fits_vmem(key, ent["blocks"])
                ):
                    self._entries[key] = ent
                else:
                    dropped.append(key)
        # unknown version: start clean (stale caches must not steer the search)
        if dropped:
            _warn_once(
                f"autotune cache {self.path}: quarantined {len(dropped)}"
                f" invalid entr{'y' if len(dropped) == 1 else 'ies'}"
                f" (failed block/VMEM-model validation); they will be retuned"
            )
            _rledger.record(
                "autotune.cache_load",
                cause=f"{len(dropped)} entries failed validation",
                fallback="retune",
                path=str(self.path),
                keys=dropped[:8],
            )
        return self._entries

    def save(self) -> None:
        """Best-effort persistence with bounded retry: an unwritable
        filesystem must never turn into a matmul-time crash, so after the
        retries the final OSError is still swallowed (each retry is a ledger
        event, so persistent write failure stays visible)."""
        entries = self._load()
        payload = {"version": CACHE_VERSION, "entries": entries}

        def _write_once() -> None:
            tmp = None
            try:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                fd, tmp = tempfile.mkstemp(
                    dir=str(self.path.parent), prefix=self.path.name, suffix=".tmp"
                )
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except OSError:
                if tmp is not None:
                    try:
                        os.unlink(tmp)
                    except OSError:
                        pass
                raise

        try:
            _retry_call(
                _write_once,
                retries=2,
                base_delay=0.01,
                retry_on=(OSError,),
                site="autotune.cache_save",
            )
        except OSError:
            pass

    # -- access --------------------------------------------------------------

    def get(self, key: str) -> Optional[Blocks]:
        ent = self._load().get(key)
        return tuple(ent["blocks"]) if ent else None

    def put(
        self, key: str, blocks: Blocks, *, source: str, ms: Optional[float] = None
    ) -> None:
        self._load()[key] = {
            "blocks": [int(x) for x in blocks],
            "source": source,
            "ms": ms,
        }

    def keys(self) -> List[str]:
        return list(self._load())


_WARNED: set = set()


def _warn_once(msg: str) -> None:
    """One warning per distinct message per process — a corrupt cache is
    diagnosable without flooding every subsequent load."""
    if msg not in _WARNED:
        _WARNED.add(msg)
        warnings.warn(msg, stacklevel=3)


def _valid_blocks(blocks) -> bool:
    return (
        isinstance(blocks, (list, tuple))
        and len(blocks) == 3
        and all(isinstance(x, int) and x > 0 for x in blocks)
    )


_DEFAULT_CACHE: Optional[AutotuneCache] = None


def default_cache() -> AutotuneCache:
    """Process-wide cache instance (respects $REPRO_AUTOTUNE_CACHE)."""
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None or _DEFAULT_CACHE.path != Path(
        os.environ.get(_ENV_CACHE, DEFAULT_CACHE_FILENAME)
    ):
        _DEFAULT_CACHE = AutotuneCache()
    return _DEFAULT_CACHE


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------


def _warm_start(
    cache: AutotuneCache, m: int, k: int, n: int, dtype, backend: str, platform: str
) -> Optional[Blocks]:
    """Blocks of the nearest cached shape with the same dtype/backend/platform.

    Distance is L1 in log2 space over (M, K, N) — a 2048³ entry warm-starts a
    4096³ search better than a 512x512x128 one.
    """
    suffix = f"|{jnp.dtype(dtype).name}|{backend}|"
    best, best_d = None, float("inf")
    for key in cache.keys():
        if suffix not in key or not key.endswith(f"|{platform}"):
            continue
        try:
            mm, kk, nn = (int(x) for x in key.split("|", 1)[0].split("x"))
        except ValueError:
            continue
        d = sum(
            abs(np.log2(a) - np.log2(b))
            for a, b in zip((m, k, n), (mm, kk, nn))
        )
        if d < best_d:
            best, best_d = cache.get(key), d
    return best


def measure_best_ms(fn: Callable, *args, warmup: int = 1, reps: int = 3) -> float:
    """Best-of-`reps` wall time of `fn(*args)` in milliseconds, compile
    excluded (`warmup` untimed calls first).  Results are blocked on when
    they expose `block_until_ready` — the shared timing utility behind the
    autotuner's candidate search and `costmodel/calibrate.py`'s probes."""

    def _run():
        out = fn(*args)
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        return out

    for _ in range(warmup):
        _run()
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        _run()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _default_measure(
    m: int, k: int, n: int, dtype, backend: str, blocks: Blocks
) -> float:
    """Wall-time one real kernel launch (compile excluded), in milliseconds."""
    from repro.kernels.mesh_matmul import mesh_matmul_pallas

    bm, bn, bk = blocks
    pad = lambda d, b: _ceil_div(d, b) * b
    a = jnp.zeros((pad(m, bm), pad(k, bk)), dtype)
    b = jnp.zeros((pad(k, bk), pad(n, bn)), dtype)
    kw = dict(
        block_m=bm,
        block_n=bn,
        block_k=bk,
        scramble_out=backend == "pallas_mesh_scrambled",
        interpret=jax.default_backend() != "tpu",
    )
    return measure_best_ms(lambda: mesh_matmul_pallas(a, b, **kw))


def _scramble_compatible(m: int, n: int, blocks: Blocks) -> bool:
    """The scrambled backend needs block-aligned M/N and a square block grid
    (the σ table is defined on g x g cells) — padding is rejected at dispatch,
    so the search must never propose blocks that violate either."""
    bm, bn, _ = blocks
    return m % bm == 0 and n % bn == 0 and m // bm == n // bn


def autotune(
    m: int,
    k: int,
    n: int,
    dtype,
    backend: str = "pallas_mesh",
    *,
    symmetry: int = 0,
    platform: Optional[str] = None,
    cache: Optional[AutotuneCache] = None,
    mode: str = "auto",
    measure: Optional[Callable[..., float]] = None,
    max_timed: int = 8,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    scorer: Optional[Callable[[Blocks], float]] = None,
) -> Blocks:
    """Resolve the block triple for an (M, K, N) GEMM.  Cache hit => no search.

    mode="time": measure `max_timed` candidates (warm-start candidate first,
    then by descending model score) and keep the fastest.  mode="model": pick
    the analytic argmax without running anything.  mode="auto": "time" on TPU,
    "model" elsewhere (CPU interpret timing measures Python, not the kernel).

    The cache key is shape-level only, so candidate pruning budgets for the
    worst-case epilogue working set (bias + residual tiles) — a cached entry
    is valid for every epilogue configuration of that shape.

    `scorer` (optional) replaces the analytic `model_score` ranking with an
    external cost in milliseconds (LOWER is better) — the hook
    `costmodel/choose.py` uses to rank candidates by calibrated-coefficient
    predictions while the timed search stays the tie-breaker on TPU.
    """
    platform = platform or jax.default_backend()
    cache = cache or default_cache()
    key = cache_key(m, k, n, dtype, backend, symmetry=symmetry, platform=platform)
    hit = cache.get(key)
    if hit is not None:
        return hit

    if mode == "auto":
        mode = "time" if platform == "tpu" else "model"
    if mode not in ("time", "model"):
        raise ValueError(f"mode must be auto|time|model, got {mode!r}")

    cands = candidate_blocks(
        m,
        k,
        n,
        dtype,
        vmem_budget=vmem_budget,
        has_bias=True,
        has_residual=True,
    )
    if backend == "pallas_mesh_scrambled":
        cands = [c for c in cands if _scramble_compatible(m, n, c)] or [
            (_LANE, _LANE, _LANE)  # dispatch raises its own clear error if
        ]  # even the default can't tile M/N squarely
    if scorer is not None:
        cands.sort(key=scorer)
    else:
        cands.sort(key=lambda blk: model_score(m, k, n, blk, dtype), reverse=True)

    if mode == "model":
        best, ms, source = cands[0], None, "model"
    else:
        # Warm start: measure the nearest cached shape's blocks first, then
        # the analytically best remainder — the budget (max_timed) goes to
        # the most promising region of the space.
        warm = _warm_start(cache, m, k, n, dtype, backend, platform)
        if warm in cands:
            cands.remove(warm)
            cands.insert(0, warm)
        measure = measure or _default_measure
        timed: List[Tuple[float, Blocks]] = []
        failed = 0
        for blk in cands[:max_timed]:
            # A candidate that fails to compile/run is skipped, not fatal —
            # the search degrades toward the analytic model instead of
            # crashing plan construction.
            try:
                with _obs.span("autotune.measure", key=key, blocks=list(blk)):
                    cand_ms = measure(m, k, n, dtype, backend, blk)
                timed.append((cand_ms, blk))
            except Exception as e:
                failed += 1
                _rledger.record(
                    "autotune.measure",
                    cause=f"{type(e).__name__}: {e}",
                    fallback="skip-candidate",
                    blocks=blk,
                )
        if timed:
            ms, best = min(timed, key=lambda t: t[0])
            source = "timed"
        else:
            # every timed candidate failed: fall back to the model argmax
            best, ms, source = cands[0], None, "model"
            _rledger.record(
                "autotune.measure",
                cause=f"all {failed} timed candidates failed",
                fallback="model",
                key=key,
            )

    cache.put(key, best, source=source, ms=ms)
    cache.save()
    return best


_RESOLVE_MEMO: Dict[tuple, Blocks] = {}


def resolve_blocks(
    m: int, k: int, n: int, dtype, backend: str, *, symmetry: int = 0
) -> Blocks:
    """The dispatch layer's entry point (`kernels/api.plan`): memoized
    per-process, cache-backed, never times on non-TPU hosts (mode="auto").
    `symmetry=1` keys the symmetric-readout regime's own cache partition."""
    memo_key = (
        m, k, n, jnp.dtype(dtype).name, backend, symmetry, jax.default_backend()
    )
    got = _RESOLVE_MEMO.get(memo_key)
    if got is None:
        got = autotune(m, k, n, dtype, backend, symmetry=symmetry)
        _RESOLVE_MEMO[memo_key] = got
    return got


def clear_resolve_memo() -> None:
    """Test hook: drop the per-process memo (not the persistent cache)."""
    _RESOLVE_MEMO.clear()
