"""Pallas kernel: the scrambling transformation S at block granularity.

Pure data-movement kernel — the permutation lives entirely in the block
schedule: the S^k permutation table is passed via *scalar prefetch* (SMEM),
and the input BlockSpec index_map reads it on the TPU scalar core, so the
kernel body is a single VMEM copy.  This demonstrates the paper's point that
S is "free" when folded into an array's wiring: on TPU the wiring is the
HBM->VMEM block schedule.

S^k for any integer k composes at trace time via the cycle decomposition
(`power_perm`) — the lowered kernel is identical for every k.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _HAVE_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAVE_PLTPU = False

from repro.core.scramble import _scramble_perm_np, power_perm

__all__ = ["scramble_blocks_pallas"]


def _copy_kernel(perm_ref, x_ref, o_ref):
    del perm_ref  # consumed by the index_map only
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "k", "interpret"))
def scramble_blocks_pallas(
    x: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    k: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """Apply S^k to x's trailing (m, n) dims at (block_m, block_n) granularity.

    m/block_m must equal n/block_n (square block grid g x g); S is the paper's
    permutation on the g^2 blocks.  Negative k unscrambles.
    """
    m, n = x.shape[-2], x.shape[-1]
    g = m // block_m
    if g * block_m != m or g * block_n != n or g != n // block_n:
        raise ValueError(
            f"(m={m}, n={n}) is not a square g x g grid of ({block_m},{block_n}) blocks"
        )
    if x.ndim != 2:
        # Batch dims handled by vmap; the kernel itself stays 2D.
        lead = x.shape[:-2]
        out = jax.vmap(
            lambda t: scramble_blocks_pallas(
                t, block_m=block_m, block_n=block_n, k=k, interpret=interpret
            )
        )(x.reshape(-1, m, n))
        return out.reshape(*lead, m, n)

    perm = jnp.asarray(power_perm(_scramble_perm_np(g), k), dtype=jnp.int32)

    def in_map(i, j, perm_ref):
        src = perm_ref[i * g + j]
        return src // g, src % g

    def out_map(i, j, perm_ref):
        del perm_ref
        return i, j

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g, g),
        in_specs=[pl.BlockSpec((block_m, block_n), in_map)],
        out_specs=pl.BlockSpec((block_m, block_n), out_map),
    )

    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(perm, x)
