"""Pallas TPU kernels for the mesh-array technique + jit wrappers and oracles.

mesh_matmul.py      staggered-k blocked matmul: fused scramble output, fused
                    bias/activation/residual epilogue, batched (b, i, j, k)
                    grid variant
scramble_kernel.py  S^k as a scalar-prefetch block-permutation kernel
autotune.py         block-shape autotuner: VMEM-budget candidate pruning,
                    timed/model search, versioned persistent cache
ops.py              public dispatch (xla | pallas_mesh | pallas_mesh_scrambled)
ref.py              pure-jnp oracles all kernels are tested against
"""

from repro.kernels.ops import (
    get_default_backend,
    matmul,
    scramble_blocks,
    set_default_backend,
)

__all__ = ["matmul", "scramble_blocks", "set_default_backend", "get_default_backend"]
