"""Pallas TPU kernels for the mesh-array technique + the plan/execute API.

api.py              plan/execute operator API: typed GemmSpec/Epilogue,
                    capability-based backend registry (ref | xla |
                    pallas_mesh), plan(spec) -> cached reusable executable
mesh_matmul.py      staggered-k blocked matmul: fused scramble output, fused
                    bias/activation/residual epilogue, batched (b, i, j, k)
                    grid variant
grouped.py          ragged grouped matmul (MoE experts): scalar-prefetched
                    group sizes steering a (g, i, j, k) grid
scramble_kernel.py  S^k as a scalar-prefetch block-permutation kernel
autotune.py         block-shape autotuner: VMEM-budget candidate pruning,
                    timed/model search, versioned persistent cache
ops.py              legacy string-dispatch compat shim over api.py
ref.py              pure-jnp oracles all kernels are tested against
"""

from repro.kernels.api import (
    BackendCapabilities,
    Epilogue,
    GemmSpec,
    GroupedPlan,
    GroupSpec,
    Plan,
    ShardedGroupedPlan,
    ShardedPlan,
    ShardSpec,
    default_backend,
    plan,
    register_backend,
)
from repro.kernels.ops import (
    get_default_backend,
    matmul,
    scramble_blocks,
    set_default_backend,
)

__all__ = [
    "BackendCapabilities",
    "Epilogue",
    "GemmSpec",
    "GroupSpec",
    "GroupedPlan",
    "Plan",
    "ShardSpec",
    "ShardedGroupedPlan",
    "ShardedPlan",
    "default_backend",
    "get_default_backend",
    "matmul",
    "plan",
    "register_backend",
    "scramble_blocks",
    "set_default_backend",
]
