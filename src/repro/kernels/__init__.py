"""Pallas TPU kernels for the mesh-array technique + jit wrappers and oracles.

mesh_matmul.py      staggered-k blocked matmul (+ fused scramble output)
scramble_kernel.py  S^k as a scalar-prefetch block-permutation kernel
ops.py              public dispatch (xla | pallas_mesh | pallas_mesh_scrambled)
ref.py              pure-jnp oracles all kernels are tested against
"""

from repro.kernels.ops import (
    get_default_backend,
    matmul,
    scramble_blocks,
    set_default_backend,
)

__all__ = ["matmul", "scramble_blocks", "set_default_backend", "get_default_backend"]
