"""Pallas TPU kernel: grouped (ragged-batch) matmul on the mesh-array schedule.

The MoE regime is exactly the paper's repeated-multiplication setting (Kak,
"Efficiency of Matrix Multiplication on the Cross-Wired Mesh Array"): every
layer issues dozens of small per-expert GEMMs that share K/N but differ in
(ragged) row count.  This kernel runs them all as ONE `pallas_call`:

  * **Capacity layout** — tokens arrive concatenated group-major in a
    (num_groups * rows_per_group, K) buffer; group g owns rows
    [g*rows_per_group, g*rows_per_group + size_g).  `rows_per_group` is the
    static bound (`GroupSpec`), the per-group `sizes` are runtime values.
  * **Scalar-prefetched ragged steering** — the per-group row counts ride in
    SMEM via scalar prefetch, steering the (g, i, j, k) grid: a row block
    whose rows all fall beyond its group's size skips the MXU work entirely
    (empty experts cost zero dot products), and the flush masks rows past
    the group boundary to zero.
  * **Staggered k-loop per group tile** — cell (g, i, j) contracts in the
    rotated order (g + i + j + k) mod nk, the same no-padding feeding
    discipline as `mesh_matmul_pallas` (DESIGN.md §2), now spread across
    groups as well so concurrently-active cells stream disjoint K slabs.
  * **Fused epilogue per group tile** — optional per-group bias (G, N),
    activation, and residual (rows, N) execute in the k == nk-1 flush while
    the f32 accumulator is in VMEM (DESIGN.md §3).

Contract: output row r is tokens[r] @ weights[r // rows_per_group]; rows at
or beyond a group's size are ZERO (whatever the padding rows contain).  The
pure-jnp oracle is `repro.kernels.ref.grouped_matmul_ref`; the plan/execute
integration (including the custom VJP for training) lives in
`repro.kernels.api`.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.mesh_matmul import ACTIVATIONS, _HAVE_PLTPU

if _HAVE_PLTPU:
    from jax.experimental.pallas import tpu as pltpu
else:  # pragma: no cover
    pltpu = None

__all__ = ["grouped_mesh_matmul_pallas"]


def _make_grouped_kernel(
    *, nk: int, block_m: int, activation: Optional[str], has_bias: bool,
    has_residual: bool
):
    """Kernel body for one fused-operand configuration.

    Ref order (after the scalar-prefetch sizes table): a, b, [bias],
    [residual], out, acc_scratch.
    """
    act = ACTIVATIONS[activation]

    def kernel(sz_ref, *refs):
        refs = list(refs)
        a_ref, b_ref = refs[0], refs[1]
        pos = 2
        bias_ref = res_ref = None
        if has_bias:
            bias_ref, pos = refs[pos], pos + 1
        if has_residual:
            res_ref, pos = refs[pos], pos + 1
        o_ref, acc_ref = refs[pos], refs[pos + 1]

        g = pl.program_id(0)
        i = pl.program_id(1)
        k = pl.program_id(3)
        size = sz_ref[g]
        row0 = i * block_m

        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # Ragged steering: a row block entirely past its group's size has no
        # valid rows — skip the dot (the paper's "no zeros are padded" as
        # skipped MXU issue slots for empty/short groups).
        @pl.when(row0 < size)
        def _accumulate():
            acc_ref[...] += jnp.dot(
                a_ref[...], b_ref[0], preferred_element_type=jnp.float32
            )

        @pl.when(k == nk - 1)
        def _flush():
            out = acc_ref[...]
            if bias_ref is not None:
                out = out + bias_ref[...].astype(jnp.float32)  # (1, bn) bcast
            out = act(out)
            if res_ref is not None:
                out = out + res_ref[...].astype(jnp.float32)
            rows = row0 + jax.lax.broadcasted_iota(jnp.int32, out.shape, 0)
            out = jnp.where(rows < size, out, 0.0)
            o_ref[...] = out.astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m",
        "block_n",
        "block_k",
        "stagger",
        "activation",
        "out_dtype",
        "interpret",
    ),
)
def grouped_mesh_matmul_pallas(
    tokens: jax.Array,      # (num_groups * rows_per_group, K), group-major
    sizes: jax.Array,       # (num_groups,) int32 valid-row counts
    weights: jax.Array,     # (num_groups, K, N)
    *,
    bias: Optional[jax.Array] = None,       # (num_groups, N), per-group
    residual: Optional[jax.Array] = None,   # (rows, N)
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    stagger: bool = True,
    activation: Optional[str] = None,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
) -> jax.Array:
    """out[r] = epilogue(tokens[r] @ weights[r // rows_per_group]); zero past
    each group's size.  rows_per_group must divide by block_m, K by block_k,
    N by block_n (the api-layer wrapper pads K/N)."""
    if not _HAVE_PLTPU:
        raise NotImplementedError(
            "grouped_mesh_matmul_pallas needs jax.experimental.pallas.tpu"
            " (scalar-prefetch grid specs); use the xla grouped backend on"
            " this jax build"
        )
    rows, k_dim = tokens.shape
    n_groups, k2, n = weights.shape
    if k_dim != k2:
        raise ValueError(f"contraction mismatch: {tokens.shape} @ {weights.shape}")
    if rows % n_groups:
        raise ValueError(
            f"rows={rows} not divisible by num_groups={n_groups}"
            " (capacity layout requires equal static per-group bounds)"
        )
    rpg = rows // n_groups
    if rpg % block_m or n % block_n or k_dim % block_k:
        raise ValueError(
            f"grouped shape (rpg={rpg}, K={k_dim}, N={n}) not divisible by"
            f" blocks ({block_m},{block_n},{block_k})"
        )
    if sizes.shape != (n_groups,):
        raise ValueError(f"sizes must have shape ({n_groups},), got {sizes.shape}")
    if bias is not None and bias.shape != (n_groups, n):
        raise ValueError(
            f"grouped bias must have shape ({n_groups}, {n}), got {bias.shape}"
        )
    if residual is not None and residual.shape != (rows, n):
        raise ValueError(
            f"residual must have shape ({rows}, {n}), got {residual.shape}"
        )
    if activation not in ACTIVATIONS:
        raise ValueError(
            f"activation must be one of {sorted(k for k in ACTIVATIONS if k)},"
            f" got {activation!r}"
        )
    out_dtype = out_dtype or jnp.result_type(tokens.dtype, weights.dtype)
    nm, nn, nk = rpg // block_m, n // block_n, k_dim // block_k
    grid = (n_groups, nm, nn, nk)

    def kk_of(g, i, j, k):
        return jax.lax.rem(g + i + j + k, nk) if stagger else k

    # index_maps: the sizes table is consumed only by the kernel body (ragged
    # steering); block placement is static given the capacity layout.
    def a_map(g, i, j, k, sz):
        del sz
        return g * nm + i, kk_of(g, i, j, k)

    def b_map(g, i, j, k, sz):
        del sz
        return g, kk_of(g, i, j, k), j

    def bias_map(g, i, j, k, sz):
        del i, k, sz
        return g, j

    def out_map(g, i, j, k, sz):
        del k, sz
        return g * nm + i, j

    in_specs = [
        pl.BlockSpec((block_m, block_k), a_map),
        pl.BlockSpec((1, block_k, block_n), b_map),
    ]
    operands = [tokens, weights]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, block_n), bias_map))
        operands.append(bias)
    if residual is not None:
        in_specs.append(pl.BlockSpec((block_m, block_n), out_map))
        operands.append(residual)

    scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)]
    compiler_params = None
    if _HAVE_PLTPU and not interpret:  # pragma: no cover — TPU-only path
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        )

    kernel = _make_grouped_kernel(
        nk=nk,
        block_m=block_m,
        activation=activation,
        has_bias=bias is not None,
        has_residual=residual is not None,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), out_map),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, n), out_dtype),
        compiler_params=compiler_params,
        interpret=interpret,
    )(sizes.astype(jnp.int32), *operands)
