"""Plan/execute operator API: typed GEMM specs + capability-based backends.

This module is the architectural seam between "what GEMM do I need?" and
"which kernel runs it" (DESIGN.md §8).  It separates *planning* — resolve a
backend against declared capabilities, fix block shapes through the autotuner,
precompute the σ/stagger tables host-side — from *execution* — a cached,
reusable, jitted callable that serving and training graphs invoke per request:

    spec = GemmSpec.from_operands(a, b, epilogue=Epilogue(bias=True,
                                                          activation="gelu"))
    p = plan(spec)                  # validate + autotune + build, ONCE
    y = p(a, b, bias=bias)          # reuse forever; p is cached per spec

`GemmSpec.structure` replaces the old `pallas_mesh_scrambled` pseudo-backend:
the *regime* the paper's array supports (general 2n-1-step product, the
3n/2+1 symmetric readout, the scrambling mode) is a property of the problem,
not of the kernel that happens to run it.  Backends declare which structures
(and which other capabilities: fully-batched grids, fused epilogues,
off-TPU interpret execution, autotuned blocks, device-mesh sharding) they
support via `register_backend`, so ref/XLA/Pallas implementations — and test
doubles — register uniformly; `plan` picks a capable backend instead of
string-matching.

The API is sharding-aware end to end (DESIGN.md §9): attach a frozen
`ShardSpec` (device-mesh axes + logical partition of M/K/N/batch, derivable
from `parallel.sharding.ShardingRules`) and `plan(spec, mesh=mesh)` returns a
`ShardedPlan` — the same per-shard Plan lowered through `shard_map` with a
collective schedule (`replicated` | `allgather_a` | `reduce_scatter_k` |
`ring_k`) fused around the kernel call via `parallel/collectives.py` and
`parallel/systolic.py`.  An unsharded spec is just the size-1-axes case of
the same planner path — there is one planner, not two.

The planner also covers **grouped (ragged-batch) GEMMs** (DESIGN.md §10):
attach a `GroupSpec` (num_groups, static rows-per-group bound; K/N shared)
and `plan(spec)` returns a `GroupedPlan` taking `(tokens, group_offsets,
weights_stacked)` — the MoE expert regime, where every layer multiplies many
small ragged row batches against per-expert weight slabs.  Backends declare
the `grouped` capability with a dedicated impl (the Pallas ragged mesh
kernel in `kernels/grouped.py`; segment-masked einsum on xla/ref), and an
`expert` collective schedule shards the group dim over a device mesh (EP).

The planner degrades instead of dying (DESIGN.md §11).  `plan()` resolves a
capability-ordered **fallback chain** (`FALLBACK_ORDER`: pallas_mesh → xla →
ref) behind the chosen backend: a failed plan build or a failed execution
falls to the next capable backend instead of raising, recording a
`DegradationEvent` in the plan's own `health` record (`describe()["health"]`)
and in the process-wide `resilience.ledger`.  Sharded plans degrade along the
schedule axis instead — a collective failure falls back to replicated
(unsharded) execution of the same spec.  Spec-level validation errors
(`PlanValidationError`) never trigger fallback: a spec every backend must
reject is a caller bug, not a backend failure.  The opt-in `guard_nonfinite`
plan option samples outputs for NaN/Inf post-epilogue (fused paths stay
fused) with a `raise | fallback | zero_and_record` policy.

`repro.kernels.ops.matmul` remains as a thin compat shim over this module.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
from typing import Any, Callable, Dict, FrozenSet, List, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import autotune as _autotune
from repro.kernels import ref
from repro.kernels.grouped import grouped_mesh_matmul_pallas
from repro.obs import trace as _obs
from repro.resilience import faults as _faults
from repro.resilience import ledger as _rledger
from repro.resilience.policy import (
    NonFiniteError,
    nonfinite_count,
    normalize_policy,
    scrub_nonfinite,
)
from repro.kernels.mesh_matmul import (
    ACTIVATIONS,
    mesh_matmul_pallas,
    mesh_matmul_pallas_batched,
    sigma_block_table,
)

__all__ = [
    "FALLBACK_ORDER",
    "SCHEDULES",
    "STRUCTURES",
    "BackendCapabilities",
    "CapabilityError",
    "Epilogue",
    "PlanValidationError",
    "GemmSpec",
    "GroupSpec",
    "GroupedPlan",
    "Plan",
    "ShardSpec",
    "ShardedGroupedPlan",
    "ShardedPlan",
    "AsyncResult",
    "apply_epilogue",
    "backend_names",
    "clear_plan_cache",
    "default_backend",
    "execute_async",
    "get_capabilities",
    "get_default",
    "plan",
    "plan_cache_info",
    "register_backend",
    "set_default",
    "unregister_backend",
]

STRUCTURES = ("general", "symmetric", "scrambled")

# Collective schedules a ShardedPlan can lower to (DESIGN.md §9, §15):
#   replicated        no collective — M/N/batch partitions are purely local
#                     (each device owns its C tile; all-None axes = the fully
#                     replicated degenerate case unsharded specs route through)
#   allgather_a       A row-sharded on M; each device computes its result
#                     chunk ONCE and the f32 chunks circulate the ring
#                     (collectives.ring_allgather_matmul); output replicated
#   reduce_scatter_k  A/B sharded on K; partial products ring-reduced so each
#                     device ends with its M/p row slice
#                     (collectives.matmul_ring_reducescatter)
#   ring_k            A/B sharded on K; the paper's 2n-1 staggered feed as p
#                     accumulator wavefronts ppermuting around the ring
#                     (systolic.ring_systolic_kpass); output replicated
#   *_overlap         double-buffered twin of the base schedule: every ring
#                     hop is issued while a kernel call runs, so steady-state
#                     step time is max(compute, comm) instead of the sum —
#                     bitwise-equal outputs to the serial twin on the XLA
#                     backend (the serial path is the oracle).  The column-
#                     half variants (allgather_a/ring_k) build the per-shard
#                     kernel at n/2, so they need even N and axis size >= 2.
#   pipeline          A/B sharded on K like reduce_scatter_k, but the per-rank
#                     row block is 1F1B-microbatched: accumulator chains flow
#                     through the stage ring one tick apart with every hop
#                     double-buffered (collectives.ring_pipeline_matmul);
#                     output row-sharded, bitwise-equal to reduce_scatter_k
#   expert            grouped specs only: the group (expert) dim sharded over
#                     axis_g — tokens/weights/sizes reshard at the shard_map
#                     boundary (the EP all-to-all), each device runs the
#                     grouped kernel over its local groups, output rows stay
#                     group-sharded
SCHEDULES = (
    "replicated",
    "allgather_a",
    "allgather_a_overlap",
    "reduce_scatter_k",
    "reduce_scatter_k_overlap",
    "ring_k",
    "ring_k_overlap",
    "pipeline",
    "expert",
)


def _is_overlap_schedule(sched: str) -> bool:
    """True for schedules whose ring hops are double-buffered against kernel
    calls — the cost model prices their collective under max(compute, comm)
    instead of adding it (costmodel.model.predict)."""
    return sched.endswith("_overlap") or sched == "pipeline"


def _pipeline_microbatches(eff_m: int, pk: int) -> int:
    """Microbatch count for the `pipeline` schedule: two chains per stage
    when the per-stage row block splits evenly (so the steady state always
    has one hop in flight behind one kernel), else one."""
    mb = eff_m // pk
    f = 2 if mb >= 2 and mb % 2 == 0 else 1
    return f * pk


# ---------------------------------------------------------------------------
# Typed specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """The fused-epilogue contract (DESIGN.md §3): y = act(AB + bias) + residual.

    Declares *which* epilogue operands exist — the arrays themselves are
    execution-time inputs, so one plan serves every bias/residual value.
    """

    bias: bool = False
    activation: Optional[str] = None
    residual: bool = False

    def __post_init__(self):
        if self.activation not in ACTIVATIONS:
            raise ValueError(
                f"activation must be one of {sorted(k for k in ACTIVATIONS if k)},"
                f" got {self.activation!r}"
            )
        if self.activation == "none":
            object.__setattr__(self, "activation", None)

    @property
    def is_identity(self) -> bool:
        return not (self.bias or self.residual) and self.activation is None


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """Ragged-batch structure of one grouped GEMM (DESIGN.md §10).

    `num_groups` weight slabs share K/N; tokens arrive concatenated
    group-major in a capacity layout with a STATIC `rows_per_group` bound —
    group g owns rows [g*rows_per_group, g*rows_per_group + size_g), where
    the runtime sizes ride in the `group_offsets` execution operand
    (cumulative counts, (num_groups+1,)).  Rows at or beyond a group's size
    are zero on output.  Hashable and frozen: part of the plan-cache key, so
    blocks are autotuned once per logical group shape.
    """

    num_groups: int
    rows_per_group: int

    def __post_init__(self):
        object.__setattr__(self, "num_groups", int(self.num_groups))
        object.__setattr__(self, "rows_per_group", int(self.rows_per_group))
        if self.num_groups <= 0 or self.rows_per_group <= 0:
            raise ValueError(
                f"GroupSpec dims must be positive, got num_groups="
                f"{self.num_groups}, rows_per_group={self.rows_per_group}"
            )

    @property
    def rows(self) -> int:
        """Total (static) token rows of the capacity layout."""
        return self.num_groups * self.rows_per_group


# Physical mesh axes naming a partition: a single axis name, or (for the
# no-collective dims of the replicated schedule) a tuple of axis names.
Axes = Union[str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Device-mesh partition of one GEMM (DESIGN.md §9).

    `mesh_axes` pins the (name, size) layout of the device mesh the spec was
    built for — the spec stays hashable (it is part of the plan-cache key)
    and `plan(spec, mesh=...)` verifies the live mesh matches.  The four
    axis fields name which mesh axis partitions each LOGICAL dim of
    (batch..., M, K) @ (K, N); None leaves that dim whole.  `schedule` pins a
    collective schedule from SCHEDULES, or "auto" to let the planner choose
    (K sharded -> reduce_scatter_k when M divides the axis, else ring_k;
    otherwise the no-collective replicated schedule).

    `axis_k` must be a single axis name — the K collectives are 1D rings.
    `axis_m`/`axis_n`/`axis_batch` may be axis tuples under the replicated
    schedule, where they only slice the local tile.  `axis_g` (single axis)
    partitions the group dim of a GROUPED spec — the `expert` schedule, EP.
    A ShardSpec whose axes are all None/size-1 (`ShardSpec.unsharded`)
    routes through the identical ShardedPlan path and reproduces the
    unsharded Plan bit for bit.
    """

    mesh_axes: Tuple[Tuple[str, int], ...]
    axis_m: Optional[Axes] = None
    axis_k: Optional[str] = None
    axis_n: Optional[Axes] = None
    axis_batch: Optional[Axes] = None
    axis_g: Optional[str] = None
    schedule: str = "auto"

    def __post_init__(self):
        object.__setattr__(
            self,
            "mesh_axes",
            tuple((str(n), int(s)) for n, s in self.mesh_axes),
        )
        names = [n for n, _ in self.mesh_axes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate mesh axis names in {self.mesh_axes}")
        if self.schedule not in ("auto",) + SCHEDULES:
            raise ValueError(
                f"schedule must be 'auto' or one of {SCHEDULES},"
                f" got {self.schedule!r}"
            )
        seen: List[str] = []
        for field in ("axis_m", "axis_k", "axis_n", "axis_batch", "axis_g"):
            v = getattr(self, field)
            if isinstance(v, list):
                v = tuple(v)
            if isinstance(v, tuple) and len(v) == 1:
                v = v[0]
            if field == "axis_k" and v is not None and not isinstance(v, str):
                raise ValueError(
                    f"axis_k must be a single mesh axis name (the K"
                    f" collectives are 1D rings), got {self.axis_k!r}"
                )
            if field == "axis_g" and v is not None and not isinstance(v, str):
                raise ValueError(
                    f"axis_g must be a single mesh axis name (the group dim"
                    f" shards over one EP axis), got {self.axis_g!r}"
                )
            object.__setattr__(self, field, v)
            for nm in (v,) if isinstance(v, str) else (v or ()):
                if nm not in names:
                    raise ValueError(
                        f"{field}={nm!r} is not a mesh axis; mesh has {names}"
                    )
                if nm in seen:
                    raise ValueError(
                        f"mesh axis {nm!r} partitions more than one GEMM dim"
                    )
                seen.append(nm)

    # -- constructors --------------------------------------------------------

    @classmethod
    def from_mesh(
        cls,
        mesh: Mesh,
        *,
        m: Optional[Axes] = None,
        k: Optional[str] = None,
        n: Optional[Axes] = None,
        batch: Optional[Axes] = None,
        g: Optional[str] = None,
        schedule: str = "auto",
    ) -> "ShardSpec":
        """Partition over a live device mesh by PHYSICAL axis names."""
        return cls(
            mesh_axes=tuple((str(a), int(s)) for a, s in mesh.shape.items()),
            axis_m=m,
            axis_k=k,
            axis_n=n,
            axis_batch=batch,
            axis_g=g,
            schedule=schedule,
        )

    @classmethod
    def from_rules(
        cls,
        mesh: Mesh,
        rules,
        *,
        m: Optional[str] = None,
        k: Optional[str] = None,
        n: Optional[str] = None,
        batch: Optional[str] = None,
        g: Optional[str] = None,
        schedule: str = "auto",
    ) -> "ShardSpec":
        """Partition by LOGICAL axis names (e.g. m='batch', n='mlp',
        g='experts') mapped through a `parallel.sharding.ShardingRules`
        table; rule axes the mesh doesn't carry are dropped, exactly as in
        `named_sharding`."""
        from repro.parallel.sharding import _axes_on_mesh

        def phys(logical):
            return None if logical is None else _axes_on_mesh(mesh, rules.get(logical))

        return cls.from_mesh(
            mesh,
            m=phys(m),
            k=phys(k),
            n=phys(n),
            batch=phys(batch),
            g=phys(g),
            schedule=schedule,
        )

    @classmethod
    def unsharded(cls, mesh: Mesh) -> "ShardSpec":
        """All dims whole: the degenerate ShardSpec that routes an unsharded
        product through the same ShardedPlan planner path."""
        return cls.from_mesh(mesh)

    # -- derived -------------------------------------------------------------

    def axis_size(self, axes: Optional[Axes]) -> int:
        """Product of mesh-axis sizes a partition maps to (1 for None)."""
        sizes = dict(self.mesh_axes)
        out = 1
        for nm in (axes,) if isinstance(axes, str) else (axes or ()):
            out *= sizes[nm]
        return out

    @property
    def is_trivial(self) -> bool:
        """True when every partition has size 1 (numerically unsharded)."""
        return all(
            self.axis_size(a) == 1
            for a in (
                self.axis_m,
                self.axis_k,
                self.axis_n,
                self.axis_batch,
                self.axis_g,
            )
        )


def _dtype_name(dt) -> str:
    return jnp.dtype(dt).name


@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """Logical description of one GEMM: (batch..., M, K) @ (K, N) — or, when
    `batched_b`, (batch..., M, K) @ (batch..., K, N).

    `structure` names the paper regime of the product:
      general    arbitrary C = AB (the 2n-1-step mode)
      symmetric  caller asserts C = Cᵀ (square; the early-readout mode — keys
                 a separate autotune-cache partition, sym1)
      scrambled  output lands in the paper's σ block arrangement (replaces the
                 old `pallas_mesh_scrambled` pseudo-backend)

    `blocks` is an optional (bm, bn, bk) override; entries left None are
    resolved by the autotuner at plan time.  `shard` attaches a device-mesh
    partition (ShardSpec): `plan(spec, mesh=mesh)` then returns a ShardedPlan
    lowering the per-shard product through shard_map with a collective
    schedule.  `group` attaches a GroupSpec, turning the spec into a grouped
    (ragged-batch) GEMM: (num_groups * rows_per_group, K) tokens against
    (num_groups, K, N) stacked weights — `m` is the total row bound and
    `plan` returns a GroupedPlan.  Hashable and frozen — specs are the
    plan-cache key.
    """

    m: int
    k: int
    n: int
    batch: Tuple[int, ...] = ()
    batched_b: bool = False
    dtype_a: str = "float32"
    dtype_b: str = "float32"
    out_dtype: Optional[str] = None
    structure: str = "general"
    epilogue: Epilogue = Epilogue()
    blocks: Optional[Tuple[Optional[int], Optional[int], Optional[int]]] = None
    stagger: bool = True
    shard: Optional[ShardSpec] = None
    group: Optional[GroupSpec] = None
    # Caller hint: how many products this plan will run back-to-back with the
    # SAME B (decode loops, repeated layers).  Per the cross-wired mesh-array
    # analysis (Kak, arXiv:1411.3273) repeated products amortize the fill
    # latency and the resident-operand traffic — the cost model scales its
    # per-call estimate accordingly.  Numerics are unaffected.
    repeats: int = 1

    def __post_init__(self):
        if self.structure not in STRUCTURES:
            raise ValueError(
                f"structure must be one of {STRUCTURES}, got {self.structure!r}"
            )
        if min(self.m, self.k, self.n) <= 0:
            raise ValueError(f"dims must be positive, got {(self.m, self.k, self.n)}")
        if self.batched_b and not self.batch:
            raise ValueError("batched_b requires leading batch dims")
        if self.shard is not None and not isinstance(self.shard, ShardSpec):
            raise TypeError(
                f"shard must be a ShardSpec, got {type(self.shard).__name__}"
            )
        if self.group is not None:
            if not isinstance(self.group, GroupSpec):
                raise TypeError(
                    f"group must be a GroupSpec, got {type(self.group).__name__}"
                )
            if self.structure != "general":
                raise ValueError(
                    f"grouped specs are structure='general' only (the σ and"
                    f" symmetric regimes are defined on one product), got"
                    f" {self.structure!r}"
                )
            if self.batch or self.batched_b:
                raise ValueError(
                    "grouped specs carry their batching in the GroupSpec;"
                    " leading batch dims are not supported"
                )
            if self.m != self.group.rows:
                raise ValueError(
                    f"grouped spec m={self.m} must equal"
                    f" num_groups*rows_per_group={self.group.rows}"
                    f" (use GemmSpec.for_groups)"
                )
        object.__setattr__(self, "repeats", int(self.repeats))
        if self.repeats < 1:
            raise ValueError(f"repeats must be >= 1, got {self.repeats}")
        object.__setattr__(self, "batch", tuple(int(d) for d in self.batch))
        object.__setattr__(self, "dtype_a", _dtype_name(self.dtype_a))
        object.__setattr__(self, "dtype_b", _dtype_name(self.dtype_b))
        if self.out_dtype is not None:
            object.__setattr__(self, "out_dtype", _dtype_name(self.out_dtype))
        if self.blocks is not None:
            if len(self.blocks) != 3:
                raise ValueError(
                    f"blocks must be a (bm, bn, bk) triple, got {self.blocks!r}"
                )
            bks = tuple(None if x in (None, 0) else int(x) for x in self.blocks)
            object.__setattr__(self, "blocks", None if bks == (None,) * 3 else bks)

    @classmethod
    def from_operands(
        cls,
        a: jax.Array,
        b: jax.Array,
        *,
        structure: str = "general",
        epilogue: Optional[Epilogue] = None,
        out_dtype=None,
        blocks=None,
        stagger: bool = True,
        shard: Optional[ShardSpec] = None,
        repeats: int = 1,
    ) -> "GemmSpec":
        """Spec for concrete (or abstract) operands; leading dims of `a` become
        the batch, shared with `b` when `b` carries the same leading dims."""
        if a.ndim < 2 or b.ndim < 2:
            raise ValueError(f"operands must be >= 2D, got {a.shape} @ {b.shape}")
        if a.shape[-1] != b.shape[-2]:
            raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
        batched_b = b.ndim > 2
        if batched_b and a.shape[:-2] != b.shape[:-2]:
            raise ValueError(f"batch dims mismatch: {a.shape} vs {b.shape}")
        return cls(
            m=a.shape[-2],
            k=a.shape[-1],
            n=b.shape[-1],
            batch=a.shape[:-2],
            batched_b=batched_b,
            dtype_a=a.dtype,
            dtype_b=b.dtype,
            out_dtype=out_dtype,
            structure=structure,
            epilogue=epilogue or Epilogue(),
            blocks=blocks,
            stagger=stagger,
            shard=shard,
            repeats=repeats,
        )

    @classmethod
    def for_groups(
        cls,
        group: GroupSpec,
        k: int,
        n: int,
        *,
        dtype_a="float32",
        dtype_b="float32",
        out_dtype=None,
        epilogue: Optional[Epilogue] = None,
        blocks=None,
        stagger: bool = True,
        shard: Optional[ShardSpec] = None,
        repeats: int = 1,
    ) -> "GemmSpec":
        """Spec for a grouped GEMM: (group.rows, k) tokens in the capacity
        layout against (group.num_groups, k, n) stacked weights."""
        return cls(
            m=group.rows,
            k=k,
            n=n,
            dtype_a=dtype_a,
            dtype_b=dtype_b,
            out_dtype=out_dtype,
            epilogue=epilogue or Epilogue(),
            blocks=blocks,
            stagger=stagger,
            shard=shard,
            group=group,
            repeats=repeats,
        )

    # -- derived quantities used at plan time --------------------------------

    @property
    def eff_m(self) -> int:
        """M after folding leading batch dims (b 2D folds batch into M)."""
        if self.batch and not self.batched_b:
            return math.prod(self.batch) * self.m
        return self.m

    @property
    def acc_dtype(self) -> str:
        return _dtype_name(jnp.result_type(self.dtype_a, self.dtype_b))

    def resolved_out_dtype(self) -> str:
        return self.out_dtype or self.acc_dtype

    def flops(self) -> int:
        return 2 * math.prod(self.batch or (1,)) * self.m * self.k * self.n


# ---------------------------------------------------------------------------
# Capability-based backend registry
# ---------------------------------------------------------------------------


class CapabilityError(ValueError):
    """A spec asks for something the (chosen or only) backend cannot do."""


class PlanValidationError(ValueError):
    """The SPEC itself is malformed (misaligned scramble blocks, non-square
    symmetric product, inconsistent ShardSpec, ...).  Subclasses ValueError
    for caller compatibility, but is excluded from the fallback chain: every
    backend must reject the same spec, so degrading would only mask the bug."""


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """What a registered backend declares it can execute.

    structures        subset of STRUCTURES the impl can produce
    batching          fully-batched (B, M, K) @ (B, K, N) operands
    epilogue          the DESIGN.md §3 epilogue contract (fused or not)
    epilogue_fusion   the epilogue runs inside the kernel (provenance only)
    interpret         executes off-TPU (natively or via Pallas interpret mode)
    autotune          consumes autotuned (bm, bn, bk) block shapes
    sharding          per-shard kernel composes under shard_map, so specs
                      with a ShardSpec can lower through a ShardedPlan
    grouped           executes ragged-batch specs carrying a GroupSpec
                      (requires a `grouped_impl` at registration)
    """

    structures: FrozenSet[str] = frozenset({"general"})
    batching: bool = False
    epilogue: bool = True
    epilogue_fusion: bool = False
    interpret: bool = True
    autotune: bool = False
    sharding: bool = False
    grouped: bool = False

    def __post_init__(self):
        object.__setattr__(self, "structures", frozenset(self.structures))
        unknown = self.structures - set(STRUCTURES)
        if unknown:
            raise ValueError(
                f"unknown structures {sorted(unknown)}; known: {STRUCTURES}"
            )


_CAP_FIELDS = {f.name for f in dataclasses.fields(BackendCapabilities)}

# impl(plan, a, b, bias, residual) -> array
BackendImpl = Callable[["Plan", jax.Array, jax.Array, Any, Any], jax.Array]
# grouped_impl(plan, tokens, group_offsets, weights, bias, residual) -> array
GroupedImpl = Callable[
    ["Plan", jax.Array, jax.Array, jax.Array, Any, Any], jax.Array
]


@dataclasses.dataclass(frozen=True)
class _Backend:
    name: str
    impl: BackendImpl
    caps: BackendCapabilities
    grouped_impl: Optional[GroupedImpl] = None


_REGISTRY: Dict[str, _Backend] = {}

# Plan cache: one entry per (spec, backend, platform) ever planned (defined
# here because registration evicts from it).
_PLAN_CACHE: Dict[tuple, "Plan"] = {}
_PLAN_STATS = {"hits": 0, "misses": 0}


def _evict_plans(name: str) -> None:
    """Drop cached plans for one backend: a (re|un)registered impl must not
    keep serving stale executables, and plans for OTHER backends stay valid
    (and cached) — no global invalidation, no stranded entries."""
    for key in [k for k in _PLAN_CACHE if k[1] == name]:
        del _PLAN_CACHE[key]


def register_backend(
    name: str,
    impl: BackendImpl,
    capabilities: Union[BackendCapabilities, Mapping[str, Any]],
    *,
    grouped_impl: Optional[GroupedImpl] = None,
    override: bool = False,
) -> None:
    """Register a GEMM backend under `name` with declared capabilities.

    `capabilities` is a BackendCapabilities or a mapping with only its field
    names — unknown capability keys are rejected so typos never silently grant
    an ability.  Declaring the `grouped` capability requires a matching
    `grouped_impl` (the ragged-batch entry point has a different operand
    signature).  Duplicate names are rejected unless `override=True`.
    """
    if not isinstance(capabilities, BackendCapabilities):
        unknown = set(capabilities) - _CAP_FIELDS
        if unknown:
            raise ValueError(
                f"unknown capabilities {sorted(unknown)};"
                f" known: {sorted(_CAP_FIELDS)}"
            )
        capabilities = BackendCapabilities(**capabilities)
    if capabilities.grouped and grouped_impl is None:
        raise ValueError(
            f"backend {name!r} declares the 'grouped' capability but"
            " provides no grouped_impl"
        )
    if name in _REGISTRY and not override:
        raise ValueError(
            f"backend {name!r} already registered (pass override=True to replace)"
        )
    _REGISTRY[name] = _Backend(name, impl, capabilities, grouped_impl)
    _evict_plans(name)


def unregister_backend(name: str) -> None:
    if _REGISTRY.pop(name, None) is not None:
        _evict_plans(name)
    if _DEFAULT_BACKEND[0] == name:
        _DEFAULT_BACKEND[0] = None
        _DEFAULT_EPOCH[0] += 1


def backend_names() -> List[str]:
    return list(_REGISTRY)


def get_capabilities(name: str) -> BackendCapabilities:
    return _require_backend(name).caps


def _require_backend(name: str) -> _Backend:
    be = _REGISTRY.get(name)
    if be is None:
        raise ValueError(
            f"unknown backend {name!r}; registered: {sorted(_REGISTRY)}"
        )
    return be


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _check_capabilities(spec: GemmSpec, be: _Backend) -> Optional[str]:
    """None if `be` can run `spec` here; else a human-readable reason."""
    caps = be.caps
    if spec.structure not in caps.structures:
        return (
            f"backend {be.name!r} does not support structure"
            f" {spec.structure!r} (supports {sorted(caps.structures)})"
        )
    if spec.batched_b and not caps.batching:
        return f"backend {be.name!r} does not support fully-batched operands"
    if spec.group is not None and not caps.grouped:
        return (
            f"backend {be.name!r} does not support grouped (ragged-batch)"
            f" specs (no 'grouped' capability)"
        )
    if spec.shard is not None and not caps.sharding:
        return (
            f"backend {be.name!r} does not support device-mesh sharded specs"
            f" (no 'sharding' capability)"
        )
    if not spec.epilogue.is_identity and not caps.epilogue:
        return f"backend {be.name!r} does not support the fused-epilogue contract"
    if not _on_tpu() and not caps.interpret:
        return (
            f"backend {be.name!r} requires TPU and has no interpret mode"
            f" (running on {jax.default_backend()!r})"
        )
    return None


# -- default backend (process default + scoped override) ---------------------

_DEFAULT_BACKEND: List[Optional[str]] = [None]  # None = capability-based choice
_DEFAULT_EPOCH: List[int] = [0]  # bumped on every default change (see ops.py)


def set_default(name: Optional[str]) -> None:
    """Install a process-wide default backend (None restores auto-choice)."""
    if name is not None:
        _require_backend(name)
    _DEFAULT_BACKEND[0] = name
    _DEFAULT_EPOCH[0] += 1


def get_default() -> Optional[str]:
    return _DEFAULT_BACKEND[0]


def default_epoch() -> int:
    """Monotonic counter of default-backend changes — lets the legacy shim
    detect that its recorded default has been superseded by a newer
    set_default/default_backend scope."""
    return _DEFAULT_EPOCH[0]


@contextlib.contextmanager
def default_backend(name: str):
    """Scoped default: `with default_backend("pallas_mesh"): ...` — the
    supported replacement for the mutable `set_default_backend` global."""
    prev = _DEFAULT_BACKEND[0]
    set_default(name)
    try:
        yield
    finally:
        set_default(prev)


def _choose_backend(spec: GemmSpec) -> Tuple[_Backend, Optional[Dict[str, Any]]]:
    """Capability + cost choice, returning (backend, decision provenance).

    A CAPABLE pinned default wins immediately — explicit user intent beats
    any model.  Otherwise the capable set is ranked by the cost model's
    per-backend efficiency (`costmodel.choose.decide_backend`); with the
    shipped coefficients the predicted order IS the legacy xla ->
    pallas_mesh -> registration order on every platform, and the legacy
    order index breaks exact prediction ties, so the choice only shifts
    once calibration says otherwise.  Any cost-model failure degrades to
    the legacy first-capable rule with a ledger record."""
    order: List[str] = []
    for name in (
        *((_DEFAULT_BACKEND[0],) if _DEFAULT_BACKEND[0] is not None else ()),
        "xla",
        "pallas_mesh",
        *_REGISTRY,
    ):
        if name not in order:
            order.append(name)
    reasons = []
    capable: List[Tuple[str, int]] = []
    for idx, name in enumerate(order):
        be = _REGISTRY.get(name)
        if be is None:
            continue
        reason = _check_capabilities(spec, be)
        if reason is not None:
            reasons.append(reason)
            continue
        if name == _DEFAULT_BACKEND[0]:
            return be, None
        capable.append((name, idx))
    if not capable:
        raise CapabilityError(
            "no registered backend can execute this spec: " + "; ".join(reasons)
        )
    if len(capable) == 1:
        return _REGISTRY[capable[0][0]], None
    try:
        from repro.costmodel import choose as _cm_choose

        chosen, dec = _cm_choose.decide_backend(spec, capable)
        return _REGISTRY[chosen], dec.as_dict()
    except Exception as e:  # degraded: legacy first-capable
        _rledger.record(
            "costmodel.decide_backend",
            cause=f"{type(e).__name__}: {e}",
            fallback=capable[0][0],
        )
        return _REGISTRY[capable[0][0]], None


# Capability-ordered degradation ladder (DESIGN.md §11): when a backend's
# plan build or execution fails, the plan falls to the next CAPABLE backend
# in this order (then any other registered backend, registration order).
# ref sits last: slowest, but the oracle that can always run.
FALLBACK_ORDER = ("pallas_mesh", "xla", "ref")


def _fallback_chain(spec: GemmSpec, primary: _Backend) -> List[_Backend]:
    """`primary` plus every other backend capable of `spec`, fallback-ordered."""
    chain = [primary]
    names = {primary.name}
    for name in (*FALLBACK_ORDER, *_REGISTRY):
        be = _REGISTRY.get(name)
        if be is None or be.name in names:
            continue
        if _check_capabilities(spec, be) is None:
            chain.append(be)
            names.add(be.name)
    return chain


# ---------------------------------------------------------------------------
# Shared numerics (moved from ops.py so the shim stays thin)
# ---------------------------------------------------------------------------

# d/dz of each fused activation, as a function of the *pre-activation* z
# (recomputed in the backward pass — remat, not an extra forward output).
_ACT_GRADS = {
    "relu": lambda z: (z > 0).astype(z.dtype),
    "silu": lambda z: jax.nn.sigmoid(z) * (1 + z * (1 - jax.nn.sigmoid(z))),
    "sigmoid": lambda z: jax.nn.sigmoid(z) * (1 - jax.nn.sigmoid(z)),
    "tanh": lambda z: 1 - jnp.tanh(z) ** 2,
    "gelu": lambda z: _gelu_grad(z),
}


def _gelu_grad(z):
    """Analytic derivative of ACTIVATIONS['gelu'] (same GELU_C/GELU_A)."""
    from repro.kernels.mesh_matmul import GELU_A, GELU_C

    u = jnp.tanh(GELU_C * (z + GELU_A * z**3))
    return 0.5 * (1 + u) + 0.5 * z * (1 - u**2) * GELU_C * (1 + 3 * GELU_A * z**2)


def _act_grad(z: jax.Array, activation: str) -> jax.Array:
    return _ACT_GRADS[activation](z)


def _pad_to(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def apply_epilogue(
    z: jax.Array,
    bias: Optional[jax.Array],
    activation: Optional[str],
    residual: Optional[jax.Array],
) -> jax.Array:
    """The epilogue contract as plain jnp ops (f32 in, f32 out) — the single
    unfused reference used by the XLA/ref backends and the unfused A/B lever."""
    if bias is not None:
        z = z + bias.astype(jnp.float32)
    if activation not in (None, "none"):
        z = ACTIVATIONS[activation](z)
    if residual is not None:
        z = z + residual.astype(jnp.float32)
    return z


def _mm_impl(a2, b2, bias, residual, opts) -> jax.Array:
    """Mesh-kernel matmul (2D or fully-batched 3D) with padding to block
    multiples and the fused epilogue."""
    block_m, block_n, block_k, stagger, scramble, out_dtype, interpret, act = opts
    batched = a2.ndim == 3
    m, n = a2.shape[-2], b2.shape[-1]
    ap = _pad_to(_pad_to(a2, block_m, -2), block_k, -1)
    bp = _pad_to(_pad_to(b2, block_k, -2), block_n, -1)
    if scramble and (ap.shape[-2] != m or bp.shape[-1] != n):
        raise ValueError(
            "structure='scrambled' requires block-aligned M and N "
            f"(got M={m}, N={n} with blocks {block_m}x{block_n})"
        )
    bias_p = None if bias is None else _pad_to(bias, block_n, 0)
    res_p = (
        None
        if residual is None
        else _pad_to(_pad_to(residual, block_m, -2), block_n, -1)
    )
    kernel = mesh_matmul_pallas_batched if batched else mesh_matmul_pallas
    out = kernel(
        ap,
        bp,
        bias=bias_p,
        residual=res_p,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        stagger=stagger,
        scramble_out=scramble,
        activation=act,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[..., :m, :n]


# pallas_call has no JVP rule, so training graphs need an explicit VJP.
# Forward: y = act(A @ B + bias) + residual (epilogue fused in-kernel).
# Backward: dresidual = g; dz = g * act'(z) with z recomputed by one plain
# kernel call (remat — no extra forward output); dA = dz Bᵀ and dB = Aᵀ dz are
# two more mesh-kernel matmuls; dbias reduces dz over rows.  For the scrambled
# structure C = S(...), the cotangent is unscrambled (a pure gather — the
# permutation's own transpose) first, putting the whole backward in standard
# arrangement.
@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _mm(a2, b2, bias, residual, opts) -> jax.Array:
    return _mm_impl(a2, b2, bias, residual, opts)


def _mm_fwd(a2, b2, bias, residual, opts):
    # dresidual only needs residual's DTYPE — save a scalar sentinel, not the
    # full output-sized tensor (it would stay live until the backward pass).
    res_sentinel = None if residual is None else jnp.zeros((), residual.dtype)
    return _mm_impl(a2, b2, bias, residual, opts), (a2, b2, bias, res_sentinel)


def _mm_bwd(opts, res, g):
    a2, b2, bias, res_sentinel = res
    block_m, block_n, block_k, stagger, scramble, _, interpret, act = opts
    if scramble:
        g = ref.unscramble_blocks_ref(g, block_m=block_m, block_n=block_n)
    gf = g.astype(jnp.float32)
    dresidual = None if res_sentinel is None else g.astype(res_sentinel.dtype)

    if act in (None, "none"):
        dz = gf
    else:
        # Remat the pre-activation z = A @ B + bias with a plain (no-epilogue,
        # unscrambled) kernel call, then chain through act'.
        opts_z = (block_m, block_n, block_k, stagger, False, jnp.float32, interpret, None)
        z = _mm_impl(
            a2.astype(jnp.float32), b2.astype(jnp.float32), None, None, opts_z
        )
        if bias is not None:
            z = z + bias.astype(jnp.float32)
        dz = gf * _act_grad(z, act)

    opts_a = (block_m, block_k, block_n, stagger, False, jnp.float32, interpret, None)
    opts_b = (block_k, block_n, block_m, stagger, False, jnp.float32, interpret, None)
    bT = jnp.swapaxes(b2, -1, -2).astype(jnp.float32)
    aT = jnp.swapaxes(a2, -1, -2).astype(jnp.float32)
    da = _mm(dz, bT, None, None, opts_a)
    db = _mm(aT, dz, None, None, opts_b)
    dbias = (
        None
        if bias is None
        else jnp.sum(dz, axis=tuple(range(dz.ndim - 1))).astype(bias.dtype)
    )
    return da.astype(a2.dtype), db.astype(b2.dtype), dbias, dresidual


_mm.defvjp(_mm_fwd, _mm_bwd)


# -- grouped (ragged-batch) numerics ------------------------------------------


def _grouped_valid_mask(sizes: jax.Array, n_groups: int, rpg: int) -> jax.Array:
    """(rows, 1) f32 segment mask: 1 for rows inside their group's size."""
    valid = jnp.arange(rpg)[None, :] < sizes[:, None]
    return valid.reshape(n_groups * rpg, 1).astype(jnp.float32)


def _gmm_impl(tokens, sizes, w, bias, residual, opts) -> jax.Array:
    """Grouped mesh-kernel matmul with K/N padding to block multiples."""
    block_m, block_n, block_k, stagger, out_dtype, interpret, act = opts
    n = w.shape[-1]
    tp = _pad_to(tokens, block_k, -1)
    wp = _pad_to(_pad_to(w, block_k, -2), block_n, -1)
    bias_p = None if bias is None else _pad_to(bias, block_n, -1)
    res_p = None if residual is None else _pad_to(residual, block_n, -1)
    out = grouped_mesh_matmul_pallas(
        tp,
        sizes,
        wp,
        bias=bias_p,
        residual=res_p,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        stagger=stagger,
        activation=act,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[:, :n]


# Like _mm, pallas_call has no JVP rule, so the grouped kernel carries its own
# VJP (MoE training differentiates through every expert GEMM).  Forward:
# y = mask ∘ (act(tokens @ W[g] + bias[g]) + residual).  Backward: the
# cotangent is segment-masked (forward zeroed padding rows), dz = g·act'(z)
# with z rematerialized by one plain grouped call, dtokens = grouped(dz, Wᵀ)
# reuses the ragged kernel with N/K block roles swapped, and dW is the
# capacity layout's free lunch — a single batched einsum over the (G, rpg)
# view, padding rows contributing exact zeros.
@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _gmm(tokens, sizes, w, bias, residual, opts) -> jax.Array:
    return _gmm_impl(tokens, sizes, w, bias, residual, opts)


def _gmm_fwd(tokens, sizes, w, bias, residual, opts):
    res_sentinel = None if residual is None else jnp.zeros((), residual.dtype)
    out = _gmm_impl(tokens, sizes, w, bias, residual, opts)
    return out, (tokens, sizes, w, bias, res_sentinel)


def _gmm_bwd(opts, saved, g):
    tokens, sizes, w, bias, res_sentinel = saved
    block_m, block_n, block_k, stagger, _, interpret, act = opts
    n_groups, _, n = w.shape
    rpg = tokens.shape[0] // n_groups
    mask = _grouped_valid_mask(sizes, n_groups, rpg)
    gf = g.astype(jnp.float32) * mask
    dresidual = None if res_sentinel is None else (gf).astype(res_sentinel.dtype)

    if act in (None, "none"):
        dz = gf
    else:
        opts_z = (block_m, block_n, block_k, stagger, jnp.float32, interpret, None)
        z = _gmm_impl(
            tokens.astype(jnp.float32), sizes, w.astype(jnp.float32), None, None, opts_z
        )
        if bias is not None:
            z = (
                z.reshape(n_groups, rpg, n) + bias[:, None, :].astype(jnp.float32)
            ).reshape(-1, n)
        dz = gf * _act_grad(z, act)  # gf already carries the segment mask

    wT = jnp.swapaxes(w, -1, -2).astype(jnp.float32)
    opts_t = (block_m, block_k, block_n, stagger, jnp.float32, interpret, None)
    dtokens = _gmm(dz, sizes, wT, None, None, opts_t)
    dw = jnp.einsum(
        "grk,grn->gkn",
        (tokens.astype(jnp.float32) * mask).reshape(n_groups, rpg, -1),
        dz.reshape(n_groups, rpg, n),
    )
    dbias = (
        None
        if bias is None
        else dz.reshape(n_groups, rpg, n).sum(axis=1).astype(bias.dtype)
    )
    dsizes = np.zeros(sizes.shape, dtype=jax.dtypes.float0)
    return (
        dtokens.astype(tokens.dtype),
        dsizes,
        dw.astype(w.dtype),
        dbias,
        dresidual,
    )


_gmm.defvjp(_gmm_fwd, _gmm_bwd)


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------


class AsyncResult:
    """Handle for a dispatched plan execution (DESIGN.md §15).

    jax arrays are futures already — the device computes in the background
    until something reads the value.  This handle makes that contract
    explicit: `out` is the (possibly still computing) array, `block()`
    waits for it and returns it.  Blocking raises whatever the device run
    raised (XLA defers errors to the sync point).
    """

    __slots__ = ("plan", "out")

    def __init__(self, plan: "Plan", out: jax.Array):
        self.plan = plan
        self.out = out

    def block(self) -> jax.Array:
        """Wait for the dispatched execution and return its result."""
        jax.block_until_ready(self.out)
        return self.out


@dataclasses.dataclass
class Plan:
    """A resolved, reusable GEMM executable with provenance.

    Built once by `plan(spec)`; calling it runs the chosen backend with the
    blocks/tables fixed at plan time.  Provenance (backend, blocks, estimated
    FLOPs/VMEM, σ table) is inspectable via the fields or `describe()`.
    """

    spec: GemmSpec
    backend: str
    capabilities: BackendCapabilities
    blocks: Optional[Tuple[int, int, int]]
    out_dtype: str
    interpret: bool
    flops: int
    vmem_bytes: Optional[int]
    sigma_table: Optional[np.ndarray] = None
    stagger_table: Optional[np.ndarray] = None
    # -- resilience state (DESIGN.md §11) --
    # guard: opt-in non-finite output policy; health: DegradationEvents this
    # plan recorded (build-time fallbacks + execution-time degradations);
    # _chain: backend names still available below the active one.
    guard: Optional[str] = None
    guard_sample: Optional[int] = None
    # Cost-model decision provenance (DESIGN.md §13): why this backend /
    # schedule / sharding was picked — per-candidate predicted seconds and
    # the calibration version.  None when every degree of freedom was pinned.
    decision: Optional[Dict[str, Any]] = None
    health: List = dataclasses.field(default_factory=list)
    _chain: List[str] = dataclasses.field(default_factory=list, repr=False)
    _active: Optional[str] = dataclasses.field(default=None, repr=False)
    _fn: Optional[Callable] = dataclasses.field(default=None, repr=False)

    @property
    def activation(self) -> Optional[str]:
        return self.spec.epilogue.activation

    @property
    def active_backend(self) -> str:
        """The backend actually executing: `backend` until an execution-time
        degradation swapped in a fallback."""
        return self._active or self.backend

    @property
    def executor(self) -> Callable:
        """The raw jitted executor `(a, b, bias, residual) -> out`, with no
        per-call Python validation — for benchmarking and trusted hot loops
        where even `__call__`'s shape/dtype checks are measurable."""
        return self._fn

    def describe(self) -> Dict[str, Any]:
        """JSON-able provenance record (benchmarks / serving telemetry)."""
        d = {
            "backend": self.backend,
            "structure": self.spec.structure,
            "mkn": f"{self.spec.eff_m}x{self.spec.k}x{self.spec.n}",
            "dtypes": [self.spec.dtype_a, self.spec.dtype_b],
            "batch": list(self.spec.batch),
            # eff_m in "mkn" folds the batch only when b is 2D; batched_b
            # consumers (roofline) must scale per-element byte counts by batch
            "batched_b": self.spec.batched_b,
            "repeats": self.spec.repeats,
            "blocks": list(self.blocks) if self.blocks else None,
            "epilogue": {
                "bias": self.spec.epilogue.bias,
                "activation": self.activation,
                "residual": self.spec.epilogue.residual,
            },
            "fused_epilogue": self.capabilities.epilogue_fusion,
            "out_dtype": self.out_dtype,
            "interpret": self.interpret,
            "flops": self.flops,
            "vmem_bytes": self.vmem_bytes,
            "health": {
                "active_backend": self.active_backend,
                "degraded": bool(self.health),
                "guard_nonfinite": self.guard,
                "fallback_chain": list(self._chain),
                "events": [e.as_dict() for e in self.health],
            },
        }
        if self.decision is not None:
            d["decision"] = self.decision
        grp = self.spec.group
        if grp is not None:
            ia = jnp.dtype(self.spec.dtype_a).itemsize
            io = jnp.dtype(self.out_dtype).itemsize
            d["grouped"] = {
                "num_groups": grp.num_groups,
                "rows_per_group": grp.rows_per_group,
                # dense per-group compute at the static capacity bound; the
                # ragged steering skips the share past each group's size
                "per_group_flops": 2 * grp.rows_per_group * self.spec.k * self.spec.n,
                # routing traffic: every token row is scattered in (K bytes)
                # and its result gathered back out (N bytes)
                "dispatch_bytes": grp.rows * (self.spec.k * ia + self.spec.n * io),
            }
        return d

    # -- execution -----------------------------------------------------------

    def _check_operands(self, a, b, bias, residual):
        spec = self.spec
        want_a = spec.batch + (spec.m, spec.k)
        want_b = (spec.batch if spec.batched_b else ()) + (spec.k, spec.n)
        if tuple(a.shape) != want_a or tuple(b.shape) != want_b:
            raise ValueError(
                f"operands {a.shape} @ {b.shape} do not match plan spec "
                f"{want_a} @ {want_b}"
            )
        got_dt = (_dtype_name(a.dtype), _dtype_name(b.dtype))
        if got_dt != (spec.dtype_a, spec.dtype_b):
            # out_dtype and the autotuned/VMEM-budgeted blocks were fixed for
            # the spec's dtypes — a silent cast here would mask caller intent
            raise ValueError(
                f"operand dtypes {got_dt} do not match plan spec "
                f"({spec.dtype_a}, {spec.dtype_b}); build a new GemmSpec"
            )
        epi = spec.epilogue
        for name, arr, declared in (
            ("bias", bias, epi.bias),
            ("residual", residual, epi.residual),
        ):
            if (arr is not None) != declared:
                state = "with" if declared else "without"
                raise ValueError(
                    f"plan was built {state} {name}; pass a matching "
                    f"Epilogue in the GemmSpec to change the contract"
                )
        # Epilogue shape validation — identical on every backend (same
        # exception type/message), against the LOGICAL (unpadded) shapes.
        _check_epilogue_shapes(bias, residual, spec)

    def __call__(self, a, b, bias=None, residual=None) -> jax.Array:
        self._check_operands(a, b, bias, residual)
        return self._execute((a, b, bias, residual))

    def dispatch(self, a, b, bias=None, residual=None) -> AsyncResult:
        """Enqueue an execution and return without waiting on the device.

        jax dispatches asynchronously by construction, so this costs what
        `__call__` costs minus any value read; the point is the explicit
        contract: validation and enqueue happen NOW, device work proceeds in
        the background, and `AsyncResult.block()` (or `execute_async` over a
        batch of independent plans) is the single sync point.  The enqueue
        runs under its own `plan.dispatch` obs span — NOT `plan.execute`,
        whose warm spans feed cost-model calibration and must measure device
        walltime, not host enqueue time.  Caveat: a plan with a
        `guard_nonfinite` policy host-syncs inside execution to inspect the
        output, so its dispatch is effectively synchronous (the guard wins).
        """
        self._check_operands(a, b, bias, residual)
        args = (a, b, bias, residual)
        if _obs._STATE.enabled:
            with _obs.span("plan.dispatch", **self._obs_attrs()):
                out = self._execute_impl(args)
        else:
            out = self._execute_impl(args)
        return AsyncResult(self, out)

    # -- resilience (DESIGN.md §11) ------------------------------------------

    def _record(self, site: str, cause: str, fallback: str, **detail):
        """One DegradationEvent, in the plan's health AND the global ledger."""
        ev = _rledger.record(site, cause=cause, fallback=fallback, **detail)
        self.health.append(ev)
        return ev

    def _degrade(self, args: tuple, *, site: str, cause: str, original=None):
        """Fall to the next capable backend in the chain and run `args` there.

        On success the plan PERMANENTLY swaps its executor — a backend that
        failed (or produced NaN under the `fallback` guard policy) is not
        trusted again for this plan; the hot path recovers to a single
        `_fn` call.  Exhausting the chain re-raises."""
        err = original
        while self._chain:
            name = self._chain.pop(0)
            self._record(site, cause, fallback=name, backend=self.active_backend)
            try:
                fb = plan(self.spec, backend=name, fallback=False)
                _faults.check(site, backend=name)
                out = fb._fn(*args)
            except PlanValidationError:
                raise
            except Exception as e:
                cause = f"{type(e).__name__}: {e}"
                err = e
                continue
            self._fn = fb._fn
            self._active = name
            return out
        raise RuntimeError(
            f"backend {self.active_backend!r} failed ({cause}) and the"
            f" fallback chain is exhausted for this spec"
        ) from err

    def _obs_attrs(self) -> Dict[str, Any]:
        """Span attributes for plan.execute (DESIGN.md §14), computed once
        per plan: backend/blocks/schedule provenance plus the cost-model
        `terms` the obs bridge converts into calibration records.  Cached on
        the instance — the enabled hot path pays one dict splat, not a
        describe() walk."""
        at = getattr(self, "_obs_attrs_cache", None)
        if at is None:
            spec = self.spec
            at = {
                "backend": self.active_backend,
                "structure": spec.structure,
                "mkn": f"{spec.eff_m}x{spec.k}x{spec.n}",
                "key": f"{spec.eff_m}x{spec.k}x{spec.n}|{self.backend}",
                "blocks": list(self.blocks) if self.blocks else None,
                "schedule": getattr(self, "schedule", None),
            }
            try:
                from repro.costmodel.model import terms_from_describe

                at["terms"] = terms_from_describe(self.describe())
            except Exception:
                pass  # spans still carry provenance without cost terms
            self._obs_attrs_cache = at
        return at

    def _execute(self, args: tuple) -> jax.Array:
        # Disabled tracing costs ONE attribute check here (the dispatch
        # microbench rides this path); the span itself is tracer-aware, so
        # a plan called inside an enclosing jit trace records nothing.
        if _obs._STATE.enabled:
            with _obs.span("plan.execute", **self._obs_attrs()):
                return self._execute_impl(args)
        return self._execute_impl(args)

    def _execute_impl(self, args: tuple) -> jax.Array:
        try:
            _faults.check("plan.execute", backend=self.active_backend)
            out = self._fn(*args)
        except (PlanValidationError, CapabilityError):
            raise
        except Exception as e:
            out = self._degrade(
                args,
                site="plan.execute",
                cause=f"{type(e).__name__}: {e}",
                original=e,
            )
        out = _faults.poison("kernel.output", out, backend=self.active_backend)
        if self.guard is not None:
            out = self._apply_guard(out, args)
        return out

    def _apply_guard(self, out: jax.Array, args: tuple) -> jax.Array:
        """The post-epilogue non-finite guard (fused paths stay fused: the
        check wraps the executor's OUTPUT, never reaches into the kernel)."""
        if isinstance(out, jax.core.Tracer):
            # Under an enclosing trace values are unknown: zero_and_record
            # lowers to an unconditional traced scrub; raise/fallback cannot
            # branch on traced values, so the gap is recorded, not hidden.
            if self.guard == "zero_and_record":
                return scrub_nonfinite(out)
            self._record(
                "guard.nonfinite",
                cause="guard bypassed under trace (values unknown)",
                fallback="unchecked",
                backend=self.active_backend,
            )
            return out
        bad = nonfinite_count(out, sample=self.guard_sample)
        if not bad:
            return out
        cause = f"{bad} non-finite output value(s) sampled"
        if self.guard == "zero_and_record":
            self._record(
                "guard.nonfinite", cause, fallback="zero",
                backend=self.active_backend,
            )
            return scrub_nonfinite(out)
        if self.guard == "fallback":
            out = self._degrade(args, site="guard.nonfinite", cause=cause)
            if isinstance(out, jax.core.Tracer) or not nonfinite_count(
                out, sample=self.guard_sample
            ):
                return out
            raise NonFiniteError(
                f"non-finite outputs persist after fallback"
                f" (backend {self.active_backend!r})"
            )
        raise NonFiniteError(
            f"guarded plan produced {bad} non-finite value(s) on backend"
            f" {self.active_backend!r} (structure={self.spec.structure!r},"
            f" mkn={self.spec.eff_m}x{self.spec.k}x{self.spec.n})"
        )


def _check_epilogue_shapes(bias, residual, spec: GemmSpec) -> None:
    """The `_check_epilogue` contract at the dispatch layer: every backend —
    XLA included — rejects malformed bias/residual with the same error.
    Grouped specs carry a PER-GROUP bias (num_groups, N)."""
    n = spec.n
    want_bias = (spec.group.num_groups, n) if spec.group is not None else (n,)
    if bias is not None and tuple(bias.shape) != want_bias:
        raise ValueError(
            f"bias must have shape {want_bias}, got {tuple(bias.shape)}"
        )
    want_res = spec.batch + (spec.m, n)
    if residual is not None and tuple(residual.shape) != want_res:
        raise ValueError(
            f"residual must have shape {want_res}, got {tuple(residual.shape)}"
        )


def _check_grouped_operands(plan: "Plan", tokens, group_offsets, weights,
                            bias, residual) -> None:
    """Operand validation shared by GroupedPlan and ShardedGroupedPlan."""
    spec = plan.spec
    grp = spec.group
    want_t = (grp.rows, spec.k)
    want_w = (grp.num_groups, spec.k, spec.n)
    if tuple(tokens.shape) != want_t or tuple(weights.shape) != want_w:
        raise ValueError(
            f"grouped operands {tokens.shape} / {weights.shape} do not match"
            f" plan spec tokens {want_t} / weights {want_w}"
        )
    if tuple(group_offsets.shape) != (grp.num_groups + 1,):
        raise ValueError(
            f"group_offsets must have shape ({grp.num_groups + 1},) —"
            f" cumulative row counts — got {tuple(group_offsets.shape)}"
        )
    if not jnp.issubdtype(group_offsets.dtype, jnp.integer):
        raise ValueError(
            f"group_offsets must be integer-typed, got {group_offsets.dtype}"
        )
    got_dt = (_dtype_name(tokens.dtype), _dtype_name(weights.dtype))
    if got_dt != (spec.dtype_a, spec.dtype_b):
        raise ValueError(
            f"operand dtypes {got_dt} do not match plan spec "
            f"({spec.dtype_a}, {spec.dtype_b}); build a new GemmSpec"
        )
    epi = spec.epilogue
    for name, arr, declared in (
        ("bias", bias, epi.bias),
        ("residual", residual, epi.residual),
    ):
        if (arr is not None) != declared:
            state = "with" if declared else "without"
            raise ValueError(
                f"plan was built {state} {name}; pass a matching "
                f"Epilogue in the GemmSpec to change the contract"
            )
    _check_epilogue_shapes(bias, residual, spec)


@dataclasses.dataclass
class GroupedPlan(Plan):
    """A Plan for a grouped (ragged-batch) GEMM (DESIGN.md §10).

    Execution takes `(tokens, group_offsets, weights)` — tokens in the
    group-major capacity layout, `group_offsets` the (num_groups+1,)
    cumulative valid-row counts whose diffs are the per-group sizes, weights
    stacked (num_groups, K, N).  Rows at or beyond a group's size come back
    zero.  One plan serves every routing outcome of its logical group shape:
    the offsets are an execution-time operand, not part of the spec.
    """

    def __call__(self, tokens, group_offsets, weights, bias=None, residual=None):
        _check_grouped_operands(self, tokens, group_offsets, weights, bias, residual)
        return self._execute((tokens, group_offsets, weights, bias, residual))


@dataclasses.dataclass
class ShardedPlan(Plan):
    """A Plan lowered over a device mesh (DESIGN.md §9).

    Built by `plan(spec, mesh=...)` for a spec carrying a ShardSpec: the
    per-shard product is the ordinary single-device Plan (`local`, built by
    the same planner), wrapped in `shard_map` with the chosen collective
    schedule fused around the kernel call.  Operands/results are GLOBAL
    arrays with the spec's logical shapes; `__call__` validates them exactly
    like an unsharded Plan.  The epilogue is applied after the collective
    (act(sum) != sum(act) under a K split), so it is never kernel-fused here.

    Extra provenance: the collective `schedule`, per-shard FLOPs/VMEM via
    `local`, and `bytes_moved` — collective link bytes per device per call —
    so roofline/serving tooling can report communication cost.
    """

    mesh: Any = None
    schedule: str = "replicated"
    local: Optional[Plan] = dataclasses.field(default=None, repr=False)
    bytes_moved: int = 0
    collective_phases: int = 0
    # Ring-schedule devices run the local kernel once per ring step, so the
    # per-DEVICE work is local.flops x this (reduce_scatter family: p;
    # column-half overlap variants: 2; pipeline: microbatch count).
    kernel_invocations: int = 1
    # Measured serial_ms / overlap_ms for this plan's schedule vs its serial
    # twin — recorded by benchmarks via `note_overlap_efficiency`, None until
    # something measured it (provenance, never consulted by execution).
    overlap_efficiency: Optional[float] = None

    def note_overlap_efficiency(self, ratio: float) -> None:
        """Record a measured serial/overlap time ratio (>1 means the
        double-buffered schedule won); shows up in describe()["sharding"]."""
        self.overlap_efficiency = float(ratio)

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        shard = self.spec.shard
        d["fused_epilogue"] = False  # applied post-collective, never in-kernel
        d["sharding"] = {
            "mesh": [[n, s] for n, s in shard.mesh_axes],
            "axes": {
                "m": shard.axis_m,
                "k": shard.axis_k,
                "n": shard.axis_n,
                "batch": shard.axis_batch,
                "g": shard.axis_g,
            },
            "schedule": self.schedule,
            "overlap": _is_overlap_schedule(self.schedule),
            "overlap_efficiency": self.overlap_efficiency,
            "collective_phases": self.collective_phases,
            "bytes_moved": self.bytes_moved,
            "kernel_invocations": self.kernel_invocations,
            "per_shard_mkn": [
                self.local.spec.eff_m,
                self.local.spec.k,
                self.local.spec.n,
            ],
            "per_shard_batch": list(self.local.spec.batch),
            "per_shard_flops": self.local.flops * self.kernel_invocations,
            "per_shard_vmem_bytes": self.local.vmem_bytes,
        }
        return d

    def _degrade(self, args: tuple, *, site: str, cause: str, original=None):
        """Sharded degradation ladder: a failed collective schedule falls back
        to REPLICATED execution of the identical spec — the same global
        operands run through the unsharded planner (its own backend chain
        still applies), so numerics are preserved at the cost of the
        collective's speedup."""
        if self._active == "replicated":  # already degraded once
            raise RuntimeError(
                f"sharded plan failed again after degrading to replicated"
                f" ({cause})"
            ) from original
        self._record(
            site,
            cause,
            fallback="replicated",
            schedule=self.schedule,
            backend=self.active_backend,
        )
        unspec = dataclasses.replace(self.spec, shard=None)
        fb = plan(unspec)
        out = fb._execute(args)
        self._fn = fb._fn
        self._active = "replicated"
        return out


@dataclasses.dataclass
class ShardedGroupedPlan(ShardedPlan):
    """A GroupedPlan lowered over a device mesh: the `expert` schedule.

    The group (expert) dim shards over `ShardSpec.axis_g`; tokens, sizes and
    stacked weights reshard at the shard_map boundary — under a pjit caller
    with data-sharded dispatch buffers this IS the EP all-to-all — and each
    device runs the ordinary per-shard GroupedPlan over its local groups.
    Output rows stay group-sharded (no further collective), and the epilogue
    shards with its operands — per-group bias and group-major residual
    partition on axis_g, so it stays inside the local kernel (fused on the
    Pallas backend), unlike the K-collective schedules.
    """

    __call__ = GroupedPlan.__call__

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        # ShardedPlan forces fused_epilogue=False (post-collective apply);
        # grouped sharding keeps the epilogue in the local kernel.
        d["fused_epilogue"] = self.capabilities.epilogue_fusion
        return d


# -- built-in backend implementations ----------------------------------------


def _xla_impl(p: Plan, a, b, bias, residual):
    z = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    return apply_epilogue(z, bias, p.activation, residual).astype(p.out_dtype)


def _ref_impl(p: Plan, a, b, bias, residual):
    """Pure-jnp oracle backend: same contract, no Pallas — registered through
    the same capability door as the real kernels (and usable as a test double)."""
    z = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    y = apply_epilogue(z, bias, p.activation, residual)
    if p.spec.structure == "scrambled":
        bm, bn, _ = p.blocks
        y = ref.scramble_blocks_ref(y, block_m=bm, block_n=bn)
    return y.astype(p.out_dtype)


def _pallas_impl(p: Plan, a, b, bias, residual):
    spec = p.spec
    bm, bn, bk = p.blocks
    opts = (
        bm,
        bn,
        bk,
        spec.stagger,
        spec.structure == "scrambled",
        jnp.dtype(p.out_dtype),
        p.interpret,
        spec.epilogue.activation,
    )
    if not spec.batch:
        return _mm(a, b, bias, residual, opts)
    if not spec.batched_b:
        # Fold leading batch dims of `a` into M — still a single 2D kernel.
        a2 = a.reshape(-1, spec.k)
        res2 = None if residual is None else residual.reshape(-1, spec.n)
        out = _mm(a2, b, bias, res2, opts)
        return out.reshape(*spec.batch, spec.m, spec.n)
    # Fully batched: ONE pallas_call with grid (b, i, j, k).
    af = a.reshape(-1, spec.m, spec.k)
    bf = b.reshape(-1, spec.k, spec.n)
    resf = None if residual is None else residual.reshape(-1, spec.m, spec.n)
    out = _mm(af, bf, bias, resf, opts)
    return out.reshape(*spec.batch, spec.m, spec.n)


def _grouped_sizes(p: Plan, group_offsets: jax.Array) -> jax.Array:
    del p
    return (group_offsets[1:] - group_offsets[:-1]).astype(jnp.int32)


def _xla_grouped_impl(p: Plan, tokens, group_offsets, w, bias, residual):
    """Segment-masked einsum fallback: the capacity layout makes the ragged
    batch a dense (G, rpg, K) @ (G, K, N) product; the segment mask zeroes
    rows past each group's size (identical contract to the Pallas kernel)."""
    grp = p.spec.group
    sizes = _grouped_sizes(p, group_offsets)
    rpg = grp.rows_per_group
    tg = tokens.reshape(grp.num_groups, rpg, p.spec.k)
    z = jnp.einsum("grk,gkn->grn", tg, w, preferred_element_type=jnp.float32)
    if bias is not None:
        z = z + bias[:, None, :].astype(jnp.float32)
    if p.activation not in (None, "none"):
        z = ACTIVATIONS[p.activation](z)
    if residual is not None:
        z = z + residual.reshape(z.shape).astype(jnp.float32)
    valid = jnp.arange(rpg)[None, :] < sizes[:, None]
    z = jnp.where(valid[..., None], z, 0.0)
    return z.reshape(grp.rows, p.spec.n).astype(p.out_dtype)


def _ref_grouped_impl(p: Plan, tokens, group_offsets, w, bias, residual):
    """Oracle: per-group jnp products in a Python loop (G is static), same
    epilogue + segment-mask contract as every other grouped backend."""
    grp = p.spec.group
    sizes = _grouped_sizes(p, group_offsets)
    rpg = grp.rows_per_group
    outs = []
    for g in range(grp.num_groups):
        z = jnp.matmul(
            tokens[g * rpg : (g + 1) * rpg],
            w[g],
            preferred_element_type=jnp.float32,
        )
        z = apply_epilogue(
            z,
            None if bias is None else bias[g],
            p.activation,
            None if residual is None else residual[g * rpg : (g + 1) * rpg],
        )
        z = jnp.where(jnp.arange(rpg)[:, None] < sizes[g], z, 0.0)
        outs.append(z)
    return jnp.concatenate(outs, axis=0).astype(p.out_dtype)


def _pallas_grouped_impl(p: Plan, tokens, group_offsets, w, bias, residual):
    spec = p.spec
    bm, bn, bk = p.blocks
    opts = (
        bm,
        bn,
        bk,
        spec.stagger,
        jnp.dtype(p.out_dtype),
        p.interpret,
        spec.epilogue.activation,
    )
    return _gmm(tokens, _grouped_sizes(p, group_offsets), w, bias, residual, opts)


register_backend(
    "xla",
    _xla_impl,
    BackendCapabilities(
        structures=frozenset({"general", "symmetric"}),
        batching=True,
        epilogue=True,
        epilogue_fusion=False,  # XLA may fuse, but it is not contractual
        interpret=True,  # native everywhere
        autotune=False,
        sharding=True,
        grouped=True,
    ),
    grouped_impl=_xla_grouped_impl,
)
register_backend(
    "pallas_mesh",
    _pallas_impl,
    BackendCapabilities(
        structures=frozenset({"general", "symmetric", "scrambled"}),
        batching=True,
        epilogue=True,
        epilogue_fusion=True,
        interpret=True,  # Pallas interpret mode off-TPU
        autotune=True,
        sharding=True,
        grouped=True,
    ),
    grouped_impl=_pallas_grouped_impl,
)
register_backend(
    "ref",
    _ref_impl,
    BackendCapabilities(
        structures=frozenset({"general", "symmetric", "scrambled"}),
        batching=True,
        epilogue=True,
        epilogue_fusion=False,
        interpret=True,
        autotune=False,
        sharding=True,
        grouped=True,
    ),
    grouped_impl=_ref_grouped_impl,
)


# ---------------------------------------------------------------------------
# plan()
# ---------------------------------------------------------------------------


def plan(
    spec: GemmSpec,
    *,
    backend: Optional[str] = None,
    mesh: Optional[Mesh] = None,
    guard_nonfinite: Optional[str] = None,
    guard_sample: Optional[int] = None,
    fallback: bool = True,
) -> Plan:
    """Validate `spec` against backend capabilities and return the cached,
    reusable executable for it.

    Resolution happens ONCE per (spec, backend, mesh, guard) tuple per
    platform: capability checks, autotuned block shapes, σ/stagger tables,
    collective schedule, and the jitted executor are all fixed here; repeated
    calls return the *identical* Plan object.  An explicit `backend` is
    validated strictly (CapabilityError on mismatch); otherwise the first
    capable backend is chosen — the capable set ranked by the cost model
    (DESIGN.md §13; ties reproduce pinned default → xla → pallas_mesh →
    registration order).  A spec carrying a ShardSpec requires the live
    device `mesh` and returns a ShardedPlan; equal meshes (same devices +
    axis names) key the same cache entry, different meshes plan separately.
    `mesh=` WITHOUT a ShardSpec auto-shards: the cost model enumerates axis
    assignments over the live mesh and attaches the cheapest legal
    ShardSpec (decision provenance in `describe()["decision"]`).
    A spec carrying a GroupSpec returns a GroupedPlan taking (tokens,
    group_offsets, weights) — and, with a ShardSpec too, a
    ShardedGroupedPlan (`expert` schedule).

    Resilience (DESIGN.md §11): with `fallback=True` (default) a failed plan
    BUILD falls down the capability-ordered chain (`FALLBACK_ORDER`) to the
    next backend able to run the spec, recording a DegradationEvent in the
    returned plan's `health` and the global `resilience.ledger` instead of
    raising; only when every capable backend fails does the last error
    surface.  Spec-level `PlanValidationError`s always raise — they are
    caller bugs every backend would reject.  `guard_nonfinite` opts the plan
    into the post-epilogue NaN/Inf guard with policy `raise | fallback |
    zero_and_record` (`guard_sample` spot-checks that many strided output
    elements instead of reducing the full array).
    """
    if not isinstance(spec, GemmSpec):
        raise TypeError(f"plan() takes a GemmSpec, got {type(spec).__name__}")
    if spec.shard is not None and mesh is None:
        raise ValueError(
            "spec carries a ShardSpec; pass the device mesh:"
            " plan(spec, mesh=mesh)"
        )
    shard_decision = None
    if spec.shard is None and mesh is not None:
        spec, shard_decision = _auto_shard(spec, mesh)
    if guard_nonfinite is not None:
        guard_nonfinite = normalize_policy(guard_nonfinite)
    backend_decision = None
    if backend is not None:
        be = _require_backend(backend)
        reason = _check_capabilities(spec, be)
        if reason is not None:
            raise CapabilityError(reason)
    else:
        be, backend_decision = _choose_backend(spec)

    key = (
        spec, be.name, jax.default_backend(), mesh, guard_nonfinite, guard_sample
    )
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        _PLAN_STATS["hits"] += 1
        return cached
    _PLAN_STATS["misses"] += 1

    chain = _fallback_chain(spec, be) if fallback else [be]
    build_events: List[Any] = []
    p = None
    built_at = 0
    with _obs.span(
        "plan.build",
        backend=be.name,
        structure=spec.structure,
        mkn=f"{spec.eff_m}x{spec.k}x{spec.n}",
        sharded=mesh is not None,
    ) as _bsp:
        for i, cand in enumerate(chain):
            try:
                _faults.check("plan.build", backend=cand.name)
                p = (
                    _build_plan(spec, cand)
                    if mesh is None
                    else _build_sharded_plan(spec, cand, mesh)
                )
                built_at = i
                break
            except (PlanValidationError, CapabilityError):
                raise
            except Exception as e:
                if i + 1 >= len(chain):
                    raise
                build_events.append(
                    _rledger.record(
                        "plan.build",
                        cause=f"{type(e).__name__}: {e}",
                        fallback=chain[i + 1].name,
                        backend=cand.name,
                    )
                )
        _bsp.set("built_backend", chain[built_at].name)
        _bsp.set("blocks", list(p.blocks) if p.blocks else None)
        if getattr(p, "schedule", None) is not None:
            _bsp.set("schedule", p.schedule)
    p.health.extend(build_events)
    if backend_decision is not None or shard_decision is not None:
        # merge with any schedule decision _build_sharded_plan attached
        dec = dict(p.decision or {})
        if backend_decision is not None:
            dec["backend"] = backend_decision
        if shard_decision is not None:
            dec["sharding"] = shard_decision
        p.decision = dec
    # Backends still available below the one that built — the execution-time
    # degradation ladder (Plan._degrade).
    p._chain = [c.name for c in chain[built_at + 1 :]]
    p.guard = guard_nonfinite
    p.guard_sample = guard_sample
    _PLAN_CACHE[key] = p
    return p


def _resolve_blocks_via_costmodel(
    m: int, k: int, n: int, dtype, backend: str, *, symmetry: int = 0
) -> Tuple[int, int, int]:
    """Block resolution through the cost model's chooser: IDENTICAL to
    `autotune.resolve_blocks` (same cache, same analytic ranking) until
    coefficients are CALIBRATED, when the candidate ranking switches to
    `costmodel.model.predict_blocks_ms`.  Any chooser failure degrades to
    the autotuner directly."""
    try:
        from repro.costmodel import choose as _cm_choose

        blocks, _ = _cm_choose.choose_blocks(
            m, k, n, dtype, backend, symmetry=symmetry
        )
        return blocks
    except Exception:
        return _autotune.resolve_blocks(m, k, n, dtype, backend, symmetry=symmetry)


def _grouped_block_m(rpg: int, bm: int) -> int:
    """Largest block_m that both divides rows_per_group and respects the
    tuned bm — the (g, i, j, k) grid needs whole row blocks per group."""
    if rpg % bm == 0:
        return bm
    g = math.gcd(rpg, bm)
    return g if g >= 8 else rpg


def _build_grouped_plan(spec: GemmSpec, be: _Backend) -> GroupedPlan:
    """Grouped planning: autotune ONCE per logical group shape (m = the
    rows-per-group bound), then clamp block_m to divide it."""
    grp = spec.group
    blocks = vmem = stagger_tbl = None
    if be.caps.autotune:
        partial = spec.blocks or (None, None, None)
        if None in partial:
            bm, bn, bk = _resolve_blocks_via_costmodel(
                grp.rows_per_group, spec.k, spec.n, spec.acc_dtype, be.name
            )
            blocks = tuple(p or r for p, r in zip(partial, (bm, bn, bk)))
        else:
            blocks = partial
        bm, bn, bk = blocks
        blocks = (_grouped_block_m(grp.rows_per_group, bm), bn, bk)
        vmem = _autotune.vmem_bytes(
            *blocks,
            spec.acc_dtype,
            has_bias=spec.epilogue.bias,
            has_residual=spec.epilogue.residual,
        )
        if spec.stagger:
            bm, bn, bk = blocks
            nm = grp.rows_per_group // bm
            nn = -(-spec.n // bn)
            nk = -(-spec.k // bk)
            # (g + i + j) mod nk rotation per group tile, recorded for one
            # group (the pattern shifts by g across groups)
            stagger_tbl = np.add.outer(np.arange(nm), np.arange(nn)) % max(nk, 1)
    p = GroupedPlan(
        spec=spec,
        backend=be.name,
        capabilities=be.caps,
        blocks=blocks,
        out_dtype=spec.resolved_out_dtype(),
        interpret=not _on_tpu(),
        flops=spec.flops(),
        vmem_bytes=vmem,
        stagger_table=stagger_tbl,
    )
    impl = be.grouped_impl
    p._fn = jax.jit(
        lambda t, off, w, bias, residual: impl(p, t, off, w, bias, residual)
    )
    return p


def _build_plan(spec: GemmSpec, be: _Backend) -> Plan:
    if spec.group is not None:
        return _build_grouped_plan(spec, be)
    acc_dtype = spec.acc_dtype
    blocks = None
    vmem = None
    if be.caps.autotune or spec.structure == "scrambled":
        partial = spec.blocks or (None, None, None)
        if None in partial:
            # The scrambled σ-table constraint and the symmetric early-readout
            # regime key their own autotune-cache partitions.
            tune_backend = (
                "pallas_mesh_scrambled" if spec.structure == "scrambled" else be.name
            )
            symmetry = 1 if spec.structure == "symmetric" else 0
            bm, bn, bk = _resolve_blocks_via_costmodel(
                spec.eff_m, spec.k, spec.n, acc_dtype, tune_backend, symmetry=symmetry
            )
            blocks = tuple(p or r for p, r in zip(partial, (bm, bn, bk)))
        else:
            blocks = partial
        vmem = _autotune.vmem_bytes(
            *blocks,
            acc_dtype,
            has_bias=spec.epilogue.bias,
            has_residual=spec.epilogue.residual,
        )

    sigma = stagger_tbl = None
    if spec.structure == "symmetric" and spec.m != spec.n:
        raise PlanValidationError(
            f"structure='symmetric' requires a square product, got "
            f"{spec.m}x{spec.n}"
        )
    if spec.structure == "scrambled":
        bm, bn, bk = blocks
        eff_m, n = spec.eff_m, spec.n
        if eff_m % bm or n % bn:
            raise PlanValidationError(
                "structure='scrambled' requires block-aligned M and N "
                f"(got M={eff_m}, N={n} with blocks {bm}x{bn})"
            )
        if eff_m // bm != n // bn:
            raise PlanValidationError(
                f"scramble_out needs square block grid, got {eff_m // bm}x{n // bn}"
            )
        # σ lookup table, host-side numpy, once — the kernel's scalar-prefetch
        # input is an lru_cache hit from here on.
        sigma = sigma_block_table(eff_m // bm)
    if blocks is not None and spec.stagger:
        # Per-cell k-rotation offsets ((i + j) mod nk) — the staggered
        # schedule as a host-side table, recorded for provenance/debug.
        bm, bn, bk = blocks
        nm = -(-spec.eff_m // bm)
        nn = -(-spec.n // bn)
        nk = -(-spec.k // bk)
        stagger_tbl = np.add.outer(np.arange(nm), np.arange(nn)) % max(nk, 1)

    p = Plan(
        spec=spec,
        backend=be.name,
        capabilities=be.caps,
        blocks=blocks,
        out_dtype=spec.resolved_out_dtype(),
        interpret=not _on_tpu(),
        flops=spec.flops(),
        vmem_bytes=vmem,
        sigma_table=sigma,
        stagger_table=stagger_tbl,
    )
    impl = be.impl
    p._fn = jax.jit(lambda a, b, bias, residual: impl(p, a, b, bias, residual))
    return p


# ---------------------------------------------------------------------------
# Sharded planning (DESIGN.md §9)
# ---------------------------------------------------------------------------


def _legacy_auto_schedule(spec: GemmSpec) -> str:
    """The pre-cost-model divisibility heuristic — kept as the degraded
    fallback AND the shape of the model's tie-breaks: a K partition rings
    (scatter when M divides it), anything else replicates."""
    shard = spec.shard
    pk = shard.axis_size(shard.axis_k)
    if pk > 1:
        return "reduce_scatter_k" if spec.eff_m % pk == 0 else "ring_k"
    return "replicated"


def _auto_schedule(spec: GemmSpec) -> Tuple[str, Optional[Dict[str, Any]]]:
    """Resolve schedule='auto' through the cost model (DESIGN.md §13).

    The model legality-trials every schedule with this function's OWN
    validation (pinned-schedule `_resolve_sharding` calls), so it can never
    pick an illegal one.  When no candidate is legal the legacy heuristic
    names the schedule whose validation then raises the precise error the
    caller always saw; any other cost-model failure degrades to the legacy
    choice with a ledger record."""
    try:
        from repro.costmodel import choose as _cm_choose
    except Exception:
        return _legacy_auto_schedule(spec), None
    try:
        sched, dec = _cm_choose.decide_schedule(spec)
        return sched, dec.as_dict()
    except _cm_choose.NoLegalCandidate:
        return _legacy_auto_schedule(spec), None
    except Exception as e:
        _rledger.record(
            "costmodel.decide_schedule",
            cause=f"{type(e).__name__}: {e}",
            fallback="legacy-heuristic",
        )
        return _legacy_auto_schedule(spec), None


def _auto_shard(
    spec: GemmSpec, mesh: Mesh
) -> Tuple[GemmSpec, Optional[Dict[str, Any]]]:
    """plan(spec, mesh=...) with NO ShardSpec: let the cost model pick axes
    AND schedule over the live mesh.  Degraded fallback is the unsharded
    ShardSpec — correct on any mesh — with a ledger record."""
    try:
        from repro.costmodel import choose as _cm_choose

        shard, dec = _cm_choose.decide_sharding(spec, mesh)
        return dataclasses.replace(spec, shard=shard), dec.as_dict()
    except Exception as e:
        _rledger.record(
            "costmodel.decide_sharding",
            cause=f"{type(e).__name__}: {e}",
            fallback="unsharded",
        )
        return dataclasses.replace(spec, shard=ShardSpec.unsharded(mesh)), None


def _resolve_sharding(
    spec: GemmSpec,
) -> Tuple[str, GemmSpec, int, int, Optional[Dict[str, Any]]]:
    """Choose/validate the collective schedule for `spec.shard` and derive
    (schedule, per-shard local spec, bytes_moved per device per call,
    collective phase count, cost-model decision provenance — None unless
    schedule='auto' resolved through the model).

    The local spec is the SAME GemmSpec type the unsharded planner consumes —
    epilogue stripped (applied post-collective) and accumulation pinned to
    f32, structure folded to 'general' (per-shard tiles are rectangular).
    """
    shard = spec.shard
    if spec.group is not None:
        return _resolve_grouped_sharding(spec)
    if shard.axis_g is not None:
        raise PlanValidationError(
            "axis_g partitions the group dim of a GROUPED spec; this spec"
            " carries no GroupSpec"
        )
    if spec.structure == "scrambled":
        raise PlanValidationError(
            "structure='scrambled' does not compose with a ShardSpec: the"
            " σ arrangement is defined on the global block grid"
        )
    if spec.structure == "symmetric" and spec.m != spec.n:
        raise PlanValidationError(
            f"structure='symmetric' requires a square product, got "
            f"{spec.m}x{spec.n}"
        )
    pm = shard.axis_size(shard.axis_m)
    pk = shard.axis_size(shard.axis_k)
    pn = shard.axis_size(shard.axis_n)
    pb = shard.axis_size(shard.axis_batch)
    eff_m = spec.eff_m

    sched = shard.schedule
    decision = None
    if sched == "auto":
        sched, decision = _auto_schedule(spec)
    if sched == "expert":
        raise PlanValidationError(
            "schedule 'expert' shards the group dim of a GROUPED spec;"
            " this spec carries no GroupSpec"
        )

    def div(what: str, dim: int, axes, p: int) -> int:
        if dim % p:
            raise PlanValidationError(
                f"{what}={dim} is not divisible by mesh axes {axes!r}"
                f" (size {p}) required by schedule {sched!r}"
                f" on mesh {shard.mesh_axes}"
            )
        return dim // p

    if spec.batched_b and sched != "replicated":
        raise PlanValidationError(
            f"schedule {sched!r} does not support fully-batched operands;"
            " use the replicated schedule (batch/M/N partitions are local)"
        )
    if shard.axis_batch is not None and not spec.batch:
        raise PlanValidationError("axis_batch given but the spec has no batch dims")
    if not spec.batched_b and pb > 1:
        raise PlanValidationError(
            "axis_batch partitions the leading dim of a fully-batched"
            " product; with 2D b the batch folds into M — shard axis_m"
            " instead"
        )

    lb: Tuple[int, ...] = spec.batch
    if sched == "replicated":
        if pk > 1:
            raise PlanValidationError(
                "schedule 'replicated' cannot shard K (a K partition needs a"
                " collective; use 'reduce_scatter_k' or 'ring_k')"
            )
        if spec.batched_b:
            nb = math.prod(spec.batch)
            lb = (div("batch", nb, shard.axis_batch, pb),)
            lm = div("M", spec.m, shard.axis_m, pm)
        else:
            lm = div("M", eff_m, shard.axis_m, pm)
        lk, ln = spec.k, div("N", spec.n, shard.axis_n, pn)
        bytes_moved, phases = 0, 0
    elif sched in ("allgather_a", "allgather_a_overlap"):
        if not isinstance(shard.axis_m, str):
            raise PlanValidationError(
                f"schedule {sched!r} needs a single mesh axis on M"
                f" (axis_m={shard.axis_m!r}) — the gather is a 1D ring"
            )
        if pk > 1 or pn > 1:
            raise PlanValidationError(
                f"schedule {sched!r} shards only M; drop axis_k/axis_n"
            )
        lm = div("M", eff_m, shard.axis_m, pm)
        lk, ln = spec.k, spec.n
        if sched == "allgather_a_overlap":
            if pm < 2:
                raise PlanValidationError(
                    "schedule 'allgather_a_overlap' double-buffers a ring of"
                    f" size >= 2; axis_m={shard.axis_m!r} has size {pm}"
                )
            if spec.n < 2 or spec.n % 2:
                raise PlanValidationError(
                    "schedule 'allgather_a_overlap' splits the local product"
                    f" into two column halves; N={spec.n} must be even"
                )
            ln = spec.n // 2  # per-shard kernel built at the half width
        # Each device computes its (lm, n) result chunk ONCE; the f32 chunks
        # hop the ring pm-1 times (input rotation would re-run the full-K
        # kernel pm times for the same bytes — the old pathology).
        bytes_moved = (pm - 1) * lm * spec.n * 4
        phases = pm - 1
    elif sched in (
        "reduce_scatter_k",
        "reduce_scatter_k_overlap",
        "ring_k",
        "ring_k_overlap",
        "pipeline",
    ):
        if shard.axis_k is None:
            raise PlanValidationError(f"schedule {sched!r} requires axis_k")
        if pm > 1 or pn > 1:
            if shard.schedule == "auto":
                raise PlanValidationError(
                    "no collective schedule combines a K partition with an"
                    " M/N partition; shard K alone (reduce_scatter_k /"
                    " ring_k) or drop axis_k"
                )
            raise PlanValidationError(
                f"schedule {sched!r} shards only K; drop axis_m/axis_n"
            )
        lk = div("K", spec.k, shard.axis_k, pk)
        ln = spec.n
        if sched in ("reduce_scatter_k", "reduce_scatter_k_overlap"):
            lm = div("M", eff_m, shard.axis_k, pk)
            # f32 accumulator row-chunks hop the ring p-1 times
            bytes_moved = (pk - 1) * lm * spec.n * 4
            phases = pk - 1
        elif sched == "pipeline":
            mb = div("M", eff_m, shard.axis_k, pk)
            micro = _pipeline_microbatches(eff_m, pk)
            lm = eff_m // micro  # one microbatch chain per kernel call
            # same total accumulator bytes as reduce_scatter_k, split over
            # micro/pk chains of (pk-1) hops each
            bytes_moved = (pk - 1) * mb * spec.n * 4
            phases = micro - micro // pk  # (micro/pk chains) x (pk-1) hops
        else:  # ring_k / ring_k_overlap
            lm = eff_m
            if sched == "ring_k_overlap":
                if pk < 2:
                    raise PlanValidationError(
                        "schedule 'ring_k_overlap' double-buffers a ring of"
                        f" size >= 2; axis_k={shard.axis_k!r} has size {pk}"
                    )
                if spec.n < 2 or spec.n % 2:
                    raise PlanValidationError(
                        "schedule 'ring_k_overlap' splits the partial into"
                        f" two column halves; N={spec.n} must be even"
                    )
                ln = spec.n // 2  # per-shard kernel built at the half width
            # full f32 accumulator wavefronts hop the ring p-1 times
            bytes_moved = (pk - 1) * eff_m * spec.n * 4
            phases = pk - 1
    else:  # pragma: no cover — ShardSpec.__post_init__ rejects unknown names
        raise PlanValidationError(f"unknown schedule {sched!r}")

    local = dataclasses.replace(
        spec,
        m=lm,
        k=lk,
        n=ln,
        batch=lb if spec.batched_b else (),
        batched_b=spec.batched_b,
        structure="general",
        epilogue=Epilogue(),
        out_dtype="float32",
        shard=None,
    )
    return sched, local, bytes_moved, phases, decision


def _resolve_grouped_sharding(
    spec: GemmSpec,
) -> Tuple[str, GemmSpec, int, int, Optional[Dict[str, Any]]]:
    """The grouped analogue of `_resolve_sharding`: the only meaningful
    partition is the group (expert) dim over `axis_g` — the `expert`
    schedule.  Tokens/sizes/weights reshard at the shard_map boundary (the
    EP all-to-all); there is no in-body collective, so bytes_moved reports
    the boundary resharding cost."""
    shard = spec.shard
    grp = spec.group
    for field in ("axis_m", "axis_k", "axis_n", "axis_batch"):
        if getattr(shard, field) is not None and shard.axis_size(getattr(shard, field)) > 1:
            raise PlanValidationError(
                f"grouped specs shard only the group dim (axis_g);"
                f" drop {field}"
            )
    pg = shard.axis_size(shard.axis_g)
    sched = shard.schedule
    if sched == "auto":
        sched = "expert" if pg > 1 else "replicated"
    if sched not in ("expert", "replicated"):
        raise PlanValidationError(
            f"schedule {sched!r} does not apply to grouped specs; use"
            " 'expert' (group dim over axis_g) or 'replicated'"
        )
    if sched == "replicated" and pg > 1:
        raise PlanValidationError(
            "schedule 'replicated' cannot shard the group dim; use 'expert'"
        )
    if grp.num_groups % pg:
        raise PlanValidationError(
            f"num_groups={grp.num_groups} is not divisible by mesh axis"
            f" {shard.axis_g!r} (size {pg}) required by schedule 'expert'"
            f" on mesh {shard.mesh_axes}"
        )
    local_grp = GroupSpec(grp.num_groups // pg, grp.rows_per_group)
    local = dataclasses.replace(
        spec, m=local_grp.rows, group=local_grp, shard=None
    )
    if pg > 1:
        ia = jnp.dtype(spec.dtype_a).itemsize
        io = jnp.dtype(spec.resolved_out_dtype()).itemsize
        # boundary all-to-all: (p-1)/p of the token rows change device on the
        # way in, and again on the way out
        bytes_moved = (pg - 1) * grp.rows * (spec.k * ia + spec.n * io) // pg
        phases = pg - 1
    else:
        bytes_moved, phases = 0, 0
    # EP has one meaningful partition — no candidate set, no decision record
    return ("expert" if pg > 1 else "replicated"), local, bytes_moved, phases, None


def _grouped_sharded_executor(
    spec: GemmSpec, sched: str, mesh: Mesh, local_plan: Plan
) -> Callable:
    """shard_map executor for grouped specs: group-sharded tokens/sizes/
    weights in, group-sharded output rows out, local GroupedPlan in the body."""
    from repro.parallel.sharding import shard_map as _shard_map

    ag = spec.shard.axis_g if sched == "expert" else None
    epi = spec.epilogue

    def body(t_blk, sz_blk, w_blk, *rest):
        it = iter(rest)
        bias_blk = next(it) if epi.bias else None
        res_blk = next(it) if epi.residual else None
        off = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(sz_blk).astype(jnp.int32)]
        )
        return local_plan._fn(t_blk, off, w_blk, bias_blk, res_blk)

    # The epilogue is per-row / per-group (no cross-device reduction), so it
    # shards with its operands: bias (G, N) and residual (rows, group-major)
    # both partition on the group axis — unlike the K-collective schedules,
    # nothing has to move post-collective.
    in_specs = [P(ag, None), P(ag), P(ag, None, None)]
    if epi.bias:
        in_specs.append(P(ag, None))
    if epi.residual:
        in_specs.append(P(ag, None))
    mapped = _shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=P(ag, None),
        check_vma=False,
    )

    def run(tokens, group_offsets, weights, bias, residual):
        sizes = (group_offsets[1:] - group_offsets[:-1]).astype(jnp.int32)
        args = [tokens, sizes, weights]
        if epi.bias:
            args.append(bias)
        if epi.residual:
            args.append(residual)
        return mapped(*args)

    return jax.jit(run)


def _sharded_executor(
    spec: GemmSpec, sched: str, mesh: Mesh, local_plan: Plan
) -> Callable:
    """The jitted global-operand executor: shard_map(collective ∘ per-shard
    kernel) with batch folding/unfolding around it."""
    from repro.parallel.collectives import (
        matmul_ring_reducescatter,
        ring_allgather_matmul,
        ring_pipeline_matmul,
    )
    from repro.parallel.sharding import shard_map as _shard_map
    from repro.parallel.systolic import ring_systolic_kpass

    shard = spec.shard
    epi = spec.epilogue
    act = epi.activation
    out_dt = jnp.dtype(spec.resolved_out_dtype())
    am, ak, an, ab = shard.axis_m, shard.axis_k, shard.axis_n, shard.axis_batch
    overlap = sched.endswith("_overlap")
    base = sched[: -len("_overlap")] if overlap else sched

    def local_mm(x, y):
        return local_plan._fn(x, y, None, None)

    if spec.batched_b:  # replicated schedule only (validated upstream)
        in_a, in_b = P(ab, am, None), P(ab, None, an)
        in_bias, in_res = P(an), P(ab, am, an)
        out_spec = P(ab, am, an)
    elif sched == "replicated":
        in_a, in_b = P(am, None), P(None, an)
        in_bias, in_res = P(an), P(am, an)
        out_spec = P(am, an)
    elif base == "allgather_a":
        in_a, in_b, in_bias, in_res = P(am, None), P(), P(), P()
        out_spec = P()
    elif base in ("reduce_scatter_k", "pipeline"):
        in_a, in_b, in_bias = P(None, ak), P(ak, None), P()
        in_res = out_spec = P(ak, None)
    else:  # ring_k / ring_k_overlap
        in_a, in_b, in_bias, in_res = P(None, ak), P(ak, None), P(), P()
        out_spec = P()

    if sched == "pipeline":
        micro = _pipeline_microbatches(spec.eff_m, shard.axis_size(ak))

    def body(*args):
        a_blk, b_blk, *rest = args
        it = iter(rest)
        bias_blk = next(it) if epi.bias else None
        res_blk = next(it) if epi.residual else None
        if sched == "replicated":
            z = local_plan._fn(a_blk, b_blk, None, None)
        elif base == "allgather_a":
            z = ring_allgather_matmul(
                a_blk, b_blk, am, matmul=local_mm, overlap=overlap
            )
        elif base == "reduce_scatter_k":
            z = matmul_ring_reducescatter(
                a_blk, b_blk, ak, matmul=local_mm, overlap=overlap
            )
        elif sched == "pipeline":
            z = ring_pipeline_matmul(
                a_blk, b_blk, ak, microbatches=micro, matmul=local_mm
            )
        else:
            z = ring_systolic_kpass(
                a_blk, b_blk, axis=ak, matmul=local_mm, overlap=overlap
            )
        return apply_epilogue(z, bias_blk, act, res_blk).astype(out_dt)

    in_specs = [in_a, in_b]
    if epi.bias:
        in_specs.append(in_bias)
    if epi.residual:
        in_specs.append(in_res)
    # Ring outputs are replicated by construction, not by a verifiable
    # per-op replication rule — declare specs, skip the rep check.
    mapped = _shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=out_spec,
        check_vma=False,
    )
    eff_m = spec.eff_m

    def run(a, b, bias, residual):
        if spec.batched_b:
            nb = math.prod(spec.batch)
            af = a.reshape(nb, spec.m, spec.k)
            bf = b.reshape(nb, spec.k, spec.n)
            resf = None if residual is None else residual.reshape(nb, spec.m, spec.n)
            args = [af, bf]
        else:
            # Leading batch dims of `a` fold into M, exactly as in the
            # unsharded pallas path — the M partition shards eff_m.
            af = a.reshape(eff_m, spec.k)
            resf = None if residual is None else residual.reshape(eff_m, spec.n)
            args = [af, b]
        if epi.bias:
            args.append(bias)
        if epi.residual:
            args.append(resf)
        out = mapped(*args)
        return out.reshape(*spec.batch, spec.m, spec.n) if spec.batch else out

    return jax.jit(run)


def _build_sharded_plan(spec: GemmSpec, be: _Backend, mesh: Mesh) -> ShardedPlan:
    """ONE planner: resolve the collective schedule, build the per-shard Plan
    through the ordinary `plan()` path (cached, autotuned at the LOCAL shape),
    and wrap it in the shard_map executor."""
    shard = spec.shard
    live = tuple((str(n), int(s)) for n, s in mesh.shape.items())
    if live != shard.mesh_axes:
        raise PlanValidationError(
            f"ShardSpec was built for mesh axes {shard.mesh_axes} but"
            f" plan() got a mesh with {live}; rebuild it with"
            f" ShardSpec.from_mesh(mesh, ...)"
        )
    sched, local_spec, bytes_moved, phases, sched_decision = _resolve_sharding(spec)
    local_plan = plan(local_spec, backend=be.name)
    # Per-device kernel calls: the reduce-scatter family runs the local
    # kernel once per ring step (p = phases + 1); pipeline runs it once per
    # microbatch chain step; the column-half overlap variants run the
    # half-width kernel twice; allgather_a (result-gather), replicated,
    # ring_k and expert invoke it exactly once.
    if sched in ("reduce_scatter_k", "reduce_scatter_k_overlap"):
        invocations = phases + 1
    elif sched == "pipeline":
        invocations = _pipeline_microbatches(
            spec.eff_m, shard.axis_size(shard.axis_k)
        )
    elif sched in ("allgather_a_overlap", "ring_k_overlap"):
        invocations = 2
    else:
        invocations = 1
    cls = ShardedGroupedPlan if spec.group is not None else ShardedPlan
    p = cls(
        spec=spec,
        backend=be.name,
        capabilities=be.caps,
        blocks=local_plan.blocks,
        out_dtype=spec.resolved_out_dtype(),
        interpret=not _on_tpu(),
        flops=spec.flops(),
        vmem_bytes=local_plan.vmem_bytes,
        sigma_table=None,
        stagger_table=local_plan.stagger_table,
        mesh=mesh,
        schedule=sched,
        local=local_plan,
        bytes_moved=bytes_moved,
        collective_phases=phases,
        kernel_invocations=invocations,
    )
    if sched_decision is not None:
        p.decision = {"schedule": sched_decision}
    executor = (
        _grouped_sharded_executor if spec.group is not None else _sharded_executor
    )
    p._fn = executor(spec, sched, mesh, local_plan)
    return p


def execute_async(items) -> List[jax.Array]:
    """Dispatch independent plan executions back-to-back, sync ONCE at the end.

    `items` is an iterable of `(plan, args)` pairs, `args` the positional
    operand tuple for that plan (`(a, b)`, optionally with bias/residual).
    All executions are enqueued before anything blocks, so the device (and
    XLA's async dispatch queue) overlaps them host-side; the return is the
    list of ready outputs in input order.  This is the batch form of
    `Plan.dispatch` — use it when a serve tick or benchmark has several
    independent GEMMs and per-call `block_until_ready` would serialize them.
    """
    handles = [p.dispatch(*args) for p, args in items]
    outs = [h.out for h in handles]
    jax.block_until_ready(outs)
    return outs


def clear_plan_cache() -> None:
    """Test hook: drop all cached plans and reset the hit/miss counters."""
    _PLAN_CACHE.clear()
    _PLAN_STATS.update(hits=0, misses=0)


def plan_cache_info() -> Dict[str, Any]:
    """Cache telemetry: one entry per (spec, backend, platform, mesh) ever
    planned — the same spec under two different meshes is two entries."""
    return {
        "size": len(_PLAN_CACHE),
        "hits": _PLAN_STATS["hits"],
        "misses": _PLAN_STATS["misses"],
        "plans": [p.describe() for p in _PLAN_CACHE.values()],
    }
