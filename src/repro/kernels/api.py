"""Plan/execute operator API: typed GEMM specs + capability-based backends.

This module is the architectural seam between "what GEMM do I need?" and
"which kernel runs it" (DESIGN.md §8).  It separates *planning* — resolve a
backend against declared capabilities, fix block shapes through the autotuner,
precompute the σ/stagger tables host-side — from *execution* — a cached,
reusable, jitted callable that serving and training graphs invoke per request:

    spec = GemmSpec.from_operands(a, b, epilogue=Epilogue(bias=True,
                                                          activation="gelu"))
    p = plan(spec)                  # validate + autotune + build, ONCE
    y = p(a, b, bias=bias)          # reuse forever; p is cached per spec

`GemmSpec.structure` replaces the old `pallas_mesh_scrambled` pseudo-backend:
the *regime* the paper's array supports (general 2n-1-step product, the
3n/2+1 symmetric readout, the scrambling mode) is a property of the problem,
not of the kernel that happens to run it.  Backends declare which structures
(and which other capabilities: fully-batched grids, fused epilogues,
off-TPU interpret execution, autotuned blocks) they support via
`register_backend`, so ref/XLA/Pallas implementations — and test doubles —
register uniformly; `plan` picks a capable backend instead of string-matching.

`repro.kernels.ops.matmul` remains as a thin compat shim over this module.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
from typing import Any, Callable, Dict, FrozenSet, List, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune as _autotune
from repro.kernels import ref
from repro.kernels.mesh_matmul import (
    ACTIVATIONS,
    mesh_matmul_pallas,
    mesh_matmul_pallas_batched,
    sigma_block_table,
)

__all__ = [
    "STRUCTURES",
    "BackendCapabilities",
    "CapabilityError",
    "Epilogue",
    "GemmSpec",
    "Plan",
    "apply_epilogue",
    "backend_names",
    "clear_plan_cache",
    "default_backend",
    "get_capabilities",
    "get_default",
    "plan",
    "plan_cache_info",
    "register_backend",
    "set_default",
    "unregister_backend",
]

STRUCTURES = ("general", "symmetric", "scrambled")


# ---------------------------------------------------------------------------
# Typed specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """The fused-epilogue contract (DESIGN.md §3): y = act(AB + bias) + residual.

    Declares *which* epilogue operands exist — the arrays themselves are
    execution-time inputs, so one plan serves every bias/residual value.
    """

    bias: bool = False
    activation: Optional[str] = None
    residual: bool = False

    def __post_init__(self):
        if self.activation not in ACTIVATIONS:
            raise ValueError(
                f"activation must be one of {sorted(k for k in ACTIVATIONS if k)},"
                f" got {self.activation!r}"
            )
        if self.activation == "none":
            object.__setattr__(self, "activation", None)

    @property
    def is_identity(self) -> bool:
        return not (self.bias or self.residual) and self.activation is None


def _dtype_name(dt) -> str:
    return jnp.dtype(dt).name


@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """Logical description of one GEMM: (batch..., M, K) @ (K, N) — or, when
    `batched_b`, (batch..., M, K) @ (batch..., K, N).

    `structure` names the paper regime of the product:
      general    arbitrary C = AB (the 2n-1-step mode)
      symmetric  caller asserts C = Cᵀ (square; the early-readout mode — keys
                 a separate autotune-cache partition, sym1)
      scrambled  output lands in the paper's σ block arrangement (replaces the
                 old `pallas_mesh_scrambled` pseudo-backend)

    `blocks` is an optional (bm, bn, bk) override; entries left None are
    resolved by the autotuner at plan time.  Hashable and frozen — specs are
    the plan-cache key.
    """

    m: int
    k: int
    n: int
    batch: Tuple[int, ...] = ()
    batched_b: bool = False
    dtype_a: str = "float32"
    dtype_b: str = "float32"
    out_dtype: Optional[str] = None
    structure: str = "general"
    epilogue: Epilogue = Epilogue()
    blocks: Optional[Tuple[Optional[int], Optional[int], Optional[int]]] = None
    stagger: bool = True

    def __post_init__(self):
        if self.structure not in STRUCTURES:
            raise ValueError(
                f"structure must be one of {STRUCTURES}, got {self.structure!r}"
            )
        if min(self.m, self.k, self.n) <= 0:
            raise ValueError(f"dims must be positive, got {(self.m, self.k, self.n)}")
        if self.batched_b and not self.batch:
            raise ValueError("batched_b requires leading batch dims")
        object.__setattr__(self, "batch", tuple(int(d) for d in self.batch))
        object.__setattr__(self, "dtype_a", _dtype_name(self.dtype_a))
        object.__setattr__(self, "dtype_b", _dtype_name(self.dtype_b))
        if self.out_dtype is not None:
            object.__setattr__(self, "out_dtype", _dtype_name(self.out_dtype))
        if self.blocks is not None:
            if len(self.blocks) != 3:
                raise ValueError(
                    f"blocks must be a (bm, bn, bk) triple, got {self.blocks!r}"
                )
            bks = tuple(None if x in (None, 0) else int(x) for x in self.blocks)
            object.__setattr__(self, "blocks", None if bks == (None,) * 3 else bks)

    @classmethod
    def from_operands(
        cls,
        a: jax.Array,
        b: jax.Array,
        *,
        structure: str = "general",
        epilogue: Optional[Epilogue] = None,
        out_dtype=None,
        blocks=None,
        stagger: bool = True,
    ) -> "GemmSpec":
        """Spec for concrete (or abstract) operands; leading dims of `a` become
        the batch, shared with `b` when `b` carries the same leading dims."""
        if a.ndim < 2 or b.ndim < 2:
            raise ValueError(f"operands must be >= 2D, got {a.shape} @ {b.shape}")
        if a.shape[-1] != b.shape[-2]:
            raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
        batched_b = b.ndim > 2
        if batched_b and a.shape[:-2] != b.shape[:-2]:
            raise ValueError(f"batch dims mismatch: {a.shape} vs {b.shape}")
        return cls(
            m=a.shape[-2],
            k=a.shape[-1],
            n=b.shape[-1],
            batch=a.shape[:-2],
            batched_b=batched_b,
            dtype_a=a.dtype,
            dtype_b=b.dtype,
            out_dtype=out_dtype,
            structure=structure,
            epilogue=epilogue or Epilogue(),
            blocks=blocks,
            stagger=stagger,
        )

    # -- derived quantities used at plan time --------------------------------

    @property
    def eff_m(self) -> int:
        """M after folding leading batch dims (b 2D folds batch into M)."""
        if self.batch and not self.batched_b:
            return math.prod(self.batch) * self.m
        return self.m

    @property
    def acc_dtype(self) -> str:
        return _dtype_name(jnp.result_type(self.dtype_a, self.dtype_b))

    def resolved_out_dtype(self) -> str:
        return self.out_dtype or self.acc_dtype

    def flops(self) -> int:
        return 2 * math.prod(self.batch or (1,)) * self.m * self.k * self.n


# ---------------------------------------------------------------------------
# Capability-based backend registry
# ---------------------------------------------------------------------------


class CapabilityError(ValueError):
    """A spec asks for something the (chosen or only) backend cannot do."""


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """What a registered backend declares it can execute.

    structures        subset of STRUCTURES the impl can produce
    batching          fully-batched (B, M, K) @ (B, K, N) operands
    epilogue          the DESIGN.md §3 epilogue contract (fused or not)
    epilogue_fusion   the epilogue runs inside the kernel (provenance only)
    interpret         executes off-TPU (natively or via Pallas interpret mode)
    autotune          consumes autotuned (bm, bn, bk) block shapes
    """

    structures: FrozenSet[str] = frozenset({"general"})
    batching: bool = False
    epilogue: bool = True
    epilogue_fusion: bool = False
    interpret: bool = True
    autotune: bool = False

    def __post_init__(self):
        object.__setattr__(self, "structures", frozenset(self.structures))
        unknown = self.structures - set(STRUCTURES)
        if unknown:
            raise ValueError(
                f"unknown structures {sorted(unknown)}; known: {STRUCTURES}"
            )


_CAP_FIELDS = {f.name for f in dataclasses.fields(BackendCapabilities)}

# impl(plan, a, b, bias, residual) -> array
BackendImpl = Callable[["Plan", jax.Array, jax.Array, Any, Any], jax.Array]


@dataclasses.dataclass(frozen=True)
class _Backend:
    name: str
    impl: BackendImpl
    caps: BackendCapabilities


_REGISTRY: Dict[str, _Backend] = {}

# Plan cache: one entry per (spec, backend, platform) ever planned (defined
# here because registration evicts from it).
_PLAN_CACHE: Dict[tuple, "Plan"] = {}
_PLAN_STATS = {"hits": 0, "misses": 0}


def _evict_plans(name: str) -> None:
    """Drop cached plans for one backend: a (re|un)registered impl must not
    keep serving stale executables, and plans for OTHER backends stay valid
    (and cached) — no global invalidation, no stranded entries."""
    for key in [k for k in _PLAN_CACHE if k[1] == name]:
        del _PLAN_CACHE[key]


def register_backend(
    name: str,
    impl: BackendImpl,
    capabilities: Union[BackendCapabilities, Mapping[str, Any]],
    *,
    override: bool = False,
) -> None:
    """Register a GEMM backend under `name` with declared capabilities.

    `capabilities` is a BackendCapabilities or a mapping with only its field
    names — unknown capability keys are rejected so typos never silently grant
    an ability.  Duplicate names are rejected unless `override=True`.
    """
    if not isinstance(capabilities, BackendCapabilities):
        unknown = set(capabilities) - _CAP_FIELDS
        if unknown:
            raise ValueError(
                f"unknown capabilities {sorted(unknown)};"
                f" known: {sorted(_CAP_FIELDS)}"
            )
        capabilities = BackendCapabilities(**capabilities)
    if name in _REGISTRY and not override:
        raise ValueError(
            f"backend {name!r} already registered (pass override=True to replace)"
        )
    _REGISTRY[name] = _Backend(name, impl, capabilities)
    _evict_plans(name)


def unregister_backend(name: str) -> None:
    if _REGISTRY.pop(name, None) is not None:
        _evict_plans(name)
    if _DEFAULT_BACKEND[0] == name:
        _DEFAULT_BACKEND[0] = None
        _DEFAULT_EPOCH[0] += 1


def backend_names() -> List[str]:
    return list(_REGISTRY)


def get_capabilities(name: str) -> BackendCapabilities:
    return _require_backend(name).caps


def _require_backend(name: str) -> _Backend:
    be = _REGISTRY.get(name)
    if be is None:
        raise ValueError(
            f"unknown backend {name!r}; registered: {sorted(_REGISTRY)}"
        )
    return be


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _check_capabilities(spec: GemmSpec, be: _Backend) -> Optional[str]:
    """None if `be` can run `spec` here; else a human-readable reason."""
    caps = be.caps
    if spec.structure not in caps.structures:
        return (
            f"backend {be.name!r} does not support structure"
            f" {spec.structure!r} (supports {sorted(caps.structures)})"
        )
    if spec.batched_b and not caps.batching:
        return f"backend {be.name!r} does not support fully-batched operands"
    if not spec.epilogue.is_identity and not caps.epilogue:
        return f"backend {be.name!r} does not support the fused-epilogue contract"
    if not _on_tpu() and not caps.interpret:
        return (
            f"backend {be.name!r} requires TPU and has no interpret mode"
            f" (running on {jax.default_backend()!r})"
        )
    return None


# -- default backend (process default + scoped override) ---------------------

_DEFAULT_BACKEND: List[Optional[str]] = [None]  # None = capability-based choice
_DEFAULT_EPOCH: List[int] = [0]  # bumped on every default change (see ops.py)


def set_default(name: Optional[str]) -> None:
    """Install a process-wide default backend (None restores auto-choice)."""
    if name is not None:
        _require_backend(name)
    _DEFAULT_BACKEND[0] = name
    _DEFAULT_EPOCH[0] += 1


def get_default() -> Optional[str]:
    return _DEFAULT_BACKEND[0]


def default_epoch() -> int:
    """Monotonic counter of default-backend changes — lets the legacy shim
    detect that its recorded default has been superseded by a newer
    set_default/default_backend scope."""
    return _DEFAULT_EPOCH[0]


@contextlib.contextmanager
def default_backend(name: str):
    """Scoped default: `with default_backend("pallas_mesh"): ...` — the
    supported replacement for the mutable `set_default_backend` global."""
    prev = _DEFAULT_BACKEND[0]
    set_default(name)
    try:
        yield
    finally:
        set_default(prev)


def _choose_backend(spec: GemmSpec) -> _Backend:
    """Capability-based choice: the pinned default first (if capable), then
    xla, then pallas_mesh, then registration order."""
    order: List[str] = []
    for name in (
        *((_DEFAULT_BACKEND[0],) if _DEFAULT_BACKEND[0] is not None else ()),
        "xla",
        "pallas_mesh",
        *_REGISTRY,
    ):
        if name not in order:
            order.append(name)
    reasons = []
    for name in order:
        be = _REGISTRY.get(name)
        if be is None:
            continue
        reason = _check_capabilities(spec, be)
        if reason is None:
            return be
        reasons.append(reason)
    raise CapabilityError(
        "no registered backend can execute this spec: " + "; ".join(reasons)
    )


# ---------------------------------------------------------------------------
# Shared numerics (moved from ops.py so the shim stays thin)
# ---------------------------------------------------------------------------

# d/dz of each fused activation, as a function of the *pre-activation* z
# (recomputed in the backward pass — remat, not an extra forward output).
_ACT_GRADS = {
    "relu": lambda z: (z > 0).astype(z.dtype),
    "silu": lambda z: jax.nn.sigmoid(z) * (1 + z * (1 - jax.nn.sigmoid(z))),
    "sigmoid": lambda z: jax.nn.sigmoid(z) * (1 - jax.nn.sigmoid(z)),
    "tanh": lambda z: 1 - jnp.tanh(z) ** 2,
    "gelu": lambda z: _gelu_grad(z),
}


def _gelu_grad(z):
    """Analytic derivative of ACTIVATIONS['gelu'] (same GELU_C/GELU_A)."""
    from repro.kernels.mesh_matmul import GELU_A, GELU_C

    u = jnp.tanh(GELU_C * (z + GELU_A * z**3))
    return 0.5 * (1 + u) + 0.5 * z * (1 - u**2) * GELU_C * (1 + 3 * GELU_A * z**2)


def _act_grad(z: jax.Array, activation: str) -> jax.Array:
    return _ACT_GRADS[activation](z)


def _pad_to(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def apply_epilogue(
    z: jax.Array,
    bias: Optional[jax.Array],
    activation: Optional[str],
    residual: Optional[jax.Array],
) -> jax.Array:
    """The epilogue contract as plain jnp ops (f32 in, f32 out) — the single
    unfused reference used by the XLA/ref backends and the unfused A/B lever."""
    if bias is not None:
        z = z + bias.astype(jnp.float32)
    if activation not in (None, "none"):
        z = ACTIVATIONS[activation](z)
    if residual is not None:
        z = z + residual.astype(jnp.float32)
    return z


def _mm_impl(a2, b2, bias, residual, opts) -> jax.Array:
    """Mesh-kernel matmul (2D or fully-batched 3D) with padding to block
    multiples and the fused epilogue."""
    block_m, block_n, block_k, stagger, scramble, out_dtype, interpret, act = opts
    batched = a2.ndim == 3
    m, n = a2.shape[-2], b2.shape[-1]
    ap = _pad_to(_pad_to(a2, block_m, -2), block_k, -1)
    bp = _pad_to(_pad_to(b2, block_k, -2), block_n, -1)
    if scramble and (ap.shape[-2] != m or bp.shape[-1] != n):
        raise ValueError(
            "structure='scrambled' requires block-aligned M and N "
            f"(got M={m}, N={n} with blocks {block_m}x{block_n})"
        )
    bias_p = None if bias is None else _pad_to(bias, block_n, 0)
    res_p = (
        None
        if residual is None
        else _pad_to(_pad_to(residual, block_m, -2), block_n, -1)
    )
    kernel = mesh_matmul_pallas_batched if batched else mesh_matmul_pallas
    out = kernel(
        ap,
        bp,
        bias=bias_p,
        residual=res_p,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        stagger=stagger,
        scramble_out=scramble,
        activation=act,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[..., :m, :n]


# pallas_call has no JVP rule, so training graphs need an explicit VJP.
# Forward: y = act(A @ B + bias) + residual (epilogue fused in-kernel).
# Backward: dresidual = g; dz = g * act'(z) with z recomputed by one plain
# kernel call (remat — no extra forward output); dA = dz Bᵀ and dB = Aᵀ dz are
# two more mesh-kernel matmuls; dbias reduces dz over rows.  For the scrambled
# structure C = S(...), the cotangent is unscrambled (a pure gather — the
# permutation's own transpose) first, putting the whole backward in standard
# arrangement.
@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _mm(a2, b2, bias, residual, opts) -> jax.Array:
    return _mm_impl(a2, b2, bias, residual, opts)


def _mm_fwd(a2, b2, bias, residual, opts):
    # dresidual only needs residual's DTYPE — save a scalar sentinel, not the
    # full output-sized tensor (it would stay live until the backward pass).
    res_sentinel = None if residual is None else jnp.zeros((), residual.dtype)
    return _mm_impl(a2, b2, bias, residual, opts), (a2, b2, bias, res_sentinel)


def _mm_bwd(opts, res, g):
    a2, b2, bias, res_sentinel = res
    block_m, block_n, block_k, stagger, scramble, _, interpret, act = opts
    if scramble:
        g = ref.unscramble_blocks_ref(g, block_m=block_m, block_n=block_n)
    gf = g.astype(jnp.float32)
    dresidual = None if res_sentinel is None else g.astype(res_sentinel.dtype)

    if act in (None, "none"):
        dz = gf
    else:
        # Remat the pre-activation z = A @ B + bias with a plain (no-epilogue,
        # unscrambled) kernel call, then chain through act'.
        opts_z = (block_m, block_n, block_k, stagger, False, jnp.float32, interpret, None)
        z = _mm_impl(
            a2.astype(jnp.float32), b2.astype(jnp.float32), None, None, opts_z
        )
        if bias is not None:
            z = z + bias.astype(jnp.float32)
        dz = gf * _act_grad(z, act)

    opts_a = (block_m, block_k, block_n, stagger, False, jnp.float32, interpret, None)
    opts_b = (block_k, block_n, block_m, stagger, False, jnp.float32, interpret, None)
    bT = jnp.swapaxes(b2, -1, -2).astype(jnp.float32)
    aT = jnp.swapaxes(a2, -1, -2).astype(jnp.float32)
    da = _mm(dz, bT, None, None, opts_a)
    db = _mm(aT, dz, None, None, opts_b)
    dbias = (
        None
        if bias is None
        else jnp.sum(dz, axis=tuple(range(dz.ndim - 1))).astype(bias.dtype)
    )
    return da.astype(a2.dtype), db.astype(b2.dtype), dbias, dresidual


_mm.defvjp(_mm_fwd, _mm_bwd)


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Plan:
    """A resolved, reusable GEMM executable with provenance.

    Built once by `plan(spec)`; calling it runs the chosen backend with the
    blocks/tables fixed at plan time.  Provenance (backend, blocks, estimated
    FLOPs/VMEM, σ table) is inspectable via the fields or `describe()`.
    """

    spec: GemmSpec
    backend: str
    capabilities: BackendCapabilities
    blocks: Optional[Tuple[int, int, int]]
    out_dtype: str
    interpret: bool
    flops: int
    vmem_bytes: Optional[int]
    sigma_table: Optional[np.ndarray] = None
    stagger_table: Optional[np.ndarray] = None
    _fn: Optional[Callable] = dataclasses.field(default=None, repr=False)

    @property
    def activation(self) -> Optional[str]:
        return self.spec.epilogue.activation

    @property
    def executor(self) -> Callable:
        """The raw jitted executor `(a, b, bias, residual) -> out`, with no
        per-call Python validation — for benchmarking and trusted hot loops
        where even `__call__`'s shape/dtype checks are measurable."""
        return self._fn

    def describe(self) -> Dict[str, Any]:
        """JSON-able provenance record (benchmarks / serving telemetry)."""
        return {
            "backend": self.backend,
            "structure": self.spec.structure,
            "mkn": f"{self.spec.eff_m}x{self.spec.k}x{self.spec.n}",
            "batch": list(self.spec.batch),
            "blocks": list(self.blocks) if self.blocks else None,
            "epilogue": {
                "bias": self.spec.epilogue.bias,
                "activation": self.activation,
                "residual": self.spec.epilogue.residual,
            },
            "fused_epilogue": self.capabilities.epilogue_fusion,
            "out_dtype": self.out_dtype,
            "interpret": self.interpret,
            "flops": self.flops,
            "vmem_bytes": self.vmem_bytes,
        }

    # -- execution -----------------------------------------------------------

    def _check_operands(self, a, b, bias, residual):
        spec = self.spec
        want_a = spec.batch + (spec.m, spec.k)
        want_b = (spec.batch if spec.batched_b else ()) + (spec.k, spec.n)
        if tuple(a.shape) != want_a or tuple(b.shape) != want_b:
            raise ValueError(
                f"operands {a.shape} @ {b.shape} do not match plan spec "
                f"{want_a} @ {want_b}"
            )
        got_dt = (_dtype_name(a.dtype), _dtype_name(b.dtype))
        if got_dt != (spec.dtype_a, spec.dtype_b):
            # out_dtype and the autotuned/VMEM-budgeted blocks were fixed for
            # the spec's dtypes — a silent cast here would mask caller intent
            raise ValueError(
                f"operand dtypes {got_dt} do not match plan spec "
                f"({spec.dtype_a}, {spec.dtype_b}); build a new GemmSpec"
            )
        epi = spec.epilogue
        for name, arr, declared in (
            ("bias", bias, epi.bias),
            ("residual", residual, epi.residual),
        ):
            if (arr is not None) != declared:
                state = "with" if declared else "without"
                raise ValueError(
                    f"plan was built {state} {name}; pass a matching "
                    f"Epilogue in the GemmSpec to change the contract"
                )
        # Epilogue shape validation — identical on every backend (same
        # exception type/message), against the LOGICAL (unpadded) shapes.
        _check_epilogue_shapes(bias, residual, spec)

    def __call__(self, a, b, bias=None, residual=None) -> jax.Array:
        self._check_operands(a, b, bias, residual)
        return self._fn(a, b, bias, residual)


def _check_epilogue_shapes(bias, residual, spec: GemmSpec) -> None:
    """The `_check_epilogue` contract at the dispatch layer: every backend —
    XLA included — rejects malformed bias/residual with the same error."""
    n = spec.n
    if bias is not None and tuple(bias.shape) != (n,):
        raise ValueError(f"bias must have shape ({n},), got {tuple(bias.shape)}")
    want_res = spec.batch + (spec.m, n)
    if residual is not None and tuple(residual.shape) != want_res:
        raise ValueError(
            f"residual must have shape {want_res}, got {tuple(residual.shape)}"
        )


# -- built-in backend implementations ----------------------------------------


def _xla_impl(p: Plan, a, b, bias, residual):
    z = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    return apply_epilogue(z, bias, p.activation, residual).astype(p.out_dtype)


def _ref_impl(p: Plan, a, b, bias, residual):
    """Pure-jnp oracle backend: same contract, no Pallas — registered through
    the same capability door as the real kernels (and usable as a test double)."""
    z = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    y = apply_epilogue(z, bias, p.activation, residual)
    if p.spec.structure == "scrambled":
        bm, bn, _ = p.blocks
        y = ref.scramble_blocks_ref(y, block_m=bm, block_n=bn)
    return y.astype(p.out_dtype)


def _pallas_impl(p: Plan, a, b, bias, residual):
    spec = p.spec
    bm, bn, bk = p.blocks
    opts = (
        bm,
        bn,
        bk,
        spec.stagger,
        spec.structure == "scrambled",
        jnp.dtype(p.out_dtype),
        p.interpret,
        spec.epilogue.activation,
    )
    if not spec.batch:
        return _mm(a, b, bias, residual, opts)
    if not spec.batched_b:
        # Fold leading batch dims of `a` into M — still a single 2D kernel.
        a2 = a.reshape(-1, spec.k)
        res2 = None if residual is None else residual.reshape(-1, spec.n)
        out = _mm(a2, b, bias, res2, opts)
        return out.reshape(*spec.batch, spec.m, spec.n)
    # Fully batched: ONE pallas_call with grid (b, i, j, k).
    af = a.reshape(-1, spec.m, spec.k)
    bf = b.reshape(-1, spec.k, spec.n)
    resf = None if residual is None else residual.reshape(-1, spec.m, spec.n)
    out = _mm(af, bf, bias, resf, opts)
    return out.reshape(*spec.batch, spec.m, spec.n)


register_backend(
    "xla",
    _xla_impl,
    BackendCapabilities(
        structures=frozenset({"general", "symmetric"}),
        batching=True,
        epilogue=True,
        epilogue_fusion=False,  # XLA may fuse, but it is not contractual
        interpret=True,  # native everywhere
        autotune=False,
    ),
)
register_backend(
    "pallas_mesh",
    _pallas_impl,
    BackendCapabilities(
        structures=frozenset({"general", "symmetric", "scrambled"}),
        batching=True,
        epilogue=True,
        epilogue_fusion=True,
        interpret=True,  # Pallas interpret mode off-TPU
        autotune=True,
    ),
)
register_backend(
    "ref",
    _ref_impl,
    BackendCapabilities(
        structures=frozenset({"general", "symmetric", "scrambled"}),
        batching=True,
        epilogue=True,
        epilogue_fusion=False,
        interpret=True,
        autotune=False,
    ),
)


# ---------------------------------------------------------------------------
# plan()
# ---------------------------------------------------------------------------


def plan(spec: GemmSpec, *, backend: Optional[str] = None) -> Plan:
    """Validate `spec` against backend capabilities and return the cached,
    reusable executable for it.

    Resolution happens ONCE per (spec, backend) pair per platform: capability
    checks, autotuned block shapes, σ/stagger tables, and the jitted executor
    are all fixed here; repeated calls return the *identical* Plan object.
    An explicit `backend` is validated strictly (CapabilityError on mismatch);
    otherwise the first capable backend is chosen (pinned default → xla →
    pallas_mesh → registration order).
    """
    if not isinstance(spec, GemmSpec):
        raise TypeError(f"plan() takes a GemmSpec, got {type(spec).__name__}")
    if backend is not None:
        be = _require_backend(backend)
        reason = _check_capabilities(spec, be)
        if reason is not None:
            raise CapabilityError(reason)
    else:
        be = _choose_backend(spec)

    key = (spec, be.name, jax.default_backend())
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        _PLAN_STATS["hits"] += 1
        return cached
    _PLAN_STATS["misses"] += 1

    p = _build_plan(spec, be)
    _PLAN_CACHE[key] = p
    return p


def _build_plan(spec: GemmSpec, be: _Backend) -> Plan:
    acc_dtype = spec.acc_dtype
    blocks = None
    vmem = None
    if be.caps.autotune or spec.structure == "scrambled":
        partial = spec.blocks or (None, None, None)
        if None in partial:
            # The scrambled σ-table constraint and the symmetric early-readout
            # regime key their own autotune-cache partitions.
            tune_backend = (
                "pallas_mesh_scrambled" if spec.structure == "scrambled" else be.name
            )
            symmetry = 1 if spec.structure == "symmetric" else 0
            bm, bn, bk = _autotune.resolve_blocks(
                spec.eff_m, spec.k, spec.n, acc_dtype, tune_backend, symmetry=symmetry
            )
            blocks = tuple(p or r for p, r in zip(partial, (bm, bn, bk)))
        else:
            blocks = partial
        vmem = _autotune.vmem_bytes(
            *blocks,
            acc_dtype,
            has_bias=spec.epilogue.bias,
            has_residual=spec.epilogue.residual,
        )

    sigma = stagger_tbl = None
    if spec.structure == "symmetric" and spec.m != spec.n:
        raise ValueError(
            f"structure='symmetric' requires a square product, got "
            f"{spec.m}x{spec.n}"
        )
    if spec.structure == "scrambled":
        bm, bn, bk = blocks
        eff_m, n = spec.eff_m, spec.n
        if eff_m % bm or n % bn:
            raise ValueError(
                "structure='scrambled' requires block-aligned M and N "
                f"(got M={eff_m}, N={n} with blocks {bm}x{bn})"
            )
        if eff_m // bm != n // bn:
            raise ValueError(
                f"scramble_out needs square block grid, got {eff_m // bm}x{n // bn}"
            )
        # σ lookup table, host-side numpy, once — the kernel's scalar-prefetch
        # input is an lru_cache hit from here on.
        sigma = sigma_block_table(eff_m // bm)
    if blocks is not None and spec.stagger:
        # Per-cell k-rotation offsets ((i + j) mod nk) — the staggered
        # schedule as a host-side table, recorded for provenance/debug.
        bm, bn, bk = blocks
        nm = -(-spec.eff_m // bm)
        nn = -(-spec.n // bn)
        nk = -(-spec.k // bk)
        stagger_tbl = np.add.outer(np.arange(nm), np.arange(nn)) % max(nk, 1)

    p = Plan(
        spec=spec,
        backend=be.name,
        capabilities=be.caps,
        blocks=blocks,
        out_dtype=spec.resolved_out_dtype(),
        interpret=not _on_tpu(),
        flops=spec.flops(),
        vmem_bytes=vmem,
        sigma_table=sigma,
        stagger_table=stagger_tbl,
    )
    impl = be.impl
    p._fn = jax.jit(lambda a, b, bias, residual: impl(p, a, b, bias, residual))
    return p


def clear_plan_cache() -> None:
    """Test hook: drop all cached plans and reset the hit/miss counters."""
    _PLAN_CACHE.clear()
    _PLAN_STATS.update(hits=0, misses=0)


def plan_cache_info() -> Dict[str, Any]:
    """Cache telemetry: one entry per (spec, backend) pair ever planned."""
    return {
        "size": len(_PLAN_CACHE),
        "hits": _PLAN_STATS["hits"],
        "misses": _PLAN_STATS["misses"],
        "plans": [p.describe() for p in _PLAN_CACHE.values()],
    }
