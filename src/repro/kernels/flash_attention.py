"""Pallas TPU kernel: flash attention (tiled online-softmax SDPA).

The jnp chunked path (models/attention._sdpa_chunked) removes the O(T^2)
*resident* score tensor but still materializes one (Tq, C) block per chunk in
HBM — XLA won't keep a 32k-row block in VMEM.  This kernel tiles BOTH q and
kv: each grid cell owns a (block_q, head_dim) query tile, loops over kv tiles
with the (m, l, acc) online-softmax recurrence entirely in VMEM scratch, and
writes only the final (block_q, head_dim) output — HBM traffic is exactly
Q + K + V + O.

Grid: (batch*kv_heads, num_q_blocks, num_kv_blocks); the kv axis is the
innermost (sequential) dimension; q/batch axes are parallel.  GQA is handled
by folding the `rep` q-heads-per-kv-head into the q tile's row dimension.

Validated against kernels/ref.sdpa_ref in interpret mode (tests/
test_flash_kernel.py sweeps shapes, dtypes, causal on/off); the compiled
path targets TPU (dimension_semantics marks the kv axis "arbitrary").
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _HAVE_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAVE_PLTPU = False

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (block_q, hd)
    k_ref,  # (block_k, hd)
    v_ref,  # (block_k, hd)
    o_ref,  # (block_q, hd)
    m_ref,  # VMEM (block_q,) running max
    l_ref,  # VMEM (block_q,) running denominator
    acc_ref,  # VMEM (block_q, hd) f32 accumulator
    *,
    nk: int,
    block_q: int,
    block_k: int,
    causal: bool,
    rep: int,
    scale: float,
):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qb = pl.program_id(1)
    s = jnp.dot(
        q_ref[...], k_ref[...].T, preferred_element_type=jnp.float32
    ) * scale  # (block_q, block_k)
    if causal:
        # q rows fold `rep` heads: token index = row // rep
        qpos = (qb * block_q + jax.lax.iota(jnp.int32, block_q)) // rep
        kpos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(qpos[:, None] >= kpos[None, :], s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v_ref.dtype), v_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(kb == nk - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows (never for causal)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, Tq, H, hd)
    k: jax.Array,  # (B, Tk, KV, hd)
    v: jax.Array,  # (B, Tk, KV, hd)
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """HBM-optimal SDPA: traffic = Q + K + V + O.  Tq*rep %% block_q == 0 and
    Tk %% block_k == 0 required (model seq lens are powers of two)."""
    b, tq, h, hd = q.shape
    tk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    scale = hd**-0.5

    # fold (B, KV) into the grid's parallel axis and `rep` into q rows:
    # q rows are ordered (token, rep) so causal indexing is row // rep.
    qf = (
        q.reshape(b, tq, kvh, rep, hd)
        .transpose(0, 2, 1, 3, 4)
        .reshape(b * kvh, tq * rep, hd)
    )
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, tk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, tk, hd)

    rows = tq * rep
    if rows % block_q or tk % block_k:
        raise ValueError(
            f"(Tq*rep={rows}, Tk={tk}) not divisible by blocks ({block_q},{block_k})"
        )
    nq, nk = rows // block_q, tk // block_k

    if _HAVE_PLTPU:
        scratch = [
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ]
    else:  # pragma: no cover
        scratch = [
            pl.MemorySpace.ANY((block_q,), jnp.float32),
            pl.MemorySpace.ANY((block_q,), jnp.float32),
            pl.MemorySpace.ANY((block_q, hd), jnp.float32),
        ]

    compiler_params = None
    if _HAVE_PLTPU and not interpret:  # pragma: no cover — TPU-only path
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )

    kernel = functools.partial(
        _flash_kernel,
        nk=nk,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
        rep=rep,
        scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * kvh, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((None, block_k, hd), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((None, block_k, hd), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, rows, hd), q.dtype),
        scratch_shapes=scratch,
        compiler_params=compiler_params,
        interpret=interpret,
    )(qf, kf, vf)

    return (
        out.reshape(b, kvh, tq, rep, hd).transpose(0, 2, 1, 3, 4).reshape(b, tq, h, hd)
    )
