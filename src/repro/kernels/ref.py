"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert_allclose against these.
They define the *semantics*; the kernels define the *schedule*.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scramble import _scramble_perm_np

__all__ = [
    "grouped_matmul_ref",
    "matmul_ref",
    "mesh_matmul_ref",
    "scramble_blocks_ref",
    "unscramble_blocks_ref",
]


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B with f32 accumulation (the MXU contract)."""
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def _block_perm_pq(n_blocks: int):
    perm = _scramble_perm_np(n_blocks)
    return perm // n_blocks, perm % n_blocks  # (p, q) block held at each cell


def mesh_matmul_ref(
    a: jax.Array, b: jax.Array, *, block_m: int, block_n: int, out_dtype=None
) -> jax.Array:
    """Scrambled-output matmul: cell-block (i,j) of the result holds standard
    block sigma(i,j) of A @ B.  Requires a square (g x g) output block grid.
    """
    m, n = a.shape[0], b.shape[1]
    gm, gn = m // block_m, n // block_n
    if gm != gn:
        raise ValueError(f"scrambled output needs a square block grid, got {gm}x{gn}")
    c = matmul_ref(a, b, out_dtype)
    return scramble_blocks_ref(c, block_m=block_m, block_n=block_n)


def scramble_blocks_ref(x: jax.Array, *, block_m: int, block_n: int) -> jax.Array:
    """Apply the paper's S at block granularity to the trailing 2 dims of x."""
    m, n = x.shape[-2], x.shape[-1]
    g = m // block_m
    if g != n // block_n or g * block_m != m or g * block_n != n:
        raise ValueError(f"(m={m}, n={n}) not a square grid of ({block_m},{block_n}) blocks")
    p_idx, q_idx = _block_perm_pq(g)
    lead = x.shape[:-2]
    blocks = x.reshape(*lead, g, block_m, g, block_n)
    blocks = jnp.moveaxis(blocks, -2, -3)  # (..., g, g, bm, bn)
    flat = blocks.reshape(*lead, g * g, block_m, block_n)
    gathered = jnp.take(flat, jnp.asarray(p_idx * g + q_idx), axis=-3)
    out = gathered.reshape(*lead, g, g, block_m, block_n)
    out = jnp.moveaxis(out, -2, -3)
    return out.reshape(*lead, m, n)


def unscramble_blocks_ref(x: jax.Array, *, block_m: int, block_n: int) -> jax.Array:
    """Inverse of scramble_blocks_ref."""
    m, n = x.shape[-2], x.shape[-1]
    g = m // block_m
    perm = _scramble_perm_np(g)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.shape[0], dtype=perm.dtype)
    lead = x.shape[:-2]
    blocks = x.reshape(*lead, g, block_m, g, block_n)
    blocks = jnp.moveaxis(blocks, -2, -3)
    flat = blocks.reshape(*lead, g * g, block_m, block_n)
    gathered = jnp.take(flat, jnp.asarray(inv), axis=-3)
    out = gathered.reshape(*lead, g, g, block_m, block_n)
    out = jnp.moveaxis(out, -2, -3)
    return out.reshape(*lead, m, n)


def grouped_matmul_ref(
    tokens: jax.Array,   # (num_groups * rows_per_group, K), group-major
    sizes: jax.Array,    # (num_groups,) valid-row counts
    weights: jax.Array,  # (num_groups, K, N)
    out_dtype=None,
) -> jax.Array:
    """Grouped (ragged-batch) matmul oracle: row r of the capacity-layout
    buffer multiplies its group's weight slab; rows at or beyond a group's
    size are zero regardless of their contents (the grouped-kernel contract,
    DESIGN.md §10)."""
    n_groups, k, n = weights.shape
    rpg = tokens.shape[0] // n_groups
    out_dtype = out_dtype or jnp.result_type(tokens.dtype, weights.dtype)
    tg = tokens.reshape(n_groups, rpg, k)
    z = jnp.einsum(
        "grk,gkn->grn", tg, weights, preferred_element_type=jnp.float32
    )
    valid = jnp.arange(rpg)[None, :] < sizes[:, None]  # (G, rpg) segment mask
    z = jnp.where(valid[..., None], z, 0.0)
    return z.reshape(n_groups * rpg, n).astype(out_dtype)
