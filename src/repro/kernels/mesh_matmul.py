"""Pallas TPU kernel: blocked matmul with the mesh-array staggered-k schedule.

TPU adaptation of the paper (DESIGN.md §2).  The paper's mesh array removes
the zero-padding skew of the standard systolic array by letting node (i, j)
start immediately and accept a permuted output arrangement.  At TPU block
granularity the same idea becomes:

  * **Staggered k-loop** — output tile (i, j) runs its contraction loop in the
    rotated order k_eff = (i + j + k) mod nk.  Concurrently-active grid cells
    therefore stream *disjoint* (A row-block, B col-block) pairs from HBM into
    VMEM instead of all touching k=0 first — the memory-system analogue of the
    paper's "no zeros are padded in its inputs" feeding discipline (and the
    block-level form of Cannon's alignment).
  * **Fused scramble output** — optionally the grid cell (i, j) computes the
    *standard* block sigma(i, j) and writes it at cell (i, j), so the output
    lands in the paper's scrambled arrangement at zero extra bytes.  The
    sigma tables are precomputed host-side (numpy, once per grid size) and fed
    through *scalar prefetch*, so the BlockSpec index_maps are single SMEM
    lookups on the scalar core — not re-derived closed-form arithmetic per
    grid step.
  * **Fused epilogue** (DESIGN.md §3) — bias add, activation, and an optional
    residual add execute inside the `k == nk-1` flush while the f32
    accumulator is still in VMEM, so a dense layer (y = act(xW + b) [+ r]) is
    one kernel instead of a GEMM followed by 2-3 XLA elementwise passes over
    HBM.
  * **Batched grid** — `mesh_matmul_pallas_batched` runs (B, M, K) @ (B, K, N)
    as a single `pallas_call` with grid (b, i, j, k), replacing the
    per-element vmap launch (one kernel, one tuning decision, b parallel).

The kernel accumulates in a float32 VMEM scratch across the arbitrary
(sequential) k dimension and applies the epilogue + cast once on the final k
step.  Block shapes default to MXU-aligned (128, 128, 128); `ops.matmul`
resolves them through `kernels/autotune.py` when not given.

Validated on CPU with interpret=True against `repro.kernels.ref` oracles;
compiled path targets TPU (dimension_semantics marks b/i/j parallel).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU-specific extras are importable on CPU builds of jax as well.
    from jax.experimental.pallas import tpu as pltpu

    _HAVE_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAVE_PLTPU = False

from repro.core.scramble import _scramble_perm_np

__all__ = [
    "ACTIVATIONS",
    "mesh_matmul_pallas",
    "mesh_matmul_pallas_batched",
    "sigma_block_table",
]


# Epilogue activations: f32 in, f32 out, Pallas-lowerable (no erf — the tanh
# gelu matches jax.nn.gelu(approximate=True), the framework default).
# GELU_C/GELU_A are shared with the analytic derivative in ops._gelu_grad —
# change the approximation here and the gradient follows.
GELU_C = 0.7978845608028654  # sqrt(2/pi)
GELU_A = 0.044715

ACTIVATIONS = {
    None: lambda x: x,
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "silu": lambda x: x * jax.lax.logistic(x),
    "sigmoid": jax.lax.logistic,
    "tanh": jnp.tanh,
    "gelu": lambda x: 0.5 * x * (1.0 + jnp.tanh(GELU_C * (x + GELU_A * x * x * x))),
}


@functools.lru_cache(maxsize=None)
def sigma_block_table(g: int) -> np.ndarray:
    """Host-side sigma table: flat standard block index (p*g + q) held at each
    mesh cell, row-major over cells.  Computed once per grid size with numpy
    and passed to the kernel via scalar prefetch."""
    return _scramble_perm_np(g).astype(np.int32)


def _stagger(i, j, k, nk):
    """The mesh-array rotation: which k-block cell (i, j) consumes at phase k."""
    return jax.lax.rem(i + j + k, nk)


def _make_kernel(
    *, nk: int, k_axis: int, activation: Optional[str], has_bias: bool,
    has_residual: bool, has_sigma: bool, batched: bool
):
    """Build the kernel body for one configuration of fused operands.

    Ref order (after optional scalar-prefetch sigma table, consumed only by
    the index_maps): a, b, [bias], [residual], out, acc_scratch.
    """
    act = ACTIVATIONS[activation]

    def kernel(*refs):
        refs = list(refs)
        if has_sigma:
            refs.pop(0)
        a_ref, b_ref = refs[0], refs[1]
        pos = 2
        bias_ref = res_ref = None
        if has_bias:
            bias_ref, pos = refs[pos], pos + 1
        if has_residual:
            res_ref, pos = refs[pos], pos + 1
        o_ref, acc_ref = refs[pos], refs[pos + 1]

        k = pl.program_id(k_axis)

        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        a_blk = a_ref[0] if batched else a_ref[...]
        b_blk = b_ref[0] if batched else b_ref[...]
        acc_ref[...] += jnp.dot(a_blk, b_blk, preferred_element_type=jnp.float32)

        @pl.when(k == nk - 1)
        def _flush():
            out = acc_ref[...]
            if bias_ref is not None:
                out = out + bias_ref[...].astype(jnp.float32)  # (1, bn) bcast
            out = act(out)
            if res_ref is not None:
                r = res_ref[0] if batched else res_ref[...]
                out = out + r.astype(jnp.float32)
            if batched:
                o_ref[0] = out.astype(o_ref.dtype)
            else:
                o_ref[...] = out.astype(o_ref.dtype)

    return kernel


def _check_epilogue(activation, bias, residual, m, n, n_batch):
    if activation not in ACTIVATIONS:
        raise ValueError(
            f"activation must be one of {sorted(k for k in ACTIVATIONS if k)},"
            f" got {activation!r}"
        )
    if bias is not None and bias.shape != (n,):
        raise ValueError(f"bias must have shape ({n},), got {bias.shape}")
    want_res = (m, n) if n_batch is None else (n_batch, m, n)
    if residual is not None and residual.shape != want_res:
        raise ValueError(f"residual must have shape {want_res}, got {residual.shape}")


def _pallas_matmul(
    a,
    b,
    bias,
    residual,
    *,
    block_m,
    block_n,
    block_k,
    stagger,
    scramble_out,
    activation,
    out_dtype,
    interpret,
    batched,
):
    """Shared 2D/batched pallas_call assembly."""
    if batched:
        n_batch, m, k_dim = a.shape
        n = b.shape[-1]
        if b.shape != (n_batch, k_dim, n):
            raise ValueError(f"batched contraction mismatch: {a.shape} @ {b.shape}")
    else:
        n_batch = None
        m, k_dim = a.shape
        k2, n = b.shape
        if k_dim != k2:
            raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    if m % block_m or n % block_n or k_dim % block_k:
        raise ValueError(
            f"shape ({m},{k_dim})x({k_dim},{n}) not divisible by blocks "
            f"({block_m},{block_n},{block_k})"
        )
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    _check_epilogue(activation, bias, residual, m, n, n_batch)
    nm, nn, nk = m // block_m, n // block_n, k_dim // block_k

    if scramble_out and nm != nn:
        raise ValueError(f"scramble_out needs square block grid, got {nm}x{nn}")

    grid = (n_batch, nm, nn, nk) if batched else (nm, nn, nk)
    k_axis = len(grid) - 1

    def kk_of(i, j, k):
        return _stagger(i, j, k, nk) if stagger else k

    # index_maps: `cell` receives the (i, j) grid coordinates (and the sigma
    # scalar-prefetch ref when scrambling); (p, q) is the standard block the
    # cell computes — equal to (i, j) unless the output is scrambled, in which
    # case it is one SMEM table lookup (host-precomputed, DESIGN.md §2).
    if scramble_out:
        g = nm

        def pq(i, j, sig_ref):
            flat = sig_ref[i * g + j]
            return flat // g, flat % g

    else:

        def pq(i, j, sig_ref):
            del sig_ref
            return i, j

    def with_batch(f):
        """Lift a (i, j, k, [sig]) map to the batched grid (b, i, j, k, [sig])."""
        if not batched:
            return f
        return lambda bi, i, j, k, *sig: (bi,) + tuple(f(i, j, k, *sig))

    def a_map(i, j, k, *sig):
        p, _ = pq(i, j, sig[0] if sig else None)
        return p, kk_of(i, j, k)

    def b_map(i, j, k, *sig):
        _, q = pq(i, j, sig[0] if sig else None)
        return kk_of(i, j, k), q

    def bias_map(i, j, k, *sig):
        _, q = pq(i, j, sig[0] if sig else None)
        return 0, q

    def res_map(i, j, k, *sig):
        return pq(i, j, sig[0] if sig else None)

    def o_map(i, j, k, *sig):
        return i, j

    lead = (1,) if batched else ()

    in_specs = [
        pl.BlockSpec(lead + (block_m, block_k), with_batch(a_map)),
        pl.BlockSpec(lead + (block_k, block_n), with_batch(b_map)),
    ]
    operands = [a, b]
    if bias is not None:
        # bias is shared across the batch: keep its BlockSpec 2D everywhere.
        if batched:
            bias_spec = pl.BlockSpec(
                (1, block_n), lambda bi, i, j, k, *sig: bias_map(i, j, k, *sig)
            )
        else:
            bias_spec = pl.BlockSpec((1, block_n), bias_map)
        in_specs.append(bias_spec)
        operands.append(bias.reshape(1, n))
    if residual is not None:
        in_specs.append(
            pl.BlockSpec(lead + (block_m, block_n), with_batch(res_map))
        )
        operands.append(residual)

    out_spec = pl.BlockSpec(lead + (block_m, block_n), with_batch(o_map))
    out_shape = jax.ShapeDtypeStruct(
        ((n_batch, m, n) if batched else (m, n)), out_dtype
    )

    scratch = (
        [pltpu.VMEM((block_m, block_n), jnp.float32)]
        if _HAVE_PLTPU
        else [pl.MemorySpace.ANY((block_m, block_n), jnp.float32)]  # pragma: no cover
    )

    compiler_params = None
    if _HAVE_PLTPU and not interpret:  # pragma: no cover — TPU-only path
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel",) * k_axis + ("arbitrary",),
        )

    kernel = _make_kernel(
        nk=nk,
        k_axis=k_axis,
        activation=activation,
        has_bias=bias is not None,
        has_residual=residual is not None,
        has_sigma=scramble_out,
        batched=batched,
    )

    if scramble_out:
        sigma = jnp.asarray(sigma_block_table(nm))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_spec,
            scratch_shapes=scratch,
        )
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            compiler_params=compiler_params,
            interpret=interpret,
        )(sigma, *operands)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=compiler_params,
        interpret=interpret,
    )(*operands)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m",
        "block_n",
        "block_k",
        "stagger",
        "scramble_out",
        "activation",
        "out_dtype",
        "interpret",
    ),
)
def mesh_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    stagger: bool = True,
    scramble_out: bool = False,
    activation: Optional[str] = None,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
) -> jax.Array:
    """C = epilogue(A @ B) on the mesh-array schedule.

    Args:
      a: (M, K);  b: (K, N).  M, N, K must divide by the block shape (the
        `ops.matmul` wrapper pads arbitrary shapes).
      bias: optional (N,), added to the f32 accumulator before `activation`.
      residual: optional (M, N), added after `activation` (DESIGN.md §3:
        y = act(AB + bias) + residual).
      stagger: rotate each tile's k-loop by (i + j) mod nk (the paper's
        no-padding feeding).  False gives the standard k-ordered schedule —
        kept selectable so benchmarks can compare the two schedules.
      scramble_out: land the output in the paper's scrambled block
        arrangement (requires a square output block grid); the epilogue is
        applied to the *standard* block before placement.
      activation: one of ACTIVATIONS (None | relu | silu | sigmoid | tanh |
        gelu), applied in the k == nk-1 flush.
      interpret: run the kernel body in Python on CPU (validation mode).
    """
    return _pallas_matmul(
        a,
        b,
        bias,
        residual,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        stagger=stagger,
        scramble_out=scramble_out,
        activation=activation,
        out_dtype=out_dtype,
        interpret=interpret,
        batched=False,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m",
        "block_n",
        "block_k",
        "stagger",
        "scramble_out",
        "activation",
        "out_dtype",
        "interpret",
    ),
)
def mesh_matmul_pallas_batched(
    a: jax.Array,
    b: jax.Array,
    *,
    bias: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    stagger: bool = True,
    scramble_out: bool = False,
    activation: Optional[str] = None,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
) -> jax.Array:
    """Batched C[b] = epilogue(A[b] @ B[b]) as ONE kernel with grid
    (b, i, j, k) — replaces the per-element vmap launch in `ops.matmul`.

    a: (B, M, K); b: (B, K, N); bias (N,) is shared across the batch;
    residual: (B, M, N).  Semantics otherwise identical to
    `mesh_matmul_pallas` per batch element.
    """
    return _pallas_matmul(
        a,
        b,
        bias,
        residual,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        stagger=stagger,
        scramble_out=scramble_out,
        activation=activation,
        out_dtype=out_dtype,
        interpret=interpret,
        batched=True,
    )
