"""Pallas TPU kernel: blocked matmul with the mesh-array staggered-k schedule.

TPU adaptation of the paper (DESIGN.md §2).  The paper's mesh array removes
the zero-padding skew of the standard systolic array by letting node (i, j)
start immediately and accept a permuted output arrangement.  At TPU block
granularity the same idea becomes:

  * **Staggered k-loop** — output tile (i, j) runs its contraction loop in the
    rotated order k_eff = (i + j + k) mod nk.  Concurrently-active grid cells
    therefore stream *disjoint* (A row-block, B col-block) pairs from HBM into
    VMEM instead of all touching k=0 first — the memory-system analogue of the
    paper's "no zeros are padded in its inputs" feeding discipline (and the
    block-level form of Cannon's alignment).
  * **Fused scramble output** — optionally the grid cell (i, j) computes the
    *standard* block sigma(i, j) and writes it at cell (i, j), so the output
    lands in the paper's scrambled arrangement at zero extra bytes: the
    permutation is folded into the output BlockSpec index_map exactly as the
    array's wiring folds it into node placement.

The kernel accumulates in a float32 VMEM scratch across the arbitrary
(sequential) k dimension and casts once on the final k step.  Block shapes
default to MXU-aligned (128, 128, 128).

Validated on CPU with interpret=True against `repro.kernels.ref` oracles;
compiled path targets TPU (dimension_semantics marks i/j parallel).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU-specific extras are importable on CPU builds of jax as well.
    from jax.experimental.pallas import tpu as pltpu

    _HAVE_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAVE_PLTPU = False

from repro.core.scramble import sigma_traced

__all__ = ["mesh_matmul_pallas"]


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """Grid (i, j, k): accumulate a_ref @ b_ref into acc, flush on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _stagger(i, j, k, nk):
    """The mesh-array rotation: which k-block cell (i, j) consumes at phase k."""
    return jax.lax.rem(i + j + k, nk)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m",
        "block_n",
        "block_k",
        "stagger",
        "scramble_out",
        "out_dtype",
        "interpret",
    ),
)
def mesh_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    stagger: bool = True,
    scramble_out: bool = False,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B on the mesh-array schedule.

    Args:
      a: (M, K);  b: (K, N).  M, N, K must divide by the block shape (the
        `ops.matmul` wrapper pads arbitrary shapes).
      stagger: rotate each tile's k-loop by (i + j) mod nk (the paper's
        no-padding feeding).  False gives the standard k-ordered schedule —
        kept selectable so benchmarks can compare the two schedules.
      scramble_out: land the output in the paper's scrambled block
        arrangement (requires a square output block grid).
      interpret: run the kernel body in Python on CPU (validation mode).
    """
    m, k_dim = a.shape
    k2, n = b.shape
    if k_dim != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    if m % block_m or n % block_n or k_dim % block_k:
        raise ValueError(
            f"shape ({m},{k_dim})x({k2},{n}) not divisible by blocks "
            f"({block_m},{block_n},{block_k})"
        )
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    nm, nn, nk = m // block_m, n // block_n, k_dim // block_k

    if scramble_out:
        if nm != nn:
            raise ValueError(f"scramble_out needs square block grid, got {nm}x{nn}")

        # Cell (i, j) computes standard block (p, q) = sigma(i, j): reads A
        # row-block p and B col-block q, writes at cell (i, j).  The output
        # permutation is pure index_map arithmetic (evaluated on the scalar
        # core) — zero extra data movement.
        def a_map(i, j, k):
            p, _ = sigma_traced(nm, i, j)
            return p, _stagger(i, j, k, nk) if stagger else k

        def b_map(i, j, k):
            _, q = sigma_traced(nm, i, j)
            return _stagger(i, j, k, nk) if stagger else k, q

    else:

        def a_map(i, j, k):
            return i, _stagger(i, j, k, nk) if stagger else k

        def b_map(i, j, k):
            return _stagger(i, j, k, nk) if stagger else k, j

    def o_map(i, j, k):
        return i, j

    scratch = (
        [pltpu.VMEM((block_m, block_n), jnp.float32)]
        if _HAVE_PLTPU
        else [pl.MemorySpace.ANY((block_m, block_n), jnp.float32)]  # pragma: no cover
    )

    compiler_params = None
    if _HAVE_PLTPU and not interpret:  # pragma: no cover — TPU-only path
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )

    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), a_map),
            pl.BlockSpec((block_k, block_n), b_map),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        compiler_params=compiler_params,
        interpret=interpret,
    )(a, b)
