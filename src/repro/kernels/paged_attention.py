"""Paged-KV gather attention for continuous-batching decode (DESIGN.md §12).

The serving scheduler (`launch/scheduler.py`) stores every sequence's KV
cache as fixed-size **pages** inside one shared pool per layer; a sequence
owns an arbitrary, non-contiguous set of pages named by its **block table**.
Decode attention must therefore gather K/V through the block table instead
of slicing a dense per-sequence cache.  Two implementations live behind a
capability door mirroring the GEMM backend registry (`kernels/api.py`):

  pallas_paged  one `pallas_call` whose k/v BlockSpec index_maps read the
                scalar-prefetched block table — page `p` of sequence `s`
                streams pool row `bt[s, p]` straight into VMEM (no gathered
                copy of the context is ever materialized), with the flash
                (m, l, acc) online-softmax recurrence in VMEM scratch and
                pages past the sequence length skipped entirely.
  xla_gather    `pool[block_table]` gather + masked softmax, written
                op-for-op like `models.attention._sdpa` so decode through
                pages is **bitwise equal** to decode against the dense cache
                (the scheduler's correctness contract, tested in
                tests/test_scheduler.py).

The door (`resolve_paged_impl`) applies the same rule as the GEMM registry's
interpret capability: an impl that cannot execute off-TPU is only eligible
on TPU (or when Pallas interpret mode is explicitly requested); asking for
an unavailable impl raises the registry's `CapabilityError`.  On CPU CI the
door resolves to `xla_gather`; on TPU it resolves to `pallas_paged`.

Layout contract (single decode token per sequence slot):

  q             (S, H, hd)           one query token per slot
  k_pool/v_pool (P, page_size, KV, hd)  shared pools; page 0 is the
                                     scheduler's scratch page (inactive
                                     slots write there, never read back)
  block_tables  (S, n_pages) int32   page ids per slot; unallocated -> 0
  lengths       (S,) int32           valid context length INCLUDING the
                                     freshly written token (= pos + 1)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.api import CapabilityError
from repro.kernels.mesh_matmul import _HAVE_PLTPU

if _HAVE_PLTPU:
    from jax.experimental.pallas import tpu as pltpu
else:  # pragma: no cover
    pltpu = None

__all__ = [
    "PAGED_FALLBACK_ORDER",
    "gather_pages",
    "paged_attention",
    "paged_attention_pallas",
    "paged_attention_xla",
    "paged_impl_names",
    "register_paged_impl",
    "resolve_paged_impl",
]

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# XLA gather fallback (bitwise-parity reference)
# ---------------------------------------------------------------------------


def gather_pages(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(P, ps, KV, hd) pool + (S, n) tables -> (S, n*ps, KV, hd) context."""
    s, n = block_tables.shape
    _, ps, kvh, hd = pool.shape
    return jnp.take(pool, block_tables, axis=0).reshape(s, n * ps, kvh, hd)


def paged_attention_xla(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Gathered-context SDPA, op-for-op `models.attention._sdpa`.

    The op sequence (einsum scaling, -1e30 where-mask, f32 softmax) is kept
    IDENTICAL to the dense decode path so a sequence served through pages
    produces bit-identical logits to the legacy `generate()` loop — pool
    rows past `lengths` (scratch page, unwritten slots) mask to exp -> 0.0
    exactly and contribute nothing.
    """
    del interpret  # native jnp: runs everywhere
    s, h, hd = q.shape
    k = gather_pages(k_pool, block_tables)
    v = gather_pages(v_pool, block_tables)
    kvh = k.shape[2]
    rep = h // kvh
    q5 = q.reshape(s, 1, kvh, rep, hd)
    scores = jnp.einsum(
        "btkrd,bskd->bkrts", q5, k, preferred_element_type=jnp.float32
    ) / (hd**0.5)
    valid = jnp.arange(k.shape[1])[None, :] < lengths[:, None]  # (S, T)
    scores = jnp.where(valid[:, None, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrts,bskd->btkrd", probs, v)
    return out.reshape(s, h, hd)


# ---------------------------------------------------------------------------
# Pallas kernel: block-table-steered gather attention
# ---------------------------------------------------------------------------


def _paged_kernel(
    bt_ref,  # SMEM (S, n_pages) block tables (scalar prefetch)
    len_ref,  # SMEM (S,) valid lengths (scalar prefetch)
    q_ref,  # (rep, hd) query rows for this (slot, kv-head)
    k_ref,  # (ps, hd) one page of keys
    v_ref,  # (ps, hd) one page of values
    o_ref,  # (rep, hd)
    m_ref,  # VMEM (rep,) running max
    l_ref,  # VMEM (rep,) running denominator
    acc_ref,  # VMEM (rep, hd) f32 accumulator
    *,
    page_size: int,
    n_pages: int,
    scale: float,
):
    s = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[s]
    start = p * page_size

    # Pages entirely past the sequence length are skipped — the block table
    # points them at the scratch page and no MXU work is issued (the paged
    # analogue of the grouped kernel's ragged steering).
    @pl.when(start < length)
    def _accumulate():
        sc = (
            jnp.dot(q_ref[...], k_ref[...].T, preferred_element_type=jnp.float32)
            * scale
        )  # (rep, ps)
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        sc = jnp.where(kpos < length, sc, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        prob = jnp.exp(sc - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(prob, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] += (
            jnp.dot(
                prob.astype(v_ref.dtype), v_ref[...],
                preferred_element_type=jnp.float32,
            )
            - (1.0 - corr[:, None]) * acc_ref[...]
        )

    @pl.when(p == n_pages - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # length >= 1 in practice
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """One pallas_call over grid (slots, kv_heads, pages); the k/v index_maps
    consume the scalar-prefetched block table, so page p of slot s DMAs pool
    row bt[s, p] directly — the gather IS the block placement."""
    if not _HAVE_PLTPU:
        raise NotImplementedError(
            "paged_attention_pallas needs jax.experimental.pallas.tpu"
            " (scalar-prefetch grid specs); use the xla_gather impl"
        )
    s, h, hd = q.shape
    n_pool, ps, kvh, hd2 = k_pool.shape
    if hd != hd2:
        raise ValueError(f"head_dim mismatch: q {q.shape} vs pool {k_pool.shape}")
    if v_pool.shape != k_pool.shape:
        raise ValueError(f"k/v pool mismatch: {k_pool.shape} vs {v_pool.shape}")
    if block_tables.shape[0] != s or lengths.shape != (s,):
        raise ValueError(
            f"block_tables {block_tables.shape} / lengths {lengths.shape}"
            f" do not match {s} slots"
        )
    rep = h // kvh
    n_pages = block_tables.shape[1]
    scale = hd**-0.5

    qf = q.reshape(s, kvh, rep, hd)

    kernel = functools.partial(
        _paged_kernel, page_size=ps, n_pages=n_pages, scale=scale
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s, kvh, n_pages),
        in_specs=[
            pl.BlockSpec((None, None, rep, hd), lambda i, j, p, bt, ln: (i, j, 0, 0)),
            pl.BlockSpec(
                (None, ps, None, hd), lambda i, j, p, bt, ln: (bt[i, p], 0, j, 0)
            ),
            pl.BlockSpec(
                (None, ps, None, hd), lambda i, j, p, bt, ln: (bt[i, p], 0, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (None, None, rep, hd), lambda i, j, p, bt, ln: (i, j, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
    )
    compiler_params = None
    if not interpret:  # pragma: no cover — TPU-only path
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, kvh, rep, hd), q.dtype),
        compiler_params=compiler_params,
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), qf, k_pool, v_pool)
    return out.reshape(s, h, hd)


# ---------------------------------------------------------------------------
# Capability door (same rules as the GEMM backend registry)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _PagedImpl:
    name: str
    fn: Callable
    # Mirrors BackendCapabilities.interpret: executes off-TPU natively.  An
    # impl without it is only eligible on TPU or under explicit Pallas
    # interpret mode.
    interpret: bool


_PAGED_REGISTRY: Dict[str, _PagedImpl] = {}

# Preference order when no impl is requested (mirrors api.FALLBACK_ORDER:
# the kernel first, the always-runnable gather last).
PAGED_FALLBACK_ORDER = ("pallas_paged", "xla_gather")


def register_paged_impl(
    name: str, fn: Callable, *, interpret: bool, override: bool = False
) -> None:
    if name in _PAGED_REGISTRY and not override:
        raise ValueError(
            f"paged impl {name!r} already registered (pass override=True)"
        )
    _PAGED_REGISTRY[name] = _PagedImpl(name, fn, interpret)


def paged_impl_names() -> List[str]:
    return list(_PAGED_REGISTRY)


def _unavailable_reason(impl: _PagedImpl, interpret: bool) -> Optional[str]:
    if impl.interpret or interpret:
        return None
    if not _HAVE_PLTPU:
        return f"impl {impl.name!r} needs jax.experimental.pallas.tpu"
    if jax.default_backend() != "tpu":
        return (
            f"impl {impl.name!r} requires TPU and interpret mode was not"
            f" requested (running on {jax.default_backend()!r})"
        )
    return None  # pragma: no cover — TPU runtime


def resolve_paged_impl(
    requested: Optional[str] = None, *, interpret: bool = False
) -> str:
    """The capability door: requested impl or the first runnable one.

    Explicitly requesting an impl the runtime cannot execute raises
    `CapabilityError` (never a silent substitution); with no request, the
    preference order degrades from the Pallas kernel to the XLA gather.
    """
    if requested is not None:
        impl = _PAGED_REGISTRY.get(requested)
        if impl is None:
            raise ValueError(
                f"unknown paged impl {requested!r};"
                f" registered: {sorted(_PAGED_REGISTRY)}"
            )
        reason = _unavailable_reason(impl, interpret)
        if reason is not None:
            raise CapabilityError(reason)
        return requested
    reasons = []
    for name in (*PAGED_FALLBACK_ORDER, *_PAGED_REGISTRY):
        impl = _PAGED_REGISTRY.get(name)
        if impl is None:
            continue
        reason = _unavailable_reason(impl, interpret)
        if reason is None:
            return name
        reasons.append(reason)
    raise CapabilityError(
        "no registered paged-attention impl can run here: " + "; ".join(reasons)
    )


def paged_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    impl: Optional[str] = None,
    interpret: bool = False,
) -> jax.Array:
    """Dispatch through the door; resolution happens at trace time (static)."""
    name = resolve_paged_impl(impl, interpret=interpret)
    return _PAGED_REGISTRY[name].fn(
        q, k_pool, v_pool, block_tables, lengths, interpret=interpret
    )


register_paged_impl("pallas_paged", paged_attention_pallas, interpret=False)
register_paged_impl("xla_gather", paged_attention_xla, interpret=True)
