"""AdamW in pure JAX (pytree states), with global-norm clipping.

State is a pytree-of-pytrees {"m", "v", "count"} matching the params tree.
`adamw_update` is pure/jit-safe; the learning rate is passed per-step (from
`optim.schedules`).  ZeRO-1 sharding of m/v lives in `optim/zero.py` (the
states get their own logical->physical rules, sharded over the DP axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params: Any) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads: Any,
    opt_state: Any,
    params: Any,
    lr: jax.Array,
    cfg: AdamWConfig = AdamWConfig(),
) -> Tuple[Any, Any, jax.Array]:
    """Returns (new_params, new_opt_state, pre-clip grad norm)."""
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    count = opt_state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # no weight decay on norms/biases/scalars
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, gnorm
