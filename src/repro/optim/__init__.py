from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedules import constant, warmup_cosine
from repro.optim.zero import zero1_rules, zero1_state_axes

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "global_norm",
    "clip_by_global_norm",
    "constant",
    "warmup_cosine",
    "zero1_rules",
    "zero1_state_axes",
]
