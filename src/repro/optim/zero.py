"""ZeRO-1: shard AdamW m/v states over the data-parallel axis.

With pjit, optimizer states are first-class sharded arrays: we give them their
own logical->physical rules where the 'embed' (and fallback largest) axis maps
to ('pod','data') — so each DP rank owns a 1/|DP| slice of every m/v tensor
(instead of replicating them), and XLA inserts the gather/scatter around the
update exactly as hand-written ZeRO would.  Param/activation rules stay
unchanged.

`zero1_axes(model)` rewrites the model's logical-axes tree for m/v.
"""

from __future__ import annotations

from typing import Any

import jax

from repro.parallel.sharding import DEFAULT_RULES, ShardingRules

__all__ = ["zero1_rules", "zero1_state_axes"]


def zero1_rules(base: ShardingRules = DEFAULT_RULES) -> ShardingRules:
    """Optimizer-state rules: embed dim additionally sharded over DP."""
    return base.replace(embed=("pod", "data"), layers=None)


def zero1_state_axes(param_axes: Any) -> Any:
    """m/v logical axes == param axes (the rules table does the ZeRO remap).

    Kept as a function so callers can opt specific leaves out (e.g. scalars).
    """
    return {
        "m": param_axes,
        "v": param_axes,
        "count": None,
    }
