"""Deterministic, resumable, host-sharded data pipeline.

Synthetic corpus (offline container): a seeded Markov-ish token stream that is
a pure function of (seed, step, host_shard) — so
  * any host can regenerate exactly its shard (host-sharded loading),
  * restoring a checkpoint and re-seeking to `step` reproduces the stream
    bit-exactly (resumable iterator state == a single integer),
  * straggler-failover can reassign shards deterministically.

`SyntheticLM` yields {"tokens", "labels"} with labels = next-token targets.
`pack_documents` implements standard example packing for variable-length docs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "pack_documents"]


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        if self.global_batch % self.num_hosts:
            raise ValueError("global_batch must divide evenly across hosts")


class SyntheticLM:
    """Deterministic synthetic LM batches; state is just `self.step`."""

    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step

    def state(self) -> int:
        return self.step

    def restore(self, step: int) -> None:
        self.step = step

    def _host_batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_host = cfg.global_batch // cfg.num_hosts
        # Per-(step, host) fold of the root seed — order-independent, elastic.
        rng = np.random.Generator(
            np.random.Philox(key=cfg.seed, counter=[0, 0, step, cfg.host_id])
        )
        # Markov-ish stream: mixture of a linear-congruential walk and noise —
        # has learnable structure (tests check loss decreases) yet is cheap.
        b = per_host
        s = cfg.seq_len + 1
        start = rng.integers(0, cfg.vocab_size, size=(b, 1))
        steps = rng.integers(1, 7, size=(b, s - 1))
        walk = (np.cumsum(steps, axis=1) * 31 + start) % cfg.vocab_size
        noise_mask = rng.random((b, s - 1)) < 0.1
        noise = rng.integers(0, cfg.vocab_size, size=(b, s - 1))
        seq = np.concatenate([start, np.where(noise_mask, noise, walk)], axis=1)
        seq = seq.astype(np.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self._host_batch(self.step)
        self.step += 1
        return batch


def pack_documents(
    docs: List[np.ndarray], seq_len: int, pad_id: int = 0
) -> Dict[str, np.ndarray]:
    """Greedy packing of variable-length docs into (n, seq_len) rows with
    segment ids (for packed-example attention masking)."""
    rows, segs = [], []
    cur, cur_seg, seg_idx = [], [], 1
    for doc in docs:
        doc = doc[: seq_len]  # truncate over-long docs
        if len(cur) + len(doc) > seq_len:
            pad = seq_len - len(cur)
            rows.append(np.concatenate([cur, np.full(pad, pad_id, np.int32)]))
            segs.append(np.concatenate([cur_seg, np.zeros(pad, np.int32)]))
            cur, cur_seg, seg_idx = [], [], 1
        cur = np.concatenate([cur, doc]).astype(np.int32) if len(cur) else doc.astype(np.int32)
        cur_seg = (
            np.concatenate([cur_seg, np.full(len(doc), seg_idx, np.int32)])
            if len(cur_seg)
            else np.full(len(doc), seg_idx, np.int32)
        )
        seg_idx += 1
    if len(cur):
        pad = seq_len - len(cur)
        rows.append(np.concatenate([cur, np.full(pad, pad_id, np.int32)]))
        segs.append(np.concatenate([cur_seg, np.zeros(pad, np.int32)]))
    return {"tokens": np.stack(rows), "segment_ids": np.stack(segs)}
