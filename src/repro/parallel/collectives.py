"""Overlapped collective-matmul building blocks (TP comm/compute fusion).

Standard TP layers do `all_gather(x) @ W` or `reduce_scatter(x @ W)` as two
serial phases.  These ring variants fuse the neighbour exchanges with the
local matmuls (Wang et al., "Overlap communication with dependent
computation", and the TPU collective-matmul in XLA).  Each helper has two
selectable dataflows:

  overlap=False   the serial oracle: every ring step's `ppermute` is ordered
                  after the step's kernel call — step time = compute + comm.
  overlap=True    double-buffered: the `ppermute` for shard s+1 is issued
                  first, the kernel runs on shard s against the resident
                  buffer, then the buffers swap — the hop carries NO data
                  dependence on the in-flight kernel, so XLA's latency-hiding
                  scheduler runs them concurrently and the steady-state step
                  time is max(compute, comm).  Outputs are bitwise-equal to
                  the serial path (identical kernel calls in identical
                  accumulation order); the oracle is asserted in tests and
                  the sharded bench.

Used by the hillclimb experiments (EXPERIMENTS.md §Perf) as the beyond-paper
collective schedule, and by the ShardedPlan collective schedules in
`kernels/api.py` (`allgather_a[_overlap]`, `reduce_scatter_k[_overlap]`,
`pipeline`) — the `matmul=` hook is what lets the planner fuse its per-shard
kernel call (Pallas mesh kernel or XLA dot) inside the ring instead of a
hard-wired jnp.dot.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "ring_allgather_matmul",
    "matmul_ring_reducescatter",
    "ring_pipeline_matmul",
    "psum_if_multi",
]

# Per-step local product hook: (chunk, weights) -> f32 partial.  None selects
# the plain XLA dot; ShardedPlan passes its per-shard Plan executor here.
MatmulFn = Optional[Callable[[jax.Array, jax.Array], jax.Array]]


def _default_mm(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def _shift(p: int, by: int = 1):
    return [(s, (s - by) % p) for s in range(p)]


def _axis_size(axis) -> int:
    """Static named-axis size: jax >= 0.6 has jax.lax.axis_size; on 0.4.x
    jax.core.axis_frame(name) returns the size directly."""
    names = axis if isinstance(axis, (tuple, list)) else (axis,)
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(names)
    size = 1
    for name in names:
        size *= jax.core.axis_frame(name)
    return size


def ring_allgather_matmul(
    x_blk: jax.Array,
    w: jax.Array,
    axis: str,
    *,
    matmul: MatmulFn = None,
    overlap: bool = False,
) -> jax.Array:
    """Computes all_gather(x, axis) @ w without materializing the gather.

    x_blk: local (m_blk, k) shard of a row-sharded X (full X is (p*m_blk, k));
    w: replicated (k, n).  Returns the local (p*m_blk, n) result — i.e. the
    full product, replicated ring-step by ring-step while RESULT chunks
    circulate.

    Each rank computes its own (m_blk, n) partial ONCE and the f32 result
    chunks hop the ring — not the input chunks.  (The input-rotation form
    re-ran the full-K kernel p times per rank for identical bytes moved: p x
    the FLOPs for the same answer, the `allgather_a` pathology the sharded
    bench used to show at 56 ms vs 11 ms.)  SPMD runs the same kernel on the
    same shard values whichever rank executes it, so the result-rotation
    output is bitwise-identical to the input-rotation one.

    overlap=True splits the local product into two column halves and
    double-buffers them: the first half's result chunk starts hopping while
    the second half's kernel is still on the MXU, and the two chains'
    hops/writes interleave — steady state max(compute, comm).  With the
    default dot the halves are bitwise-equal to the full-width product
    (each output element reduces the same K sequence); a `matmul` kernel
    hook receives (m_blk, k) @ (k, n/2) halves, so the planner builds its
    per-shard kernel at the half width.

    `matmul` computes each local product (default: XLA f32 dot).
    """
    from repro.resilience import faults

    sched = "allgather_a_overlap" if overlap else "allgather_a"
    faults.check("collective.step", schedule=sched, axis=axis)
    mm = matmul or _default_mm
    p = _axis_size(axis)
    idx = jax.lax.axis_index(axis)
    m_blk, n = x_blk.shape[0], w.shape[1]
    out = jnp.zeros((p * m_blk, n), dtype=jnp.promote_types(x_blk.dtype, jnp.float32))

    if not overlap or p == 1 or n < 2:
        cur = mm(x_blk, w)  # the ONE local kernel call
        for t in range(p):
            # chunk `cur` was computed by rank (idx + t) mod p
            src = (idx + t) % p
            out = jax.lax.dynamic_update_slice(out, cur, (src * m_blk, 0))
            if t < p - 1:
                cur = jax.lax.ppermute(cur, axis, _shift(p, 1))
        return out

    n2 = n // 2
    # Half 0's kernel, then its first hop is in flight while half 1's kernel
    # runs — the double buffer.  Both chains then alternate hop/write.
    cur0 = mm(x_blk, w[:, :n2])
    out = jax.lax.dynamic_update_slice(out, cur0, (idx * m_blk, 0))
    cur0 = jax.lax.ppermute(cur0, axis, _shift(p, 1))
    cur1 = mm(x_blk, w[:, n2:])
    out = jax.lax.dynamic_update_slice(out, cur1, (idx * m_blk, n2))
    cur1 = jax.lax.ppermute(cur1, axis, _shift(p, 1))
    for t in range(1, p):
        faults.check("collective.step", schedule=sched, axis=axis, step=t)
        src = (idx + t) % p
        out = jax.lax.dynamic_update_slice(out, cur0, (src * m_blk, 0))
        out = jax.lax.dynamic_update_slice(out, cur1, (src * m_blk, n2))
        if t < p - 1:
            cur0 = jax.lax.ppermute(cur0, axis, _shift(p, 1))
            cur1 = jax.lax.ppermute(cur1, axis, _shift(p, 1))
    return out


def matmul_ring_reducescatter(
    x: jax.Array,
    w_blk: jax.Array,
    axis: str,
    *,
    matmul: MatmulFn = None,
    overlap: bool = False,
) -> jax.Array:
    """Computes reduce_scatter(x @ w_col_shards) with ring accumulation.

    x: local (m, k_blk) shard of a column-sharded X; w_blk: local (k_blk, n).
    Full product rows are reduced around the ring so each rank ends with its
    (m/p, n) slice of sum_k X_k @ W_k.

    overlap=True hoists step t+1's kernel call ahead of step t's accumulator
    hop: the next partial depends only on resident operands, never on the
    in-flight accumulator, so the `ppermute` and the kernel overlap — steady
    state max(compute, comm).  The accumulator receives the same partials in
    the same order either way, so the output is bitwise-equal to the serial
    path unconditionally.  `matmul` computes each (m/p, k_blk) @ (k_blk, n)
    step (default: XLA f32 dot).
    """
    from repro.resilience import faults

    sched = "reduce_scatter_k_overlap" if overlap else "reduce_scatter_k"
    faults.check("collective.step", schedule=sched, axis=axis)
    mm = matmul or _default_mm
    p = _axis_size(axis)
    idx = jax.lax.axis_index(axis)
    m, n = x.shape[0], w_blk.shape[1]
    if m % p:
        raise ValueError(f"rows {m} not divisible by ring size {p}")
    mb = m // p

    def rows_for(step: int) -> jax.Array:
        # Each accumulation chain is destined for a fixed output rank and
        # moves one hop down the ring per step; the chain that ENDS at rank r
        # is held by rank r + (p-1-t) at step t, so rank `idx` at step t
        # contributes the slice destined for (idx + t + 1) mod p — constant
        # along its chain.
        dst = (idx + step + 1) % p
        return jax.lax.dynamic_slice(x, (dst * mb, 0), (mb, x.shape[1]))

    acc = jnp.zeros((mb, n), dtype=jnp.promote_types(x.dtype, jnp.float32))
    if not overlap:
        for t in range(p):
            acc = acc + mm(rows_for(t), w_blk)
            if t < p - 1:
                acc = jax.lax.ppermute(acc, axis, _shift(p, 1))
        return acc

    part = mm(rows_for(0), w_blk)
    for t in range(p):
        acc = acc + part
        if t < p - 1:
            faults.check("collective.step", schedule=sched, axis=axis, step=t)
            # the hop is in flight while the NEXT partial is on the MXU
            acc = jax.lax.ppermute(acc, axis, _shift(p, 1))
            part = mm(rows_for(t + 1), w_blk)
    return acc


def ring_pipeline_matmul(
    x: jax.Array,
    w_blk: jax.Array,
    axis: str,
    *,
    microbatches: int,
    matmul: MatmulFn = None,
) -> jax.Array:
    """1F1B-microbatched reduce-scatter: the planner-routed pipeline schedule.

    Same contract as `matmul_ring_reducescatter` — x: local (m, k_blk) shard
    of a column-sharded X, w_blk: local (k_blk, n), each rank ends with its
    (m/p, n) row slice of sum_k X_k @ W_k — but the per-rank row block is
    split into `microbatches/p` sub-slices whose accumulator chains flow
    through the stage ring one tick apart (1F1B: at any tick each stage holds
    ONE microbatch's kernel call and ONE in-flight hop; fill = warmup of the
    first chain, steady = one hop overlapping one kernel, drain = the last
    chain's final adds).  In-flight state is one (m/µ, n) accumulator + one
    partial instead of the whole row block — the pipeline's memory shape —
    and every hop is double-buffered against the next tick's kernel exactly
    like `matmul_ring_reducescatter(overlap=True)`.

    `microbatches` must be a multiple of the ring size p and divide m.  Rows
    accumulate in the same ring order as the reduce-scatter, so the output is
    bitwise-equal to both reducescatter dataflows.
    """
    from repro.resilience import faults

    faults.check("collective.step", schedule="pipeline", axis=axis)
    mm = matmul or _default_mm
    p = _axis_size(axis)
    idx = jax.lax.axis_index(axis)
    m, n = x.shape[0], w_blk.shape[1]
    if microbatches % p or microbatches <= 0:
        raise ValueError(
            f"microbatches {microbatches} must be a positive multiple of the"
            f" ring size {p}"
        )
    if m % microbatches:
        raise ValueError(f"rows {m} not divisible by microbatches {microbatches}")
    f = microbatches // p  # chains per rank (pipeline rounds)
    mb = m // p  # rows this rank ends with
    msb = mb // f  # rows per microbatch chain

    def part_for(rnd: int, step: int) -> jax.Array:
        # Round `rnd` runs the reduce-scatter chain over sub-slice rnd of
        # every rank's destination block, so assembled outputs keep the
        # reduce-scatter row layout (and its bitwise accumulation order).
        dst = (idx + step + 1) % p
        rows = jax.lax.dynamic_slice(
            x, (dst * mb + rnd * msb, 0), (msb, x.shape[1])
        )
        return mm(rows, w_blk)

    outs = []
    part = part_for(0, 0)  # fill: the first microbatch's kernel
    for rnd in range(f):
        acc = jnp.zeros((msb, n), dtype=jnp.promote_types(x.dtype, jnp.float32))
        for t in range(p):
            acc = acc + part
            if rnd == f - 1 and t == p - 1:
                break  # drain: the last chain's final add, nothing in flight
            faults.check(
                "collective.step", schedule="pipeline", axis=axis, step=(rnd, t)
            )
            nrnd, nt = (rnd, t + 1) if t < p - 1 else (rnd + 1, 0)
            if t < p - 1:
                # steady state: this chain's hop overlaps the next kernel
                acc = jax.lax.ppermute(acc, axis, _shift(p, 1))
            part = part_for(nrnd, nt)
        outs.append(acc)
    return jnp.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]


def psum_if_multi(x: jax.Array, axis: str) -> jax.Array:
    """psum that is a no-op on a missing/size-1 axis (mesh-shape agnostic)."""
    try:
        size = _axis_size(axis)
    except NameError:
        return x
    return jax.lax.psum(x, axis) if size > 1 else x
