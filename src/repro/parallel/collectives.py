"""Overlapped collective-matmul building blocks (TP comm/compute fusion).

Standard TP layers do `all_gather(x) @ W` or `reduce_scatter(x @ W)` as two
serial phases.  These ring variants interleave the p neighbour exchanges with
the p partial matmuls (Wang et al., "Overlap communication with dependent
computation", and the TPU collective-matmul in XLA): each step multiplies the
chunk it already holds while ppermuting the next chunk — the same
double-buffered dataflow as `parallel/systolic.py`, applied to 1D rings.

Used by the hillclimb experiments (EXPERIMENTS.md §Perf) as the beyond-paper
collective schedule, and by the ShardedPlan collective schedules in
`kernels/api.py` (`allgather_a`, `reduce_scatter_k`) — the `matmul=` hook is
what lets the planner fuse its per-shard kernel call (Pallas mesh kernel or
XLA dot) inside the ring instead of a hard-wired jnp.dot.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["ring_allgather_matmul", "matmul_ring_reducescatter", "psum_if_multi"]

# Per-step local product hook: (chunk, weights) -> f32 partial.  None selects
# the plain XLA dot; ShardedPlan passes its per-shard Plan executor here.
MatmulFn = Optional[Callable[[jax.Array, jax.Array], jax.Array]]


def _default_mm(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def _shift(p: int, by: int = 1):
    return [(s, (s - by) % p) for s in range(p)]


def _axis_size(axis) -> int:
    """Static named-axis size: jax >= 0.6 has jax.lax.axis_size; on 0.4.x
    jax.core.axis_frame(name) returns the size directly."""
    names = axis if isinstance(axis, (tuple, list)) else (axis,)
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(names)
    size = 1
    for name in names:
        size *= jax.core.axis_frame(name)
    return size


def ring_allgather_matmul(
    x_blk: jax.Array, w: jax.Array, axis: str, *, matmul: MatmulFn = None
) -> jax.Array:
    """Computes all_gather(x, axis) @ w without materializing the gather.

    x_blk: local (m_blk, k) shard of a row-sharded X (full X is (p*m_blk, k));
    w: replicated (k, n).  Returns the local (p*m_blk, n) result — i.e. the
    full product, built ring-step by ring-step while chunks circulate.
    `matmul` computes each (m_blk, k) @ (k, n) step (default: XLA f32 dot).
    """
    from repro.resilience import faults

    faults.check("collective.step", schedule="allgather_a", axis=axis)
    mm = matmul or _default_mm
    p = _axis_size(axis)
    idx = jax.lax.axis_index(axis)
    m_blk, n = x_blk.shape[0], w.shape[1]
    out = jnp.zeros((p * m_blk, n), dtype=jnp.promote_types(x_blk.dtype, jnp.float32))
    cur = x_blk
    for t in range(p):
        # chunk `cur` originated at rank (idx + t) mod p
        src = (idx + t) % p
        part = mm(cur, w)
        out = jax.lax.dynamic_update_slice(out, part, (src * m_blk, 0))
        if t < p - 1:
            cur = jax.lax.ppermute(cur, axis, _shift(p, 1))
    return out


def matmul_ring_reducescatter(
    x: jax.Array, w_blk: jax.Array, axis: str, *, matmul: MatmulFn = None
) -> jax.Array:
    """Computes reduce_scatter(x @ w_col_shards) with ring accumulation.

    x: local (m, k_blk) shard of a column-sharded X; w_blk: local (k_blk, n).
    Full product rows are reduced around the ring so each rank ends with its
    (m/p, n) slice of sum_k X_k @ W_k; the accumulator hop overlaps the next
    partial matmul.  `matmul` computes each (m/p, k_blk) @ (k_blk, n) step
    (default: XLA f32 dot).
    """
    from repro.resilience import faults

    faults.check("collective.step", schedule="reduce_scatter_k", axis=axis)
    mm = matmul or _default_mm
    p = _axis_size(axis)
    idx = jax.lax.axis_index(axis)
    m, n = x.shape[0], w_blk.shape[1]
    if m % p:
        raise ValueError(f"rows {m} not divisible by ring size {p}")
    mb = m // p
    # Each accumulation chain is destined for a fixed output rank and moves
    # one hop down the ring per step; the chain that ENDS at rank r is held
    # by rank r + (p-1-t) at step t, so rank `idx` at step t contributes the
    # slice destined for (idx + t + 1) mod p — constant along its chain.
    acc = jnp.zeros((mb, n), dtype=jnp.promote_types(x.dtype, jnp.float32))
    for t in range(p):
        dst = (idx + t + 1) % p
        rows = jax.lax.dynamic_slice(x, (dst * mb, 0), (mb, x.shape[1]))
        acc = acc + mm(rows, w_blk)
        if t < p - 1:
            acc = jax.lax.ppermute(acc, axis, _shift(p, 1))
    return acc


def psum_if_multi(x: jax.Array, axis: str) -> jax.Array:
    """psum that is a no-op on a missing/size-1 axis (mesh-shape agnostic)."""
    try:
        size = _axis_size(axis)
    except NameError:
        return x
    return jax.lax.psum(x, axis) if size > 1 else x
