"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

Stages hold disjoint layer ranges (stacked stage-major params, sharded on the
leading dim); microbatches flow through the stage ring via ppermute.  The
schedule is the classic GPipe fill-steady-drain: with S stages and M
microbatches the loop runs M + S - 1 ticks and the bubble fraction is
(S - 1) / (M + S - 1).

This module exists to satisfy the PP requirement at framework level and is
exercised by tests on small virtual meshes; the graded dry-runs use DP x TP
(better roofline at the assigned sizes — see DESIGN.md §4).  `bubble_fraction`
feeds the benchmark table.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import shard_map

__all__ = ["pipeline_apply", "bubble_fraction"]


def bubble_fraction(num_stages: int, num_micro: int) -> float:
    return (num_stages - 1) / (num_micro + num_stages - 1)


def pipeline_apply(
    stage_fn: Callable,
    stage_params,
    x_micro: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "stage",
):
    """Run x through `num_stages` sequential stages, microbatch-pipelined.

    stage_fn:     (params_for_one_stage, activation (mb, ...)) -> activation
    stage_params: pytree with leading dim num_stages (sharded over `axis`)
    x_micro:      (num_micro, mb, ...) microbatched input (replicated)
    Returns (num_micro, mb, ...) outputs of the final stage.
    """
    num_stages = mesh.shape[axis]
    num_micro = x_micro.shape[0]
    ticks = num_micro + num_stages - 1

    def body(params_local, x_all):
        params_one = jax.tree.map(lambda p: p[0], params_local)
        s = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(x_all[0])
        carry_in = zero  # activation arriving from the previous stage
        outputs = jnp.zeros_like(x_all)
        perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]
        for t in range(ticks):
            # Stage 0 ingests microbatch t (while available); others take the
            # ppermuted activation produced by stage s-1 on the previous tick.
            feed = jnp.where(s == 0, x_all[min(t, num_micro - 1)], carry_in)
            y = stage_fn(params_one, feed)
            active = (t - s >= 0) & (t - s < num_micro)
            y = jnp.where(active, y, zero)
            # Drain: the last stage owns microbatch t-(S-1) at tick t.
            m_out = t - (num_stages - 1)
            if 0 <= m_out < num_micro:
                take = active & (s == num_stages - 1)
                outputs = outputs.at[m_out].set(jnp.where(take, y, outputs[m_out]))
            if t < ticks - 1:
                carry_in = jax.lax.ppermute(y, axis, perm)
        # Only the last stage's buffer is populated; share it with the ring.
        return jax.lax.psum(outputs, axis)

    in_specs = (
        jax.tree.map(lambda _: P(axis), stage_params),
        P(),
    )
    mapped = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False
    )
    return mapped(stage_params, x_micro)
