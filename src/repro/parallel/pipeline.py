"""Pipeline parallelism over a 'stage' mesh axis: schedules + reference loop.

Stages hold disjoint layer ranges (stacked stage-major params, sharded on the
leading dim); microbatches flow through the stage ring via ppermute.  Two
schedules are modelled:

  gpipe  fill -> steady -> drain over M + S - 1 forward ticks; all M
         microbatches are in flight at the steady peak.
  1f1b   one-forward-one-backward: after the S-1-tick fill each stage
         alternates one forward with one backward tick, so at most
         min(S, M) microbatches are ever in flight.  The bubble fraction
         is the SAME (S-1)/(M+S-1) as GPipe — 1F1B's win is peak
         activation memory, not bubble time (Narayanan et al., PipeDream).

`pipeline_ticks` gives the exact fill/steady/drain tick counts per schedule
(unit-tested); `bubble_fraction` is the headline scalar the benchmark table
and the cost model's `pipeline` collective schedule consume.

`pipeline_apply` is the executable reference loop (forward-only, i.e. the
GPipe tick structure): each tick's stage-ring `ppermute` is issued directly
after the stage kernel, before the drain bookkeeping, so the neighbour hop is
in flight while the tick finishes — the same double-buffer dataflow as the
overlapped ring collectives (`parallel/collectives.py`).  The *planner-routed*
pipeline schedule — 1F1B microbatching of the reduce-scatter ring with
double-buffered hops — is `collectives.ring_pipeline_matmul`, reached via
`ShardSpec(schedule="pipeline")` in `kernels/api.py`.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import shard_map

__all__ = ["pipeline_apply", "pipeline_ticks", "bubble_fraction"]


def pipeline_ticks(num_stages: int, num_micro: int, *, schedule: str = "gpipe") -> dict:
    """Exact tick accounting for a pipeline schedule.

    Returns fill/steady/drain/total tick counts, the bubble (idle stage-ticks
    at the last stage), the bubble fraction, and the peak number of
    microbatches in flight — the quantity that actually separates 1F1B from
    GPipe.  `gpipe` counts forward ticks only (matching `pipeline_apply`);
    `1f1b` counts forward+backward ticks (one tick each).
    """
    s, m = int(num_stages), int(num_micro)
    if s < 1 or m < 1:
        raise ValueError(f"need num_stages >= 1 and num_micro >= 1, got {s}, {m}")
    fill = s - 1  # ticks before the last stage sees microbatch 0
    drain = s - 1  # ticks after the first stage goes idle
    if schedule == "gpipe":
        total = m + s - 1
        work = m  # forward ticks each stage executes
        peak = m  # all microbatches' activations live through the fill
    elif schedule == "1f1b":
        # After the fill each stage strictly alternates 1 fwd / 1 bwd, so a
        # microbatch's backward frees its activation before fwd s+1 starts:
        total = 2 * (m + s - 1)
        work = 2 * m  # one forward + one backward tick per microbatch
        peak = min(s, m)
    else:
        raise ValueError(f"unknown pipeline schedule {schedule!r}")
    steady = total - fill - drain  # ticks with every stage busy
    bubble = total - work  # idle ticks per stage (fill at the tail, drain at 0)
    return {
        "schedule": schedule,
        "num_stages": s,
        "num_micro": m,
        "fill": fill,
        "steady": steady,
        "drain": drain,
        "total": total,
        "bubble": bubble,
        "bubble_fraction": (s - 1) / (m + s - 1),
        "peak_in_flight": peak,
    }


def bubble_fraction(num_stages: int, num_micro: int, *, schedule: str = "gpipe") -> float:
    """Idle fraction of the pipeline: (S-1)/(M+S-1) for gpipe AND 1f1b.

    Identical by design — 1F1B reorders work inside the steady window without
    shrinking the fill/drain ramps; its advantage is `peak_in_flight`
    (see `pipeline_ticks`), i.e. activation memory, not bubble time.
    """
    return pipeline_ticks(num_stages, num_micro, schedule=schedule)["bubble_fraction"]


def pipeline_apply(
    stage_fn: Callable,
    stage_params,
    x_micro: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "stage",
):
    """Run x through `num_stages` sequential stages, microbatch-pipelined.

    stage_fn:     (params_for_one_stage, activation (mb, ...)) -> activation
    stage_params: pytree with leading dim num_stages (sharded over `axis`)
    x_micro:      (num_micro, mb, ...) microbatched input (replicated)
    Returns (num_micro, mb, ...) outputs of the final stage.
    """
    num_stages = mesh.shape[axis]
    num_micro = x_micro.shape[0]
    ticks = num_micro + num_stages - 1

    def body(params_local, x_all):
        params_one = jax.tree.map(lambda p: p[0], params_local)
        s = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(x_all[0])
        carry_in = zero  # activation arriving from the previous stage
        outputs = jnp.zeros_like(x_all)
        perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]
        for t in range(ticks):
            # Stage 0 ingests microbatch t (while available); others take the
            # ppermuted activation produced by stage s-1 on the previous tick.
            feed = jnp.where(s == 0, x_all[min(t, num_micro - 1)], carry_in)
            y = stage_fn(params_one, feed)
            active = (t - s >= 0) & (t - s < num_micro)
            y = jnp.where(active, y, zero)
            if t < ticks - 1:
                # Issue the stage hop before the drain bookkeeping below: the
                # ppermute depends only on y, so it is in flight while the
                # output scatter runs (double-buffered, like the overlapped
                # ring collectives).
                carry_in = jax.lax.ppermute(y, axis, perm)
            # Drain: the last stage owns microbatch t-(S-1) at tick t.
            m_out = t - (num_stages - 1)
            if 0 <= m_out < num_micro:
                take = active & (s == num_stages - 1)
                outputs = outputs.at[m_out].set(jnp.where(take, y, outputs[m_out]))
        # Only the last stage's buffer is populated; share it with the ring.
        return jax.lax.psum(outputs, axis)

    in_specs = (
        jax.tree.map(lambda _: P(axis), stage_params),
        P(),
    )
    mapped = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False
    )
    return mapped(stage_params, x_micro)
