"""Int8 error-feedback gradient compression for the DP all-reduce.

At 1000+ chip scale the data-parallel gradient all-reduce is the largest
fixed collective.  This module compresses it 4x (f32 -> int8 payload plus one
f32 scale scalar per tensor) with *error feedback* (Seide et al. 2014;
Karimireddy et al. 2019): the quantization residual is carried into the next
step's gradient, so the compression bias telescopes and SGD-style convergence
is preserved.

Semantics (per tensor, inside shard_map over the DP axes):
    corrected = grad + error_state
    scale     = pmax(max|corrected|) / 127          (1 scalar all-reduce)
    q         = round(corrected / scale)  : int8
    summed    = psum(q as int32)                    (the 4x-smaller payload)
    mean_grad = summed * scale / n_devices
    new_error = corrected - q * scale               (local residual)

The int32 psum accumulator is exact for <= 2^24 devices, so the compressed
all-reduce is deterministic.  `compressed_psum_mean` is the drop-in for
`jax.lax.pmean` in `train/train_step.py` (enabled by
`TrainConfig.grad_compression="int8_ef"`).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum_mean", "init_error_state", "compressed_pmean_tree"]


def init_error_state(grads: Any) -> Any:
    """Zero residual pytree matching the gradient pytree (f32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum_mean(
    g: jax.Array, e: jax.Array, axis_names
) -> Tuple[jax.Array, jax.Array]:
    """One tensor: (mean-of-grads approximation, new error residual)."""
    corrected = g.astype(jnp.float32) + e
    # Shared scale => psum of int8 payloads is a faithful fixed-point sum.
    amax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis_names)
    scale = jnp.maximum(amax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    summed = jax.lax.psum(q.astype(jnp.int32), axis_names)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_names)
    mean = summed.astype(jnp.float32) * scale / n.astype(jnp.float32)
    new_e = corrected - q.astype(jnp.float32) * scale
    return mean.astype(g.dtype), new_e


def compressed_pmean_tree(grads: Any, errors: Any, axis_names) -> Tuple[Any, Any]:
    """Pytree version; returns (mean grads, new error states)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [compressed_psum_mean(g, e, axis_names) for g, e in zip(flat_g, flat_e)]
    means = treedef.unflatten([m for m, _ in out])
    new_errors = treedef.unflatten([e for _, e in out])
    return means, new_errors
