"""Logical-axis sharding rules (MaxText-style) for DP / TP / EP / SP.

Models annotate every parameter and activation with *logical* axis names;
this module maps them to physical mesh axes via a rules table, producing
`PartitionSpec`s / `NamedSharding`s consumed by pjit in `launch/dryrun.py`
and `launch/train.py`.

Physical mesh axes (launch/mesh.py):
    single pod:  ('data', 'model')            16 x 16
    multi-pod:   ('pod', 'data', 'model')     2 x 16 x 16  ('pod' = outer DP)

Default logical->physical rules:
    batch    -> ('pod', 'data')     pure DP over pod+data
    seq      -> None                (SP rule available for long-context)
    embed    -> None                activations replicated over 'model'
    heads    -> 'model'             Megatron TP: attention heads
    kv_heads -> 'model'             GQA KV heads (capped by kv count)
    mlp      -> 'model'             Megatron TP: FFN hidden
    experts  -> 'model'             EP: MoE expert dim
    expert_rows -> 'model'          EP: grouped-GEMM dispatch-buffer rows
    vocab    -> 'model'             vocab-sharded embedding + logits
    state    -> None                SSM recurrent state (small)
    kv_seq   -> None                KV-cache length ('data' under SP rules)
    stage    -> 'stage'             PP (only present on PP meshes)
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "SP_DECODE_RULES",
    "logical_to_physical",
    "named_sharding",
    "shard_map",
    "tree_shardings",
    "constrain",
]

Rules = Mapping[str, Any]

_DEFAULT: dict = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": None,  # Megatron-SP: layer-boundary activation carriers
    "seq_attn": None,  # context parallelism: q/out seq dim in chunked attention
    # (set to 'model' when num_heads %% TP != 0 — phi3 40H, qwen2 28H)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    # EP: the grouped-GEMM capacity buffer's row dim is expert-major
    # (models/moe.py), so sharding it over 'model' co-locates each expert's
    # token rows with its weight slab — pjit's resharding of the dispatch
    # buffer into this layout IS the EP all-to-all (DESIGN.md §10).
    "expert_rows": "model",
    "vocab": "model",
    "state": None,
    "kv_seq": None,
    "kv_batch": ("pod", "data"),
    "layers": None,
    "stage": "stage",
    "frames": None,
    "patches": None,
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Immutable logical->physical table; `replace` builds variants."""

    table: Tuple[Tuple[str, Any], ...]

    @classmethod
    def make(cls, overrides: Optional[Rules] = None) -> "ShardingRules":
        merged = dict(_DEFAULT)
        if overrides:
            merged.update(overrides)
        return cls(tuple(sorted(merged.items())))

    def get(self, logical: Optional[str]):
        if logical is None:
            return None
        d = dict(self.table)
        if logical not in d:
            raise KeyError(f"unknown logical axis {logical!r}")
        return d[logical]

    def replace(self, **overrides) -> "ShardingRules":
        d = dict(self.table)
        d.update(overrides)
        return ShardingRules(tuple(sorted(d.items())))


DEFAULT_RULES = ShardingRules.make()

# FSDP parameter rules: the 'embed' dim of every weight is additionally
# sharded over the DP axes, so params + optimizer state shard across the FULL
# mesh (TP x DP).  Activations keep DEFAULT_RULES — their 'embed' maps through
# this table too, but the duplicate-axis dedup in logical_to_physical drops it
# wherever 'batch' already owns the data axes.  XLA inserts the per-layer
# weight all-gathers (ZeRO-3/FSDP streaming), which overlap the scanned
# layer compute.  Required: mistral-large-123b params+opt = 1.2 TB.
PARAM_RULES = DEFAULT_RULES.replace(embed=("pod", "data"))

# Megatron sequence parallelism for training: remat-saved layer-boundary
# carriers are stored seq-sharded over 'model' (16x smaller residency).
TRAIN_RULES = DEFAULT_RULES.replace(seq_sp="model")

# Sequence-parallel decode rules: long-context KV caches / recurrent streams
# are sharded along their length over 'data' (batch is tiny in long_500k).
SP_DECODE_RULES = DEFAULT_RULES.replace(
    kv_seq=("pod", "data"), kv_batch=None, batch=None
)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True, **kw):
    """Version-compat shard_map: jax >= 0.5 exposes `jax.shard_map` with
    `check_vma`; 0.4.x has `jax.experimental.shard_map.shard_map` with the
    same flag spelled `check_rep`."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kw,
        )
    from jax.experimental.shard_map import shard_map as _shard_map  # noqa: PLC0415

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma, **kw,
    )


def _axes_on_mesh(mesh: Mesh, axes):
    """Drop rule axes the mesh doesn't have (e.g. 'pod' on single-pod)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.shape else None
    present = tuple(a for a in axes if a in mesh.shape)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def logical_to_physical(
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
) -> P:
    """('batch', 'seq', 'embed') -> PartitionSpec(('pod','data'), None, None)."""
    phys = [_axes_on_mesh(mesh, rules.get(ax)) for ax in logical_axes]
    # A physical axis may appear at most once in a spec; later wins -> None.
    seen = set()
    cleaned = []
    for a in phys:
        names = (a,) if isinstance(a, str) else (a or ())
        if any(n in seen for n in names):
            cleaned.append(None)
            continue
        seen.update(names)
        cleaned.append(a)
    return P(*cleaned)


def named_sharding(
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
    shape: Optional[Sequence[int]] = None,
) -> NamedSharding:
    spec = logical_to_physical(logical_axes, mesh, rules)
    if shape is not None:
        spec = _drop_indivisible(spec, shape, mesh)
    return NamedSharding(mesh, spec)


def _axes_size(mesh: Mesh, a) -> int:
    if a is None:
        return 1
    if isinstance(a, str):
        return mesh.shape[a]
    n = 1
    for x in a:
        n *= mesh.shape[x]
    return n


# (spec, shape, mesh-shape) triples already warned about — the fallback is
# per-layer-per-step hot-path code, so each distinct drop warns exactly once.
_WARNED_DROPS: set = set()


def _drop_indivisible(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Replicate any dim whose size doesn't divide by its mapped axes product.

    pjit *arguments* require exact divisibility (XLA pads only internal ops);
    odd published dims (vocab=49155, heads=40 vs TP=16) fall back to
    replicated on that dim — recorded in EXPERIMENTS.md §Dry-run notes.
    The drop is no longer silent: each distinct (spec, shape, mesh) warns
    once, so a mis-sized dim that quietly replicates a 16-way-sharded tensor
    shows up in logs instead of only in the memory profile.
    """
    out, dropped = [], []
    for dim, a in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if a is None or dim % _axes_size(mesh, a) == 0:
            out.append(a)
        else:
            out.append(None)
            dropped.append((dim, a))
    if dropped:
        key = (tuple(spec), tuple(shape), tuple(mesh.shape.items()))
        if key not in _WARNED_DROPS:
            _WARNED_DROPS.add(key)
            detail = ", ".join(
                f"dim {dim} % {_axes_size(mesh, a)} != 0 (axes {a!r})"
                for dim, a in dropped
            )
            warnings.warn(
                f"sharding {spec} of shape {tuple(shape)} fell back to"
                f" replicated on indivisible dim(s): {detail}",
                UserWarning,
                stacklevel=3,
            )
    return P(*out)


def tree_shardings(
    logical_tree,
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
    aval_tree=None,
):
    """Map a pytree of logical-axis tuples to a matching tree of NamedShardings.

    Leaves of `logical_tree` are tuples like ('embed', 'mlp') (or None for
    fully-replicated scalars/vectors).  With `aval_tree` (matching tree of
    arrays/ShapeDtypeStructs) non-divisible dims are dropped to replicated —
    required for pjit argument shardings.
    """
    is_leaf = lambda x: x is None or isinstance(x, tuple)
    if aval_tree is None:
        one = lambda axes: (
            NamedSharding(mesh, P()) if axes is None else named_sharding(axes, mesh, rules)
        )
        return jax.tree.map(one, logical_tree, is_leaf=is_leaf)

    def one_shaped(axes, aval):
        if axes is None:
            return NamedSharding(mesh, P())
        return named_sharding(axes, mesh, rules, shape=aval.shape)

    return jax.tree.map(one_shaped, logical_tree, aval_tree, is_leaf=is_leaf)


def constrain(x: jax.Array, logical_axes, mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
    """with_sharding_constraint by logical names, divisibility-safe."""
    return jax.lax.with_sharding_constraint(
        x, named_sharding(logical_axes, mesh, rules, shape=x.shape)
    )
