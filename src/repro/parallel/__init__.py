"""Distribution layer: sharding rules, systolic matmul, PP, compression, overlap."""

from repro.parallel.collectives import (
    matmul_ring_reducescatter,
    ring_allgather_matmul,
)
from repro.parallel.compression import (
    compressed_pmean_tree,
    compressed_psum_mean,
    init_error_state,
)
from repro.parallel.pipeline import bubble_fraction, pipeline_apply
from repro.parallel.sharding import (
    DEFAULT_RULES,
    SP_DECODE_RULES,
    ShardingRules,
    constrain,
    logical_to_physical,
    named_sharding,
    shard_map,
    tree_shardings,
)
from repro.parallel.systolic import (
    phase_counts,
    ring_systolic_kpass,
    systolic_matmul,
)

__all__ = [
    "systolic_matmul",
    "ring_systolic_kpass",
    "phase_counts",
    "shard_map",
    "pipeline_apply",
    "bubble_fraction",
    "ring_allgather_matmul",
    "matmul_ring_reducescatter",
    "compressed_psum_mean",
    "compressed_pmean_tree",
    "init_error_state",
    "ShardingRules",
    "DEFAULT_RULES",
    "SP_DECODE_RULES",
    "logical_to_physical",
    "named_sharding",
    "tree_shardings",
    "constrain",
]
