"""Distributed systolic matmul: the mesh array realized on the TPU ICI torus.

The paper's array is a grid of MACs with nearest-neighbour wires; a TPU pod is
a grid of chips with nearest-neighbour ICI links.  This module runs C = A @ B
with A, B, C block-sharded over a square (p x p) sub-mesh of devices, using
`shard_map` + `jax.lax.ppermute` neighbour rotations (Cannon's schedule, which
is the block-level form of the systolic array).

Hardware adaptation of the paper's step-count claim (DESIGN.md §2):

  * A physical systolic fabric pays the *skew*: hop-by-hop pre-alignment costs
    up to p-1 neighbour steps, so naive aligned Cannon takes ~2p-1 collective
    phases — the analogue of the standard array's 3n-2.
  * ICI is a *switched* torus: an arbitrary permutation is ONE
    collective-permute.  We fold the whole alignment into a single ppermute
    over the flattened 2D axis (row i shifts by i — inexpressible as a uniform
    1D shift, trivial as a 2D permutation).  Total phases: p+1 — the paper's
    2n-1-style saving, delivered by hardware routing instead of output
    scrambling.  (The output-permutation trick itself lives at the kernel
    level, where BlockSpec index_maps play the role of node wiring; block-SPMD
    cannot express per-device feeding schedules — recorded as an adaptation.)
  * Compute/comm overlap: each loop step's ppermutes depend only on the
    *current* buffers, never on the step's matmul, so XLA's latency-hiding
    scheduler overlaps the neighbour exchange with the MXU work
    (double-buffering in dataflow form).  The loop is unrolled (p is a static
    mesh dimension) to give the scheduler full freedom.

`phase_counts()` reports the collective-phase arithmetic for the benchmark
table; `systolic_matmul` is the user-facing jit entry point.

`ring_systolic_kpass` is the 1D-ring form of the same principle and the
backend of the ShardedPlan `ring_k` collective schedule (`kernels/api.py`):
with A column- and B row-sharded over K, p accumulator wavefronts circulate
the ring via `jax.lax.ppermute`, each picking up the resident partial product
as it passes — partial products flow through neighbours instead of returning
to a central psum point, the paper's 2n-1 staggered feed at device
granularity.  This module is consulted by the planner, not just by demos.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import shard_map

__all__ = [
    "systolic_matmul",
    "systolic_matmul_shardmap",
    "ring_systolic_kpass",
    "phase_counts",
]


def _shift_perm(p: int, shift: int) -> list[Tuple[int, int]]:
    """Uniform circular shift along one axis: src -> (src - shift) mod p."""
    return [(s, (s - shift) % p) for s in range(p)]


def _alignment_perm_2d(p: int, *, align_a: bool) -> list[Tuple[int, int]]:
    """Cannon pre-alignment as ONE permutation over the flattened (p, p) axes.

    A: device (i, j) must receive A-block (i, (i + j) mod p)  => row i shifts
       left by i.  B: device (i, j) must receive B-block ((i + j) mod p, j)
       => column j shifts up by j.  Flattened index = i * p + j.
    """
    perm = []
    for i in range(p):
        for j in range(p):
            if align_a:
                src = i * p + ((i + j) % p)
            else:
                src = ((i + j) % p) * p + j
            perm.append((src, i * p + j))
    return perm


def phase_counts(p: int) -> dict:
    """Collective-phase accounting, mirroring the paper's step counts.

    naive (hop-by-hop alignment, the 'standard array' analogue):
        (p-1) A-hops + (p-1) B-hops happen concurrently -> p-1 phases,
        then p compute steps with p-1 rotation phases hidden under them.
    switched (this module, the 'mesh array' analogue):
        1 alignment permute phase + p compute steps.
    1D K-pass (the ShardedPlan 'ring_k' / 'reduce_scatter_k' schedules):
        gather-then-compute psums partials through a ring all-reduce,
        2(p-1) phases — partials return to a central point, the 3n-2 regime;
        the ring-systolic pass flows them through neighbours in p-1 phases,
        the 2n-1 regime.
    """
    return {
        "p": p,
        "naive_phases": (p - 1) + p,  # 2p-1  ~ the 3n-2 regime
        "switched_phases": 1 + p,  # p+1  ~ the 2n-1 regime
        "kpass_psum_phases": 2 * (p - 1),  # ring all-reduce of partials
        "kpass_ring_phases": p - 1,  # ring_systolic_kpass wavefronts
        "paper_standard_steps": 3 * p - 2,
        "paper_mesh_steps": 2 * p - 1,
    }


def ring_systolic_kpass(
    a_blk: jax.Array,
    b_blk: jax.Array,
    *,
    axis: str,
    matmul: Optional[Callable[[jax.Array, jax.Array], jax.Array]] = None,
    overlap: bool = False,
) -> jax.Array:
    """K-contraction over a device ring with systolic partial-product flow.

    a_blk: local (m, k/p) column shard of A; b_blk: local (k/p, n) row shard
    of B (shard t holds the K-slice resident on rank t).  Each rank computes
    its partial product ONCE; p accumulator wavefronts then circulate the
    ring (`ppermute`), each adding the resident partial as it passes.  After
    p-1 hops every wavefront has visited all p ranks, so each rank holds the
    full C = sum_t A_t @ B_t — replicated output with no psum tree.

    This is the paper's staggered feed mapped onto collectives: wavefront w
    starts at rank w (the stagger), and partials flow through neighbours
    instead of returning to a central point (2n-1 vs 3n-2; DESIGN.md §9).
    Each rank's sum accumulates in ring order starting from its own partial,
    so cross-rank float32 results can differ in the last ulp (exact for
    integer-valued data); `out_specs` replication is therefore declared, not
    verified (check_vma=False).  `matmul` computes the one local
    (m, k/p) @ (k/p, n) product (default: XLA f32 dot).

    overlap=True splits the partial into two column halves and staggers the
    chains: the first half's accumulator hop is issued while the second
    half's kernel is still running, and each later hop overlaps the other
    chain's add — the explicit double-buffer form of the dataflow the serial
    loop only *permits* the scheduler to overlap.  Per chain the hop/add
    sequence is identical to the serial loop, so XLA-dot results match
    bitwise (a half-width `matmul` kernel hook may retile, so the general
    oracle is exactness on integer-valued data).
    """
    from repro.parallel.collectives import _axis_size, _default_mm, _shift
    from repro.resilience import faults

    sched = "ring_k_overlap" if overlap else "ring_k"
    faults.check("collective.step", schedule=sched, axis=axis)
    mm = matmul or _default_mm
    p = _axis_size(axis)
    n = b_blk.shape[1]
    if not overlap or p == 1 or n < 2:
        part = mm(a_blk, b_blk)
        acc = part
        # Unrolled wavefront loop: each hop's ppermute depends only on the
        # previous accumulator, and `part` is loop-invariant, so XLA overlaps
        # the neighbour exchange with the adds (same dataflow as the 2D loop
        # above).
        for _ in range(p - 1):
            acc = jax.lax.ppermute(acc, axis, _shift(p, 1)) + part
        return acc

    n2 = n // 2
    # Chain 0's kernel, then its first hop is in flight while chain 1's
    # kernel runs — the double buffer.
    part0 = mm(a_blk, b_blk[:, :n2])
    acc0 = jax.lax.ppermute(part0, axis, _shift(p, 1)) + part0
    part1 = mm(a_blk, b_blk[:, n2:])
    acc1 = jax.lax.ppermute(part1, axis, _shift(p, 1)) + part1
    for t in range(p - 2):
        faults.check("collective.step", schedule=sched, axis=axis, step=t)
        acc0 = jax.lax.ppermute(acc0, axis, _shift(p, 1)) + part0
        acc1 = jax.lax.ppermute(acc1, axis, _shift(p, 1)) + part1
    return jnp.concatenate([acc0, acc1], axis=1)


def systolic_matmul_shardmap(
    a_blk: jax.Array,
    b_blk: jax.Array,
    *,
    axis_x: str,
    axis_y: str,
    p: int,
    precision=None,
) -> jax.Array:
    """shard_map body: local (m_blk, k_blk) @ (k_blk, n_blk) Cannon loop.

    Call under `shard_map` with a_blk = A[i, j], b_blk = B[i, j] resident and
    returns the resident C[i, j].  Exposed separately so model TP layers can
    embed it inside larger shard_map blocks.
    """
    both = (axis_x, axis_y)

    # Phase 0: single-permute alignment (the switched-torus skew removal).
    a_cur = jax.lax.ppermute(a_blk, both, _alignment_perm_2d(p, align_a=True))
    b_cur = jax.lax.ppermute(b_blk, both, _alignment_perm_2d(p, align_a=False))

    acc = jnp.zeros(
        (a_blk.shape[0], b_blk.shape[1]),
        dtype=jnp.promote_types(a_blk.dtype, jnp.float32),
    )
    # Unrolled systolic loop: matmul(t) and rotate(t->t+1) both read the
    # current buffers, so the exchange overlaps the MXU work.
    for t in range(p):
        partial_prod = jnp.dot(
            a_cur, b_cur, preferred_element_type=jnp.float32, precision=precision
        )
        if t < p - 1:
            a_nxt = jax.lax.ppermute(a_cur, axis_y, _shift_perm(p, 1))
            b_nxt = jax.lax.ppermute(b_cur, axis_x, _shift_perm(p, 1))
            a_cur, b_cur = a_nxt, b_nxt
        acc = acc + partial_prod
    return acc


@functools.partial(jax.jit, static_argnames=("mesh", "axes", "out_dtype"))
def _systolic_jit(a, b, mesh, axes, out_dtype):
    axis_x, axis_y = axes
    p = mesh.shape[axis_x]

    body = functools.partial(
        systolic_matmul_shardmap, axis_x=axis_x, axis_y=axis_y, p=p
    )
    mapped = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_x, axis_y), P(axis_x, axis_y)),
        out_specs=P(axis_x, axis_y),
    )
    return mapped(a, b).astype(out_dtype)


def systolic_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: Mesh,
    axes: Tuple[str, str] = ("data", "model"),
    out_dtype=None,
) -> jax.Array:
    """C = A @ B with all three matrices block-sharded over a square 2D mesh.

    a: (M, K), b: (K, N); M, K divisible by mesh.shape[axes[0]] and K, N by
    mesh.shape[axes[1]] — and the mesh must be square on these two axes
    (production mesh: data=model=16).
    """
    axis_x, axis_y = axes
    p, p2 = mesh.shape[axis_x], mesh.shape[axis_y]
    if p != p2:
        raise ValueError(f"systolic matmul needs a square mesh, got {p}x{p2}")
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    for dim, div, what in ((m, p, "M"), (k, p, "K"), (n, p, "N")):
        if dim % div:
            raise ValueError(f"{what}={dim} not divisible by mesh dim {div}")
    out_dtype = out_dtype or jnp.result_type(a.dtype, b.dtype)
    return _systolic_jit(a, b, mesh, (axis_x, axis_y), out_dtype)
