"""Minimal structured metrics logging (stdout + optional JSONL file).

The optional file lane writes through `repro.obs.export.JsonlSink`, which
owns the handle: `close()` (or using the logger as a context manager)
releases it deterministically instead of leaking an open append handle for
the life of the process.  The printing API (`log` / `warn` / `summary`) is
unchanged — `train.loop` and the tests call it exactly as before.
"""

from __future__ import annotations

import json
import sys
import time
from typing import Any, Dict, Optional

from repro.obs.export import JsonlSink

__all__ = ["MetricsLogger"]


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, stream=None):
        self.path = path
        self.stream = stream or sys.stdout
        self._sink = JsonlSink(path) if path else None
        self.history: list = []

    @property
    def closed(self) -> bool:
        return self._sink.closed if self._sink else False

    def log(self, step: int, metrics: Dict[str, Any]) -> None:
        rec = {"step": step, "t": time.time(), **metrics}
        self.history.append(rec)
        short = " ".join(
            f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in metrics.items()
        )
        print(f"[step {step}] {short}", file=self.stream)
        if self._sink:
            self._sink.write(rec)

    def warn(self, msg: str) -> None:
        print(f"[warn] {msg}", file=self.stream)

    def summary(self, info: Dict[str, Any]) -> None:
        print(f"[summary] {json.dumps(info)}", file=self.stream)
        if self._sink:
            self._sink.write({"summary": info})

    def close(self) -> None:
        if self._sink:
            self._sink.close()

    def __enter__(self) -> "MetricsLogger":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
