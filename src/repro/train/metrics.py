"""Minimal structured metrics logging (stdout + optional JSONL file)."""

from __future__ import annotations

import json
import sys
import time
from typing import Any, Dict, Optional

__all__ = ["MetricsLogger"]


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, stream=None):
        self.path = path
        self.stream = stream or sys.stdout
        self._fh = open(path, "a") if path else None
        self.history: list = []

    def log(self, step: int, metrics: Dict[str, Any]) -> None:
        rec = {"step": step, "t": time.time(), **metrics}
        self.history.append(rec)
        short = " ".join(
            f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in metrics.items()
        )
        print(f"[step {step}] {short}", file=self.stream)
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()

    def warn(self, msg: str) -> None:
        print(f"[warn] {msg}", file=self.stream)

    def summary(self, info: Dict[str, Any]) -> None:
        print(f"[summary] {json.dumps(info)}", file=self.stream)
        if self._fh:
            self._fh.write(json.dumps({"summary": info}) + "\n")
            self._fh.flush()
