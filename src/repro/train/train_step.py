"""Train/serve step factories — the functions the launcher lowers with pjit.

`make_train_step(model, ...)` returns a pure (state, batch) -> (state, metrics)
function: value_and_grad over `model.loss`, global-norm clipping, AdamW with a
schedule.  State = {"params", "opt", "step"}.  Under pjit the DP gradient
all-reduce is implicit in the sharded loss mean; the int8 error-feedback
variant (`make_dp_train_step_compressed`) expresses the data-parallel outer
loop with shard_map so the compressed all-reduce is explicit (used by
examples/tests; see parallel/compression.py).

`make_serve_step(model)` returns (params, tokens, state, pos) ->
(next_tokens, state): one greedy decode step — the function behind the
decode_32k / long_500k dry-run cells.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import ShardCtx
from repro.models.registry import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.parallel.compression import compressed_pmean_tree, init_error_state
from repro.parallel.sharding import shard_map

__all__ = [
    "init_train_state",
    "make_train_step",
    "make_serve_step",
    "make_prefill_step",
    "make_dp_train_step_compressed",
]


def init_train_state(model: Model, key: jax.Array) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params), "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(model: Model) -> Dict[str, Any]:
    """ShapeDtypeStruct state tree for dry-run lowering (no allocation)."""
    params = model.abstract_params()
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {
        "params": params,
        "opt": {
            "m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        },
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_train_step(
    model: Model,
    schedule: Callable[[jax.Array], jax.Array],
    adamw_cfg: AdamWConfig = AdamWConfig(),
    ctx: ShardCtx = ShardCtx(),
    grad_accum: int = 1,
) -> Callable:
    """grad_accum > 1: microbatch gradient accumulation — the global batch is
    split on its leading dim and scanned, with an f32 grad accumulator sharded
    like the params (FSDP).  This bounds both the attention-score working set
    and the remat-carrier residency per microbatch (DESIGN.md §4)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch, ctx
        )
        del loss
        return grads, metrics

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            grads, metrics = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda t: t.reshape(grad_accum, t.shape[0] // grad_accum, *t.shape[1:]),
                batch,
            )

            def body(acc, mb):
                g, m = grads_of(params, mb)
                acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), acc, g)
                return acc, m

            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, mstack = jax.lax.scan(body, acc0, micro)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            metrics = jax.tree.map(lambda m: m.mean(), mstack)
        lr = schedule(state["opt"]["count"])
        new_params, new_opt, gnorm = adamw_update(
            grads, state["opt"], params, lr, adamw_cfg
        )
        metrics = {**metrics, "grad_norm": gnorm, "lr": lr}
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def make_prefill_step(model: Model, ctx: ShardCtx = ShardCtx()) -> Callable:
    def prefill_step(params, batch):
        logits, state = model.prefill(params, batch, ctx)
        # next-token from the last position — the serving handoff artifact
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, state

    return prefill_step


def make_serve_step(model: Model, ctx: ShardCtx = ShardCtx()) -> Callable:
    def serve_step(params, tokens, state, pos):
        logits, new_state = model.decode(params, tokens, state, pos, ctx)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, new_state

    return serve_step


def make_dp_train_step_compressed(
    model: Model,
    schedule: Callable,
    mesh,
    adamw_cfg: AdamWConfig = AdamWConfig(),
    dp_axes: Tuple[str, ...] = ("data",),
) -> Callable:
    """Data-parallel train step with explicit int8 error-feedback all-reduce.

    State additionally carries {"err": residual tree}.  Params/opt replicated;
    batch sharded over dp_axes.  For pure-DP meshes (examples/tests).
    """

    def local_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        return grads, metrics

    def step_fn(state, batch):
        def body(params, opt, step, err, batch):
            # err leaves carry a leading per-device axis (dp, *param_shape),
            # sharded over dp_axes -> each rank sees its own (1, ...) residual.
            err_local = jax.tree.map(lambda e: e[0], err)
            grads, metrics = local_grads(params, batch)
            mean_grads, new_err = compressed_pmean_tree(grads, err_local, dp_axes)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, dp_axes), metrics)
            lr = schedule(opt["count"])
            new_params, new_opt, gnorm = adamw_update(mean_grads, opt, params, lr, adamw_cfg)
            new_err = jax.tree.map(lambda e: e[None], new_err)
            return new_params, new_opt, step + 1, new_err, {**metrics, "grad_norm": gnorm}

        pspec_rep = jax.tree.map(lambda _: P(), state["params"])
        opt_rep = jax.tree.map(lambda _: P(), state["opt"])
        err_spec = jax.tree.map(lambda _: P(dp_axes), state["err"])
        batch_spec = jax.tree.map(lambda _: P(dp_axes), batch)
        mapped = shard_map(
            body,
            mesh=mesh,
            in_specs=(pspec_rep, opt_rep, P(), err_spec, batch_spec),
            out_specs=(pspec_rep, opt_rep, P(), err_spec, jax.tree.map(lambda _: P(), {"loss": 0, "accuracy": 0, "lb_loss": 0, "router_z": 0, "grad_norm": 0})),
            check_vma=False,
        )
        new_params, new_opt, new_step, new_err, metrics = mapped(
            state["params"], state["opt"], state["step"], state["err"], batch
        )
        return {"params": new_params, "opt": new_opt, "step": new_step, "err": new_err}, metrics

    return step_fn


def init_dp_train_state_compressed(
    model: Model, key: jax.Array, mesh=None, dp_axes: Tuple[str, ...] = ("data",)
) -> Dict[str, Any]:
    """State with per-rank error residuals: err leaves are (dp, *param_shape)."""
    state = init_train_state(model, key)
    dp = 1
    if mesh is not None:
        for a in dp_axes:
            dp *= mesh.shape.get(a, 1)
    err = init_error_state(state["params"])
    state["err"] = jax.tree.map(
        lambda e: jnp.broadcast_to(e[None], (dp,) + e.shape), err
    )
    return state
