from repro.train.loop import LoopConfig, train_loop
from repro.train.metrics import MetricsLogger
from repro.train.train_step import (
    abstract_train_state,
    init_train_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

__all__ = [
    "LoopConfig",
    "train_loop",
    "MetricsLogger",
    "init_train_state",
    "abstract_train_state",
    "make_train_step",
    "make_serve_step",
    "make_prefill_step",
]
