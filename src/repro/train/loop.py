"""Fault-tolerant training loop.

Mechanics (all exercised by tests/test_fault_tolerance.py):
  * periodic checkpoints (sync or async) + auto-resume from latest,
  * crash recovery: a step that raises is retried from the last checkpoint
    (up to max_restarts); the deterministic step-indexed data pipeline makes
    recovery bit-exact,
  * straggler mitigation: per-step wall-clock deadline; slow steps are logged
    and counted (on real fleets the same hook triggers hot-spare swap),
  * failure injection hook for tests (`failure_hook(step) -> None|raise`).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.train.metrics import MetricsLogger

__all__ = ["LoopConfig", "train_loop"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    max_restarts: int = 3
    step_deadline_s: Optional[float] = None  # straggler threshold
    log_every: int = 10


def train_loop(
    train_step: Callable,
    state: Dict[str, Any],
    data_iter,
    cfg: LoopConfig,
    ckpt: Optional[CheckpointManager] = None,
    logger: Optional[MetricsLogger] = None,
    failure_hook: Optional[Callable[[int], None]] = None,
    checkpointer=None,  # optional AsyncCheckpointer wrapping `ckpt`
) -> Dict[str, Any]:
    """Runs to cfg.total_steps; returns the final state.

    `data_iter` must expose .state()/.restore(step) (see data/pipeline.py);
    checkpoint metadata records the data position so resume is exact.
    """
    owns_logger = logger is None
    logger = logger or MetricsLogger()
    step = int(jax.device_get(state["step"]))
    restarts = 0
    stragglers = 0

    def save(step_i: int) -> None:
        if ckpt is None:
            return
        meta = {"data_step": data_iter.state()}
        if checkpointer is not None:
            checkpointer.submit(step_i, state, meta)
        else:
            ckpt.save(step_i, state, meta)

    while step < cfg.total_steps:
        try:
            if failure_hook is not None:
                failure_hook(step)
            batch = next(data_iter)
            t0 = time.monotonic()
            state, metrics = train_step(state, batch)
            jax.block_until_ready(state["step"])
            dt = time.monotonic() - t0
            if cfg.step_deadline_s is not None and dt > cfg.step_deadline_s:
                stragglers += 1
                logger.warn(
                    f"straggler: step {step} took {dt:.3f}s "
                    f"(deadline {cfg.step_deadline_s}s) — count={stragglers}"
                )
            step += 1
            if step % cfg.log_every == 0 or step == cfg.total_steps:
                logger.log(step, jax.tree.map(lambda m: float(jax.device_get(m)), metrics))
            if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                save(step)
        except KeyboardInterrupt:
            raise
        except Exception as e:  # crash recovery path
            restarts += 1
            if ckpt is None or restarts > cfg.max_restarts:
                raise
            if checkpointer is not None:
                checkpointer.wait()
            latest = ckpt.latest_step()
            logger.warn(
                f"step {step} failed ({type(e).__name__}: {e}); "
                f"restoring step {latest} (restart {restarts}/{cfg.max_restarts})"
            )
            if latest is None:
                raise
            state = ckpt.restore(latest, state)
            data_iter.restore(ckpt.meta(latest)["data_step"])
            step = latest
    if checkpointer is not None:
        checkpointer.wait()
    logger.summary({"restarts": restarts, "stragglers": stragglers, "final_step": step})
    if owns_logger:
        logger.close()  # a caller-provided logger stays open for the caller
    return state
