import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline inputs from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/]

Per cell this script:
  1. builds make_production_mesh(multi_pod=...),
  2. constructs abstract inputs (ShapeDtypeStructs — zero allocation) and
     NamedShardings from the model's logical-axis trees,
  3. jit(...).lower(...).compile() for the cell's entry point
     (train_step / prefill_step / serve_step per DESIGN.md §6),
  4. prints compiled.memory_analysis() + cost_analysis() and parses collective
     traffic from the HLO (launch/hlo_stats.py),
  5. writes artifacts/<mesh>/<arch>__<shape>.json for launch/roofline.py.

Skip rules (DESIGN.md §5): long_500k only for supports_long_context archs.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh
from repro.models import ShardCtx, get_model
from repro.optim import AdamWConfig, warmup_cosine
from repro.parallel.sharding import DEFAULT_RULES, ShardingRules, tree_shardings
from repro.train.train_step import (
    abstract_train_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

__all__ = ["run_cell", "input_specs"]


def _rules_for(cfg, shape, mesh, tuned: bool = False) -> ShardingRules:
    """Per-cell sharding rules (DESIGN.md §4/§6).

    tuned=True layers on the §Perf winners: Megatron-SP remat carriers for
    train, and sequence-parallel attention wherever heads don't divide TP.
    """
    rules = DEFAULT_RULES
    tp = mesh.shape.get("model", 1)
    if shape.kind in ("decode", "long_decode"):
        if cfg.num_kv_heads % tp:
            # GQA kv heads don't divide TP: shard the cache length instead (SP)
            rules = rules.replace(kv_heads=None, kv_seq="model")
    if shape.kind == "long_decode":
        # B=1: no batch sharding; stream the huge KV/state over DP axes too
        rules = rules.replace(batch=None, kv_batch=None, kv_seq=("pod", "data"))
        if cfg.num_kv_heads % tp == 0:
            rules = rules.replace(kv_heads="model")
    if tuned:
        if shape.kind == "train":
            rules = rules.replace(seq_sp="model")
        if shape.kind in ("train", "prefill") and cfg.num_heads % tp:
            rules = rules.replace(seq_attn="model")
    return rules


def input_specs(arch: str, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the cell's entry point."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = get_model(cfg)
    if shape.kind in ("train", "prefill"):
        batch, axes = model.batch_specs(shape)
        return {"batch": batch, "batch_axes": axes}
    tokens, state, pos, axes = model.decode_input_specs(shape)
    return {"tokens": tokens, "state": state, "pos": pos, "state_axes": axes}


def _cell_applicable(cfg, shape) -> Optional[str]:
    if shape.kind == "long_decode" and not cfg.supports_long_context:
        return (
            "N/A: pure full-attention arch — long_500k requires sub-quadratic "
            "attention (skip recorded per DESIGN.md §5)"
        )
    return None


def build_lowered(cfg, shape, mesh, rules, param_rules=None):
    """Build + lower the cell's entry point for an explicit config (no compile).

    Shared by the baseline dry-run and the cost-probe lowerings (which pass a
    reduced-depth, scan-unrolled variant of the same config).

    param_rules: separate logical->physical table for params + optimizer state
    (e.g. PARAM_RULES for FSDP: 'embed' additionally sharded over DP axes —
    XLA inserts the per-layer all-gathers).  Activations keep `rules`.
    """
    model = get_model(cfg)
    ctx = ShardCtx(mesh, rules)
    prules = param_rules or rules

    if shape.kind == "train":
        state = abstract_train_state(model)
        batch, batch_axes = model.batch_specs(shape)
        p_axes = model.logical_axes()
        params_abs = state["params"]
        state_sh = {
            "params": tree_shardings(p_axes, mesh, prules, params_abs),
            "opt": {
                "m": tree_shardings(p_axes, mesh, prules, params_abs),
                "v": tree_shardings(p_axes, mesh, prules, params_abs),
                "count": NamedSharding(mesh, P()),
            },
            "step": NamedSharding(mesh, P()),
        }
        batch_sh = {k: tree_shardings(batch_axes[k], mesh, rules, batch[k]) for k in batch}
        step_fn = make_train_step(
            model, warmup_cosine(3e-4, 100, 10_000), AdamWConfig(), ctx,
            grad_accum=getattr(cfg, "grad_accum", 1),
        )
        jitted = jax.jit(
            step_fn,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state, batch)
    elif shape.kind == "prefill":
        batch, batch_axes = model.batch_specs(shape)
        params = model.abstract_params()
        params_sh = tree_shardings(model.logical_axes(), mesh, prules, params)
        batch_sh = {k: tree_shardings(batch_axes[k], mesh, rules, batch[k]) for k in batch}
        step_fn = make_prefill_step(model, ctx)
        jitted = jax.jit(step_fn, in_shardings=(params_sh, batch_sh))
        lowered = jitted.lower(params, batch)
    else:  # decode / long_decode
        tokens, dstate, pos, state_axes = model.decode_input_specs(shape)
        params = model.abstract_params()
        params_sh = tree_shardings(model.logical_axes(), mesh, prules, params)
        state_sh = {k: tree_shardings(state_axes[k], mesh, rules, dstate[k]) for k in dstate}
        tok_sh = tree_shardings(("batch", None), mesh, rules, tokens)
        next_sh = tree_shardings(
            ("batch",), mesh, rules, jax.ShapeDtypeStruct(tokens.shape[:1], jnp.int32)
        )
        step_fn = make_serve_step(model, ctx)
        jitted = jax.jit(
            step_fn,
            in_shardings=(params_sh, tok_sh, state_sh, NamedSharding(mesh, P())),
            out_shardings=(next_sh, state_sh),
            donate_argnums=(2,),
        )
        lowered = jitted.lower(params, tokens, dstate, pos)
    return lowered


# --- cost probe ------------------------------------------------------------
# XLA cost_analysis counts a while-loop body ONCE regardless of trip count,
# so a scanned L-layer model under-reports flops/bytes/collectives by ~L x.
# Fix: lower the SAME cell at two reduced depths k1 < k2 with the layer scans
# fully UNROLLED (cfg.scan_unroll), fit the per-depth-unit slope, and
# extrapolate to the full depth.  The full-depth scanned compile is still what
# validates sharding + memory fit; the probe only corrects the cost terms.

PROBE_DEPTHS = (2, 4)


def _probe_cfg(cfg, k: int):
    if cfg.family == "hybrid":
        # depth unit = one (period x mamba + shared-attn) segment
        return dataclasses.replace(
            cfg, num_layers=k * cfg.shared_attn_period, scan_unroll=True
        )
    if cfg.family == "audio":
        # enc and dec scale together (enc_layers == dec_layers for whisper)
        return dataclasses.replace(
            cfg, num_layers=k, enc_layers=k, dec_layers=k, scan_unroll=True
        )
    return dataclasses.replace(cfg, num_layers=k, scan_unroll=True)


def _full_depth_units(cfg) -> float:
    if cfg.family == "hybrid":
        # fractional tail segment approximates `tail` mamba layers (slightly
        # overcounts the shared block: 38 = 6*6 + 2 -> 6.33 units); noted in
        # EXPERIMENTS.md SS-Dry-run.
        return cfg.num_layers / cfg.shared_attn_period
    if cfg.family == "audio":
        return float(cfg.enc_layers)
    return float(cfg.num_layers)


def _normalize_cost(cost):
    """compiled.cost_analysis() returns a per-device LIST of dicts on jax
    0.4.x and a flat dict on newer releases — normalize to the dict."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _cost_triple(compiled) -> Dict[str, float]:
    cost = _normalize_cost(compiled.cost_analysis())
    coll = collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_link_bytes": sum(s["link_bytes"] for s in coll.values()),
    }


def probe_corrected_costs(cfg, shape, mesh, rules, param_rules=None) -> Dict[str, Any]:
    """Two reduced-depth unrolled compiles -> per-layer slope -> full-depth cost."""
    k1, k2 = PROBE_DEPTHS
    c1 = _cost_triple(
        build_lowered(_probe_cfg(cfg, k1), shape, mesh, rules, param_rules).compile()
    )
    c2 = _cost_triple(
        build_lowered(_probe_cfg(cfg, k2), shape, mesh, rules, param_rules).compile()
    )
    full = _full_depth_units(cfg)
    out: Dict[str, Any] = {"probe_depths": [k1, k2], "full_depth_units": full}
    # grad_accum wraps the whole microbatch pass in ANOTHER while loop (also
    # counted once) -> scale by the accumulation factor (slightly overcounts
    # the single optimizer update, conservative).
    ga = max(1, getattr(cfg, "grad_accum", 1))
    for key in ("flops", "bytes", "coll_link_bytes"):
        slope = (c2[key] - c1[key]) / (k2 - k1)
        out[key] = (c1[key] + max(0.0, full - k1) * slope) * ga
        out[key + "_per_unit"] = slope
    return out


def recurrence_traffic_analytic(cfg, shape, mesh, rules) -> float:
    """HBM bytes/device of sequential recurrent-state updates NOT visible to
    the probe (the time scans' bodies are also counted once by cost_analysis).

    rwkv6 (ssm): the faithful WKV scan carries a (B_loc, H, K, V) f32 state
    through T per-token steps per layer -> L*T*2*state_bytes (x3 for train:
    fwd + remat-recompute + bwd state grads).
    zamba2 (hybrid): SSD is chunk-parallel; only the inter-chunk carry scan is
    sequential -> L*(T/chunk)*2*state_bytes.
    Transformer families: no sequential recurrence -> 0.
    """
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    # local batch after sharding ('batch' -> DP axes unless rules dropped it)
    phys = rules.get("batch")
    dp = 1
    if phys is not None:
        for a in (phys,) if isinstance(phys, str) else phys:
            dp *= mesh.shape.get(a, 1)
    b_loc = max(1, shape.global_batch // dp)
    t_len = shape.seq_len if shape.kind in ("train", "prefill") else 1
    train_mult = 3.0 if shape.kind == "train" else 1.0
    if cfg.family == "ssm":
        h, hd = cfg.num_heads, cfg.head_dim_
        state_bytes = b_loc * h * hd * hd * 4
        if getattr(cfg, "wkv_chunked", False) and t_len > 1:
            # chunk-parallel WKV (models/rwkv._wkv_chunked): per chunk, the
            # state is touched twice and the (C, C, K) decay tensor + (C, C)
            # attention block are materialized once each (r+w).
            c = cfg.wkv_chunk
            nc = max(1, t_len // c)
            d_block = b_loc * c * c * h * hd * 4  # exp(diff) tensor, f32
            a_block = b_loc * c * c * h * 4
            per_chunk = 2 * state_bytes + 2 * (d_block + a_block)
            return float(cfg.num_layers * nc * per_chunk * train_mult)
        steps = t_len
    else:
        d_in = cfg.ssm_expand * cfg.d_model
        state_bytes = b_loc * d_in * cfg.ssm_state_size * 4
        steps = max(1, t_len // 128)  # ssm.py _CHUNK
    return float(cfg.num_layers * steps * 2 * state_bytes * train_mult)


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    rules_override: Optional[ShardingRules] = None,
    param_rules: Optional[ShardingRules] = None,
    remat: Optional[str] = None,
    cfg_overrides: Optional[Dict[str, Any]] = None,
    tuned: bool = False,
    probe: bool = True,
    verbose: bool = True,
) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh) cell; returns the artifact dict."""
    cfg = get_config(arch)
    if tuned:
        cfg = cfg.tuned()
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat_policy=remat)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    skip = _cell_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    art: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
    }
    if skip:
        art["status"] = "skipped"
        art["reason"] = skip
        if verbose:
            print(f"[{mesh_name}] {arch} x {shape_name}: SKIP ({skip})")
        return art

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rules = rules_override or _rules_for(cfg, shape, mesh, tuned=tuned)
    if tuned and param_rules is None and shape.kind == "train":
        from repro.parallel.sharding import PARAM_RULES

        param_rules = PARAM_RULES  # FSDP params+opt (fit + §Perf A1/C2)
    model = get_model(cfg)

    t0 = time.monotonic()
    lowered = build_lowered(cfg, shape, mesh, rules, param_rules)
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    cost = _normalize_cost(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    art.update(
        status="ok",
        n_devices=n_dev,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        memory_analysis={
            k: int(getattr(mem, k, 0))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            )
        }
        if mem is not None
        else {},
        collectives=coll,
        collective_link_bytes=sum(s["link_bytes"] for s in coll.values()),
        n_params=model_param_count(model),
        n_active_params=cfg.n_active_params(),
        tokens_per_step=shape.global_batch
        * (shape.seq_len if shape.kind in ("train", "prefill") else 1),
    )
    if probe:
        t0 = time.monotonic()
        pr = probe_corrected_costs(cfg, shape, mesh, rules, param_rules)
        art["probe"] = pr
        art["flops_per_device_corrected"] = pr["flops"]
        art["bytes_per_device_corrected"] = pr["bytes"]
        art["collective_link_bytes_corrected"] = pr["coll_link_bytes"]
        art["recurrence_bytes_analytic"] = recurrence_traffic_analytic(
            cfg, shape, mesh, rules
        )
        art["probe_s"] = round(time.monotonic() - t0, 2)
    if verbose:
        ma = art["memory_analysis"]
        print(
            f"[{mesh_name}] {arch} x {shape_name}: OK "
            f"compile={t_compile:.1f}s flops/dev={art['flops_per_device']:.3e} "
            f"bytes/dev={art['bytes_per_device']:.3e} "
            f"args/dev={ma.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
            f"temp/dev={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
            f"coll_link_bytes/dev={art['collective_link_bytes']:.3e}"
        )
        print(f"  memory_analysis: {ma}")
        ca_keys = {k: v for k, v in sorted(cost.items()) if isinstance(v, float) and v}
        print(f"  cost_analysis: { {k: f'{v:.3e}' for k, v in list(ca_keys.items())[:8]} }")
        print(f"  collectives: { {k: int(v['count']) for k, v in coll.items()} }")
    return art


def model_param_count(model) -> int:
    import numpy as np

    return int(
        sum(np.prod(l.shape) for l in jax.tree.leaves(model.abstract_params()))
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--tuned", action="store_true",
                    help="apply §Perf winners (cfg.tuned() + SP/seq_attn/FSDP rules)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi_pod in meshes:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        os.makedirs(os.path.join(args.out, mesh_name), exist_ok=True)
        for arch, shape in cells:
            path = os.path.join(args.out, mesh_name, f"{arch}__{shape}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[{mesh_name}] {arch} x {shape}: exists, skip")
                continue
            try:
                # probe corrects cost terms for the (single-pod) roofline table;
                # multi-pod cells only validate sharding/compile -> skip probe.
                art = run_cell(
                    arch, shape, multi_pod=multi_pod, remat=args.remat,
                    tuned=args.tuned, probe=not multi_pod,
                )
            except Exception as e:
                traceback.print_exc()
                art = {
                    "arch": arch,
                    "shape": shape,
                    "mesh": mesh_name,
                    "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                }
                failures.append((mesh_name, arch, shape))
            with open(path, "w") as f:
                json.dump(art, f, indent=1)
    if failures:
        print(f"\nFAILED cells ({len(failures)}):")
        for f3 in failures:
            print("  ", *f3)
        raise SystemExit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
