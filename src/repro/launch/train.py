"""End-to-end training driver.

Runs any assigned architecture (full config on a production mesh under pjit,
or `--reduced` on whatever devices exist — the CPU path used by tests and
examples), with the full fault-tolerance stack: atomic/async checkpoints,
`--resume auto`, deterministic resumable data, straggler logging.

Examples
--------
  # CPU: train the paper demo config for 200 steps
  PYTHONPATH=src python -m repro.launch.train --arch mesh-paper-demo \
      --steps 200 --batch 8 --seq 128

  # CPU: reduced olmoe with checkpointing + crash-resume
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --reduced \
      --steps 100 --ckpt-dir /tmp/ckpt --resume auto
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.async_writer import AsyncCheckpointer
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import ShardCtx, get_model
from repro.optim import AdamWConfig, warmup_cosine
from repro.parallel.sharding import DEFAULT_RULES, tree_shardings
from repro.train.loop import LoopConfig, train_loop
from repro.train.metrics import MetricsLogger
from repro.train.train_step import init_train_state, make_train_step

__all__ = ["main", "build_trainer"]


def build_trainer(
    cfg,
    *,
    batch: int,
    seq: int,
    mesh=None,
    lr: float = 3e-4,
    total_steps: int = 1000,
    grad_accum: int = 1,
    seed: int = 0,
):
    """Construct (train_step_fn, state, data_iter) for a config.

    With `mesh`, the step is jitted with NamedShardings from the model's
    logical axes (the same path the dry-run lowers); without, plain jit.
    """
    model = get_model(cfg)
    key = jax.random.PRNGKey(seed)
    schedule = warmup_cosine(lr, min(100, total_steps // 10 + 1), total_steps)
    ctx = ShardCtx(mesh, DEFAULT_RULES) if mesh is not None else ShardCtx()
    step_fn = make_train_step(model, schedule, AdamWConfig(), ctx, grad_accum=grad_accum)

    state = init_train_state(model, key)
    if mesh is not None:
        p_axes = model.logical_axes()
        state_sh = {
            "params": tree_shardings(p_axes, mesh, DEFAULT_RULES, state["params"]),
            "opt": {
                "m": tree_shardings(p_axes, mesh, DEFAULT_RULES, state["params"]),
                "v": tree_shardings(p_axes, mesh, DEFAULT_RULES, state["params"]),
                "count": jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            },
            "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        }
        state = jax.device_put(state, state_sh)
        step_fn = jax.jit(step_fn, in_shardings=(state_sh, None), out_shardings=(state_sh, None), donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch, seed=seed))
    return step_fn, state, data


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="CPU-smoke dims")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--resume", default=None, choices=(None, "auto"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="none", choices=("none", "local-dp", "prod"),
                    help="'prod' requires a 256-device runtime (dry-run covers it offline)")
    ap.add_argument("--step-deadline-s", type=float, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family in ("audio", "vlm"):
        raise SystemExit(f"{args.arch}: synthetic LM trainer covers token-LM families; "
                         "see tests/test_models_smoke.py for audio/vlm train steps")

    mesh = None
    if args.mesh == "local-dp":
        mesh = make_local_mesh((jax.device_count(), 1), ("data", "model"))
    elif args.mesh == "prod":
        mesh = make_production_mesh()

    step_fn, state, data = build_trainer(
        cfg, batch=args.batch, seq=args.seq, mesh=mesh, lr=args.lr,
        total_steps=args.steps, grad_accum=args.grad_accum, seed=args.seed,
    )

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    writer = AsyncCheckpointer(ckpt) if (ckpt and args.async_ckpt) else None
    if ckpt and args.resume == "auto":
        latest = ckpt.latest_step()
        if latest is not None:
            print(f"[resume] restoring step {latest} from {args.ckpt_dir}")
            state = ckpt.restore(latest, state)
            data.restore(ckpt.meta(latest)["data_step"])

    loop_cfg = LoopConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        step_deadline_s=args.step_deadline_s,
        log_every=args.log_every,
    )
    logger = MetricsLogger()
    state = train_loop(step_fn, state, data, loop_cfg, ckpt=ckpt, logger=logger, checkpointer=writer)
    if writer is not None:
        writer.close()
    final_loss = logger.history[-1]["loss"] if logger.history else float("nan")
    print(f"[done] {args.arch} steps={args.steps} final_loss={final_loss:.4f}")


if __name__ == "__main__":
    main()
