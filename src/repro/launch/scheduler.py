"""Continuous-batching serve loop over a paged KV cache (DESIGN.md §12).

`launch/serve.generate` serves one batch at a time: every request in the
batch prefilled together, decoded in lockstep, and the whole batch held
until its slowest member finishes.  This module replaces that with the
serving loop the paper's repeated-product pipelining actually wants: a
fixed set of decode *slots* advances one token per tick, and sequences are
admitted into and retired out of slots **every step** — a finished request
frees its slot (and KV pages) immediately for the next queued request.

KV state is a paged pool per layer (fixed-size pages, per-sequence block
tables, host-side free-list allocator — `PageAllocator`), attended through
`kernels/paged_attention` (Pallas gather kernel on TPU, bitwise `_sdpa`
-mirroring XLA gather elsewhere).  Page 0 is reserved scratch: empty slots
carry an all-zero block table and harmlessly read/write it.

Robustness is the contract, built on PR 6's machinery (DESIGN.md §11):

  admission     bounded queue; overflow and never-fits requests are SHED
                (`serve.shed` ledger events), never queued forever
  deadlines     per-request tick budgets; expired requests — queued or
                running — are evicted and their pages reclaimed
                (`serve.timeout`)
  preemption    page-allocator exhaustion evicts the lowest-priority
                (youngest among ties) running sequence and retries
                (`serve.preempt`); a victimless failure evicts the
                requester itself, so the loop always makes progress
  fault sites   `serve.admit` (fires -> that request is shed),
                `serve.step` (fires -> the tick is skipped, not the
                server), `kv.page_alloc` (fires -> the allocation is
                deferred/stalled one tick and retried) — all wired into
                the `ci-default` chaos plan
  warmup        server start builds a guarded canary GEMM plan (consuming
                any armed plan.build / plan.execute / kernel.output
                triggers outside the serving traces) and pre-traces
                prefill + decode steps so no request pays a compile
  drain         `drain()` / context-manager exit runs the loop until every
                admitted request has retired (graceful shutdown)

Families: dense / moe / vlm serve through the paged path; ssm (rwkv)
carries its O(1) recurrent state stacked per slot — same admission /
deadline / shedding ladder, no pages to allocate.  hybrid / audio are not
schedulable here (enc-dec or mixed state) and are rejected up front.

The decode step is ONE jitted call at a fixed (max_slots,) shape — slot
occupancy changes never retrace — and pools are deliberately NOT donated:
a failed step leaves the pre-step pools intact, so a tick can be skipped
and retried (graceful degradation is worth the copy).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ShardCtx
from repro.obs import metrics as _metrics
from repro.obs import trace as _obs
from repro.resilience import faults, ledger

__all__ = [
    "ContinuousBatchingServer",
    "PageAllocator",
    "PagesExhausted",
    "Request",
    "RequestResult",
    "ServeConfig",
]

_SCHEDULABLE = ("dense", "moe", "vlm", "ssm")


class PagesExhausted(RuntimeError):
    """Free-list is smaller than the requested allocation."""


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Scheduler capacity + policy knobs (all counts, no wall-clock)."""

    max_slots: int = 4  # concurrent decode lanes (the batched step's S)
    page_size: int = 8  # tokens per KV page
    num_pages: int = 64  # pool size INCLUDING the reserved scratch page 0
    max_pages_per_seq: int = 8  # block-table width
    queue_capacity: int = 16  # bounded admission queue
    default_deadline: int = 512  # ticks from submission before eviction
    impl: Optional[str] = None  # paged-attention impl (None = capability door)
    interpret: bool = False  # Pallas interpret mode for the paged kernel
    warmup_prompt_lens: Tuple[int, ...] = ()  # prefill shapes to pre-trace

    def __post_init__(self):
        if self.max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {self.max_slots}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.num_pages < 2:
            raise ValueError(
                f"num_pages must be >= 2 (page 0 is reserved scratch), got"
                f" {self.num_pages}"
            )
        if self.max_pages_per_seq < 1 or self.queue_capacity < 1:
            raise ValueError(f"invalid capacities in {self}")


@dataclasses.dataclass(frozen=True)
class Request:
    rid: str
    prompt: np.ndarray  # (T,) int32 token ids
    max_new_tokens: int
    priority: int = 0  # higher survives preemption longer
    deadline: Optional[int] = None  # ticks from submission (None = config)
    arrival: int = 0  # tick at which `run()` submits this request


@dataclasses.dataclass
class RequestResult:
    rid: str
    status: str  # "ok" | "shed" | "timeout" | "preempted"
    tokens: List[int]  # generated tokens (possibly partial on eviction)
    reason: str = ""
    submitted_tick: int = -1
    finished_tick: int = -1
    latency_s: float = 0.0


class PageAllocator:
    """Host-side free-list over pool pages 1..num_pages-1 (0 = scratch).

    `alloc` is a fault site (`kv.page_alloc`): an injected failure surfaces
    exactly like transient exhaustion and the scheduler retries next tick.
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError(f"need >= 2 pages (page 0 is scratch), got {num_pages}")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, 0, -1))  # pop() -> 1 first

    @property
    def free_count(self) -> int:
        return len(self._free)

    def alloc(self, n: int, *, reason: str, rid: str = "") -> List[int]:
        faults.check("kv.page_alloc", reason=reason, rid=rid)
        if n > len(self._free):
            raise PagesExhausted(
                f"need {n} pages, {len(self._free)} free (rid={rid!r}, {reason})"
            )
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if not 0 < p < self.num_pages:
                raise ValueError(f"page {p} out of range (pool {self.num_pages})")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)


@dataclasses.dataclass(eq=False)
class _Seq:
    """One admitted sequence occupying a decode slot.

    Identity semantics (eq=False): membership checks against `_active` must
    mean "this exact sequence object is still live", never field equality.
    """

    req: Request
    slot: int
    pages: List[int]
    pos: int  # next write position == current length (incl. vlm patches)
    tokens: List[int]
    deadline_tick: int
    admit_tick: int
    submitted_tick: int
    submitted_at: float
    stalled: bool = False  # page-alloc fault this tick: skip, retry next


class ContinuousBatchingServer:
    """Admit/step/retire serving loop; see the module docstring.

    Typical use::

        server = ContinuousBatchingServer(model, params, ServeConfig(...))
        server.warmup()
        results = server.run(requests)      # or submit() + step() + drain()
    """

    def __init__(self, model, params, cfg: ServeConfig, ctx: ShardCtx = ShardCtx()):
        fam = model.cfg.family
        if fam not in _SCHEDULABLE:
            raise NotImplementedError(
                f"family {fam!r} is not schedulable (supported: {_SCHEDULABLE});"
                " audio is enc-dec (frames batch), hybrid carries mixed"
                " KV+conv state"
            )
        self.model = model
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self._paged = model.supports_paged  # dense/moe/vlm; ssm stacks state
        self._patch_offset = (
            model.cfg.num_stub_patches if fam == "vlm" else 0
        )
        self._tick = 0
        self._queue: List[Tuple[Request, int, float]] = []  # (req, tick, t_submit)
        self._active: List[_Seq] = []
        self._free_slots = list(range(cfg.max_slots - 1, -1, -1))
        self.results: Dict[str, RequestResult] = {}
        self.counters = {
            "served": 0, "shed": 0, "timeout": 0, "preempted": 0,
            "ticks": 0, "skipped_ticks": 0, "decode_tokens": 0,
        }
        # Obs instruments (DESIGN.md §14): the typed, label-aware mirror of
        # `self.counters` — process-global (labels aggregate across server
        # instances) where the dict above stays per-instance for tests.
        self._m_requests = _metrics.counter(
            "serve_requests_total", "request outcomes by status",
            labels=("status",),
        )
        self._m_admitted = _metrics.counter(
            "serve_admitted_total", "requests admitted into decode slots")
        self._m_ticks = _metrics.counter(
            "serve_ticks_total", "scheduler ticks by outcome",
            labels=("outcome",),
        )
        self._m_tokens = _metrics.counter(
            "serve_decode_tokens_total", "tokens produced by decode ticks")
        self._m_ttft = _metrics.histogram(
            "serve_ttft_seconds", "submission -> first token latency")
        self._m_tpot = _metrics.histogram(
            "serve_tpot_seconds", "per-tick decode wall time (time per token)")

        if self._paged:
            self.alloc = PageAllocator(cfg.num_pages)
            self.pools = {
                name: jnp.zeros(s.shape, s.dtype)
                for name, s in model.paged_pool_specs(
                    cfg.num_pages, cfg.page_size
                ).items()
            }
        else:
            self.alloc = None
            specs = model.decode_state_specs(cfg.max_slots, 0)
            self.state = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), specs
            )

        self._build_steps()

    # -- jitted steps (traced once; shapes never change across ticks) -------

    def _build_steps(self):
        model, ctx, cfg = self.model, self.ctx, self.cfg
        # Prefill shares launch/serve's per-(model, ctx) jitted-step cache:
        # the scheduler and the legacy driver reuse one trace per shape.
        from repro.launch.serve import serving_steps

        self._prefill, _ = serving_steps(model, ctx)

        if self._paged:
            impl, interpret = cfg.impl, cfg.interpret

            def decode(params, tokens, pools, block_tables, positions):
                logits, pools = model.paged_decode(
                    params, tokens, pools, block_tables, positions, ctx,
                    impl=impl, interpret=interpret,
                )
                nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
                return nxt, pools

            # NOT donated: a failed/skipped tick must leave pools intact.
            self._decode = jax.jit(decode)

            def scatter(pools, caches, pages):
                # caches: {"k","v"} (L, 1, T, KV, hd); pages: (n,) ids.
                # T is padded up to n*page_size; the zero tail is masked by
                # `lengths` in attention and overwritten as decode proceeds.
                def put(pool, c):
                    layers, _, t, kvh, hd = c.shape
                    n = pages.shape[0]
                    ps = pool.shape[2]
                    c2 = jnp.pad(c[:, 0], [(0, 0), (0, n * ps - t), (0, 0), (0, 0)])
                    return pool.at[:, pages].set(
                        c2.reshape(layers, n, ps, kvh, hd).astype(pool.dtype)
                    )

                return {
                    "k": put(pools["k"], caches["k"]),
                    "v": put(pools["v"], caches["v"]),
                }

            self._scatter = jax.jit(scatter)  # one trace per (T, n) pair
        else:

            def decode_ssm(params, tokens, state):
                # rwkv decode is position-free; state rows are per-slot.
                logits, state = model.decode(params, tokens, state, jnp.int32(0), ctx)
                nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
                return nxt, state

            self._decode = jax.jit(decode_ssm)

            def insert(state, new, slot):
                return jax.tree.map(
                    lambda st, nw: st.at[:, slot].set(nw[:, 0].astype(st.dtype)),
                    state,
                    new,
                )

            self._insert_state = jax.jit(insert)

    # -- capacity arithmetic -------------------------------------------------

    def _prefill_len(self, req: Request) -> int:
        return int(req.prompt.shape[0]) + self._patch_offset

    def _pages_for(self, length: int) -> int:
        return -(-length // self.cfg.page_size)  # ceil

    def _deadline_ticks(self, req: Request) -> int:
        # `is not None`, not truthiness: an explicit deadline=0 means "expire
        # immediately", not "use the default".
        return req.deadline if req.deadline is not None else self.cfg.default_deadline

    def _fits(self, req: Request) -> Optional[str]:
        """None if the request can ever be served, else the shed reason."""
        total = self._prefill_len(req) + req.max_new_tokens
        if not self._paged:
            return None
        if self._pages_for(total) > self.cfg.max_pages_per_seq:
            return "too_long:block_table"
        if self._pages_for(total) > self.cfg.num_pages - 1:
            return "too_long:pool"
        return None

    # -- lifecycle events ----------------------------------------------------

    def _finish(self, rid: str, status: str, tokens: List[int], *,
                reason: str, submitted_tick: int, submitted_at: float) -> None:
        self.results[rid] = RequestResult(
            rid=rid,
            status=status,
            tokens=tokens,
            reason=reason,
            submitted_tick=submitted_tick,
            finished_tick=self._tick,
            latency_s=time.monotonic() - submitted_at,
        )
        key = {"ok": "served", "shed": "shed", "timeout": "timeout",
               "preempted": "preempted"}[status]
        self.counters[key] += 1
        self._m_requests.inc(status=key)

    def _shed(self, req: Request, reason: str, *, submitted_tick: int,
              submitted_at: float) -> None:
        ledger.record("serve.shed", cause=reason, fallback="shed", rid=req.rid)
        self._finish(req.rid, "shed", [], reason=reason,
                     submitted_tick=submitted_tick, submitted_at=submitted_at)

    def _evict(self, seq: _Seq, status: str, reason: str) -> None:
        if self._paged and seq.pages:
            self.alloc.free(seq.pages)
            seq.pages = []  # retired sequences must never grow or double-free
        self._free_slots.append(seq.slot)
        self._active.remove(seq)
        self._finish(seq.req.rid, status, seq.tokens, reason=reason,
                     submitted_tick=seq.submitted_tick,
                     submitted_at=seq.submitted_at)

    # -- submission ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Enqueue a request; over-capacity and never-fits are shed NOW."""
        now = time.monotonic()
        if req.rid in self.results or any(
            q.rid == req.rid for q, _, _ in self._queue
        ) or any(s.req.rid == req.rid for s in self._active):
            raise ValueError(f"duplicate request id {req.rid!r}")
        reason = self._fits(req)
        if reason is not None:
            self._shed(req, reason, submitted_tick=self._tick, submitted_at=now)
            return
        if len(self._queue) >= self.cfg.queue_capacity:
            self._shed(req, "queue_full", submitted_tick=self._tick,
                       submitted_at=now)
            return
        self._queue.append((req, self._tick, now))

    # -- the tick ------------------------------------------------------------

    def step(self) -> None:
        """One scheduler tick: expire, admit, grow, decode, retire."""
        self._tick += 1
        self.counters["ticks"] += 1
        # The per-tick span nests everything the tick does (admission
        # prefills, the decode step) and costs one attribute check when
        # tracing is off; exports flush at drain/exit, never here.
        with _obs.span("serve.tick", tick=self._tick,
                       active=len(self._active), queued=len(self._queue)):
            try:
                faults.check("serve.step", tick=self._tick)
            except Exception as e:  # injected: skip the tick, keep the server
                ledger.record(
                    "serve.step",
                    cause=f"{type(e).__name__}: {e}",
                    fallback="skip_tick",
                    tick=self._tick,
                )
                self.counters["skipped_ticks"] += 1
                self._m_ticks.inc(outcome="skipped")
                return
            self._m_ticks.inc(outcome="ok")

            self._expire_deadlines()
            self._admit()
            self._ensure_pages()
            self._decode_tick()

    def _expire_deadlines(self) -> None:
        for seq in list(self._active):
            if self._tick >= seq.deadline_tick:
                ledger.record(
                    "serve.timeout", cause="deadline", fallback="evict",
                    rid=seq.req.rid, tick=self._tick,
                )
                self._evict(seq, "timeout", "deadline")
        still = []
        for req, tick, t0 in self._queue:
            ddl = tick + self._deadline_ticks(req)
            if self._tick >= ddl:
                ledger.record(
                    "serve.timeout", cause="deadline_queued", fallback="evict",
                    rid=req.rid, tick=self._tick,
                )
                self._finish(req.rid, "timeout", [], reason="deadline_queued",
                             submitted_tick=tick, submitted_at=t0)
            else:
                still.append((req, tick, t0))
        self._queue = still

    def _admit(self) -> None:
        while self._queue and self._free_slots:
            req, submitted_tick, submitted_at = self._queue[0]
            try:
                faults.check("serve.admit", rid=req.rid)
            except Exception as e:  # injected: this request is shed
                self._queue.pop(0)
                self._shed(req, f"{type(e).__name__}: {e}",
                           submitted_tick=submitted_tick,
                           submitted_at=submitted_at)
                continue

            prefill_len = self._prefill_len(req)
            pages: List[int] = []
            if self._paged:
                # Optimistic admission: pages for the prompt plus the first
                # decode token; growth pages are claimed tick by tick (and
                # contended through preemption).
                n0 = self._pages_for(prefill_len + 1)
                try:
                    pages = self.alloc.alloc(n0, reason="admit", rid=req.rid)
                except PagesExhausted:
                    break  # wait for retirements; deadline bounds the wait
                except Exception as e:  # injected: defer one tick
                    ledger.record(
                        "kv.page_alloc",
                        cause=f"{type(e).__name__}: {e}",
                        fallback="defer_admission",
                        rid=req.rid,
                    )
                    break

            self._queue.pop(0)
            slot = self._free_slots.pop()
            with _obs.span("serve.prefill", rid=req.rid, tokens=prefill_len):
                first_tok, state = self._run_prefill(req)
            self._m_admitted.inc()
            # TTFT: submission -> first token (prefill emits it greedily).
            self._m_ttft.observe(time.monotonic() - submitted_at)
            if self._paged:
                self.pools = self._scatter(
                    self.pools, state, jnp.asarray(pages, jnp.int32)
                )
            else:
                self.state = self._insert_state(
                    self.state, state, jnp.int32(slot)
                )
            seq = _Seq(
                req=req,
                slot=slot,
                pages=pages,
                pos=prefill_len,
                tokens=[int(first_tok[0])],
                deadline_tick=submitted_tick + self._deadline_ticks(req),
                admit_tick=self._tick,
                submitted_tick=submitted_tick,
                submitted_at=submitted_at,
            )
            self._active.append(seq)
            if len(seq.tokens) >= req.max_new_tokens:
                self._evict(seq, "ok", "")

    def _run_prefill(self, req: Request):
        cfg = self.model.cfg
        prompts = jnp.asarray(req.prompt, jnp.int32)[None, :]
        batch = {"tokens": prompts, "labels": prompts}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (1, cfg.num_stub_patches, cfg.d_model), cfg.adtype
            )
        return self._prefill(self.params, batch)

    def _ensure_pages(self) -> None:
        """Every active sequence needs page pos//page_size before decoding."""
        if not self._paged:
            return
        for seq in list(self._active):
            # An earlier sequence's _preempt_for may have evicted this one
            # (identity check: _Seq is eq=False); a retired sequence must not
            # claim fresh pages — they would leak — or preempt live peers.
            if seq not in self._active:
                continue
            seq.stalled = False
            needed = seq.pos // self.cfg.page_size + 1
            while len(seq.pages) < needed:
                try:
                    seq.pages += self.alloc.alloc(1, reason="grow", rid=seq.req.rid)
                except PagesExhausted:
                    if not self._preempt_for(seq):
                        # seq itself was the victim: stop growing IT, but the
                        # remaining active sequences still need their pages
                        # before this tick decodes (a missed growth here would
                        # silently write KV through scratch page 0).
                        break
                except faults.FaultError as e:
                    # Transient (injected) allocator failure: the sequence
                    # sits out this tick and retries, it is NOT evicted.
                    ledger.record(
                        "kv.page_alloc",
                        cause=f"{type(e).__name__}: {e}",
                        fallback="stall",
                        rid=seq.req.rid,
                    )
                    seq.stalled = True
                    break

    def _preempt_for(self, seq: _Seq) -> bool:
        """Evict the lowest-priority (youngest among ties) active sequence to
        free pages for `seq`.  Returns False iff `seq` itself was the victim
        (the caller must stop growing it)."""
        victim = min(self._active, key=lambda s: (s.req.priority, -s.admit_tick))
        ledger.record(
            "serve.preempt",
            cause="pages_exhausted",
            fallback="evict",
            rid=victim.req.rid,
            for_rid=seq.req.rid,
            tick=self._tick,
        )
        self._evict(victim, "preempted", f"pages_exhausted(for={seq.req.rid})")
        return victim is not seq

    def _decode_tick(self) -> None:
        ready = [s for s in self._active if not s.stalled]
        if not ready:
            return
        s_max = self.cfg.max_slots
        tokens = np.zeros((s_max, 1), np.int32)
        positions = np.zeros((s_max,), np.int32)
        for seq in ready:
            tokens[seq.slot, 0] = seq.tokens[-1]
            positions[seq.slot] = seq.pos
        # The decode span covers the jitted step AND the host sync
        # (np.asarray blocks), so its duration is the honest per-tick
        # decode wall time — the same number the tpot histogram records.
        t0 = time.monotonic()
        with _obs.span("serve.decode", slots=len(ready), tick=self._tick):
            if self._paged:
                tables = np.zeros((s_max, self.cfg.max_pages_per_seq), np.int32)
                for seq in ready:
                    tables[seq.slot, : len(seq.pages)] = seq.pages
                nxt, self.pools = self._decode(
                    self.params,
                    jnp.asarray(tokens),
                    self.pools,
                    jnp.asarray(tables),
                    jnp.asarray(positions),
                )
            else:
                nxt, self.state = self._decode(
                    self.params, jnp.asarray(tokens), self.state
                )
            nxt = np.asarray(nxt)
        self._m_tpot.observe(time.monotonic() - t0)
        for seq in ready:
            seq.tokens.append(int(nxt[seq.slot]))
            seq.pos += 1
            self.counters["decode_tokens"] += 1
            self._m_tokens.inc()
            if len(seq.tokens) >= seq.req.max_new_tokens:
                self._evict(seq, "ok", "")

    # -- driving -------------------------------------------------------------

    @property
    def pending(self) -> int:
        return len(self._queue) + len(self._active)

    def warmup(self) -> None:
        """Build the canary plan and pre-trace serving steps (no request
        pays a compile, and any armed plan.build / plan.execute /
        kernel.output fault triggers are consumed OUTSIDE the serving
        traces — a NaN poison lands in the guarded canary, not baked into
        the decode-step jit program)."""
        from repro.kernels import api

        # Pre-resolve the cost model's coefficients (one calibration-file
        # read, memoized) so plan-time auto decisions inside a serving tick
        # never touch the filesystem (DESIGN.md §13).
        try:
            from repro.costmodel import current_coefficients

            current_coefficients()
        except Exception:
            pass  # planner degrades to defaults on its own

        a = jnp.ones((8, 8), jnp.float32)
        canary = api.plan(
            api.GemmSpec.from_operands(a, a, blocks=(8, 8, 8)),
            guard_nonfinite="zero_and_record",
        )
        # Async dispatch (DESIGN.md §15): the cold compile proceeds in the
        # background while the prefill/decode warmups below build their own
        # traces; the handle is collected after.  The guarded canary
        # host-syncs inside execution anyway (documented dispatch caveat),
        # but the call path exercises plan.dispatch on every serve startup.
        cold = canary.dispatch(a, a)
        cold.block()
        # Second execution is compile-free: when tracing is on, its
        # plan.execute span is the warm sample the obs bridge feeds to
        # cost-model calibration (the cold first call is discarded).
        jax.block_until_ready(canary(a, a))

        for t in self.cfg.warmup_prompt_lens:
            dummy = Request(rid=f"__warmup_{t}", prompt=np.zeros(t, np.int32),
                            max_new_tokens=1)
            self._run_prefill(dummy)
        s_max = self.cfg.max_slots
        tokens = jnp.zeros((s_max, 1), jnp.int32)
        positions = jnp.zeros((s_max,), jnp.int32)
        if self._paged:
            # All-zero tables: the trace writes only the scratch page; the
            # updated pools are discarded.
            tables = jnp.zeros((s_max, self.cfg.max_pages_per_seq), jnp.int32)
            self._decode(self.params, tokens, self.pools, tables, positions)
        else:
            self._decode(self.params, tokens, self.state)

    def drain(self, *, max_ticks: int = 1_000_000) -> None:
        """Run until every admitted request has retired (graceful shutdown).
        Liveness is deadline-bounded: even permanently stalled sequences are
        evicted when their tick budget runs out."""
        ticks = 0
        while self.pending:
            self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError(f"drain exceeded {max_ticks} ticks")
        # Drain is the scheduler's I/O point (DESIGN.md §14): ticks never
        # touch the filesystem, so buffered span->calibration records are
        # folded into the cost-model cache here, after the loop empties.
        from repro.obs import bridge as _bridge

        if _bridge.installed():
            _bridge.flush_calibration()

    def run(self, requests: Sequence[Request]) -> Dict[str, RequestResult]:
        """Submit `requests` at their arrival ticks, drive to completion."""
        todo = sorted(requests, key=lambda r: r.arrival)
        i = 0
        while i < len(todo) or self.pending:
            while i < len(todo) and todo[i].arrival <= self._tick:
                self.submit(todo[i])
                i += 1
            self.step()
        return dict(self.results)

    def __enter__(self) -> "ContinuousBatchingServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.drain()
