"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS before first jax init, while tests/benches must see 1.
"""

from __future__ import annotations

import math
import os

import jax

try:  # jax >= 0.5 exposes AxisType; 0.4.x builds (e.g. 0.4.37) do not.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover — version-dependent
    AxisType = None

__all__ = ["make_production_mesh", "make_local_mesh", "forced_device_env", "PROD_TP"]

PROD_TP = 16  # 'model' axis size on the production meshes


def _make_mesh(shape, axes):
    """jax.make_mesh with axis_types when the installed jax supports it."""
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips multi-pod.

    Axes: ('data', 'model') single-pod, ('pod', 'data', 'model') multi-pod
    ('pod' composes with 'data' as outer DP; PP over 'pod' is available via
    parallel/pipeline.py but the graded dry-runs use DP x TP — DESIGN.md §4).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def forced_device_env(n_devices: int, *, pythonpath=("src",)) -> dict:
    """Environment for a subprocess that must see `n_devices` virtual CPU
    devices (multi-device tests/benches re-exec because the parent process
    already initialized jax at its own device count).

    Replaces any existing --xla_force_host_platform_device_count in XLA_FLAGS
    (appending would leave duplicate flags with parser-order semantics) and
    prepends `pythonpath` entries while keeping the inherited PYTHONPATH.
    """
    env = dict(os.environ)
    flags = [
        f
        for f in env.get("XLA_FLAGS", "").split()
        if not f.startswith("--xla_force_host_platform_device_count")
    ]
    env["XLA_FLAGS"] = " ".join(
        flags + [f"--xla_force_host_platform_device_count={n_devices}"]
    )
    inherited = env.get("PYTHONPATH")
    env["PYTHONPATH"] = os.pathsep.join(
        list(pythonpath) + ([inherited] if inherited else [])
    )
    return env


def make_local_mesh(shape, axes):
    """Small mesh over whatever devices exist (tests / CPU examples).

    Validates the request against the live runtime up front —
    `jax.make_mesh` otherwise fails with an opaque reshape/assignment error
    when the shape doesn't fit the device count.
    """
    shape, axes = tuple(shape), tuple(axes)
    if len(shape) != len(axes):
        raise ValueError(
            f"mesh shape {shape} and axis names {axes} must have equal rank"
        )
    need, have = math.prod(shape), jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh shape {shape} ({'x'.join(map(str, shape))} = {need} devices)"
            f" exceeds the {have} available {jax.default_backend()} device(s);"
            f" for CPU tests set"
            f" XLA_FLAGS=--xla_force_host_platform_device_count={need}"
            f" before the first jax call"
        )
    return _make_mesh(shape, axes)
