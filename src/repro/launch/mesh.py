"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS before first jax init, while tests/benches must see 1.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType

__all__ = ["make_production_mesh", "make_local_mesh", "PROD_TP"]

PROD_TP = 16  # 'model' axis size on the production meshes


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips multi-pod.

    Axes: ('data', 'model') single-pod, ('pod', 'data', 'model') multi-pod
    ('pod' composes with 'data' as outer DP; PP over 'pod' is available via
    parallel/pipeline.py but the graded dry-runs use DP x TP — DESIGN.md §4).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(shape, axes):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
