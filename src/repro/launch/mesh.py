"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS before first jax init, while tests/benches must see 1.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes AxisType; 0.4.x builds (e.g. 0.4.37) do not.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover — version-dependent
    AxisType = None

__all__ = ["make_production_mesh", "make_local_mesh", "PROD_TP"]

PROD_TP = 16  # 'model' axis size on the production meshes


def _make_mesh(shape, axes):
    """jax.make_mesh with axis_types when the installed jax supports it."""
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips multi-pod.

    Axes: ('data', 'model') single-pod, ('pod', 'data', 'model') multi-pod
    ('pod' composes with 'data' as outer DP; PP over 'pod' is available via
    parallel/pipeline.py but the graded dry-runs use DP x TP — DESIGN.md §4).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(shape, axes):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    return _make_mesh(shape, axes)
