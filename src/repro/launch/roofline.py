"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/<mesh>/<arch>__<shape>.json (written by launch/dryrun.py) and
derives, per cell:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs          [s]
    memory term     = HLO_bytes_per_device / HBM_bw              [s]
    collective term = collective_link_bytes_per_device / link_bw [s]

    MODEL_FLOPS  = 6·N·D (train, dense) / 6·N_active·D (train, MoE)
                   2·N(_active)·D for inference steps (fwd only)
    useful ratio = MODEL_FLOPS / (HLO_FLOPs · n_devices)
    roofline fraction = t_model / max(terms)
        where t_model = MODEL_FLOPS / (n_devices · peak) — the step time if
        only useful model FLOPs ran at MXU peak.  This single number is the
        score we hillclimb: <1 means the dominant structural term (wasted
        compute, HBM streaming, or ICI traffic) exceeds useful compute.

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline [--dir artifacts/pod16x16]
        [--md artifacts/roofline.md] [--json artifacts/roofline.json]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

__all__ = [
    "PEAK_FLOPS",
    "HBM_BW",
    "LINK_BW",
    "analyze_artifact",
    "analyze_dir",
    "analyze_plan",
    "render_markdown",
]

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9       # bytes/s per chip
LINK_BW = 50e9       # bytes/s per ICI link

_HINTS = {
    "compute": "reduce recompute (remat policy) / pick a lower-waste schedule — HLO FLOPs exceed the useful-model floor",
    "memory": "raise arithmetic intensity: fuse ops, larger per-chip tiles, avoid streaming weights/caches more than once",
    "collective": "reshard to cut ICI traffic: different TP axis placement, overlap/ring schedules, gradient compression",
    "collective(hidden)": "collective is the largest term but the schedule double-buffers it behind kernel calls — already hidden; cut link bytes to go faster",
}


def model_flops(art: Dict[str, Any]) -> float:
    """Useful-model FLOPs per step for the cell (whole job, not per device)."""
    n_active = art.get("n_active_params") or art.get("n_params") or 0
    kind = art.get("kind", "train")
    tokens = art.get("tokens_per_step")
    if tokens is None:
        # Reconstruct from the shape registry (artifacts written before the
        # tokens_per_step field was added).
        from repro.configs import SHAPES

        sh = SHAPES[art["shape"]]
        tokens = sh.global_batch * (sh.seq_len if kind in ("train", "prefill") else 1)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens


def analyze_artifact(art: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    """Compute roofline terms for one artifact dict; None for skipped cells."""
    if art.get("status") != "ok":
        return None
    n_dev = art["n_devices"]
    # Prefer probe-corrected costs (scan-body undercount fixed; see dryrun.py)
    flops = art.get("flops_per_device_corrected", art["flops_per_device"])
    byts = art.get("bytes_per_device_corrected", art["bytes_per_device"])
    byts += art.get("recurrence_bytes_analytic", 0.0)
    coll = art.get(
        "collective_link_bytes_corrected", art.get("collective_link_bytes", 0.0)
    )
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(art)
    t_model = mf / (n_dev * PEAK_FLOPS)
    hlo_total = flops * n_dev
    return {
        "arch": art["arch"],
        "shape": art["shape"],
        "mesh": art["mesh"],
        "kind": art["kind"],
        "n_devices": n_dev,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "t_bound_s": terms[dominant],
        "model_flops": mf,
        "useful_ratio": (mf / hlo_total) if hlo_total else 0.0,
        "roofline_fraction": (t_model / terms[dominant]) if terms[dominant] else 0.0,
        "hint": _HINTS[dominant],
    }


def analyze_plan(desc: Dict[str, Any]) -> Dict[str, Any]:
    """Roofline terms for ONE GEMM plan from its `Plan.describe()` record —
    per device, per call, at the TPU v5e constants.

    For a ShardedPlan the sharding provenance supplies per-shard FLOPs and
    the collective's bytes-moved, so the communication cost of a schedule is
    reportable before any profile exists (serve `--plan-stats`, the sharded
    bench).  Unsharded plans get a zero collective term through the same
    arithmetic.  Grouped plans (a "grouped" provenance record) decompose
    into per-group compute terms — rows stream once but every group's
    weight slab streams — plus the dispatch (scatter/gather routing) bytes;
    unknown record shapes degrade to the plain-GEMM arithmetic instead of
    raising.

    The byte/FLOP arithmetic lives in `costmodel.model.terms_from_describe`
    (the machine-usable `terms` dict is echoed back in the result for the
    cost model and calibration); this function adds the fixed TPU v5e
    constants, dominant-term classification, and tuning hints.
    """
    from repro.costmodel.model import terms_from_describe

    sh = desc.get("sharding") or {}
    grp = desc.get("grouped") or {}
    t = terms_from_describe(desc)
    flops, hbm_bytes, coll_bytes = t["flops"], t["hbm_bytes"], t["collective_bytes"]
    terms = {
        "compute": flops / PEAK_FLOPS,
        "memory": hbm_bytes / HBM_BW,
        "collective": coll_bytes / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    overlap = bool(t.get("overlap"))
    # An overlapped schedule hides the collective behind kernel calls: the
    # bound is max of all three terms (DESIGN.md §15), and a collective-
    # dominant cell gets the "already hidden" hint instead of the reshard one.
    if overlap:
        t_total = max(terms.values())
        hint_key = "collective(hidden)" if dominant == "collective" else dominant
    else:
        t_total = max(terms["compute"], terms["memory"]) + terms["collective"]
        hint_key = dominant
    out = {
        "backend": desc["backend"],
        "mkn": desc["mkn"],
        "schedule": sh.get("schedule"),
        "overlap": overlap,
        "per_shard_flops": flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": coll_bytes,
        "terms": t,
        "t_compute_s": terms["compute"],
        "t_memory_s": terms["memory"],
        "t_collective_s": terms["collective"],
        "dominant": dominant,
        "t_bound_s": terms[dominant],
        "t_total_s": t_total,
        "hint": _HINTS[hint_key],
    }
    if grp:
        out["grouped"] = {
            "num_groups": grp.get("num_groups"),
            "rows_per_group": grp.get("rows_per_group"),
            "per_group_flops": grp.get("per_group_flops"),
            "per_group_t_compute_s": grp.get("per_group_flops", 0) / PEAK_FLOPS,
            "dispatch_bytes": grp.get("dispatch_bytes", 0),
            "t_dispatch_s": grp.get("dispatch_bytes", 0) / HBM_BW,
        }
    return out


def analyze_dir(path: str) -> List[Dict[str, Any]]:
    rows, skips = [], []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        art = json.load(open(f))
        if not isinstance(art, dict) or "arch" not in art:
            continue
        r = analyze_artifact(art)
        if r is None:
            skips.append({"arch": art["arch"], "shape": art["shape"],
                          "status": art.get("status"), "reason": art.get("reason", art.get("error", ""))})
        else:
            rows.append(r)
    return rows + [{"skip": True, **s} for s in skips]


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def render_markdown(rows: List[Dict[str, Any]], title: str = "") -> str:
    out = []
    if title:
        out.append(f"### {title}\n")
    out.append("| arch | shape | compute | memory | collective | dominant | useful FLOP ratio | roofline frac |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("skip"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | {r['status'].upper()} | — | {r.get('reason','')[:60]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['t_compute_s'])} | {_fmt_s(r['t_memory_s'])} "
            f"| {_fmt_s(r['t_collective_s'])} | **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} |"
        )
    return "\n".join(out) + "\n"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/pod16x16")
    ap.add_argument("--md", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    rows = analyze_dir(args.dir)
    md = render_markdown(rows, title=f"Roofline — {args.dir}")
    print(md)
    live = [r for r in rows if not r.get("skip")]
    if live:
        worst = min(live, key=lambda r: r["roofline_fraction"])
        collb = [r for r in live if r["dominant"] == "collective"]
        print(f"worst roofline fraction: {worst['arch']} x {worst['shape']} = {worst['roofline_fraction']:.3f}")
        print(f"collective-bound cells: {[(r['arch'], r['shape']) for r in collb]}")
    if args.md:
        with open(args.md, "w") as f:
            f.write(md)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
