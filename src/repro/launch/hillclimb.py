import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
)

"""Perf hillclimb driver (EXPERIMENTS.md §Perf).

Three cells (worst roofline fraction / most collective-bound / most
paper-representative) with named variants, each a (sharding rules, param
rules, config override, remat) tuple.  Every variant is lowered + compiled +
probe-corrected exactly like the baseline sweep, so before/after numbers are
apples-to-apples.

A second, MEASURED lane hillclimbs the GEMM layer itself: `--gemm` times
the `GEMM_VARIANTS` through the plan/execute API (`kernels.api.plan` + the
autotuner's `measure_best_ms` — not the legacy ops entry points) and writes
each measurement in the cost-model calibration record format
({"terms", "ms", "source"}), so `costmodel.calibrate.ingest` folds them
into the coefficient fit (`--ingest` does it in the same run).

  PYTHONPATH=src python -m repro.launch.hillclimb [--cell A|B|C|D] [--variant NAME]
  PYTHONPATH=src python -m repro.launch.hillclimb --gemm [--ingest]
"""

import argparse
import json
from typing import Any, Dict, Optional

from repro.launch.dryrun import run_cell
from repro.launch.roofline import analyze_artifact
from repro.parallel.sharding import DEFAULT_RULES, PARAM_RULES, TRAIN_RULES

# variant := (arch, shape, dict(rules=…, param_rules=…, cfg=…, remat=…))
_FSDP = PARAM_RULES
_SP = TRAIN_RULES  # seq_sp -> 'model' (Megatron-SP remat carriers)
_SP_ATTN = TRAIN_RULES.replace(seq_attn="model")  # + context-parallel attention

CELLS: Dict[str, Dict[str, Any]] = {
    # A: most paper-representative — the largest dense-GEMM workload
    # (88 layers x 12288 wide); the paper's schedule is a GEMM schedule.
    "A": {
        "arch": "mistral-large-123b",
        "shape": "train_4k",
        "variants": {
            "A0_baseline": {},
            "A1_fsdp": {"param_rules": _FSDP},
            "A2_fsdp_sp": {"param_rules": _FSDP, "rules": _SP},
            "A3_fsdp_sp_flash": {
                "param_rules": _FSDP,
                "rules": _SP,
                "cfg": {"attn_chunk": 1024},
            },
            "A4_remat_none": {
                "param_rules": _FSDP,
                "rules": _SP,
                "cfg": {"attn_chunk": 1024},
                "remat": "none",
            },
            # fit pass: microbatching bounds activation residency; HBM must
            # land under 16 GiB/chip for the config to be deployable.
            "A5_fit_ga8": {
                "param_rules": _FSDP,
                "rules": _SP,
                "cfg": {"attn_chunk": 1024, "grad_accum": 8},
            },
            "A6_fit_ga16": {
                "param_rules": _FSDP,
                "rules": _SP,
                "cfg": {"attn_chunk": 1024, "grad_accum": 16},
            },
            # A7 REFUTED: ga=64 -> microbatch 4 < dp=16 -> batch axis can't
            # shard -> replicated activations (recorded in §Perf; kept for the log)
            "A7_fit_ga64": {
                "param_rules": _FSDP,
                "rules": _SP,
                "cfg": {"attn_chunk": 1024, "grad_accum": 64},
            },
            "A8_fit_rematfull_ga16": {
                "param_rules": _FSDP,
                "rules": _SP,
                "cfg": {"attn_chunk": 1024, "grad_accum": 16},
                "remat": "full",
            },
        },
    },
    # B: worst roofline fraction — O(S^2) attention bytes at S=32k, and
    # 40 heads %% 16 != 0 leaves attention UNSHARDED on the TP axis.
    "B": {
        "arch": "phi3-medium-14b",
        "shape": "prefill_32k",
        "variants": {
            "B0_baseline": {},
            "B1_flash": {"cfg": {"attn_chunk": 1024}},
            "B2_flash_seqattn": {
                "cfg": {"attn_chunk": 1024},
                "rules": DEFAULT_RULES.replace(seq_attn="model"),
            },
            "B3_flash_seqattn_c2048": {
                "cfg": {"attn_chunk": 2048},
                "rules": DEFAULT_RULES.replace(seq_attn="model"),
            },
        },
    },
    # C: most collective-bound (highest collective:compute ratio) + the
    # replicated-unembed pathology (vocab 49155 %% 16 != 0).
    "C": {
        "arch": "granite-3-8b",
        "shape": "train_4k",
        "variants": {
            "C0_baseline": {},
            "C1_vocabpad": {"cfg": {"vocab_pad_multiple": 256}},
            "C2_vocabpad_fsdp": {
                "cfg": {"vocab_pad_multiple": 256},
                "param_rules": _FSDP,
            },
            "C3_vocabpad_fsdp_sp_flash": {
                "cfg": {"vocab_pad_multiple": 256, "attn_chunk": 1024},
                "param_rules": _FSDP,
                "rules": _SP,
            },
            "C4_remat_none": {
                "cfg": {"vocab_pad_multiple": 256, "attn_chunk": 1024},
                "param_rules": _FSDP,
                "rules": _SP,
                "remat": "none",
            },
            "C5_fit_ga8": {
                "cfg": {
                    "vocab_pad_multiple": 256,
                    "attn_chunk": 1024,
                    "grad_accum": 8,
                },
                "param_rules": _FSDP,
                "rules": _SP,
            },
            "C6_fit_rematnone_ga8": {
                "cfg": {
                    "vocab_pad_multiple": 256,
                    "attn_chunk": 1024,
                    "grad_accum": 8,
                },
                "param_rules": _FSDP,
                "rules": _SP,
                "remat": "none",
            },
        },
    },
    # D (bonus, beyond-paper): rwkv6 train — the sequential WKV recurrence's
    # per-step state traffic dominates; chunked GEMM-form WKV fixes it.
    "D": {
        "arch": "rwkv6-1.6b",
        "shape": "train_4k",
        "variants": {
            "D0_baseline": {},
            "D1_wkv_chunked": {"cfg": {"wkv_chunked": True}},
            "D2_wkv_chunked_sp": {"cfg": {"wkv_chunked": True}, "rules": _SP},
            "D3_fit_ga8": {
                "cfg": {"wkv_chunked": True, "grad_accum": 8},
                "rules": _SP,
            },
        },
    },
}


# GEMM-layer variants measured through plan/execute: shapes spread to
# separate the FLOP term from fixed overhead, plus the paper regimes the
# cost model prices differently (symmetric early readout, repeated products).
GEMM_VARIANTS: Dict[str, Dict[str, Any]] = {
    "G0_tiny": {"mkn": (64, 64, 64)},
    "G1_cube256": {"mkn": (256, 256, 256)},
    "G2_cube512": {"mkn": (512, 512, 512)},
    "G3_wide_n": {"mkn": (128, 256, 1024)},
    "G4_symmetric": {"mkn": (256, 256, 256), "structure": "symmetric"},
    "G5_repeats8": {"mkn": (256, 256, 256), "repeats": 8},
}


def run_gemm_variant(
    name: str,
    out_dir: str = "artifacts/hillclimb",
    *,
    backend: Optional[str] = None,
    reps: int = 3,
):
    """Time one GEMM variant through the plan/execute API and write the
    measurement as a calibration record (`costmodel.calibrate` format).

    `repeats=r` variants execute the plan r times back to back against the
    same operands and record the per-product mean, matching the cost
    model's amortized per-call prediction."""
    import jax.numpy as jnp

    from repro.costmodel import current_coefficients, predict, terms_from_describe
    from repro.kernels import api
    from repro.kernels.autotune import measure_best_ms

    v = GEMM_VARIANTS[name]
    m, k, n = v["mkn"]
    repeats = int(v.get("repeats", 1))
    spec = api.GemmSpec(
        m=m, k=k, n=n, structure=v.get("structure", "general"), repeats=repeats
    )
    p = api.plan(spec, backend=backend)
    a = jnp.ones((m, k), jnp.float32)
    b = jnp.ones((k, n), jnp.float32)
    if repeats > 1:

        def run_repeated(a_, b_, bias_, res_):
            out = None
            for _ in range(repeats):
                out = p.executor(a_, b_, bias_, res_)
            return out

        ms = measure_best_ms(run_repeated, a, b, None, None, reps=reps) / repeats
    else:
        ms = measure_best_ms(p.executor, a, b, None, None, reps=reps)
    terms = terms_from_describe(p.describe())
    rec = {
        "terms": terms,
        "ms": ms,
        "source": "hillclimb",
        "key": f"{name}|{p.backend}",
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"gemm__{name}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    pred = predict(terms, current_coefficients())["total_s"] * 1e3
    print(
        f"{name:16s} {m}x{k}x{n} backend={p.backend:12s} "
        f"measured={ms:9.3f}ms predicted={pred:9.3f}ms "
        f"ratio={ms / pred if pred else float('inf'):6.2f}x"
    )
    return rec


def run_variant(cell: str, name: str, out_dir: str = "artifacts/hillclimb"):
    spec = CELLS[cell]
    v = spec["variants"][name]
    art = run_cell(
        spec["arch"],
        spec["shape"],
        rules_override=v.get("rules"),
        param_rules=v.get("param_rules"),
        cfg_overrides=v.get("cfg"),
        remat=v.get("remat"),
        probe=True,
        verbose=False,
    )
    art["variant"] = name
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{cell}__{name}.json")
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    r = analyze_artifact(art)
    ma = art.get("memory_analysis", {})
    hbm_gib = (ma.get("argument_size_in_bytes", 0) + ma.get("temp_size_in_bytes", 0)) / 2**30
    print(
        f"{name:28s} compute={r['t_compute_s']:8.3f}s memory={r['t_memory_s']:8.3f}s "
        f"collective={r['t_collective_s']:8.3f}s dominant={r['dominant']:10s} "
        f"useful={r['useful_ratio']:.3f} fraction={r['roofline_fraction']:.4f} "
        f"hbm={hbm_gib:.1f}GiB"
    )
    return art, r


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=sorted(CELLS))
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default="artifacts/hillclimb")
    ap.add_argument(
        "--gemm", action="store_true",
        help="run the measured GEMM variants (calibration-record output)",
    )
    ap.add_argument(
        "--ingest", action="store_true",
        help="fold the GEMM measurements into the costmodel calibration file",
    )
    args = ap.parse_args()
    if args.gemm:
        records = []
        names = [args.variant] if args.variant else list(GEMM_VARIANTS)
        for name in names:
            try:
                records.append(run_gemm_variant(name, args.out))
            except Exception as e:
                print(f"{name:16s} FAILED: {type(e).__name__}: {e}")
        if args.ingest and records:
            from repro.costmodel import ingest

            added = ingest(records)
            print(f"ingested {added} records into the calibration file")
        return
    cells = [args.cell] if args.cell else sorted(CELLS)
    for cell in cells:
        spec = CELLS[cell]
        print(f"\n== cell {cell}: {spec['arch']} x {spec['shape']}")
        names = [args.variant] if args.variant else list(spec["variants"])
        for name in names:
            try:
                run_variant(cell, name, args.out)
            except Exception as e:
                print(f"{name:28s} FAILED: {type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
