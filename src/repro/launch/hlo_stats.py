"""Parse collective traffic out of compiled (post-SPMD) HLO text.

`compiled.cost_analysis()` gives FLOPs and HBM bytes but NOT collective
traffic, so the roofline's third term comes from scanning the per-device HLO
for all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops, summing their payload bytes, and applying ring-cost multipliers:

    all-gather        (n-1)/n * result_bytes       per device through a link
    reduce-scatter    (n-1)/n * operand_bytes
    all-reduce        2 (n-1)/n * operand_bytes    (RS + AG)
    all-to-all        (n-1)/n * operand_bytes
    collective-permute  operand_bytes              (one neighbour hop)

n = replica-group size parsed per op.  Orthogonal-axis collectives could use
disjoint links concurrently; we conservatively serialize (documented in
EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

__all__ = ["collective_stats", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %all-reduce.5 = f32[16,1024]{1,0} all-reduce(f32[16,1024]{1,0} %x), ...
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([0-9,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * DTYPE_BYTES[dtype]


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Returns {op_kind: {"count", "payload_bytes", "link_bytes"}} (per device).

    link_bytes applies the ring multiplier; payload_bytes is the raw result
    size.  '-done' ops are skipped (counted at '-start').
    """
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "payload_bytes": 0.0, "link_bytes": 0.0}
    )
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_body, dtype, dims, kind = m.groups()
        if tuple_body is not None:
            payload = sum(
                _shape_bytes(dt, dm) for dt, dm in _SHAPE_RE.findall(tuple_body)
            )
            # async tuples carry (operand, result): count the result half
            payload //= 2 if kind != "all-to-all" else 1
        else:
            payload = _shape_bytes(dtype, dims)
        # group size n
        n = 1
        g = _GROUPS_RE.search(line)
        if g:
            n = len([x for x in g.group(1).split(",") if x.strip()])
        else:
            g2 = _GROUPS_V2_RE.search(line)
            if g2:
                n = int(g2.group(2))
        n = max(n, 2)
        frac = (n - 1) / n
        if kind == "all-reduce":
            link = 2 * frac * payload
        elif kind == "collective-permute":
            link = float(payload)
        else:
            link = frac * payload
        s = stats[kind]
        s["count"] += 1
        s["payload_bytes"] += float(payload)
        s["link_bytes"] += link
    return dict(stats)
