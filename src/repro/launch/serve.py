"""Batched serving driver: prefill + greedy decode with a KV/recurrent cache.

The inference-side end-to-end example (the dry-run lowers the same
`prefill_step` / `serve_step` functions at production shapes; this driver
runs them for real at reduced shapes on CPU, or full shapes on a TPU
runtime).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
      --batch 4 --prompt-len 32 --gen 16

Every projection GEMM routes through the plan/execute API
(`repro.kernels.api`): the first prefill/decode trace *plans* each logical
GEMM shape once (backend choice, autotuned blocks, σ tables, and — for
specs carrying a ShardSpec — the collective schedule), and the process-wide
plan cache serves every subsequent request — `--plan-stats` prints the
cache (one entry per (spec, backend, mesh) triple, however many requests
ran), including per-plan communication cost for sharded plans.  `--mesh
DxM` serves under a local device mesh (sharding constraints active).

Robustness (DESIGN.md §11): `--requests N` serves N independent prompt
batches through `serve_requests`, which isolates each request — one request
raising (poisoned input, injected fault at the `serve.request` site) is
reported, recorded in the resilience ledger, and *skipped*; the remaining
requests still serve.  Any degradation events accumulated during the run
(backend fallbacks, guard trips, retries) are printed at exit.
"""

from __future__ import annotations

import argparse
import time
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels import api as kernel_api
from repro.models import ShardCtx, get_model
from repro.obs import trace as _obs
from repro.resilience import faults as _faults
from repro.resilience import ledger as _rledger
from repro.train.train_step import make_prefill_step, make_serve_step

__all__ = [
    "generate",
    "main",
    "report_plan_cache",
    "serve_requests",
    "serving_steps",
]


# One jitted prefill/serve step pair per (model, ctx) for the whole process.
# `generate()` used to call jax.jit on a fresh closure per request, so every
# request re-traced even though GEMM plans were cached; now the first request
# traces and the rest replay (asserted trace-flat in tests/test_scheduler.py).
# Keyed on id(model) with the model stored in the entry so a dead id can't
# alias a new model; ShardCtx is frozen/hashable.  The cache is a bounded LRU:
# the jitted closures capture the model strongly (so weakrefs would never
# collect), and a long-lived process cycling through many models must not
# grow memory without bound — least-recently-served pairs are dropped and
# simply re-trace if that model ever comes back.
_STEP_CACHE: "OrderedDict" = OrderedDict()
_STEP_CACHE_MAX = 8


def serving_steps(model, ctx: ShardCtx = ShardCtx()):
    """Return the cached (prefill_step, serve_step) jitted pair for a model.

    The serve step donates its state argument (the KV cache buffer is reused
    across decode steps); the prefill step is shared with the
    continuous-batching scheduler (`launch/scheduler.py`), which admits at
    batch 1 through the same trace.
    """
    key = (id(model), ctx)
    entry = _STEP_CACHE.get(key)
    if entry is not None and entry[0] is model:
        _STEP_CACHE.move_to_end(key)
        return entry[1], entry[2]
    prefill = jax.jit(make_prefill_step(model, ctx))
    serve = jax.jit(make_serve_step(model, ctx), donate_argnums=(2,))
    _STEP_CACHE[key] = (model, prefill, serve)
    while len(_STEP_CACHE) > _STEP_CACHE_MAX:
        _STEP_CACHE.popitem(last=False)
    return prefill, serve


def report_plan_cache(prefix: str = "[serve]") -> dict:
    """Print + return the GEMM plan-cache telemetry for this process.

    Serving wants planning out of the request path: each (spec, backend,
    mesh) triple is planned at most once per process, and this report is the
    observable proof (hits = executions that reused an existing plan).
    Sharded plans additionally report their collective schedule and the
    roofline communication cost derived from bytes-moved provenance;
    grouped plans (MoE expert shapes) report groups x rows-per-group,
    per-group FLOPs, and dispatch (routing) bytes.

    Cost-model provenance (DESIGN.md §13) rides along: every entry prints
    its predicted milliseconds under the current coefficients — plus the
    measured milliseconds when the calibration file holds a record for the
    same shape/backend — and entries whose backend/schedule/sharding the
    cost model chose print the decision (chosen candidate + how many were
    ranked + calibration source).
    """
    from repro.costmodel import current_coefficients, predict, terms_from_describe
    from repro.costmodel.calibrate import default_cache
    from repro.launch.roofline import analyze_plan

    info = kernel_api.plan_cache_info()
    print(
        f"{prefix} GEMM plan cache: {info['size']} plans, "
        f"{info['hits']} hits, {info['misses']} misses"
    )
    coeffs = current_coefficients()
    try:
        measured_ms = {
            rec.get("key"): rec["ms"]
            for rec in default_cache().records(coeffs.platform)
        }
    except Exception:  # a broken calibration file must not break the report
        measured_ms = {}
    # Observed execute latencies from the tracing ring, keyed the same way
    # as the calibration cache ("MxKxN|backend") so each plan row can show
    # predicted vs actually-traced milliseconds side by side (DESIGN.md §14).
    obs_ms: dict = {}
    for sp in _obs.spans("plan.execute"):
        k = sp.attrs.get("key")
        if k:
            obs_ms.setdefault(k, []).append(sp.duration_s * 1e3)
    for p in info["plans"]:
        blocks = "x".join(map(str, p["blocks"])) if p["blocks"] else "-"
        epi = p["epilogue"]
        epi_s = (
            ("+b" if epi["bias"] else "")
            + (f"+{epi['activation']}" if epi["activation"] else "")
            + ("+r" if epi["residual"] else "")
        ) or "-"
        sh = p.get("sharding")
        if sh:
            mesh_s = "x".join(str(s) for _, s in sh["mesh"])
            rl = analyze_plan(p)
            shard_s = (
                f"{sh['schedule']}@{mesh_s} moved={sh['bytes_moved']}B "
                f"t_coll={rl['t_collective_s'] * 1e6:.2f}us"
            )
            if sh.get("overlap"):
                # double-buffered schedule: the collective above is hidden
                # behind kernel calls; show the measured ratio if a bench
                # recorded one (serial_ms / overlap_ms)
                eff = sh.get("overlap_efficiency")
                shard_s += " ov" + (f"={eff:.2f}x" if eff else "")
        else:
            shard_s = "-"
        grp = p.get("grouped")
        grp_s = (
            f"{grp['num_groups']}x{grp['rows_per_group']} "
            f"pgflops={grp['per_group_flops']:.1e} "
            f"dispatch={grp['dispatch_bytes']}B"
            if grp
            else "-"
        )
        pred_ms = predict(terms_from_describe(p), coeffs)["total_s"] * 1e3
        meas = measured_ms.get(f"{p['mkn']}|{p['backend']}")
        cost_s = f"pred={pred_ms:.3f}ms"
        if meas is not None:
            cost_s += f" meas={meas:.3f}ms"
        durs = sorted(obs_ms.get(f"{p['mkn']}|{p['backend']}", ()))
        if durs:
            p50 = durs[len(durs) // 2]
            p99 = durs[min(len(durs) - 1, int(len(durs) * 0.99))]
            cost_s += f" obs[n={len(durs)}]=p50:{p50:.3f}/p99:{p99:.3f}ms"
        dec = p.get("decision") or {}
        dec_bits = []
        for kind in ("backend", "sharding", "schedule"):
            d = dec.get(kind)
            if d:
                dec_bits.append(
                    f"{kind}:{d['chosen']}/{len(d.get('candidates', []))}cand"
                )
        if dec_bits:
            cal = next(iter(dec.values())).get("calibration", {})
            dec_s = " ".join(dec_bits) + f" [{cal.get('source', '?')}]"
        else:
            dec_s = "-"
        print(
            f"{prefix}   {p['backend']:11s} {p['structure']:9s} "
            f"{p['mkn']:>18s} batch={p['batch'] or '-'} blocks={blocks} "
            f"epi={epi_s:12s} flops={p['flops']:.2e} grp={grp_s} shard={shard_s} "
            f"{cost_s} decision={dec_s}"
        )
    return info


def generate(
    model,
    params,
    prompts: jax.Array,  # (B, T_prompt) int32
    *,
    gen_len: int,
    ctx: ShardCtx = ShardCtx(),
    greedy: bool = True,
):
    """Prefill the prompts then decode `gen_len` tokens greedily.

    Returns (tokens (B, gen_len), steps_per_s). Works for every family with a
    decode path (dense/moe/ssm/hybrid/vlm text-only prompts; audio is
    enc-dec and served via its own frames batch — see tests).
    """
    cfg = model.cfg
    b, t_prompt = prompts.shape
    prefill, serve = serving_steps(model, ctx)

    batch = {"tokens": prompts, "labels": prompts}
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((b, cfg.num_stub_patches, cfg.d_model), cfg.adtype)
    next_tok, state = prefill(params, batch)
    # Grow caches to prompt+gen capacity where the family uses KV caches:
    # prefill returns length-T caches; decode writes at position `pos`, so we
    # pad the cache length dim up front (recurrent families carry O(1) state).
    if cfg.family in ("dense", "moe", "vlm"):
        pad = gen_len
        state = jax.tree.map(
            lambda c: jnp.pad(c, [(0, 0), (0, 0), (0, pad)] + [(0, 0)] * (c.ndim - 3)),
            state,
        )

    toks = [next_tok]
    pos = t_prompt + (cfg.num_stub_patches if cfg.family == "vlm" else 0)
    t0 = time.monotonic()
    for i in range(gen_len - 1):
        next_tok, state = serve(params, toks[-1][:, None], state, jnp.int32(pos + i))
        toks.append(next_tok)
    jax.block_until_ready(toks[-1])
    dt = time.monotonic() - t0
    # Degenerate timings (gen_len == 1, or a clock that didn't advance)
    # report 0.0, never inf — the rate lands in printed stats and
    # BENCH_kernels.json, and inf is invalid JSON.
    steps_per_s = (gen_len - 1) / dt if dt > 0 and gen_len > 1 else 0.0
    return jnp.stack(toks, axis=1), steps_per_s


def serve_requests(
    model,
    params,
    request_prompts,
    *,
    gen_len: int,
    ctx: ShardCtx = ShardCtx(),
    prefix: str = "[serve]",
):
    """Serve a sequence of independent prompt batches, isolating failures.

    Each element of `request_prompts` is a (B, T) int32 prompt batch served
    via `generate`.  A request that raises is reported (one line, with the
    error), recorded in the resilience ledger under the `serve.request`
    site, and skipped — it never takes the other requests down.  Returns a
    list parallel to `request_prompts`: (tokens, steps_per_s) for served
    requests, None for skipped ones.
    """
    results = []
    for i, prompts in enumerate(request_prompts):
        try:
            # span attrs must not assume a well-formed request: the failure
            # path below (and the chaos warmup's probe) serves garbage prompts
            batch = int(getattr(prompts, "shape", (0,))[0] or 0)
            with _obs.span("serve.request", request=i, batch=batch, gen=gen_len):
                _faults.check("serve.request", request=i)
                results.append(
                    generate(model, params, prompts, gen_len=gen_len, ctx=ctx)
                )
        except Exception as e:
            _rledger.record(
                "serve.request",
                cause=f"{type(e).__name__}: {e}",
                fallback="skip",
                request=i,
            )
            print(f"{prefix} request {i} FAILED ({type(e).__name__}: {e}) — skipped")
            results.append(None)
    served = sum(r is not None for r in results)
    if served < len(results):
        print(f"{prefix} served {served}/{len(results)} requests")
    return results


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--requests",
        type=int,
        default=1,
        help="serve N independent prompt batches; a failing request is "
        "reported and skipped, not fatal",
    )
    ap.add_argument(
        "--scheduler",
        action="store_true",
        help="serve through the continuous-batching scheduler (paged KV "
        "cache, admission control, deadlines) instead of one batch per "
        "request — each request becomes one single-prompt scheduler request",
    )
    ap.add_argument(
        "--plan-stats",
        action="store_true",
        help="print the GEMM plan cache after serving (one plan per spec)",
    )
    ap.add_argument(
        "--obs-export",
        default=None,
        metavar="PATH",
        help="enable structured tracing for the run and write a Chrome-trace "
        "timeline to PATH at exit (plus PATH.prom Prometheus metrics and "
        "PATH.jsonl raw spans); also bridges ledger events into metrics and "
        "feeds plan.execute spans to the cost-model calibration cache",
    )
    ap.add_argument(
        "--mesh",
        default=None,
        metavar="DxM",
        help="serve under a local ('data', 'model') device mesh, e.g. 1x1 or"
        " 2x4 (needs that many devices; sharding constraints activate)",
    )
    args = ap.parse_args(argv)

    if args.obs_export:
        # Tracing + both bridge feeds go live BEFORE any model work so the
        # timeline covers warmup, planning, and every request.  Exports are
        # written once, at the end of main — the serving path stays I/O-free.
        from repro.obs import bridge as _bridge

        _obs.enable()
        _bridge.install()

    ctx = ShardCtx()
    if args.mesh:
        from repro.launch.mesh import make_local_mesh

        shape = tuple(int(x) for x in args.mesh.lower().split("x"))
        mesh = make_local_mesh(shape, ("data", "model"))
        ctx = ShardCtx(mesh=mesh)
        print(f"[serve] mesh: {dict(mesh.shape)}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "audio":
        raise SystemExit("audio (whisper) serving is exercised in tests with a frames batch")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    request_prompts = [
        jax.random.randint(
            jax.random.PRNGKey(args.seed + 1 + r),
            (args.batch, args.prompt_len),
            0,
            cfg.vocab_size,
        ).astype(jnp.int32)
        for r in range(max(args.requests, 1))
    ]

    _faults.install_env_plan()
    if args.scheduler:
        from repro.launch.scheduler import ContinuousBatchingServer, Request, ServeConfig

        total_len = args.prompt_len + args.gen
        if cfg.family == "vlm":
            total_len += cfg.num_stub_patches
        pages_per_seq = -(-total_len // 8)  # ceil
        scfg = ServeConfig(
            max_slots=args.batch,
            page_size=8,
            num_pages=1 + args.batch * pages_per_seq,
            max_pages_per_seq=pages_per_seq,
            queue_capacity=max(args.requests, 1),
            warmup_prompt_lens=(args.prompt_len,),
        )
        server = ContinuousBatchingServer(model, params, scfg, ctx)
        server.warmup()
        reqs = [
            Request(rid=f"req{r}", prompt=np.asarray(p[0]), max_new_tokens=args.gen)
            for r, p in enumerate(request_prompts)
        ]
        t0 = time.monotonic()
        results_by_rid = server.run(reqs)
        dt = time.monotonic() - t0
        print(
            f"[serve] {args.arch} scheduler slots={scfg.max_slots} "
            f"pages={scfg.num_pages}x{scfg.page_size} prompt={args.prompt_len} "
            f"gen={args.gen} ticks={server.counters['ticks']}"
        )
        for r in reqs:
            res = results_by_rid[r.rid]
            head = res.tokens[:16] if res.tokens else []
            print(
                f"[serve] {res.rid}: {res.status:9s} {len(res.tokens)} tokens "
                f"lat={res.latency_s * 1e3:.1f}ms {head}"
            )
        rate = server.counters["decode_tokens"] / dt if dt > 0 else 0.0
        print(f"[serve] {server.counters}, {rate:.1f} tok/s")
    else:
        results = serve_requests(model, params, request_prompts, gen_len=args.gen, ctx=ctx)
        print(f"[serve] {args.arch} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
        for r, res in enumerate(results):
            if res is None:
                continue
            out, rate = res
            print(
                f"[serve] req {r}: decode steps/s {rate:.2f} "
                f"({rate * args.batch:.1f} tok/s batched), row 0: {np.asarray(out[0])[:16]}"
            )
    if args.plan_stats:
        report_plan_cache()
        if _obs.is_enabled():
            st = _obs.stats()
            print(
                f"[serve] obs: {st['finished']} spans "
                f"({st['retained']} retained, {st['dropped']} dropped, "
                f"{st['suppressed_in_trace']} suppressed-in-jit)"
            )
    if _rledger.count():
        print(_rledger.format_summary("[serve]"))

    if args.obs_export:
        from repro.obs import bridge as _bridge
        from repro.obs import export as _export

        ingested = _bridge.flush_calibration()
        _export.write_chrome_trace(
            args.obs_export,
            metadata={
                "arch": args.arch,
                "requests": max(args.requests, 1),
                "scheduler": bool(args.scheduler),
                "calibration": _bridge.calibration_stamp(),
            },
        )
        _export.write_prometheus(args.obs_export + ".prom")
        _export.write_spans_jsonl(args.obs_export + ".jsonl")
        print(
            f"[serve] obs export: {args.obs_export} (+.prom, +.jsonl), "
            f"{ingested} calibration records ingested"
        )


if __name__ == "__main__":
    main()
