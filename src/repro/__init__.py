"""repro — the Kak mesh-array matrix-multiplication technique as a production
JAX/TPU training + serving framework.

Layers (see DESIGN.md):
  core/       paper contribution: mesh-array simulators, scramble S, symmetries
  kernels/    Pallas TPU kernels (staggered-k mesh matmul, scramble) + oracles
  models/     10-architecture model zoo (dense/MoE/SSM/hybrid/enc-dec/VLM)
  configs/    published architecture configs + reduced smoke variants
  parallel/   DP/TP/EP/SP/PP sharding, distributed systolic matmul, compression
  data/       deterministic resumable synthetic data pipeline
  optim/      AdamW + schedules + ZeRO-1
  checkpoint/ atomic async checkpointing + elastic re-mesh restore
  train/      fault-tolerant training loop, serve loop
  launch/     production mesh, multi-pod dry-run, roofline, train/serve CLIs
"""

__version__ = "1.0.0"
