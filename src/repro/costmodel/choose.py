"""Candidate enumeration + cost-ranked choice for the planner (DESIGN.md §13).

`kernels/api.plan()` consults this module whenever a degree of freedom is
left unspecified:

  decide_schedule   ShardSpec.schedule == "auto" with pinned axes — rank
                    every divisibility-LEGAL collective schedule (legality
                    is established by trial `_resolve_sharding` calls with
                    the schedule pinned, so an illegal candidate can never
                    be chosen by construction)
  decide_sharding   plan(spec, mesh=...) with NO ShardSpec — enumerate axis
                    assignments over the live mesh (M-replicated,
                    allgather_a, reduce_scatter_k, ring_k, N-replicated,
                    2D M x N, expert for grouped specs, plus unsharded —
                    and, under CALIBRATED coefficients, the double-buffered
                    `*_overlap`/`pipeline` family) and return the cheapest
                    legal ShardSpec
  decide_backend    rank the capability-legal backends by predicted cost
                    (per-platform `backend_efficiency`); the caller's
                    legacy preference order is the deterministic tie-break
  choose_blocks     block triples stay with `kernels/autotune.py`; once
                    coefficients are CALIBRATED the autotuner's candidate
                    ranking switches to `predict_blocks_ms` (its timed
                    search remains the tie-breaker on TPU)

Every decision returns a JSON-able `Decision` recorded in
`Plan.describe()["decision"]`: the chosen candidate, every candidate's
predicted seconds (and term breakdown), and the calibration provenance —
so `launch/serve.py --plan-stats` and the ledger can show *why*.

Rankings use `calibrate.current_coefficients()` (calibrated numbers when a
`.costmodel_cache.json` fit exists, shipped defaults otherwise) and are
deterministic for a fixed calibration file: pure arithmetic, no timing on
CPU.  On TPU (or under $REPRO_COSTMODEL_TIMED=1) the top-2 schedule
candidates are additionally TIMED through real plan executions and the
measurement wins — the autotuner-style tie-break inside the model's noise.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.costmodel.calibrate import current_coefficients
from repro.costmodel.model import (
    COST_MODEL_VERSION,
    CostCoefficients,
    predict,
    predict_blocks_ms,
    terms_from_describe,
)
from repro.resilience import ledger as _rledger

__all__ = [
    "Decision",
    "NoLegalCandidate",
    "choose_blocks",
    "decide_backend",
    "decide_schedule",
    "decide_sharding",
]

_ENV_TIMED = "REPRO_COSTMODEL_TIMED"

# Deterministic preference among predicted-cost ties (cheap-first philosophy:
# no collective beats a scatter beats a gather beats a full ring wavefront;
# a serial schedule beats its overlap twin at equal prediction — simpler
# dataflow — so overlap only wins when calibrated link terms say it does).
_SCHED_PREF = (
    "replicated",
    "reduce_scatter_k",
    "allgather_a",
    "ring_k",
    "reduce_scatter_k_overlap",
    "allgather_a_overlap",
    "ring_k_overlap",
    "pipeline",
    "expert",
)


def _is_overlap(sched: str) -> bool:
    """Mirror of `api._is_overlap_schedule` (duplicated to avoid the import
    cycle): double-buffered ring schedules priced as max(compute, comm)."""
    return sched.endswith("_overlap") or sched == "pipeline"


class NoLegalCandidate(Exception):
    """No candidate survived legality trials — the caller falls back to its
    legacy resolution (which raises the precise validation error)."""


@dataclasses.dataclass
class Decision:
    """Provenance of one cost-model choice, as recorded in describe()."""

    kind: str  # "schedule" | "sharding" | "backend" | "blocks"
    chosen: str
    candidates: List[Dict[str, Any]]
    calibration: Dict[str, Any]

    def as_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "chosen": self.chosen,
            "candidates": self.candidates,
            "calibration": self.calibration,
        }


def _stamp(coeffs: CostCoefficients) -> Dict[str, Any]:
    return {
        "model_version": COST_MODEL_VERSION,
        "source": coeffs.source,
        "platform": coeffs.platform,
    }


def _best_backend(coeffs: CostCoefficients) -> Optional[str]:
    """The platform's fastest known GEMM path — schedule/sharding rankings
    are backend-relative, so predicting every candidate at the same (best)
    efficiency keeps absolute numbers honest without biasing the order."""
    if not coeffs.backend_efficiency:
        return None
    return max(coeffs.backend_efficiency, key=lambda kv: kv[1])[0]


def _candidate_terms(spec, sched: str, local, bytes_moved: int, phases: int):
    """Synthesize the describe()-shaped record for a candidate that has not
    been planned yet, and derive its cost terms (one arithmetic path:
    `model.terms_from_describe`).  The invocation arithmetic mirrors
    `api._build_sharded_plan` exactly — a drifted copy here would misprice
    candidates against the plans they become."""
    if sched in ("reduce_scatter_k", "reduce_scatter_k_overlap"):
        inv = phases + 1
    elif sched in ("allgather_a_overlap", "ring_k_overlap"):
        inv = 2  # two column-half kernel calls
    elif sched == "pipeline":
        from repro.kernels import api as _api

        inv = _api._pipeline_microbatches(
            spec.eff_m, spec.shard.axis_size(spec.shard.axis_k)
        )
    else:
        inv = 1
    desc: Dict[str, Any] = {
        "backend": None,
        "mkn": f"{spec.eff_m}x{spec.k}x{spec.n}",
        "dtypes": [spec.dtype_a, spec.dtype_b],
        "out_dtype": spec.resolved_out_dtype(),
        "flops": spec.flops(),
        "batch": list(spec.batch),
        "batched_b": spec.batched_b,
        "structure": spec.structure,
        "repeats": getattr(spec, "repeats", 1),
    }
    if spec.group is not None:
        grp = spec.group
        import numpy as _np

        ia = _np.dtype(spec.dtype_a).itemsize
        io = _np.dtype(spec.resolved_out_dtype()).itemsize
        desc["grouped"] = {
            "num_groups": grp.num_groups,
            "rows_per_group": grp.rows_per_group,
            "per_group_flops": 2 * grp.rows_per_group * spec.k * spec.n,
            "dispatch_bytes": grp.rows * (spec.k * ia + spec.n * io),
        }
    shard = spec.shard
    desc["sharding"] = {
        "schedule": sched,
        "overlap": _is_overlap(sched),
        "bytes_moved": bytes_moved,
        "collective_phases": phases,
        "kernel_invocations": inv,
        "per_shard_mkn": [local.eff_m, local.k, local.n],
        "per_shard_batch": list(local.batch),
        "per_shard_flops": local.flops() * inv,
        "mesh": [[n, s] for n, s in shard.mesh_axes],
        "axes": {
            "m": shard.axis_m,
            "k": shard.axis_k,
            "n": shard.axis_n,
            "batch": shard.axis_batch,
            "g": shard.axis_g,
        },
    }
    return terms_from_describe(desc)


def _rank(
    cands: List[Dict[str, Any]], illegal: List[Dict[str, Any]]
) -> List[Dict[str, Any]]:
    def pref(name: str) -> int:
        base = name.split("[", 1)[0]
        return _SCHED_PREF.index(base) if base in _SCHED_PREF else len(_SCHED_PREF)

    cands.sort(key=lambda c: (c["predicted_s"], pref(c["name"]), c["name"]))
    return cands + illegal


def _evaluate(spec, shard, coeffs) -> Tuple[Optional[Dict[str, Any]], Optional[str]]:
    """Legality-trial one (spec, pinned-schedule ShardSpec) candidate.

    Returns (candidate record, None) when `_resolve_sharding` accepts it,
    (None, reason) when it raises PlanValidationError — the trial is the
    same validation the real plan build runs, so legality here IS legality
    there."""
    from repro.kernels import api

    trial = dataclasses.replace(spec, shard=shard)
    try:
        sched, local, bytes_moved, phases, _ = api._resolve_sharding(trial)
    except api.PlanValidationError as e:
        return None, str(e)
    terms = _candidate_terms(trial, sched, local, bytes_moved, phases)
    pred = predict(terms, coeffs, backend=_best_backend(coeffs))
    overlap = bool(terms.get("overlap"))
    return (
        {
            "name": sched,
            "schedule": sched,
            "predicted_s": pred["total_s"],
            "t_compute_s": pred["t_compute_s"],
            "t_memory_s": pred["t_memory_s"],
            "t_collective_s": pred["t_collective_s"],
            "overlap": overlap,
            # how total_s was composed — the §15 pricing, visible in
            # describe()["decision"] provenance
            "pricing": (
                "max(compute,memory,collective)+latency"
                if overlap
                else "max(compute,memory)+collective+latency"
            ),
            "legal": True,
        },
        None,
    )


def _timed_tiebreak(
    spec, mesh, ranked: List[Dict[str, Any]], shards: Dict[str, Any]
) -> List[Dict[str, Any]]:
    """On TPU (or $REPRO_COSTMODEL_TIMED=1): time the top-2 predicted
    candidates through real plan executions and reorder by measurement.
    CPU stays pure-model so auto resolution is deterministic (interpret-mode
    timing measures Python, not the kernel — the autotune.py lesson)."""
    import jax

    if os.environ.get(_ENV_TIMED, "") != "1" and jax.default_backend() != "tpu":
        return ranked
    legal = [c for c in ranked if c.get("legal")]
    if len(legal) < 2 or mesh is None:
        return ranked
    import jax.numpy as jnp

    from repro.kernels import api
    from repro.kernels.autotune import measure_best_ms

    for cand in legal[:2]:
        shard = shards.get(cand["name"])
        if shard is None:
            continue
        try:
            p = api.plan(dataclasses.replace(spec, shard=shard), mesh=mesh)
            a = jnp.ones(spec.batch + (spec.m, spec.k), spec.dtype_a)
            b_shape = (
                spec.batch + (spec.k, spec.n) if spec.batched_b else (spec.k, spec.n)
            )
            b = jnp.ones(b_shape, spec.dtype_b)
            cand["measured_ms"] = measure_best_ms(p, a, b)
        except Exception as e:
            _rledger.record(
                "costmodel.tiebreak",
                cause=f"{type(e).__name__}: {e}",
                fallback="model-order",
                candidate=cand["name"],
            )
    timed = [c for c in legal[:2] if "measured_ms" in c]
    if len(timed) == 2 and (
        timed[0]["measured_ms"] > timed[1]["measured_ms"]
    ) != (timed[0]["predicted_s"] > timed[1]["predicted_s"]):
        # the measurement disagrees within the top-2: trust it
        legal[0], legal[1] = legal[1], legal[0]
        return legal + [c for c in ranked if not c.get("legal")]
    return ranked


def decide_schedule(spec, mesh=None) -> Tuple[str, Decision]:
    """Resolve `schedule="auto"` for a spec with PINNED shard axes.

    Candidates are the non-expert SCHEDULES (expert belongs to grouped
    specs, which route `_resolve_grouped_sharding`); each is legality-
    trialed with the schedule pinned and the survivors are ranked by
    predicted cost.  The overlap family (`*_overlap` / `pipeline`) only
    enters the candidate set under CALIBRATED coefficients: with shipped
    defaults (zero latency terms) its max(compute, comm) pricing would
    dominate every serial schedule unconditionally, and auto resolution
    must stay legacy-equivalent until real link measurements justify the
    switch.  Pinning an overlap schedule explicitly always works.  Raises
    NoLegalCandidate when nothing survives so the caller's legacy heuristic
    can produce its precise validation error.
    """
    from repro.kernels import api

    coeffs = current_coefficients()
    overlap_ok = coeffs.source == "calibrated"
    shard = spec.shard
    cands: List[Dict[str, Any]] = []
    illegal: List[Dict[str, Any]] = []
    shards: Dict[str, Any] = {}
    for sched in (s for s in api.SCHEDULES if s != "expert"):
        if _is_overlap(sched) and not overlap_ok:
            continue
        pinned = dataclasses.replace(shard, schedule=sched)
        cand, reason = _evaluate(spec, pinned, coeffs)
        if cand is not None:
            cands.append(cand)
            shards[cand["name"]] = pinned
        else:
            illegal.append(
                {"name": sched, "legal": False, "reason": reason[:120]}
            )
    if not cands:
        raise NoLegalCandidate(
            f"no legal collective schedule for shard axes of {spec!r}"
        )
    ranked = _rank(cands, illegal)
    ranked = _timed_tiebreak(spec, mesh, ranked, shards)
    chosen = ranked[0]["name"]
    return chosen, Decision("schedule", chosen, ranked, _stamp(coeffs))


def _sharding_candidates(
    spec, mesh, *, overlap_ok: bool = False
) -> List[Tuple[str, Any]]:
    """(label, ShardSpec) axis assignments to trial over the live mesh.

    `overlap_ok` admits the double-buffered family — gated on calibrated
    coefficients by the caller, same reasoning as `decide_schedule`."""
    from repro.kernels.api import ShardSpec

    axes = list(mesh.shape.items())
    # schedule pinned so the legality trial never re-enters auto resolution
    out: List[Tuple[str, Any]] = [
        ("unsharded", ShardSpec.from_mesh(mesh, schedule="replicated"))
    ]
    if spec.group is not None:
        for name, size in axes:
            if size > 1:
                out.append(
                    (
                        f"expert[g={name}]",
                        ShardSpec.from_mesh(mesh, g=name, schedule="expert"),
                    )
                )
        return out
    for name, size in axes:
        if size <= 1:
            continue
        out.extend(
            [
                (
                    f"replicated[m={name}]",
                    ShardSpec.from_mesh(mesh, m=name, schedule="replicated"),
                ),
                (
                    f"allgather_a[m={name}]",
                    ShardSpec.from_mesh(mesh, m=name, schedule="allgather_a"),
                ),
                (
                    f"reduce_scatter_k[k={name}]",
                    ShardSpec.from_mesh(mesh, k=name, schedule="reduce_scatter_k"),
                ),
                (
                    f"ring_k[k={name}]",
                    ShardSpec.from_mesh(mesh, k=name, schedule="ring_k"),
                ),
                (
                    f"replicated[n={name}]",
                    ShardSpec.from_mesh(mesh, n=name, schedule="replicated"),
                ),
            ]
        )
        if overlap_ok:
            out.extend(
                [
                    (
                        f"reduce_scatter_k_overlap[k={name}]",
                        ShardSpec.from_mesh(
                            mesh, k=name, schedule="reduce_scatter_k_overlap"
                        ),
                    ),
                    (
                        f"allgather_a_overlap[m={name}]",
                        ShardSpec.from_mesh(
                            mesh, m=name, schedule="allgather_a_overlap"
                        ),
                    ),
                    (
                        f"ring_k_overlap[k={name}]",
                        ShardSpec.from_mesh(mesh, k=name, schedule="ring_k_overlap"),
                    ),
                    (
                        f"pipeline[k={name}]",
                        ShardSpec.from_mesh(mesh, k=name, schedule="pipeline"),
                    ),
                ]
            )
        if spec.batched_b:
            out.append(
                (
                    f"replicated[batch={name}]",
                    ShardSpec.from_mesh(mesh, batch=name, schedule="replicated"),
                )
            )
    if len(axes) >= 2 and not spec.batched_b:
        (a0, _), (a1, _) = axes[0], axes[1]
        out.append(
            (
                f"replicated[m={a0},n={a1}]",
                ShardSpec.from_mesh(mesh, m=a0, n=a1, schedule="replicated"),
            )
        )
    return out


_SHARD_MEMO: Dict[tuple, Tuple[Any, Decision]] = {}


def decide_sharding(spec, mesh) -> Tuple[Any, Decision]:
    """Choose a full ShardSpec (axes AND schedule) for a spec with none.

    This is where reduce_scatter_k outranks allgather_a on the BENCH spec:
    the gather schedule re-runs the FULL-K per-shard kernel p times (8x the
    FLOPs of the scatter's K/p slabs) for identical bytes moved.  Memoized
    per (spec, mesh axes, platform, coefficients) — auto-sharding a cached
    plan's spec costs one dict lookup.
    """
    import jax

    coeffs = current_coefficients()
    memo_key = (spec, tuple(mesh.shape.items()), jax.default_backend(), coeffs)
    got = _SHARD_MEMO.get(memo_key)
    if got is not None:
        return got
    cands: List[Dict[str, Any]] = []
    illegal: List[Dict[str, Any]] = []
    shards: Dict[str, Any] = {}
    overlap_ok = coeffs.source == "calibrated"
    for label, shard in _sharding_candidates(spec, mesh, overlap_ok=overlap_ok):
        cand, reason = _evaluate(spec, shard, coeffs)
        if cand is not None:
            cand["name"] = label
            cands.append(cand)
            shards[label] = shard
        else:
            illegal.append({"name": label, "legal": False, "reason": reason[:120]})
    if not cands:
        raise NoLegalCandidate(
            f"no legal axis assignment for {spec!r} on mesh {dict(mesh.shape)}"
        )
    ranked = _rank(cands, illegal)
    ranked = _timed_tiebreak(spec, mesh, ranked, shards)
    chosen = ranked[0]["name"]
    decision = Decision("sharding", chosen, ranked, _stamp(coeffs))
    got = (shards[chosen], decision)
    _SHARD_MEMO[memo_key] = got
    return got


def decide_backend(
    spec, candidates: Sequence[Tuple[str, int]]
) -> Tuple[str, Decision]:
    """Rank capability-legal backends by predicted cost.

    `candidates` is [(name, legacy_order_index)] — the index is the
    deterministic tie-break, so equal predictions reproduce the legacy
    pinned-default -> xla -> pallas_mesh -> registration order exactly.
    """
    coeffs = current_coefficients()
    desc = {
        "backend": None,
        "mkn": f"{spec.eff_m}x{spec.k}x{spec.n}",
        "dtypes": [spec.dtype_a, spec.dtype_b],
        "out_dtype": spec.resolved_out_dtype(),
        "flops": spec.flops(),
        "batch": list(spec.batch),
        "batched_b": spec.batched_b,
        "structure": spec.structure,
        "repeats": getattr(spec, "repeats", 1),
    }
    terms = terms_from_describe(desc)
    rows = []
    for name, order in candidates:
        pred = predict(terms, coeffs, backend=name)
        rows.append(
            {
                "name": name,
                "predicted_s": pred["total_s"],
                "efficiency": coeffs.efficiency(name),
                "legal": True,
                "_order": order,
            }
        )
    rows.sort(key=lambda r: (r["predicted_s"], r["_order"]))
    for r in rows:
        del r["_order"]
    chosen = rows[0]["name"]
    return chosen, Decision("backend", chosen, rows, _stamp(coeffs))


def choose_blocks(
    m: int, k: int, n: int, dtype, backend: str, *, symmetry: int = 0
):
    """Resolve the block triple, consulting the cost model once calibrated.

    With shipped-default coefficients this IS `autotune.resolve_blocks`
    (identical choice, identical caching) — the analytic `model_score`
    ranking was validated by the autotune bench and stays authoritative
    until measurements say otherwise.  With CALIBRATED coefficients the
    candidate ranking switches to `predict_blocks_ms` under the same cache
    and timed-search tie-break.  Returns (blocks, decision | None).
    """
    from repro.kernels import autotune as _autotune

    coeffs = current_coefficients()
    if coeffs.source != "calibrated":
        return _autotune.resolve_blocks(
            m, k, n, dtype, backend, symmetry=symmetry
        ), None
    blocks = _autotune.autotune(
        m,
        k,
        n,
        dtype,
        backend,
        symmetry=symmetry,
        scorer=lambda blk: predict_blocks_ms(m, k, n, blk, coeffs),
    )
    decision = Decision(
        "blocks",
        "x".join(map(str, blocks)),
        [
            {
                "name": "x".join(map(str, blocks)),
                "predicted_s": predict_blocks_ms(m, k, n, blocks, coeffs) / 1e3,
                "legal": True,
            }
        ],
        _stamp(coeffs),
    )
    return blocks, decision


def clear_decision_memo() -> None:
    """Test hook: drop the per-process sharding-decision memo."""
    _SHARD_MEMO.clear()
