"""Cost-model subsystem (DESIGN.md §13): the planner's brain.

`model` turns a plan's describe() record into roofline terms and predicted
seconds under per-platform coefficients; `calibrate` fits those coefficients
from measured probes (versioned `.costmodel_cache.json`); `choose` ranks
candidate backends / block shapes / collective schedules / mesh shardings
for `kernels.api.plan()` and records the decision provenance.
"""

from repro.costmodel.calibrate import (
    CALIBRATION_VERSION,
    CalibrationCache,
    calibrate,
    clear_coefficients_memo,
    current_coefficients,
    default_cache,
    fit_coefficients,
    ingest,
    run_probes,
)
from repro.costmodel.choose import (
    Decision,
    NoLegalCandidate,
    choose_blocks,
    clear_decision_memo,
    decide_backend,
    decide_schedule,
    decide_sharding,
)
from repro.costmodel.model import (
    COST_MODEL_VERSION,
    CostCoefficients,
    default_coefficients,
    predict,
    predict_blocks_ms,
    repeat_amortization,
    structure_step_factor,
    terms_from_describe,
)

__all__ = [
    "CALIBRATION_VERSION",
    "COST_MODEL_VERSION",
    "CalibrationCache",
    "CostCoefficients",
    "Decision",
    "NoLegalCandidate",
    "calibrate",
    "choose_blocks",
    "clear_coefficients_memo",
    "clear_decision_memo",
    "current_coefficients",
    "decide_backend",
    "decide_schedule",
    "decide_sharding",
    "default_cache",
    "default_coefficients",
    "fit_coefficients",
    "ingest",
    "predict",
    "predict_blocks_ms",
    "repeat_amortization",
    "run_probes",
    "structure_step_factor",
    "terms_from_describe",
]
