"""Analytical GEMM cost model (DESIGN.md §13).

The planner's brain predicts the wall time of one plan execution from the
same terms `launch/roofline.analyze_plan` reports — compute, memory, and
collective seconds — parameterized by per-platform `CostCoefficients`
instead of the roofline's fixed TPU v5e constants.  Everything here is pure
arithmetic over `Plan.describe()`-shaped records: no jax import, no timing,
no I/O — `costmodel/calibrate.py` owns measurement and persistence, and
`costmodel/choose.py` owns candidate enumeration.

Two ingredients go beyond a plain roofline, both from the paper family:

  * structure_step_factor — a `structure="symmetric"` product reads out in
    `symmetric_readout_steps(n)` ≈ floor(3n/2) mesh steps instead of the
    general 2n-1 (Kak 2010 §symmetries), so its compute term scales by that
    ratio; general and scrambled products pay the full 2n-1 horizon.
  * repeat_amortization — `GemmSpec.repeats` declares that the plan runs r
    times back to back against resident weights (decode loops, MoE layers).
    The cross-wired mesh array computes r pipelined products in r·n + (n-1)
    steps (Kak, arXiv:1411.3273), so the per-product step cost falls from
    2n-1 toward n; the B operand also streams once, not r times.

`predict` combines the terms as `max(compute, memory) + collective +
latency` for the serial schedules: compute and HBM streaming overlap (the
kernels are pipelined) but a gather-then-compute collective is a barrier,
and each collective phase / kernel launch pays a fixed latency the byte
terms can't see (the coefficients calibration actually fits on small
probes).  For the double-buffered schedules (`*_overlap` / `pipeline`,
DESIGN.md §15) every ring hop is issued behind a kernel call, so the
steady state is `max(compute, memory, collective) + latency` — the overlap
pricing that lets a calibrated `schedule="auto"` pick them whenever the
link time would otherwise be exposed.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np

__all__ = [
    "COST_MODEL_VERSION",
    "CostCoefficients",
    "default_coefficients",
    "predict",
    "predict_blocks_ms",
    "repeat_amortization",
    "structure_step_factor",
    "terms_from_describe",
]

COST_MODEL_VERSION = 1

# Largest n whose symmetric readout horizon is computed exactly from the
# mesh completion times (O(n^2) work, cached); beyond it the empirical
# closed form floor(3n/2) is used (validated against exact in tests).
_EXACT_SYMMETRIC_N = 128


@dataclasses.dataclass(frozen=True)
class CostCoefficients:
    """Per-platform hardware coefficients the prediction is linear in.

    `backend_efficiency` maps backend names to the fraction of
    `flops_per_s` that backend sustains (1.0 = the platform's best GEMM
    path); unknown backends get `default_efficiency`.  `source` records
    whether the numbers are shipped defaults or a measured calibration
    (see calibrate.py); frozen + tuple-typed so coefficients are hashable
    and usable in memo keys.
    """

    flops_per_s: float
    hbm_bytes_per_s: float
    link_bytes_per_s: float
    phase_latency_s: float = 0.0
    launch_overhead_s: float = 0.0
    backend_efficiency: Tuple[Tuple[str, float], ...] = ()
    default_efficiency: float = 0.5
    platform: str = "cpu"
    source: str = "default"

    def efficiency(self, backend: Optional[str]) -> float:
        for name, eff in self.backend_efficiency:
            if name == backend:
                return eff
        return self.default_efficiency

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["backend_efficiency"] = {k: v for k, v in self.backend_efficiency}
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "CostCoefficients":
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in fields}
        be = kw.get("backend_efficiency") or ()
        if isinstance(be, Mapping):
            be = tuple(sorted((str(k), float(v)) for k, v in be.items()))
        else:
            be = tuple((str(k), float(v)) for k, v in be)
        kw["backend_efficiency"] = be
        return cls(**kw)


def default_coefficients(platform: Optional[str] = None) -> CostCoefficients:
    """Shipped coefficients: TPU v5e roofline constants on TPU; CPU numbers
    anchored to the measured `BENCH_kernels.json["xla_gemm"]` series
    (~105–136 GFLOP/s f32 on the CI host).  Latency coefficients default to
    zero — byte terms alone reproduce the legacy auto-schedule heuristic
    exactly, and calibration fits the real fixed costs when asked."""
    if platform is None:
        platform = "cpu"
    if platform == "tpu":
        return CostCoefficients(
            flops_per_s=197e12,
            hbm_bytes_per_s=819e9,
            link_bytes_per_s=50e9,
            backend_efficiency=(("pallas_mesh", 1.0), ("ref", 0.02), ("xla", 0.95)),
            platform="tpu",
        )
    return CostCoefficients(
        flops_per_s=1e11,
        hbm_bytes_per_s=2e10,
        link_bytes_per_s=1e10,
        # interpret-mode Pallas runs the grid in Python; ref materializes
        # rank-1 updates — both orders of magnitude off the XLA dot
        backend_efficiency=(("pallas_mesh", 0.05), ("ref", 0.01), ("xla", 1.0)),
        platform=str(platform),
    )


@functools.lru_cache(maxsize=None)
def _symmetric_steps(n: int) -> int:
    if n <= _EXACT_SYMMETRIC_N:
        from repro.core.symmetries import symmetric_readout_steps

        return symmetric_readout_steps(n)
    return (3 * n) // 2  # empirical closed form (== exact for all tested n)


def structure_step_factor(structure: str, n: int) -> float:
    """Per-product step-count ratio vs the general 2n-1 readout horizon.

    symmetric products finish at `symmetric_readout_steps(n)` (the paper's
    n+1+n/2 bound, empirically floor(3n/2)); general and scrambled pay the
    full horizon (the σ arrangement permutes cells, it doesn't finish
    earlier), factor 1.0.
    """
    n = max(1, int(n))
    if structure != "symmetric" or n == 1:
        return 1.0
    return _symmetric_steps(n) / (2 * n - 1)


def repeat_amortization(repeats: int, n: int) -> float:
    """Per-product step factor for r pipelined products on the cross-wired
    array: r products take r·n + (n-1) steps, so each costs
    (n + (n-1)/r) / (2n-1) of a standalone product — 1.0 at r=1, falling
    toward n/(2n-1) ≈ 1/2 as the pipeline fills."""
    r = max(1, int(repeats))
    n = max(1, int(n))
    return (n + (n - 1) / r) / (2 * n - 1)


def terms_from_describe(desc: Mapping[str, Any]) -> Dict[str, Any]:
    """Machine-usable cost terms for one `Plan.describe()` record.

    This is the single owner of the byte/FLOP arithmetic `roofline
    .analyze_plan` historically computed inline (same conventions: ring
    schedules stream `kernel_invocations` A chunks and output tiles per
    call, batched_b scales per-element traffic by the batch, grouped specs
    stream every group's weight slab plus the dispatch routing bytes, with
    EP scaling both to the per-device share).  Unknown record shapes
    degrade to the plain-GEMM arithmetic instead of raising.
    """
    sh = desc.get("sharding") or {}
    grp = desc.get("grouped") or {}
    flops = sh.get("per_shard_flops", desc["flops"])
    if "per_shard_mkn" in sh:
        m, k, n = (int(x) for x in sh["per_shard_mkn"])
        # batched_b local specs keep their batch dims out of eff_m
        nb = math.prod(sh.get("per_shard_batch") or [1])
    else:
        m, k, n = (int(x) for x in desc["mkn"].split("x"))
        # "mkn" folds batch into M only for 2D b; batched_b products stream
        # per-element A/B/C, so scale bytes to match the batch-inclusive FLOPs
        nb = math.prod(desc.get("batch") or [1]) if desc.get("batched_b") else 1
    dt_a, dt_b = desc.get("dtypes", ["float32", "float32"])
    ia = np.dtype(dt_a).itemsize
    ib = np.dtype(dt_b).itemsize
    io = np.dtype(desc.get("out_dtype") or "float32").itemsize
    # Ring schedules re-invoke the per-shard kernel once per step: the device
    # streams `inv` A chunks and writes `inv` output tiles per call.
    inv = int(sh.get("kernel_invocations", 1))
    dispatch_bytes = 0
    if grp:
        # Grouped: M is the total row bound (rows stream once), but the
        # weight term is per GROUP — every (K, N) slab streams — and the
        # sort/scatter/gather routing traffic rides the memory term too.
        n_groups = grp.get("num_groups", 1)
        dispatch_bytes = grp.get("dispatch_bytes", 0)
        if sh:
            # expert schedule: `m` above is already the per-shard row count
            # (per_shard_mkn); scale group count and dispatch traffic to the
            # per-device share using the group axis size from the record
            mesh_sizes = {nm: s for nm, s in sh.get("mesh", [])}
            pg = mesh_sizes.get((sh.get("axes") or {}).get("g"), 1) or 1
            n_groups = max(1, n_groups // pg)
            dispatch_bytes //= pg
        a_bytes = m * k * ia
        b_bytes = n_groups * k * n * ib
        out_bytes = m * n * io
    else:
        a_bytes = nb * inv * m * k * ia
        b_bytes = nb * k * n * ib
        out_bytes = nb * inv * m * n * io
    return {
        "flops": int(flops),
        "a_bytes": int(a_bytes),
        "b_bytes": int(b_bytes),
        "out_bytes": int(out_bytes),
        "dispatch_bytes": int(dispatch_bytes),
        "hbm_bytes": int(a_bytes + b_bytes + out_bytes + dispatch_bytes),
        "collective_bytes": int(sh.get("bytes_moved", 0)),
        "collective_phases": int(sh.get("collective_phases", 0)),
        "kernel_invocations": inv,
        "overlap": bool(sh.get("overlap", False)),
        "schedule": sh.get("schedule"),
        "structure": desc.get("structure", "general"),
        "readout_n": n,
        "repeats": int(desc.get("repeats", 1)),
        "backend": desc.get("backend"),
    }


def predict(
    terms: Mapping[str, Any],
    coeffs: CostCoefficients,
    *,
    backend: Optional[str] = None,
) -> Dict[str, float]:
    """Predicted seconds for one execution of a plan with these terms.

    total = max(compute, memory) + collective + latency — compute overlaps
    HBM streaming, a serial collective is a barrier, and latency charges
    the per-phase and per-launch fixed costs.  Terms with `overlap` set
    (the double-buffered ring schedules) hide the collective behind the
    kernel calls instead: total = max(compute, memory, collective) +
    latency.  The paper-structure factors scale the compute term
    (symmetric early readout) and amortize launch latency and B streaming
    over `repeats` pipelined products.
    """
    be = backend if backend is not None else terms.get("backend")
    eff = max(coeffs.efficiency(be), 1e-6)
    n = int(terms.get("readout_n", 1))
    r = max(1, int(terms.get("repeats", 1)))
    factor = structure_step_factor(terms.get("structure", "general"), n)
    amort = repeat_amortization(r, n)
    t_compute = terms["flops"] / (coeffs.flops_per_s * eff) * factor * amort
    # With repeats the weights stay resident: B streams once per r products.
    hbm = (
        terms.get("a_bytes", 0)
        + terms.get("out_bytes", 0)
        + terms.get("dispatch_bytes", 0)
        + terms.get("b_bytes", 0) / r
    )
    if not any(k in terms for k in ("a_bytes", "b_bytes", "out_bytes")):
        hbm = terms.get("hbm_bytes", 0)
    t_memory = hbm / coeffs.hbm_bytes_per_s
    t_collective = terms.get("collective_bytes", 0) / coeffs.link_bytes_per_s
    t_latency = (
        terms.get("collective_phases", 0) * coeffs.phase_latency_s
        + terms.get("kernel_invocations", 1) * coeffs.launch_overhead_s * amort
    )
    if terms.get("overlap"):
        # double-buffered ring: hops hidden behind kernel calls (§15)
        total = max(t_compute, t_memory, t_collective) + t_latency
    else:
        total = max(t_compute, t_memory) + t_collective + t_latency
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "t_latency_s": t_latency,
        "total_s": total,
    }


def predict_blocks_ms(
    m: int, k: int, n: int, blocks: Tuple[int, int, int], coeffs: CostCoefficients
) -> float:
    """Predicted milliseconds for one (bm, bn, bk)-blocked GEMM — the cost
    model's block scorer (lower is better, unlike autotune.model_score).

    The padded iteration space sets the compute term (overhang blocks issue
    dead MXU slots) and per-phase streaming sets the memory term; used by
    the autotuner's optional cost-model ranking once coefficients are
    calibrated.
    """
    bm, bn, bk = blocks
    ceil = lambda a, b: -(-a // b)
    pm, pn, pk = ceil(m, bm) * bm, ceil(n, bn) * bn, ceil(k, bk) * bk
    flops = 2 * pm * pn * pk
    # every (i, j) cell streams its A row-block and B col-block per k phase
    phases = ceil(k, bk)
    bytes_streamed = ceil(m, bm) * ceil(n, bn) * phases * (bm * bk + bk * bn) * 4
    t = max(
        flops / coeffs.flops_per_s,
        bytes_streamed / coeffs.hbm_bytes_per_s,
    )
    return t * 1e3
