"""Measured calibration of the cost-model coefficients (DESIGN.md §13).

`calibrate()` times a small probe set through the ordinary plan/execute
path (`kernels.api.plan` + the autotuner's `measure_best_ms` timing
utility), then fits `CostCoefficients` to the measurements with a
deterministic coordinate-descent hillclimb (the `launch/hillclimb.py`
refinement idiom: propose one coefficient move at a time, keep strict
improvements).  Fitted coefficients persist to a versioned
`.costmodel_cache.json` next to the autotune cache, with the same
resilience contract: an unreadable file is QUARANTINED to `<path>.corrupt`
(warned once, ledger-recorded), invalid entries are dropped on load, and
saves are bounded-retry best-effort.

The record format is shared currency: `launch/hillclimb.py` writes its
variant measurements as the same `{"terms": ..., "ms": ..., "source": ...}`
dicts, and `ingest()` folds them into the calibration file so measured
refinement accumulates across tools.

`current_coefficients()` is the planner's read path: calibrated numbers if
the file has them for this platform, shipped defaults otherwise — memoized
per process so plan-time decisions never touch the filesystem twice
(`launch/scheduler.warmup` preloads it so no serving tick pays the read).
"""

from __future__ import annotations

import json
import math
import os
import tempfile
import warnings
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.costmodel.model import (
    COST_MODEL_VERSION,
    CostCoefficients,
    default_coefficients,
    predict,
    terms_from_describe,
)
from repro.obs import trace as _obs
from repro.resilience import faults as _faults
from repro.resilience import ledger as _rledger
from repro.resilience.policy import retry_call as _retry_call

__all__ = [
    "CALIBRATION_VERSION",
    "CalibrationCache",
    "calibrate",
    "clear_coefficients_memo",
    "current_coefficients",
    "default_cache",
    "fit_coefficients",
    "ingest",
    "run_probes",
]

CALIBRATION_VERSION = 1
DEFAULT_CACHE_FILENAME = ".costmodel_cache.json"
_ENV_CACHE = "REPRO_COSTMODEL_CACHE"

# Probe GEMMs: small enough for CI, spread enough to separate the FLOP
# term (large cube) from fixed launch overhead (tiny cube).
PROBE_SHAPES: Tuple[Tuple[int, int, int], ...] = (
    (64, 64, 64),
    (128, 128, 128),
    (256, 256, 256),
    (512, 512, 512),
)

_FIT_FIELDS = (
    "flops_per_s",
    "hbm_bytes_per_s",
    "link_bytes_per_s",
    "phase_latency_s",
    "launch_overhead_s",
)


def _valid_record(rec: Any) -> bool:
    return (
        isinstance(rec, dict)
        and isinstance(rec.get("terms"), dict)
        and isinstance(rec.get("ms"), (int, float))
        and rec["ms"] > 0
        and isinstance(rec["terms"].get("flops"), (int, float))
    )


class CalibrationCache:
    """Versioned persistent JSON store of fitted coefficients + records.

    On-disk format (v1):
        {"version": 1,
         "model_version": 1,
         "coefficients": {platform: {<CostCoefficients fields>}},
         "records": {platform: [{"terms": {...}, "ms": float,
                                 "source": "probe|hillclimb|bench", ...}]}}

    Resilience mirrors `kernels.autotune.AutotuneCache` (DESIGN.md §11):
    corrupt files are quarantined to `<path>.corrupt` with a one-shot
    warning and a ledger record; entries failing validation are dropped
    (recalibration rebuilds them); saves retry and then swallow OSError.
    """

    def __init__(self, path: Optional[os.PathLike] = None):
        self.path = Path(path or os.environ.get(_ENV_CACHE, DEFAULT_CACHE_FILENAME))
        self._doc: Optional[Dict[str, Any]] = None

    # -- persistence ---------------------------------------------------------

    def _quarantine_file(self, err: BaseException) -> None:
        corrupt = Path(str(self.path) + ".corrupt")
        moved = False
        try:
            os.replace(self.path, corrupt)
            moved = True
        except OSError:
            pass
        _warn_once(
            f"costmodel calibration cache {self.path} is unreadable"
            f" ({type(err).__name__}: {err});"
            + (f" moved aside to {corrupt};" if moved else "")
            + " falling back to default coefficients"
        )
        _rledger.record(
            "costmodel.cache_load",
            cause=f"{type(err).__name__}: {err}",
            fallback="quarantine",
            path=str(self.path),
            moved_to=str(corrupt) if moved else None,
        )

    def _load(self) -> Dict[str, Any]:
        if self._doc is not None:
            return self._doc
        self._doc = {"coefficients": {}, "records": {}}
        try:
            _faults.check("costmodel.cache_load", path=str(self.path))
            raw = json.loads(self.path.read_text())
        except FileNotFoundError:
            return self._doc  # first run: nothing to load, nothing to warn
        except (OSError, json.JSONDecodeError, _faults.FaultError) as e:
            self._quarantine_file(e)
            return self._doc
        if not isinstance(raw, dict) or raw.get("version") != CALIBRATION_VERSION:
            # unknown version: start clean — stale fits must not steer plans
            return self._doc
        dropped = 0
        for plat, cd in (raw.get("coefficients") or {}).items():
            try:
                co = CostCoefficients.from_dict({**cd, "platform": plat})
                if min(co.flops_per_s, co.hbm_bytes_per_s, co.link_bytes_per_s) <= 0:
                    raise ValueError("non-positive throughput coefficient")
            except (TypeError, ValueError):
                dropped += 1
                continue
            self._doc["coefficients"][plat] = co.as_dict()
        for plat, recs in (raw.get("records") or {}).items():
            keep = [r for r in recs if _valid_record(r)] if isinstance(recs, list) else []
            dropped += (len(recs) if isinstance(recs, list) else 1) - len(keep)
            if keep:
                self._doc["records"][plat] = keep
        if dropped:
            _warn_once(
                f"costmodel calibration cache {self.path}: dropped {dropped}"
                f" invalid entr{'y' if dropped == 1 else 'ies'}"
            )
            _rledger.record(
                "costmodel.cache_load",
                cause=f"{dropped} entries failed validation",
                fallback="recalibrate",
                path=str(self.path),
            )
        return self._doc

    def save(self) -> None:
        doc = self._load()
        payload = {
            "version": CALIBRATION_VERSION,
            "model_version": COST_MODEL_VERSION,
            "coefficients": doc["coefficients"],
            "records": doc["records"],
        }

        def _write_once() -> None:
            tmp = None
            try:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                fd, tmp = tempfile.mkstemp(
                    dir=str(self.path.parent), prefix=self.path.name, suffix=".tmp"
                )
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except OSError:
                if tmp is not None:
                    try:
                        os.unlink(tmp)
                    except OSError:
                        pass
                raise

        try:
            _retry_call(
                _write_once,
                retries=2,
                base_delay=0.01,
                retry_on=(OSError,),
                site="costmodel.cache_save",
            )
        except OSError:
            pass

    # -- access --------------------------------------------------------------

    def coefficients(self, platform: str) -> Optional[CostCoefficients]:
        cd = self._load()["coefficients"].get(platform)
        if cd is None:
            return None
        return CostCoefficients.from_dict(
            {**cd, "platform": platform, "source": "calibrated"}
        )

    def set_coefficients(self, coeffs: CostCoefficients) -> None:
        payload = coeffs.as_dict()
        payload["source"] = "calibrated"
        self._load()["coefficients"][coeffs.platform] = payload

    def records(self, platform: str) -> List[Dict[str, Any]]:
        return list(self._load()["records"].get(platform, []))

    def add_records(self, platform: str, recs: Sequence[Mapping[str, Any]]) -> int:
        """Append valid records (invalid ones are counted and skipped)."""
        good = [dict(r) for r in recs if _valid_record(r)]
        if good:
            self._load()["records"].setdefault(platform, []).extend(good)
        return len(good)


_WARNED: set = set()


def _warn_once(msg: str) -> None:
    if msg not in _WARNED:
        _WARNED.add(msg)
        warnings.warn(msg, stacklevel=3)


_DEFAULT_CACHE: Optional[CalibrationCache] = None


def default_cache() -> CalibrationCache:
    """Process-wide cache instance (respects $REPRO_COSTMODEL_CACHE)."""
    global _DEFAULT_CACHE
    want = Path(os.environ.get(_ENV_CACHE, DEFAULT_CACHE_FILENAME))
    if _DEFAULT_CACHE is None or _DEFAULT_CACHE.path != want:
        _DEFAULT_CACHE = CalibrationCache()
    return _DEFAULT_CACHE


# ---------------------------------------------------------------------------
# Probes + fitting
# ---------------------------------------------------------------------------


def run_probes(
    shapes: Sequence[Tuple[int, int, int]] = PROBE_SHAPES,
    *,
    backend: Optional[str] = None,
    reps: int = 3,
) -> List[Dict[str, Any]]:
    """Time the probe GEMMs through the plan/execute path.

    Each probe builds (or cache-hits) an ordinary `api.plan` and times the
    raw executor with `autotune.measure_best_ms` — the measurement IS the
    serving hot path, not a synthetic kernel loop.  A probe that fails to
    build or run is skipped with a ledger record; calibration degrades to
    fewer points instead of crashing.
    """
    import jax.numpy as jnp

    from repro.kernels import api
    from repro.kernels.autotune import measure_best_ms

    records: List[Dict[str, Any]] = []
    for m, k, n in shapes:
        try:
            spec = api.GemmSpec(m=m, k=k, n=n)
            p = api.plan(spec, backend=backend)
            a = jnp.ones((m, k), jnp.float32)
            b = jnp.ones((k, n), jnp.float32)
            with _obs.span("calibrate.probe", mkn=f"{m}x{k}x{n}",
                           backend=p.backend):
                ms = measure_best_ms(p.executor, a, b, None, None, reps=reps)
        except Exception as e:
            _rledger.record(
                "costmodel.probe",
                cause=f"{type(e).__name__}: {e}",
                fallback="skip-probe",
                mkn=f"{m}x{k}x{n}",
            )
            continue
        records.append(
            {
                "terms": terms_from_describe(p.describe()),
                "ms": ms,
                "source": "probe",
                "key": f"{m}x{k}x{n}|{p.backend}",
            }
        )
    return records


def _fit_error(
    records: Sequence[Mapping[str, Any]], coeffs: CostCoefficients
) -> float:
    """Mean |log(predicted / measured)| — scale-free, so a 2x miss on a 50us
    probe weighs the same as a 2x miss on a 5ms one."""
    err = 0.0
    for rec in records:
        pred = predict(rec["terms"], coeffs)["total_s"]
        meas = rec["ms"] / 1e3
        err += abs(math.log(max(pred, 1e-12) / meas))
    return err / max(1, len(records))


def fit_coefficients(
    records: Sequence[Mapping[str, Any]],
    *,
    init: Optional[CostCoefficients] = None,
    platform: Optional[str] = None,
    rounds: int = 4,
) -> CostCoefficients:
    """Deterministic coordinate-descent hillclimb over the coefficients.

    One coefficient moves at a time by a fixed multiplicative step ladder
    (latency terms that start at zero get an absolute seed ladder instead);
    only strict error improvements are kept, so the fit is reproducible for
    a fixed record list and coefficients a record set never exercises
    (e.g. link bandwidth with no collective probes) keep their defaults.
    """
    import dataclasses

    coeffs = init or default_coefficients(platform)
    if platform is not None:
        coeffs = dataclasses.replace(coeffs, platform=platform)
    if not records:
        return coeffs
    best_err = _fit_error(records, coeffs)
    steps = (4.0, 2.0, 1.4, 1.15)
    zero_seeds = (1e-6, 1e-5, 1e-4, 1e-3)
    for _ in range(rounds):
        improved = False
        for field in _FIT_FIELDS:
            cur = getattr(coeffs, field)
            cands = list(zero_seeds) if cur == 0 else [
                cur * f for f in steps
            ] + [cur / f for f in steps]
            for cand in cands:
                trial = dataclasses.replace(coeffs, **{field: cand})
                err = _fit_error(records, trial)
                if err < best_err - 1e-12:
                    coeffs, best_err, improved = trial, err, True
        if not improved:
            break
    return dataclasses.replace(coeffs, source="calibrated")


def calibrate(
    *,
    platform: Optional[str] = None,
    cache: Optional[CalibrationCache] = None,
    shapes: Sequence[Tuple[int, int, int]] = PROBE_SHAPES,
    backend: Optional[str] = None,
    persist: bool = True,
) -> CostCoefficients:
    """Probe, fit, persist, and install the platform's coefficients."""
    import jax

    platform = platform or jax.default_backend()
    cache = cache or default_cache()
    records = run_probes(shapes, backend=backend)
    cache.add_records(platform, records)
    all_records = cache.records(platform)
    coeffs = fit_coefficients(all_records, platform=platform)
    cache.set_coefficients(coeffs)
    if persist:
        cache.save()
    clear_coefficients_memo()
    return coeffs


def ingest(
    records: Sequence[Mapping[str, Any]],
    *,
    platform: Optional[str] = None,
    cache: Optional[CalibrationCache] = None,
    refit: bool = True,
    persist: bool = True,
) -> int:
    """Fold externally measured records (e.g. `launch/hillclimb.py` variant
    runs) into the calibration file; optionally refit on the union."""
    import jax

    platform = platform or jax.default_backend()
    cache = cache or default_cache()
    with _obs.span("calibrate.ingest", n=len(records), platform=platform) as sp:
        added = cache.add_records(platform, records)
        if added and refit:
            coeffs = fit_coefficients(cache.records(platform), platform=platform)
            cache.set_coefficients(coeffs)
            clear_coefficients_memo()
        # `added == 0` means nothing changed (all records invalid or empty
        # batch) — skip the save so a no-op flush never creates a cache file
        if persist and added:
            cache.save()
        sp.set("added", added)
    return added


# ---------------------------------------------------------------------------
# The planner's read path
# ---------------------------------------------------------------------------

_COEFFS_MEMO: Dict[Tuple[str, str], CostCoefficients] = {}


def current_coefficients(platform: Optional[str] = None) -> CostCoefficients:
    """Coefficients the planner should use NOW: the calibration file's fit
    for this platform when present, shipped defaults otherwise.  Memoized
    per (platform, cache path) — after `scheduler.warmup()` touches it once
    no plan-time decision performs I/O.  A broken cache degrades to
    defaults (with the cache's own quarantine warning), never raises."""
    import jax

    platform = platform or jax.default_backend()
    cache = default_cache()
    memo_key = (platform, str(cache.path))
    got = _COEFFS_MEMO.get(memo_key)
    if got is None:
        try:
            got = cache.coefficients(platform) or default_coefficients(platform)
        except Exception as e:  # pragma: no cover — load already degrades
            _rledger.record(
                "costmodel.coefficients",
                cause=f"{type(e).__name__}: {e}",
                fallback="defaults",
            )
            got = default_coefficients(platform)
        _COEFFS_MEMO[memo_key] = got
    return got


def clear_coefficients_memo() -> None:
    """Test hook: drop the per-process memo (not the persistent cache)."""
    _COEFFS_MEMO.clear()
    global _DEFAULT_CACHE
    _DEFAULT_CACHE = None
