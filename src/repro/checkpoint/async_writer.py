"""Async checkpointing: snapshot on the main thread, serialize on a worker.

The train loop calls `submit(step, tree)`: leaves are fetched to host
(device_get — cheap relative to serialization) and the npz write + rename
happens on a background thread, so the TPUs keep stepping.  Errors surface on
the next submit/wait and again in `close()`/`__exit__` — a failed write never
silently drops a checkpoint.

Transient write failures (full disk flushed by a janitor, NFS blips) are
absorbed by bounded retry with exponential backoff (`resilience.policy
.retry_call`, site="checkpoint.write"); each retry is recorded in the
resilience ledger so "succeeded on attempt 2" is visible after the fact
(DESIGN.md §11).
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.resilience import faults as _faults
from repro.resilience.policy import retry_call as _retry_call

__all__ = ["AsyncCheckpointer"]


class AsyncCheckpointer:
    """Background checkpoint writer; usable as a context manager.

    `retries`/`backoff` bound the per-checkpoint write retries (exponential
    backoff, capped at `max_backoff` seconds).  `retries=0` disables retry.
    """

    def __init__(
        self,
        manager: CheckpointManager,
        *,
        retries: int = 2,
        backoff: float = 0.05,
        max_backoff: float = 1.0,
    ):
        self.manager = manager
        self.retries = retries
        self.backoff = backoff
        self.max_backoff = max_backoff
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._closed = False
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _save_with_retry(self, step: int, host_tree: Any, meta) -> None:
        def _save_once() -> None:
            _faults.check("checkpoint.write", step=step)
            self.manager.save(step, host_tree, meta)

        _retry_call(
            _save_once,
            retries=self.retries,
            base_delay=self.backoff,
            max_delay=self.max_backoff,
            retry_on=(OSError, _faults.FaultError),
            site="checkpoint.write",
        )

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, meta = item
            try:
                self._save_with_retry(step, host_tree, meta)
            except BaseException as e:  # surfaced on next submit/wait/close
                self._err = e
            finally:
                self._q.task_done()

    def _raise_pending(self) -> None:
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError("async checkpoint write failed") from err

    def submit(self, step: int, tree: Any, meta: Optional[dict] = None) -> None:
        if self._closed:
            raise RuntimeError("submit() on a closed AsyncCheckpointer")
        self._raise_pending()
        # Snapshot NOW: device_get on an already-host numpy leaf is a no-op
        # *reference*, so force a copy — otherwise the caller mutating the
        # tree after submit() would corrupt the pending checkpoint.
        import numpy as np

        host_tree = jax.tree.map(
            lambda leaf: np.array(jax.device_get(leaf), copy=True), tree
        )
        self._q.put((step, host_tree, meta))

    def wait(self) -> None:
        self._q.join()
        self._raise_pending()

    def close(self) -> None:
        """Drain the queue, stop the worker, then surface any pending error.

        The thread is always stopped, even when the last write failed — the
        error raises AFTER shutdown so callers are never left with a live
        worker they cannot rejoin.
        """
        if self._closed:
            self._raise_pending()
            return
        self._closed = True
        self._q.join()
        self._q.put(None)
        self._thread.join()
        self._raise_pending()

    def __enter__(self) -> "AsyncCheckpointer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
            return
        # An exception is already propagating: still shut down cleanly, but
        # don't let a pending-write error mask the original exception.
        try:
            self.close()
        except RuntimeError:
            pass
