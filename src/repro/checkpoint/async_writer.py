"""Async checkpointing: snapshot on the main thread, serialize on a worker.

The train loop calls `submit(step, tree)`: leaves are fetched to host
(device_get — cheap relative to serialization) and the npz write + rename
happens on a background thread, so the TPUs keep stepping.  `wait()` drains
the queue (called before exit and before any restore).  Errors surface on the
next submit/wait — a failed write never silently drops a checkpoint.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Optional

import jax

from repro.checkpoint.manager import CheckpointManager

__all__ = ["AsyncCheckpointer"]


class AsyncCheckpointer:
    def __init__(self, manager: CheckpointManager):
        self.manager = manager
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, meta = item
            try:
                self.manager.save(step, host_tree, meta)
            except BaseException as e:  # surfaced on next submit/wait
                self._err = e
            finally:
                self._q.task_done()

    def _raise_pending(self) -> None:
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError("async checkpoint write failed") from err

    def submit(self, step: int, tree: Any, meta: Optional[dict] = None) -> None:
        self._raise_pending()
        # Snapshot NOW: device_get on an already-host numpy leaf is a no-op
        # *reference*, so force a copy — otherwise the caller mutating the
        # tree after submit() would corrupt the pending checkpoint.
        import numpy as np

        host_tree = jax.tree.map(
            lambda leaf: np.array(jax.device_get(leaf), copy=True), tree
        )
        self._q.put((step, host_tree, meta))

    def wait(self) -> None:
        self._q.join()
        self._raise_pending()

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join()
