"""Atomic, versioned checkpointing with elastic re-mesh restore.

Layout:  <dir>/step_<N>/arrays.npz + meta.json   (tmp-dir + os.replace rename
gives single-writer atomicity; a crashed write can never be mistaken for a
complete checkpoint).  keep_n old steps are garbage-collected after a
successful save.

Checkpoints store *logical* (unsharded) arrays + the pytree structure, so a
restore can target ANY mesh shape: `restore(..., shardings=tree)` device_puts
each leaf with the new mesh's NamedShardings — this is the elastic-scaling
path (N pods -> M pods) used by `launch/train.py --resume auto` and tested in
tests/test_checkpoint.py.

Reads are checksummed (DESIGN.md §11): `save` records a sha256 content
digest of arrays.npz in meta.json, and `restore` verifies it before
deserializing — a checkpoint whose bytes rotted (or were truncated by a
dying writer that somehow survived the atomic rename) is quarantined to
`<dir>.corrupt`, recorded in the resilience ledger, and surfaced as
`CorruptCheckpointError`, so `all_steps()` never offers it for resume
again (mirrors the autotune-cache quarantine).  Pre-digest checkpoints
(no recorded digest) restore unverified for compatibility.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.resilience import ledger as _ledger

__all__ = ["CheckpointManager", "CorruptCheckpointError"]


class CorruptCheckpointError(OSError):
    """arrays.npz bytes do not match the digest recorded at save time."""


def _file_digest(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return "sha256:" + h.hexdigest()


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        # npz can't hold bf16 natively: store raw bits + dtype tag.
        if arr.dtype == jax.numpy.bfloat16:
            flat[key + "::bf16"] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.directory = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra_meta: Optional[dict] = None) -> str:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_save_")
        try:
            flat = _flatten(tree)
            arrays_path = os.path.join(tmp, "arrays.npz")
            np.savez(arrays_path, **flat)
            treedef = jax.tree_util.tree_structure(tree)
            meta = {
                "step": step,
                "treedef": str(treedef),
                "digest": _file_digest(arrays_path),
                **(extra_meta or {}),
            }
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):  # overwrite-same-step: replace atomically
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep_n)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.startswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int,
        like: Any,
        shardings: Optional[Any] = None,
    ) -> Any:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs).  With `shardings` (matching tree of NamedShardings)
        each leaf is device_put onto the *current* mesh — elastic re-mesh."""
        self._verify_digest(step)
        path = os.path.join(self.directory, f"step_{step:08d}", "arrays.npz")
        data = np.load(path)
        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves_like)
        )
        out = []
        for (pth, leaf), shd in zip(leaves_like, shard_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
            if key + "::bf16" in data:
                arr = data[key + "::bf16"].view(jax.numpy.bfloat16)
            elif key in data:
                arr = data[key]
            else:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            expect = tuple(leaf.shape)
            if tuple(arr.shape) != expect:
                raise ValueError(f"{key}: checkpoint shape {arr.shape} != model {expect}")
            out.append(jax.device_put(arr, shd) if shd is not None else jax.numpy.asarray(arr))
        return treedef.unflatten(out)

    def _verify_digest(self, step: int) -> None:
        """Quarantine + raise if arrays.npz fails its recorded checksum.

        `all_steps()` only parses `step_<digits>` names, so the `.corrupt`
        -suffixed quarantine directory drops out of the resume candidates.
        """
        step_dir = os.path.join(self.directory, f"step_{step:08d}")
        recorded = self.meta(step).get("digest")
        if recorded is None:  # pre-digest checkpoint: restore unverified
            return
        actual = _file_digest(os.path.join(step_dir, "arrays.npz"))
        if actual == recorded:
            return
        quarantine = step_dir + ".corrupt"
        shutil.rmtree(quarantine, ignore_errors=True)
        os.replace(step_dir, quarantine)
        _ledger.record(
            "checkpoint.read",
            cause=f"digest mismatch: {actual} != recorded {recorded}",
            fallback="quarantine",
            step=step,
        )
        raise CorruptCheckpointError(
            f"checkpoint step {step} failed its content digest "
            f"({actual} != {recorded}); quarantined to {quarantine}"
        )

    def meta(self, step: int) -> dict:
        with open(os.path.join(self.directory, f"step_{step:08d}", "meta.json")) as f:
            return json.load(f)
