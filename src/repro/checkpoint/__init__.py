from repro.checkpoint.async_writer import AsyncCheckpointer
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager", "AsyncCheckpointer"]
