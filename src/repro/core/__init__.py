"""Core: the paper's contribution — mesh array, scrambling transformation, symmetries."""

from repro.core.mesh_array import (
    SimResult,
    mesh_completion_times,
    mesh_matmul_reference,
    mesh_start_times,
    simulate_mesh,
    simulate_standard,
    standard_completion_times,
)
from repro.core.scramble import (
    apply_scramble,
    apply_scramble_power,
    block_scramble_perm,
    cycle_decomposition,
    scramble_order,
    scramble_perm,
    sigma,
    sigma_table,
    unscramble,
)
from repro.core.symmetries import (
    check_antidiagonal_structure,
    check_mirror_rows,
    check_row1_diagonal,
    paper_symmetric_bound,
    symmetric_readout_schedule,
    symmetric_readout_steps,
)

__all__ = [
    "SimResult",
    "simulate_mesh",
    "simulate_standard",
    "mesh_matmul_reference",
    "mesh_start_times",
    "mesh_completion_times",
    "standard_completion_times",
    "sigma",
    "sigma_table",
    "scramble_perm",
    "block_scramble_perm",
    "apply_scramble",
    "apply_scramble_power",
    "unscramble",
    "cycle_decomposition",
    "scramble_order",
    "check_row1_diagonal",
    "check_mirror_rows",
    "check_antidiagonal_structure",
    "symmetric_readout_schedule",
    "symmetric_readout_steps",
    "paper_symmetric_bound",
]
