"""Cycle-accurate simulators for the Kak mesh array and the standard systolic array.

These are the *reference semantics* of the paper: every node is a MAC cell
(paper Fig. 3); the simulators advance global clock steps with `jax.lax.scan`
(one scan step == one array clock step) and reproduce, cycle by cycle:

  * mesh array:     2n-1 steps, output in the scrambled arrangement sigma_n,
  * standard array: 3n-2 steps, output in the standard arrangement,
  * symmetric-product early readout by ~ floor(3n/2) steps (paper: <= n+1+n/2).

Schedules
---------
Node (i, j) of the mesh array performs its k-th MAC (k = 1..n) at step
``start(i, j) + k - 1`` and computes c_{sigma(i,j)}.  Two start models are
provided (the paper's figures are not machine-readable; both reproduce the
2n-1 total and the sigma_n arrangement — see DESIGN.md §Paper-fidelity):

  * ``antidiagonal`` (default): start = ceil((i+j)/2).  This is the timing
    implied by the two-layered construction (A-diagonals paired with
    B-anti-diagonals) and is the only model consistent with the paper's
    symmetric-matrix claim of ~3n/2+1 steps — validated in
    `core/symmetries.py` and `benchmarks/bench_symmetric.py`.
  * ``corner``: start = max(i, j) (single-corner feeding, no wraparound).
    Same 2n-1 total; no symmetric early-readout gain.

The standard array uses start = i + j - 1 (the zero-padding skew), total 3n-2.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scramble import _scramble_perm_np

__all__ = [
    "SimResult",
    "mesh_start_times",
    "standard_start_times",
    "mesh_completion_times",
    "standard_completion_times",
    "simulate_mesh",
    "simulate_standard",
    "mesh_matmul_reference",
]

StartModel = Literal["antidiagonal", "corner"]


@dataclasses.dataclass
class SimResult:
    """Output of a cycle-accurate run.

    output:           (n, n) accumulator state after the final step.  For the
                      mesh array this is C in the *scrambled* arrangement;
                      for the standard array it is C itself.
    steps:            number of clock steps executed (2n-1 mesh, 3n-2 standard).
    completion_times: (n, n) int32 — the step at which each node performed its
                      final MAC.
    history:          (steps, n, n) accumulator after every step (only if
                      ``record_history=True``), used by the early-readout
                      analysis in `core/symmetries.py`.
    """

    output: jax.Array
    steps: int
    completion_times: np.ndarray
    history: jax.Array | None = None


def mesh_start_times(n: int, model: StartModel = "antidiagonal") -> np.ndarray:
    """(n, n) start step (1-indexed) of each mesh node."""
    i = np.arange(1, n + 1)[:, None]
    j = np.arange(1, n + 1)[None, :]
    if model == "antidiagonal":
        return (i + j + 1) // 2
    if model == "corner":
        return np.maximum(i, j)
    raise ValueError(f"unknown start model {model!r}")


def standard_start_times(n: int) -> np.ndarray:
    """(n, n) start step of each standard-array node (zero-padding skew)."""
    i = np.arange(1, n + 1)[:, None]
    j = np.arange(1, n + 1)[None, :]
    return i + j - 1


def mesh_completion_times(n: int, model: StartModel = "antidiagonal") -> np.ndarray:
    return mesh_start_times(n, model) + n - 1


def standard_completion_times(n: int) -> np.ndarray:
    return standard_start_times(n) + n - 1


def _simulate(
    a: jax.Array,
    b: jax.Array,
    start: np.ndarray,
    p_idx: np.ndarray,
    q_idx: np.ndarray,
    total_steps: int,
    record_history: bool,
) -> Tuple[jax.Array, jax.Array | None]:
    """Shared clock loop.

    Node (i, j) accumulates a[p_idx[i,j], k] * b[k, q_idx[i,j]] where
    k = t - start[i,j] (0-indexed MAC counter) whenever 0 <= k < n.
    One scan iteration == one clock step, exactly as in the paper's Fig. 3
    node semantics (multiply the incoming pair, add to the accumulator).
    """
    n = a.shape[0]
    start_j = jnp.asarray(start)  # (n, n), 1-indexed step of first MAC
    p_j = jnp.asarray(p_idx)  # (n, n) 0-indexed row of A consumed by the node
    q_j = jnp.asarray(q_idx)  # (n, n) 0-indexed col of B consumed by the node

    def step(acc, t):
        k = t - start_j  # 0-indexed MAC counter at this node, this step
        active = (k >= 0) & (k < n)
        k_safe = jnp.clip(k, 0, n - 1)
        # Incoming operand pair at each node for this clock tick.
        a_val = a[p_j, k_safe]
        b_val = b[k_safe, q_j]
        acc = acc + jnp.where(active, a_val * b_val, jnp.zeros((), a.dtype))
        return acc, (acc if record_history else None)

    acc0 = jnp.zeros((n, n), dtype=jnp.result_type(a.dtype, b.dtype))
    ts = jnp.arange(1, total_steps + 1)
    final, hist = jax.lax.scan(step, acc0, ts)
    return final, hist


@partial(jax.jit, static_argnames=("model", "record_history"))
def _simulate_mesh_jit(a, b, *, model: StartModel, record_history: bool):
    n = a.shape[0]
    perm = _scramble_perm_np(n)  # flat: cell -> (p*n+q)
    p_idx = (perm // n).reshape(n, n)
    q_idx = (perm % n).reshape(n, n)
    start = mesh_start_times(n, model)
    total = 2 * n - 1
    return _simulate(a, b, start, p_idx, q_idx, total, record_history)


def simulate_mesh(
    a: jax.Array,
    b: jax.Array,
    *,
    model: StartModel = "antidiagonal",
    record_history: bool = False,
) -> SimResult:
    """Run the mesh array on n x n inputs; returns C in scrambled arrangement.

    Asserts nothing — validation lives in tests, which check that
    `unscramble(output) == a @ b` and that the step count is exactly 2n-1.
    """
    n = a.shape[0]
    if a.shape != (n, n) or b.shape != (n, n):
        raise ValueError(f"square n x n inputs required, got {a.shape} x {b.shape}")
    out, hist = _simulate_mesh_jit(a, b, model=model, record_history=record_history)
    return SimResult(
        output=out,
        steps=2 * n - 1,
        completion_times=mesh_completion_times(n, model),
        history=hist if record_history else None,
    )


@partial(jax.jit, static_argnames=("record_history",))
def _simulate_standard_jit(a, b, *, record_history: bool):
    n = a.shape[0]
    idx = np.arange(n)
    p_idx = np.broadcast_to(idx[:, None], (n, n))  # node (i,j) computes c_ij
    q_idx = np.broadcast_to(idx[None, :], (n, n))
    start = standard_start_times(n)
    total = 3 * n - 2
    return _simulate(a, b, start, p_idx, q_idx, total, record_history)


def simulate_standard(
    a: jax.Array, b: jax.Array, *, record_history: bool = False
) -> SimResult:
    """Run the standard (Mead–Conway/Kung) array; output in standard arrangement."""
    n = a.shape[0]
    if a.shape != (n, n) or b.shape != (n, n):
        raise ValueError(f"square n x n inputs required, got {a.shape} x {b.shape}")
    out, hist = _simulate_standard_jit(a, b, record_history=record_history)
    return SimResult(
        output=out,
        steps=3 * n - 2,
        completion_times=standard_completion_times(n),
        history=hist if record_history else None,
    )


def mesh_matmul_reference(a: jax.Array, b: jax.Array) -> jax.Array:
    """One-shot functional semantics of the mesh array: scrambled(a @ b).

    Equivalent to ``simulate_mesh(a, b).output`` but as a single gather over
    the XLA matmul — the form the Pallas kernel and the distributed systolic
    matmul are tested against.
    """
    n = a.shape[-1]
    c = a @ b
    perm = jnp.asarray(_scramble_perm_np(n))
    flat = c.reshape(*c.shape[:-2], n * n)
    return jnp.take(flat, perm, axis=-1).reshape(c.shape)
