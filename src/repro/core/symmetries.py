"""Symmetry properties of the mesh-array arrangement (Kak 2010).

Implements and validates the paper's three symmetry claims, and the
symmetric-product early-readout schedule:

  1. Row 1 of the arrangement carries the diagonal c_11, c_22, ..., c_nn.
  2. Mirror rows: for r in 2..n, rows r and n+2-r are reverse-and-transpose
     images of each other (paper states this as "mirror reversed image" with
     subscripts swapped); for even n the middle row n/2+1 is self-symmetric.
  3. Anti-diagonal structure: along anti-diagonal d = i+j, one subscript is
     fixed (first subscript for even d, second for odd d), and the other
     follows the zig-zag (m, m-2, ..., 1|2, ..., m-1).

  4. Early readout: when the product C is symmetric (e.g. Gram products A·Aᵀ,
     or commuting symmetric pairs), each off-row-1 value may be read from
     whichever of the two mirror cells completes first; all values are then
     available by floor(3n/2) steps (paper bound: <= n+1+n/2), versus 2n-1
     for a general product and 3n-2 for the standard array.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.mesh_array import mesh_completion_times
from repro.core.scramble import sigma, sigma_table, scrambled_cell_of

__all__ = [
    "check_row1_diagonal",
    "check_mirror_rows",
    "check_antidiagonal_structure",
    "mirror_cell",
    "symmetric_readout_schedule",
    "symmetric_readout_steps",
    "paper_symmetric_bound",
]


def check_row1_diagonal(n: int) -> bool:
    """Claim 1: sigma(1, j) == (j, j) for all j."""
    return all(sigma(n, 1, j) == (j, j) for j in range(1, n + 1))


def mirror_cell(n: int, i: int, j: int) -> Tuple[int, int]:
    """The reverse-and-transpose mirror partner of cell (i, j), rows 2..n.

    Row r column k  <->  row n+2-r column n+1-k.  Row 1 has no partner (it
    carries the diagonal, whose transposes are themselves).
    """
    if i == 1:
        raise ValueError("row 1 has no mirror partner")
    return n + 2 - i, n + 1 - j


def check_mirror_rows(n: int) -> bool:
    """Claim 2: entry at (i, j) is the transpose of the entry at mirror(i, j).

    Covers both the paired rows (2..n/2 vs n/2+2..n et al.) and the middle-row
    self-symmetry for even n (where mirror maps the row onto itself).
    """
    tab = sigma_table(n)
    for i in range(2, n + 1):
        for j in range(1, n + 1):
            mi, mj = mirror_cell(n, i, j)
            p, q = tab[i - 1][j - 1]
            mp, mq = tab[mi - 1][mj - 1]
            if (p, q) != (mq, mp):
                return False
    return True


def check_antidiagonal_structure(n: int) -> bool:
    """Claim 3: fixed subscript alternates with anti-diagonal parity.

    Even d = i+j fixes the first subscript, odd d fixes the second; the fixed
    value is d-1 for d <= n+1 and 2n+2-d beyond.
    """
    tab = sigma_table(n)
    for i in range(1, n + 1):
        for j in range(1, n + 1):
            d = i + j
            p, q = tab[i - 1][j - 1]
            fixed = d - 1 if d <= n + 1 else 2 * n + 2 - d
            if d % 2 == 0:
                if p != fixed:
                    return False
            else:
                if q != fixed:
                    return False
    return True


def symmetric_readout_schedule(n: int) -> Dict[Tuple[int, int], Tuple[Tuple[int, int], int]]:
    """For each product entry (p, q): the cell to read it from and the step.

    Assumes C is symmetric, so c_pq may be read from the cell holding c_qp.
    Returns {(p, q): ((i, j), step)} using the anti-diagonal start model
    (the model under which the paper's 3n/2-ish claim holds — DESIGN.md).
    """
    times = mesh_completion_times(n, "antidiagonal")
    out: Dict[Tuple[int, int], Tuple[Tuple[int, int], int]] = {}
    for p in range(1, n + 1):
        for q in range(1, n + 1):
            best_cell, best_t = None, None
            for pp, qq in {(p, q), (q, p)}:
                cell = scrambled_cell_of(n, pp, qq)
                t = int(times[cell[0] - 1, cell[1] - 1])
                if best_t is None or t < best_t:
                    best_cell, best_t = cell, t
            out[(p, q)] = (best_cell, best_t)
    return out


def symmetric_readout_steps(n: int) -> int:
    """Worst-case step at which the last distinct value of a symmetric product
    becomes readable.  Empirically floor(3n/2); paper bound n+1+n/2."""
    return max(t for _, t in symmetric_readout_schedule(n).values())


def paper_symmetric_bound(n: int) -> int:
    """The paper's claimed bound: 'the integer less than or equal to n+1+n/2'."""
    return n + 1 + n // 2


def general_readout_steps(n: int) -> int:
    """Readout horizon without symmetry: all cells done, = 2n-1."""
    return int(mesh_completion_times(n, "antidiagonal").max())
