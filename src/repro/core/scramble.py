"""The mesh-array scrambling transformation S (Kak 2010).

The mesh array computes C = AB but deposits c_{sigma(i,j)} at node (i,j) for a
structured permutation sigma_n.  Multiplying by the identity exhibits sigma_n as
a scrambling transformation S on the n^2 matrix entries; this module implements:

  * the closed form of sigma_n (verified against every table printed in the
    paper, n = 3..7),
  * S / S^{-1} / S^k application (S^k in O(1) metadata via cycle decomposition,
    never as k repeated gathers),
  * cycle decomposition and the order of S (paper: 7 for n=3, 7 for n=4,
    20 for n=5),
  * flat-index permutation vectors consumed by the Pallas scramble kernel and
    by the fused mesh-matmul output arrangement.

Closed form (derived in DESIGN.md from the paper's anti-diagonal rule: "the
first and the second subscripts are fixed in alternate diagonals and
anti-diagonals", plus the zig-zag sequence along each anti-diagonal):

  for 1-indexed cell (i, j) with d = i + j:
      if d <= n + 1:  m, f, r = d - 1,      d - 1,      i
      else:           m, f, r = 2n + 1 - d, 2n + 2 - d, i - (d - n) + 1
      h = ceil(m / 2)
      v = m - 2(r - 1)                      if r <= h
        = 2(r - h)      (m odd)             otherwise
        = 2(r - h) - 1  (m even)
      sigma(i, j) = (f, v) if d even else (v, f)

m is the anti-diagonal length, f the fixed subscript value, r the 1-indexed
position along the anti-diagonal, v the zig-zag (m, m-2, ..., 1|2, ..., m-1)
value.
"""

from __future__ import annotations

import functools
import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "sigma",
    "sigma_table",
    "scramble_perm",
    "inverse_perm",
    "power_perm",
    "apply_scramble",
    "unscramble",
    "apply_scramble_power",
    "cycle_decomposition",
    "scramble_order",
    "block_scramble_perm",
]


def sigma(n: int, i: int, j: int) -> Tuple[int, int]:
    """sigma_n applied to 1-indexed cell (i, j) -> 1-indexed subscripts (p, q).

    Node (i, j) of the n x n mesh array computes c_{p,q} of C = AB.
    """
    if not (1 <= i <= n and 1 <= j <= n):
        raise ValueError(f"cell ({i},{j}) out of range for n={n}")
    d = i + j
    if d <= n + 1:
        m, f, r = d - 1, d - 1, i
    else:
        m, f, r = 2 * n + 1 - d, 2 * n + 2 - d, i - (d - n) + 1
    h = (m + 1) // 2
    if r <= h:
        v = m - 2 * (r - 1)
    else:
        v = 2 * (r - h) if m % 2 == 1 else 2 * (r - h) - 1
    return (f, v) if d % 2 == 0 else (v, f)


@functools.lru_cache(maxsize=None)
def sigma_table(n: int) -> Tuple[Tuple[Tuple[int, int], ...], ...]:
    """The full n x n arrangement table: entry [i-1][j-1] = sigma(n, i, j)."""
    return tuple(
        tuple(sigma(n, i, j) for j in range(1, n + 1)) for i in range(1, n + 1)
    )


@functools.lru_cache(maxsize=None)
def _scramble_perm_np(n: int) -> np.ndarray:
    """Flat permutation vector: scrambled.flat[cell] = standard.flat[perm[cell]].

    cell = (i-1)*n + (j-1) indexes the mesh node; perm[cell] = (p-1)*n + (q-1)
    where sigma(i, j) = (p, q).
    """
    perm = np.empty(n * n, dtype=np.int32)
    for i in range(1, n + 1):
        for j in range(1, n + 1):
            p, q = sigma(n, i, j)
            perm[(i - 1) * n + (j - 1)] = (p - 1) * n + (q - 1)
    return perm


def scramble_perm(n: int) -> np.ndarray:
    """Flat gather indices realizing S (copy — safe to mutate)."""
    return _scramble_perm_np(n).copy()


def inverse_perm(perm: np.ndarray) -> np.ndarray:
    """Inverse of a flat permutation vector."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.shape[0], dtype=perm.dtype)
    return inv


def power_perm(perm: np.ndarray, k: int) -> np.ndarray:
    """perm composed with itself k times (k may be negative), via cycles.

    O(n^2) regardless of k: each element advances k mod (its cycle length)
    positions along its cycle.  This is what makes S^k usable as a keyed
    scrambling system — the effective key is k mod order(S).
    """
    size = perm.shape[0]
    out = np.empty_like(perm)
    seen = np.zeros(size, dtype=bool)
    for start in range(size):
        if seen[start]:
            continue
        cyc = [start]
        seen[start] = True
        cur = int(perm[start])
        while cur != start:
            seen[cur] = True
            cyc.append(cur)
            cur = int(perm[cur])
        clen = len(cyc)
        shift = k % clen
        for idx, elem in enumerate(cyc):
            out[elem] = cyc[(idx + shift) % clen]
    return out


def cycle_decomposition(n: int) -> List[List[Tuple[int, int]]]:
    """Cycles of S written over 1-indexed subscripts, paper convention.

    The paper writes S as the permutation sending standard position (p, q) to
    the mesh cell that holds c_{p,q}; cycles are traced through that map.
    Reproduces e.g. n=4: (11)(42)(12 22 31 32 14 44 21)(13 33 41 34 23 24 43).
    """
    perm = _scramble_perm_np(n)
    # position (p,q) content moves to cell inv[(p,q)] under one application.
    inv = inverse_perm(perm)
    seen = np.zeros(n * n, dtype=bool)
    cycles: List[List[Tuple[int, int]]] = []
    for start in range(n * n):
        if seen[start]:
            continue
        cyc = []
        cur = start
        while not seen[cur]:
            seen[cur] = True
            cyc.append((cur // n + 1, cur % n + 1))
            cur = int(inv[cur])
        cycles.append(cyc)
    return cycles


@functools.lru_cache(maxsize=None)
def scramble_order(n: int) -> int:
    """Order (period) of S: lcm of cycle lengths.  Paper: 7, 7, 20 for n=3,4,5."""
    return math.lcm(*[len(c) for c in cycle_decomposition(n)])


# ---------------------------------------------------------------------------
# JAX application.  These are the public "scrambling system" entry points used
# by the models (privacy transform) and by examples/scrambling_demo.py.
# ---------------------------------------------------------------------------


def apply_scramble(x: jax.Array, k: int = 1) -> jax.Array:
    """Apply S^k to the trailing two (n, n) dims of x.

    k may be negative (unscrambling).  The permutation is compile-time
    metadata: lowering produces a single gather regardless of |k|.
    """
    n = x.shape[-1]
    if x.shape[-2] != n:
        raise ValueError(f"apply_scramble needs trailing (n, n) dims, got {x.shape}")
    perm = power_perm(_scramble_perm_np(n), k)
    flat = x.reshape(*x.shape[:-2], n * n)
    out = jnp.take(flat, jnp.asarray(perm), axis=-1)
    return out.reshape(x.shape)


def unscramble(x: jax.Array, k: int = 1) -> jax.Array:
    """Inverse of apply_scramble — recover the standard arrangement."""
    return apply_scramble(x, -k)


def apply_scramble_power(x: jax.Array, k: jax.Array, n: int) -> jax.Array:
    """S^k with *traced* integer k (runtime key), trailing dims (n, n).

    Precomputes all `order(S)` distinct powers as a (order, n*n) table and
    gathers the k-th row — O(order * n^2) constant data, one dynamic gather.
    This is the keyed-scrambler primitive: the key space is Z_order.
    """
    order = scramble_order(n)
    base = _scramble_perm_np(n)
    table = np.stack([power_perm(base, p) for p in range(order)])  # (order, n*n)
    perm_k = jnp.asarray(table)[k % order]
    flat = x.reshape(*x.shape[:-2], n * n)
    out = jnp.take(flat, perm_k, axis=-1)
    return out.reshape(x.shape)


def sigma_traced(n: int, i, j):
    """Closed-form sigma_n on traced 0-indexed block indices (i, j) -> (p, q).

    Pure arithmetic on the index args (no captured arrays), so it is legal
    inside a Pallas BlockSpec index_map — the permutation is evaluated on the
    TPU scalar core as part of the block schedule.  n is a static Python int.
    """
    i1, j1 = i + 1, j + 1
    d = i1 + j1
    low = d <= n + 1
    m = jnp.where(low, d - 1, 2 * n + 1 - d)
    f = jnp.where(low, d - 1, 2 * n + 2 - d)
    r = jnp.where(low, i1, i1 - (d - n) + 1)
    h = (m + 1) // 2
    v = jnp.where(
        r <= h,
        m - 2 * (r - 1),
        jnp.where(m % 2 == 1, 2 * (r - h), 2 * (r - h) - 1),
    )
    even = d % 2 == 0
    p = jnp.where(even, f, v)
    q = jnp.where(even, v, f)
    return p - 1, q - 1


def block_scramble_perm(n_blocks: int) -> np.ndarray:
    """sigma at block granularity: permutation of an (n_blocks x n_blocks) tile
    grid.  Used by the Pallas mesh-matmul kernel to fuse the paper's output
    arrangement into the output BlockSpec index_map at zero byte cost."""
    return _scramble_perm_np(n_blocks).copy()


def scrambled_cell_of(n: int, p: int, q: int) -> Tuple[int, int]:
    """Which mesh cell (i, j) holds c_{p,q}?  (All args/results 1-indexed.)"""
    inv = inverse_perm(_scramble_perm_np(n))
    cell = int(inv[(p - 1) * n + (q - 1)])
    return cell // n + 1, cell % n + 1


def format_table(n: int) -> str:
    """Render the arrangement table in the paper's `pq` notation (for docs/benches)."""
    rows = []
    for row in sigma_table(n):
        rows.append(" ".join(f"{p}{q}" if n < 10 else f"{p},{q}" for p, q in row))
    return "\n".join(rows)
