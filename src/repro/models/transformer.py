"""Decoder-only transformer LM (dense + MoE families).

Covers granite-3-8b, phi3-medium-14b, qwen2-7b, mistral-large-123b (dense),
olmoe-1b-7b, qwen2-moe-a2.7b (MoE), the pixtral-12b backbone, and the
mesh-paper demo config.

Layers are stacked on a leading (L,) axis and executed with `jax.lax.scan`
(compile time ~independent of depth — essential for 88-layer dry-runs) with a
configurable remat policy.  Entry points: `lm_forward` (train), `lm_prefill`,
`lm_decode` (serving, stacked per-layer KV caches carried through the scan).

The paper's scrambling system is integrated as an optional privacy transform:
with cfg.scramble_privacy the embedding-output activation block-grid is
scrambled with S and unscrambled before the head — a zero-FLOP keyed
permutation (examples/scrambling_demo.py; square grids only).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.scramble import scramble_order
from repro.kernels.ops import scramble_blocks
from repro.models.attention import (
    attention,
    attention_paged_decode,
    attn_specs,
    init_cache_shape,
)
from repro.models.layers import PSpec, ShardCtx, gemm, padded_vocab, rmsnorm
from repro.models.moe import moe_block, moe_specs, swiglu, swiglu_specs

__all__ = [
    "lm_specs",
    "lm_forward",
    "lm_prefill",
    "lm_decode",
    "lm_decode_paged",
    "paged_pool_specs",
    "stack_specs",
    "embed_tokens",
    "unembed",
    "block_specs",
    "block_apply",
]


def stack_specs(specs: Any, num: int) -> Any:
    """Prepend a stacked 'layers' dim to every PSpec leaf."""
    return jax.tree.map(
        lambda s: PSpec(
            (num,) + s.shape, ("layers",) + s.axes, s.scale, s.dtype, s.init
        ),
        specs,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


def block_specs(cfg) -> Dict[str, Any]:
    """One transformer block: attn + (SwiGLU | MoE) + 2 norms."""
    specs: Dict[str, Any] = {
        "ln1": PSpec((cfg.d_model,), ("embed",), init="ones"),
        "ln2": PSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn_specs(cfg),
    }
    if cfg.is_moe:
        specs["moe"] = moe_specs(cfg)
    else:
        specs["mlp"] = swiglu_specs(cfg, cfg.d_ff)
    return specs


def block_apply(
    p: Dict[str, Any],
    x: jax.Array,
    cfg,
    ctx: ShardCtx,
    *,
    cache=None,
    cache_pos=None,
    write_cache: bool = False,
) -> Tuple[jax.Array, Any, Dict[str, jax.Array]]:
    """Pre-norm block.  Returns (x, new_cache, aux)."""
    h, new_cache = attention(
        p["attn"],
        rmsnorm(x, p["ln1"], cfg.norm_eps),
        cfg,
        ctx,
        cache=cache,
        cache_pos=cache_pos,
        write_cache=write_cache,
    )
    x = x + h
    aux = {}
    if cfg.is_moe:
        h2, aux = moe_block(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, ctx)
    else:
        h2 = swiglu(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, ctx)
    return x + h2, new_cache, aux


def lm_specs(cfg) -> Dict[str, Any]:
    vpad = padded_vocab(cfg)
    specs: Dict[str, Any] = {
        "embed": PSpec((vpad, cfg.d_model), ("vocab", "embed"), 0.02),
        "blocks": stack_specs(block_specs(cfg), cfg.num_layers),
        "final_norm": PSpec((cfg.d_model,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = PSpec((cfg.d_model, vpad), ("embed", "vocab"), 0.02)
    return specs


def embed_tokens(params, tokens: jax.Array, cfg, ctx: ShardCtx) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
    return ctx.c(x, ("batch", "seq", "embed"))


def unembed(params, x: jax.Array, cfg, ctx: ShardCtx) -> jax.Array:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = gemm(x, head.astype(x.dtype), cfg)
    # Padded vocab rows (vocab_pad_multiple) never win loss/argmax.
    if head.shape[-1] != cfg.vocab_size:
        mask = jnp.arange(head.shape[-1]) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    return ctx.c(logits, ("batch", "seq", "vocab"))


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    if policy == "full":
        return jax.checkpoint(fn)
    raise ValueError(f"unknown remat policy {policy!r}")


def _maybe_scramble(x: jax.Array, cfg, inverse: bool = False) -> jax.Array:
    """Paper scrambling system on (T, D) activation block grids (square only)."""
    if not cfg.scramble_privacy:
        return x
    t, d = x.shape[-2], x.shape[-1]
    bm, bn = 128, 128
    if t % bm or d % bn or t // bm != d // bn:
        return x  # non-square grid: scrambling skipped (demo feature)
    return scramble_blocks(x, block_m=bm, block_n=bn, k=-1 if inverse else 1)


def lm_forward(params, tokens: jax.Array, cfg, ctx: ShardCtx = ShardCtx()):
    """Train/eval forward: (B, T) int32 -> (logits (B, T, V), aux dict)."""
    x = embed_tokens(params, tokens, cfg, ctx)
    x = _maybe_scramble(x, cfg)

    def body(x, lp):
        y, _, aux = block_apply(lp, x, cfg, ctx)
        y = ctx.c(y, ("batch", "seq_sp", "embed"))  # SP remat carrier
        aux_vec = jnp.stack(
            [aux.get("lb_loss", jnp.zeros((), jnp.float32)),
             aux.get("router_z", jnp.zeros((), jnp.float32))]
        )
        return y, aux_vec

    body = _remat(body, cfg.remat_policy)
    x, aux_stack = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
    x = _maybe_scramble(x, cfg, inverse=True)
    logits = unembed(params, x, cfg, ctx)
    aux = {"lb_loss": aux_stack[:, 0].mean(), "router_z": aux_stack[:, 1].mean()}
    return logits, aux


def lm_prefill(params, tokens: jax.Array, cfg, ctx: ShardCtx = ShardCtx()):
    """Prefill: returns (logits (B, T, V), stacked caches (L, B, T, KV, hd))."""
    x = embed_tokens(params, tokens, cfg, ctx)

    def body(x, lp):
        y, cache, _ = block_apply(lp, x, cfg, ctx, write_cache=True)
        return ctx.c(y, ("batch", "seq_sp", "embed")), cache

    x, caches = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
    logits = unembed(params, x, cfg, ctx)
    return logits, caches


def lm_decode(
    params,
    tokens: jax.Array,  # (B, T_new) — usually T_new = 1
    caches,  # stacked (L, B, T_max, KV, hd) pytree {"k","v"}
    pos: jax.Array,  # scalar int32: current length
    cfg,
    ctx: ShardCtx = ShardCtx(),
):
    """One decode step against per-layer KV caches; returns (logits, caches)."""
    x = embed_tokens(params, tokens, cfg, ctx)

    def body(x, layer_in):
        lp, cache = layer_in
        y, new_cache, _ = block_apply(lp, x, cfg, ctx, cache=cache, cache_pos=pos)
        return y, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches), unroll=cfg.scan_unroll)
    logits = unembed(params, x, cfg, ctx)
    return logits, new_caches


def block_apply_paged(
    p: Dict[str, Any],
    x: jax.Array,  # (S, 1, D)
    cfg,
    ctx: ShardCtx,
    *,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    positions: jax.Array,
    impl: Optional[str] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """`block_apply`'s decode branch against a paged KV pool (DESIGN.md §12)."""
    h, pools = attention_paged_decode(
        p["attn"],
        rmsnorm(x, p["ln1"], cfg.norm_eps),
        cfg,
        ctx,
        k_pool=k_pool,
        v_pool=v_pool,
        block_tables=block_tables,
        positions=positions,
        impl=impl,
        interpret=interpret,
    )
    x = x + h
    if cfg.is_moe:
        h2, _ = moe_block(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, ctx)
    else:
        h2 = swiglu(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, ctx)
    return x + h2, pools


def lm_decode_paged(
    params,
    tokens: jax.Array,  # (S, 1) — one token per sequence slot
    pools,  # {"k","v"}: (L, P, page_size, KV, hd) shared page pools
    block_tables: jax.Array,  # (S, n_pages) int32
    positions: jax.Array,  # (S,) int32 per-slot lengths
    cfg,
    ctx: ShardCtx = ShardCtx(),
    *,
    impl: Optional[str] = None,
    interpret: bool = False,
):
    """One continuous-batching decode step: every slot advances one token
    against its own block-table pages (per-slot positions — slots sit at
    different depths).  Returns (logits (S, 1, V), updated pools)."""
    x = embed_tokens(params, tokens, cfg, ctx)

    def body(x, layer_in):
        lp, kp, vp = layer_in
        y, (nk, nv) = block_apply_paged(
            lp,
            x,
            cfg,
            ctx,
            k_pool=kp,
            v_pool=vp,
            block_tables=block_tables,
            positions=positions,
            impl=impl,
            interpret=interpret,
        )
        return y, (nk, nv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["blocks"], pools["k"], pools["v"]), unroll=cfg.scan_unroll
    )
    logits = unembed(params, x, cfg, ctx)
    return logits, {"k": ks, "v": vs}


def paged_pool_specs(cfg, num_pages: int, page_size: int):
    """Abstract stacked page pools for the serving scheduler (one per layer)."""
    kv, hd = cfg.num_kv_heads, cfg.head_dim_
    shp = (cfg.num_layers, num_pages, page_size, kv, hd)
    return {
        "k": jax.ShapeDtypeStruct(shp, cfg.adtype),
        "v": jax.ShapeDtypeStruct(shp, cfg.adtype),
    }


def decode_cache_specs(cfg, batch: int, max_len: int):
    """Abstract stacked cache for serve_step lowering (ShapeDtypeStruct tree)."""
    shp = init_cache_shape(cfg, batch, max_len)
    return {
        name: jax.ShapeDtypeStruct((cfg.num_layers,) + s, cfg.adtype)
        for name, s in shp.items()
    }
