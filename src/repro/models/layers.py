"""Shared building blocks: param specs, norms, RoPE, embeddings, MLPs.

Single-source-of-truth parameter system: each model family defines a
`param_specs(cfg)` tree whose leaves are `PSpec(shape, logical_axes, scale,
dtype)`.  From that one tree we derive
  * `init_params`      — real arrays (smoke tests / examples / training),
  * `abstract_params`  — ShapeDtypeStructs (dry-run lowering, no allocation),
  * `logical_axes`     — the sharding tree consumed by parallel/sharding.py.

All GEMMs go through the plan/execute API (`repro.kernels.api`): `gemm`
builds a typed GemmSpec, `api.plan` resolves the backend against declared
capabilities ONCE per logical shape (cfg.use_mesh_kernel selects the Pallas
mesh kernel), and the cached plan executes per call; under pjit the XLA
backend is used and sharding constraints carry the TP layout.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import api as _api

__all__ = [
    "PSpec",
    "init_params",
    "abstract_params",
    "logical_axes_tree",
    "ShardCtx",
    "dense",
    "rmsnorm",
    "RotaryTable",
    "apply_rope",
    "softmax_xent",
    "gemm",
    "grouped_gemm",
]


@dataclasses.dataclass(frozen=True)
class PSpec:
    """Declarative parameter: shape + logical sharding axes + init scale."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    scale: float = 0.02
    dtype: Any = None  # filled from cfg.param_dtype at materialization
    init: str = "normal"  # normal | zeros | ones

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def _is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def init_params(key: jax.Array, specs, dtype) -> Any:
    """Materialize a PSpec tree into real arrays (deterministic per-path keys)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_pspec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        dt = s.dtype or dtype
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dt))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dt))
        else:
            out.append((jax.random.normal(k, s.shape, jnp.float32) * s.scale).astype(dt))
    return treedef.unflatten(out)


def abstract_params(specs, dtype) -> Any:
    """ShapeDtypeStruct tree — dry-run lowering without any allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype),
        specs,
        is_leaf=_is_pspec,
    )


def logical_axes_tree(specs) -> Any:
    """Matching tree of logical-axis tuples for parallel/sharding.py."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_pspec)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Threading (mesh, rules) through model code; None mesh = no constraints."""

    mesh: Any = None
    rules: Any = None

    def c(self, x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
        if self.mesh is None:
            return x
        from repro.parallel.sharding import DEFAULT_RULES, named_sharding

        rules = self.rules or DEFAULT_RULES
        return jax.lax.with_sharding_constraint(
            x, named_sharding(tuple(axes), self.mesh, rules, shape=x.shape)
        )


NO_SHARD = ShardCtx()


def padded_vocab(cfg) -> int:
    """Embedding/lm_head row count, padded so the vocab dim divides the TP
    axis (cfg.vocab_pad_multiple; 0 = exact).  Published vocabs like 49155
    (granite) otherwise force the unembed GEMM + logits to REPLICATE over
    'model' — the probe showed that costs ~16x the sharded unembed
    (EXPERIMENTS.md §Perf).  Padded logits are masked out of loss/argmax."""
    m = getattr(cfg, "vocab_pad_multiple", 0)
    if not m:
        return cfg.vocab_size
    return ((cfg.vocab_size + m - 1) // m) * m


def gemm(
    x: jax.Array,
    w: jax.Array,
    cfg,
    *,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    residual: Optional[jax.Array] = None,
    mesh: Any = None,
    shard: Any = None,
) -> jax.Array:
    """Config-routed GEMM via plan/execute: XLA dot under pjit, Pallas mesh
    kernel if selected.

    The epilogue (y = act(xW + bias) + residual) rides along: fused into the
    kernel's final-k flush on the Pallas path (cfg.fused_dense_epilogue, the
    A/B lever), applied as plain jnp ops otherwise — one call site, identical
    semantics either way.  Block shapes come from cfg.mesh_block_m/n/k when
    set (> 0); otherwise `kernels/autotune.py` resolves them at plan time.
    Plans are cached process-wide per (spec, backend, mesh) triple, so every
    retrace/request with the same logical shape reuses the same executable.

    With `shard` (a `kernels.api.ShardSpec`) and its live device `mesh`, the
    plan is a ShardedPlan: the same per-shard kernel lowered through
    shard_map with the ShardSpec's collective schedule — operands/results
    stay global arrays, so call sites do not change shape-wise.
    """
    backend = "pallas_mesh" if getattr(cfg, "use_mesh_kernel", False) else "xla"
    blocks = (
        getattr(cfg, "mesh_block_m", 0) or None,
        getattr(cfg, "mesh_block_n", 0) or None,
        getattr(cfg, "mesh_block_k", 0) or None,
    )
    if backend != "xla" and not getattr(cfg, "fused_dense_epilogue", True):
        spec = _api.GemmSpec.from_operands(
            x, w, out_dtype=jnp.float32, blocks=blocks, shard=shard
        )
        z = _api.plan(spec, backend=backend, mesh=mesh)(x, w)
        return _api.apply_epilogue(z, bias, activation, residual).astype(x.dtype)
    spec = _api.GemmSpec.from_operands(
        x,
        w,
        epilogue=_api.Epilogue(
            bias=bias is not None,
            activation=activation,
            residual=residual is not None,
        ),
        out_dtype=x.dtype,
        blocks=blocks,
        shard=shard,
    )
    return _api.plan(spec, backend=backend, mesh=mesh)(x, w, bias=bias, residual=residual)


def grouped_gemm(
    tokens: jax.Array,         # (num_groups * rows_per_group, K), group-major
    group_offsets: jax.Array,  # (num_groups + 1,) cumulative valid-row counts
    weights: jax.Array,        # (num_groups, K, N) stacked per-group slabs
    cfg,
    *,
    out_dtype=None,
    mesh: Any = None,
    shard: Any = None,
) -> jax.Array:
    """Config-routed grouped (ragged-batch) GEMM via plan/execute.

    The MoE expert path: row blocks of the capacity-layout `tokens` buffer
    multiply their group's (K, N) weight slab in ONE kernel (the Pallas
    ragged mesh kernel when cfg.use_mesh_kernel, a segment-masked einsum on
    XLA), with rows past each group's size coming back zero.  Plans are
    cached per logical group shape exactly like `gemm` — one autotune, one
    executable, every layer/step reuses it.  With `shard` (a ShardSpec
    carrying axis_g) and the live `mesh`, the plan lowers through the
    `expert` collective schedule (EP).
    """
    backend = "pallas_mesh" if getattr(cfg, "use_mesh_kernel", False) else "xla"
    num_groups, kd, n = weights.shape
    rows = tokens.shape[0]
    blocks = (
        getattr(cfg, "mesh_block_m", 0) or None,
        getattr(cfg, "mesh_block_n", 0) or None,
        getattr(cfg, "mesh_block_k", 0) or None,
    )
    spec = _api.GemmSpec.for_groups(
        _api.GroupSpec(num_groups, rows // num_groups),
        k=kd,
        n=n,
        dtype_a=tokens.dtype,
        dtype_b=weights.dtype,
        out_dtype=out_dtype or tokens.dtype,
        blocks=blocks,
        shard=shard,
    )
    return _api.plan(spec, backend=backend, mesh=mesh)(tokens, group_offsets, weights)


def dense(
    x: jax.Array,
    w: jax.Array,
    cfg,
    b: Optional[jax.Array] = None,
    *,
    activation: Optional[str] = None,
    residual: Optional[jax.Array] = None,
    mesh: Any = None,
    shard: Any = None,
) -> jax.Array:
    """Dense projection with the fused epilogue: one kernel on the mesh path."""
    return gemm(
        x, w, cfg, bias=b, activation=activation, residual=residual,
        mesh=mesh, shard=shard,
    )


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma.astype(x.dtype)


class RotaryTable:
    """Precomputed RoPE angle table; `gather(pos)` works for any position array."""

    def __init__(self, head_dim: int, theta: float, max_len: int):
        self.head_dim = head_dim
        inv = 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))
        self.inv_freq = jnp.asarray(inv, jnp.float32)
        self.max_len = max_len

    def angles(self, positions: jax.Array) -> jax.Array:
        # positions: (...,) int -> (..., head_dim/2) f32 angles
        return positions[..., None].astype(jnp.float32) * self.inv_freq


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd); positions: (B, T) or (T,).  Rotate pairs (even, odd)."""
    hd = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, T, hd/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape)


def softmax_xent(
    logits: jax.Array, labels: jax.Array, *, z_loss: float = 0.0
) -> Tuple[jax.Array, jax.Array]:
    """Stable mean token cross-entropy (+optional z-loss).  Returns (loss, acc)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse**2)
    acc = jnp.mean((jnp.argmax(lf, axis=-1) == labels).astype(jnp.float32))
    return loss, acc
