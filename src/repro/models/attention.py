"""GQA attention with RoPE, KV cache, cross-attention, and TP/SP sharding.

Modes:
  * full causal (train / prefill — prefill also writes the cache),
  * single-token decode against a cache (serve_step),
  * bidirectional (whisper encoder), cross-attention (whisper decoder).

Sharding: activations ('batch','seq','heads','head_dim'); the KV cache uses
('kv_batch','kv_seq','kv_heads','head_dim') so long-context decode can switch
to sequence-parallel rules (kv_seq -> mesh axes) when kv_heads doesn't divide
the 'model' axis — see parallel/sharding.py.  Softmax statistics over a
sequence-sharded cache are handled by XLA SPMD (the (B, H, 1, T) score tensor
for one decode token is small; the collective is a cheap all-reduce).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import PSpec, ShardCtx, apply_rope, dense

__all__ = [
    "attn_specs",
    "attention",
    "attention_paged_decode",
    "init_cache_shape",
    "Cache",
]

Cache = Dict[str, jax.Array]  # {"k": (B, T, KV, hd), "v": (B, T, KV, hd)}


def attn_specs(cfg, *, prefix_scale: float = 1.0) -> Dict[str, PSpec]:
    d, hd = cfg.d_model, cfg.head_dim_
    h, kv = cfg.num_heads, cfg.num_kv_heads
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    specs = {
        "wq": PSpec((d, h * hd), ("embed", "heads"), 0.02 * prefix_scale),
        "wk": PSpec((d, kv * hd), ("embed", "kv_heads"), 0.02 * prefix_scale),
        "wv": PSpec((d, kv * hd), ("embed", "kv_heads"), 0.02 * prefix_scale),
        "wo": PSpec((h * hd, d), ("heads", "embed"), out_scale),
    }
    if cfg.qkv_bias:
        specs["bq"] = PSpec((h * hd,), ("heads",), init="zeros")
        specs["bk"] = PSpec((kv * hd,), ("kv_heads",), init="zeros")
        specs["bv"] = PSpec((kv * hd,), ("kv_heads",), init="zeros")
    return specs


def init_cache_shape(cfg, batch: int, max_len: int) -> Dict[str, Tuple[int, ...]]:
    kv, hd = cfg.num_kv_heads, cfg.head_dim_
    return {"k": (batch, max_len, kv, hd), "v": (batch, max_len, kv, hd)}


def _sdpa_chunked(
    q: jax.Array,  # (B, Tq, H, hd)
    k: jax.Array,  # (B, Tk, KV, hd)
    v: jax.Array,
    *,
    causal: bool,
    chunk: int,
    unroll: bool = False,
) -> jax.Array:
    """Flash-style attention: online softmax over KV chunks.

    Never materializes the (Tq, Tk) score matrix — the working set per step
    is (Tq, chunk), so HBM traffic drops from O(T^2) to O(T * chunk + T * hd)
    per head.  This is the hillclimb fix for the memory-dominant prefill/train
    cells (EXPERIMENTS.md §Perf).  The chunk loop is a lax.scan whose body is
    jax.checkpoint'd: AD saves only the (m, l, acc) running stats per chunk,
    not the per-chunk probability blocks.

    Equivalent to _sdpa up to fp error; property-tested in
    tests/test_attention.py.
    """
    b, tq, h, hd = q.shape
    tk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    if tk % chunk:
        raise ValueError(f"Tk={tk} not divisible by chunk={chunk}")
    nc = tk // chunk
    q5 = q.reshape(b, tq, kvh, rep, hd)
    scale = hd**-0.5

    kc = jnp.moveaxis(k.reshape(b, nc, chunk, kvh, hd), 1, 0)  # (nc,B,C,KV,hd)
    vc = jnp.moveaxis(v.reshape(b, nc, chunk, kvh, hd), 1, 0)
    qpos = jnp.arange(tq)[:, None]  # (Tq, 1)

    @jax.checkpoint
    def body(carry, inp):
        m, l, acc = carry  # (B,KV,rep,Tq), (B,KV,rep,Tq), (B,Tq,KV,rep,hd) f32
        j, kj, vj = inp
        s = jnp.einsum(
            "btkrd,bskd->bkrts", q5, kj, preferred_element_type=jnp.float32
        ) * scale  # (B,KV,rep,Tq,C)
        if causal:
            kpos = j * chunk + jnp.arange(chunk)[None, :]
            s = jnp.where((kpos <= qpos)[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])  # (B,KV,rep,Tq,C)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkrts,bskd->btkrd", p.astype(q.dtype), vj)
        acc_new = acc * jnp.moveaxis(corr, -1, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, rep, tq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, rep, tq), jnp.float32)
    acc0 = jnp.zeros((b, tq, kvh, rep, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(nc), kc, vc), unroll=unroll
    )
    out = acc / jnp.moveaxis(l, -1, 1)[..., None]
    return out.reshape(b, tq, h, hd).astype(q.dtype)


def _sdpa(
    q: jax.Array,  # (B, Tq, H, hd)
    k: jax.Array,  # (B, Tk, KV, hd)
    v: jax.Array,
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,
    kv_valid_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Grouped-query SDPA with f32 softmax; no KV-head materialized repeat."""
    b, tq, h, hd = q.shape
    tk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    q5 = q.reshape(b, tq, kvh, rep, hd)
    scores = jnp.einsum(
        "btkrd,bskd->bkrts", q5, k, preferred_element_type=jnp.float32
    ) / (hd**0.5)
    if causal:
        qpos = jnp.arange(tq)[:, None] + q_offset  # (Tq, 1)
        kpos = jnp.arange(tk)[None, :]
        mask = kpos <= qpos  # (Tq, Tk)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    if kv_valid_len is not None:
        valid = jnp.arange(tk)[None, :] < kv_valid_len  # mask unwritten cache
        scores = jnp.where(valid[:, None, None, None] if valid.ndim == 2 else valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrts,bskd->btkrd", probs, v)
    return out.reshape(b, tq, h, hd)


def attention_paged_decode(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (S, 1, D) — one new token per sequence slot
    cfg,
    ctx: ShardCtx,
    *,
    k_pool: jax.Array,  # (P, page_size, KV, hd) shared page pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # (S, n_pages) int32
    positions: jax.Array,  # (S,) int32 — each slot's current length
    impl: Optional[str] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Single-token decode against a paged KV pool (DESIGN.md §12).

    The per-slot analogue of the `cache=...` branch of `attention`: the new
    K/V lands in page `block_tables[s, pos // page_size]` at in-page offset
    `pos % page_size`, then the slot attends over its pages through
    `kernels.paged_attention`.  Per-slot positions replace the shared scalar
    `cache_pos`, so every slot can sit at a different depth — the property
    continuous batching needs.  Op-for-op identical per row to the dense
    decode path (the xla_gather impl mirrors `_sdpa`), so a request served
    through pages is bitwise-equal to `generate()`.

    Inactive slots (all-zero block table, position 0) write into page 0 —
    the scheduler's reserved scratch page — and their output is discarded.
    Returns (y (S, 1, D), (k_pool, v_pool) with the token written).
    """
    from repro.kernels.paged_attention import paged_attention

    s, t, _ = x.shape
    if t != 1:
        raise ValueError(f"paged decode is single-token; got T={t}")
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    pos2 = positions[:, None]  # (S, 1) per-row positions for RoPE

    q = dense(x, p["wq"], cfg, p.get("bq")).reshape(s, 1, h, hd)
    k = dense(x, p["wk"], cfg, p.get("bk")).reshape(s, 1, kvh, hd)
    v = dense(x, p["wv"], cfg, p.get("bv")).reshape(s, 1, kvh, hd)
    q = apply_rope(q, pos2, cfg.rope_theta)
    k = apply_rope(k, pos2, cfg.rope_theta)
    q = ctx.c(q, ("batch", "seq", "heads", "head_dim"))

    ps = k_pool.shape[1]
    pool_shape = k_pool.shape
    page = jnp.take_along_axis(block_tables, (positions // ps)[:, None], axis=1)
    flat = page[:, 0] * ps + positions % ps  # (S,) rows in the (P*ps, ...) view
    k_pool = (
        k_pool.reshape(-1, kvh, hd)
        .at[flat]
        .set(k[:, 0].astype(k_pool.dtype))
        .reshape(pool_shape)
    )
    v_pool = (
        v_pool.reshape(-1, kvh, hd)
        .at[flat]
        .set(v[:, 0].astype(v_pool.dtype))
        .reshape(pool_shape)
    )

    out = paged_attention(
        q.reshape(s, h, hd),
        k_pool,
        v_pool,
        block_tables,
        positions + 1,  # valid length includes the token just written
        impl=impl,
        interpret=interpret,
    ).reshape(s, 1, h, hd)
    out = ctx.c(out, ("batch", "seq", "heads", "head_dim"))
    y = dense(out.reshape(s, 1, h * hd), p["wo"], cfg)
    return ctx.c(y, ("batch", "seq", "embed")), (k_pool, v_pool)


def attention(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, T, D)
    cfg,
    ctx: ShardCtx,
    *,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    use_rope: bool = True,
    cache: Optional[Cache] = None,
    cache_pos: Optional[jax.Array] = None,
    write_cache: bool = False,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Cache]]:
    """Returns (output (B, T, D), updated cache or None).

    Modes:
      cache=None, write_cache=False     train forward (full attention)
      cache=None, write_cache=True      prefill: returns fresh cache = (k, v)
      cache=..., cache_pos=p            decode: T new tokens at position p
      cross_kv=(k, v)                   cross-attention (ignores cache args)
    """
    b, t, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    if positions is None:
        positions = jnp.arange(t)[None, :] + (cache_pos if cache_pos is not None else 0)
        positions = jnp.broadcast_to(positions, (b, t))

    q = dense(x, p["wq"], cfg, p.get("bq")).reshape(b, t, h, hd)
    if cross_kv is None:
        k = dense(x, p["wk"], cfg, p.get("bk")).reshape(b, t, kvh, hd)
        v = dense(x, p["wv"], cfg, p.get("bv")).reshape(b, t, kvh, hd)
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = cross_kv
    q = ctx.c(q, ("batch", "seq", "heads", "head_dim"))

    new_cache: Optional[Cache] = None
    kv_valid_len = None
    q_offset: jax.Array | int = 0

    if cross_kv is not None:
        out = _sdpa(q, k, v, causal=False)
    elif cache is not None:
        # Decode: write the T new keys at cache_pos, attend over the prefix.
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        ck = ctx.c(ck, ("kv_batch", "kv_seq", "kv_heads", "head_dim"))
        cv = ctx.c(cv, ("kv_batch", "kv_seq", "kv_heads", "head_dim"))
        new_cache = {"k": ck, "v": cv}
        kv_valid_len = cache_pos + t
        q_offset = cache_pos
        out = _sdpa(q, ck, cv, causal=True, q_offset=q_offset, kv_valid_len=kv_valid_len)
    else:
        k = ctx.c(k, ("batch", "seq", "kv_heads", "head_dim"))
        v = ctx.c(v, ("batch", "seq", "kv_heads", "head_dim"))
        chunk = getattr(cfg, "attn_chunk", 0)
        if chunk and t > chunk and t % chunk == 0:
            # Flash-style path; q may additionally be seq-sharded over the TP
            # axis ('seq_attn' rule) when heads don't divide it — context
            # parallelism with replicated KV (see parallel/sharding.py).
            q = ctx.c(q, ("batch", "seq_attn", "heads", "head_dim"))
            out = _sdpa_chunked(
                q, k, v, causal=causal, chunk=chunk, unroll=cfg.scan_unroll
            )
            out = ctx.c(out, ("batch", "seq_attn", "heads", "head_dim"))
        else:
            out = _sdpa(q, k, v, causal=causal)
        if write_cache:
            new_cache = {
                "k": ctx.c(k, ("kv_batch", "kv_seq", "kv_heads", "head_dim")),
                "v": ctx.c(v, ("kv_batch", "kv_seq", "kv_heads", "head_dim")),
            }

    out = ctx.c(out, ("batch", "seq", "heads", "head_dim"))
    y = dense(out.reshape(b, t, h * hd), p["wo"], cfg)
    return ctx.c(y, ("batch", "seq", "embed")), new_cache
