"""Pixtral-12B backbone: mistral-nemo-style decoder with stub ViT frontend.

Per the assignment the modality frontend is a STUB: `input_specs()` supplies
precomputed patch embeddings (B, P, D) which are projected and prepended to
the text-token embeddings.  Labels/logits cover the text positions; decode
carries a KV cache over (patches + text) positions.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import PSpec, ShardCtx, gemm
from repro.models.transformer import (
    block_apply,
    block_specs,
    embed_tokens,
    lm_specs,
    stack_specs,
    unembed,
)

__all__ = ["vlm_specs", "vlm_forward", "vlm_prefill", "vlm_decode", "vlm_cache_specs"]


def vlm_specs(cfg) -> Dict[str, Any]:
    specs = lm_specs(cfg)
    specs["patch_proj"] = PSpec((cfg.d_model, cfg.d_model), ("embed", "embed"), 0.02)
    return specs


def _embed_multimodal(params, batch, cfg, ctx):
    """concat(project(patch_embeds), embed(tokens)) -> (B, P+T, D)."""
    patches = gemm(
        batch["patches"].astype(cfg.adtype), params["patch_proj"].astype(cfg.adtype), cfg
    )
    patches = ctx.c(patches, ("batch", "patches", "embed"))
    text = embed_tokens(params, batch["tokens"], cfg, ctx)
    return jnp.concatenate([patches, text], axis=1)


def vlm_forward(params, batch: Dict[str, jax.Array], cfg, ctx: ShardCtx = ShardCtx()):
    """batch: {"patches": (B, P, D), "tokens": (B, T)} -> (text logits, aux).

    Causal over the concatenated stream; returns logits for text positions.
    """
    x = _embed_multimodal(params, batch, cfg, ctx)

    def body(x, lp):
        y, _, _ = block_apply(lp, x, cfg, ctx)
        return ctx.c(y, ("batch", "seq_sp", "embed")), None

    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
    n_patches = batch["patches"].shape[1]
    logits = unembed(params, x[:, n_patches:], cfg, ctx)
    return logits, {}


def vlm_prefill(params, batch, cfg, ctx: ShardCtx = ShardCtx()):
    x = _embed_multimodal(params, batch, cfg, ctx)

    def body(x, lp):
        y, cache, _ = block_apply(lp, x, cfg, ctx, write_cache=True)
        return ctx.c(y, ("batch", "seq_sp", "embed")), cache

    x, caches = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
    n_patches = batch["patches"].shape[1]
    logits = unembed(params, x[:, n_patches:], cfg, ctx)
    return logits, caches


def vlm_decode(params, tokens, caches, pos, cfg, ctx: ShardCtx = ShardCtx()):
    """pos counts from the start of the (patches + text) stream."""
    x = embed_tokens(params, tokens, cfg, ctx)

    def body(x, layer_in):
        lp, cache = layer_in
        y, new_cache, _ = block_apply(lp, x, cfg, ctx, cache=cache, cache_pos=pos)
        return y, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches), unroll=cfg.scan_unroll)
    logits = unembed(params, x, cfg, ctx)
    return logits, new_caches


def vlm_cache_specs(cfg, batch: int, max_len: int):
    kv, hd = cfg.num_kv_heads, cfg.head_dim_
    return {
        "k": jax.ShapeDtypeStruct((cfg.num_layers, batch, max_len, kv, hd), cfg.adtype),
        "v": jax.ShapeDtypeStruct((cfg.num_layers, batch, max_len, kv, hd), cfg.adtype),
    }
