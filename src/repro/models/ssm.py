"""Mamba2 (SSD) layers + the Zamba2 hybrid (arXiv:2411.15242).

Mamba2's scalar-per-head decay makes the *chunked* SSD form numerically safe
(all exponents are differences of a monotone cumulative log-decay, hence
<= 0), so training uses matmul-rich chunked evaluation (`ssd_chunked`) and
decode carries the (H, P, N) state with an O(1) step (`ssd_step`).  The
sequential `ssd_scan` is kept as the oracle for property tests.

Zamba2 structure: `num_layers` Mamba2 blocks with one *shared-weight*
transformer block (attention + SwiGLU) applied after every
`shared_attn_period` Mamba layers — n_seg applications, each with its own KV
cache: params {"mamba_seg": (n_seg, period, ...), "mamba_tail": (tail, ...),
"shared": single block}; caches stacked (n_seg, B, T, KV, hd).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attention, attn_specs
from repro.models.layers import PSpec, ShardCtx, gemm, rmsnorm
from repro.models.moe import swiglu, swiglu_specs
from repro.models.layers import padded_vocab
from repro.models.transformer import embed_tokens, stack_specs, unembed

__all__ = [
    "zamba_specs",
    "zamba_forward",
    "zamba_prefill",
    "zamba_decode",
    "zamba_state_specs",
    "ssd_chunked",
    "ssd_scan",
    "ssd_step",
]

_CHUNK = 128
_CONV_K = 4


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_scan(x, dt, a_log, b, c, d_skip, h0):
    """Sequential oracle.  x: (B,T,H,P); dt: (B,T,H); a_log: (H,);
    b, c: (B,T,N); d_skip: (H,); h0: (B,H,P,N).  Returns (y, h_final)."""

    def step(h, inp):
        xt, dtt, bt, ct = inp
        a = jnp.exp(-jnp.exp(a_log) * dtt)  # (B, H)
        h = h * a[..., None, None] + (dtt[..., None] * xt)[..., None] * bt[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, ct) + d_skip[None, :, None] * xt
        return h, y

    xs = jax.tree.map(lambda t: jnp.moveaxis(t, 1, 0), (x, dt, b, c))
    h, y = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(y, 0, 1), h


def ssd_step(h, x, dt, a_log, b, c, d_skip):
    """One decode step.  x: (B,H,P); dt: (B,H); b, c: (B,N)."""
    a = jnp.exp(-jnp.exp(a_log) * dt)
    h = h * a[..., None, None] + (dt[..., None] * x)[..., None] * b[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, c) + d_skip[None, :, None] * x
    return y, h


def ssd_chunked(x, dt, a_log, b, c, d_skip, h0, chunk: int = _CHUNK):
    """Chunk-parallel SSD (matmul form).  Same signature as ssd_scan."""
    B, T, H, P = x.shape
    N = b.shape[-1]
    nc = -(-T // chunk)
    pad = nc * chunk - T
    if pad:
        padt = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, b, c = padt(x), padt(dt), padt(b), padt(c)
    C = chunk
    xc = x.reshape(B, nc, C, H, P)
    dtc = dt.reshape(B, nc, C, H)
    bc = b.reshape(B, nc, C, N)
    cc = c.reshape(B, nc, C, N)

    la = jnp.cumsum(-jnp.exp(a_log)[None, None, None] * dtc, axis=2)  # (B,nc,C,H) <=0, decreasing
    # Intra-chunk: y[t] += sum_{j<=t} exp(la_t - la_j) dt_j (C_t.B_j) x_j
    diff = la[:, :, :, None, :] - la[:, :, None, :, :]  # (B,nc,C,C,H): t,j
    mask = jnp.tril(jnp.ones((C, C), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    G = jnp.einsum("bktn,bkjn->bktj", cc, bc)  # (B,nc,C,C)
    M = G[..., None] * L * dtc[:, :, None, :, :]  # weight for (t, j, h)
    y = jnp.einsum("bktjh,bkjhp->bkthp", M, xc)
    # Inter-chunk: y[t] += exp(la_t) C_t . h_in ; carry h across chunks.
    decay_in = jnp.exp(la)  # (B,nc,C,H)
    a_prod = jnp.exp(la[:, :, -1, :])  # (B,nc,H)
    # per-chunk state contribution: sum_j exp(la_C - la_j) dt_j (x_j (x) B_j)
    wj = jnp.exp(la[:, :, -1:, :] - la) * dtc  # (B,nc,C,H)
    h_chunk = jnp.einsum("bkjh,bkjhp,bkjn->bkhpn", wj, xc, bc)

    def carry(h, inp):
        hc, ap = inp  # (B,H,P,N), (B,H)
        h_out = h * ap[..., None, None] + hc
        return h_out, h  # emit h_in for this chunk

    h_final, h_ins = jax.lax.scan(
        carry,
        h0,
        (jnp.moveaxis(h_chunk, 1, 0), jnp.moveaxis(a_prod, 1, 0)),
    )
    h_ins = jnp.moveaxis(h_ins, 0, 1)  # (B,nc,H,P,N)
    y = y + jnp.einsum("bkth,bktn,bkhpn->bkthp", decay_in, cc, h_ins)
    y = y.reshape(B, nc * C, H, P)[:, :T]
    y = y + d_skip[None, None, :, None] * x[:, :T].reshape(B, T, H, P)
    return y, h_final


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def _mamba_specs(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state_size
    h = cfg.ssm_num_heads
    conv_dim = d_in + 2 * n
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    return {
        "ln": PSpec((d,), ("embed",), init="ones"),
        "in_proj": PSpec((d, 2 * d_in + 2 * n + h), ("embed", "mlp"), 0.02),
        "conv_w": PSpec((_CONV_K, conv_dim), (None, "mlp"), 0.2),
        "conv_b": PSpec((conv_dim,), ("mlp",), init="zeros"),
        "a_log": PSpec((h,), (None,), 0.5),
        "dt_bias": PSpec((h,), (None,), 0.5),
        "d_skip": PSpec((h,), (None,), init="ones"),
        "out_norm": PSpec((d_in,), ("mlp",), init="ones"),
        "out_proj": PSpec((d_in, d), ("mlp", "embed"), out_scale),
    }


def _split_proj(cfg, z_xbc_dt):
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state_size
    h = cfg.ssm_num_heads
    return jnp.split(z_xbc_dt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)


def _causal_conv(xbc, w, bias, conv_state=None):
    """Depthwise causal conv (K=4) via shifted adds.  xbc: (B, T, Cd).

    conv_state: (B, K-1, Cd) previous inputs (decode);  returns (y, new_state).
    """
    b, t, cd = xbc.shape
    if conv_state is None:
        conv_state = jnp.zeros((b, _CONV_K - 1, cd), xbc.dtype)
    full = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)  # (B, T+3, Cd)
    y = sum(
        full[:, i : i + t, :] * w[i][None, None, :].astype(xbc.dtype)
        for i in range(_CONV_K)
    )
    y = jax.nn.silu(y + bias[None, None].astype(xbc.dtype))
    return y, full[:, -( _CONV_K - 1):, :]


def _mamba_block(p, x, cfg, ctx, state, *, chunked: bool):
    """state = {"h": (B,H,P,N), "conv": (B,3,Cd)}; returns (y, new_state)."""
    b, t, d = x.shape
    d_in = cfg.ssm_expand * d
    n, h = cfg.ssm_state_size, cfg.ssm_num_heads
    p_dim = d_in // h

    zxbcdt = gemm(x, p["in_proj"].astype(x.dtype), cfg)
    zxbcdt = ctx.c(zxbcdt, ("batch", "seq", "mlp"))
    z, xin, bmat, cmat, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xin, bmat, cmat], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], state["conv"])
    xin, bmat, cmat = jnp.split(xbc, [d_in, d_in + n], axis=-1)

    xh = xin.reshape(b, t, h, p_dim).astype(jnp.float32)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    bf, cf = bmat.astype(jnp.float32), cmat.astype(jnp.float32)
    a_log, d_skip = p["a_log"].astype(jnp.float32), p["d_skip"].astype(jnp.float32)

    if t == 1:
        y, h_new = ssd_step(
            state["h"], xh[:, 0], dtv[:, 0], a_log, bf[:, 0], cf[:, 0], d_skip
        )
        y = y[:, None]
    elif chunked:
        y, h_new = ssd_chunked(xh, dtv, a_log, bf, cf, d_skip, state["h"])
    else:
        y, h_new = ssd_scan(xh, dtv, a_log, bf, cf, d_skip, state["h"])

    y = y.reshape(b, t, d_in).astype(x.dtype)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = gemm(y, p["out_proj"].astype(x.dtype), cfg)
    return ctx.c(out, ("batch", "seq", "embed")), {"h": h_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# Zamba2 hybrid model
# ---------------------------------------------------------------------------


def _segments(cfg) -> Tuple[int, int, int]:
    period = cfg.shared_attn_period
    n_seg = cfg.num_layers // period
    tail = cfg.num_layers - n_seg * period
    return n_seg, period, tail


def zamba_specs(cfg) -> Dict[str, Any]:
    n_seg, period, tail = _segments(cfg)
    one = _mamba_specs(cfg)
    specs: Dict[str, Any] = {
        "embed": PSpec((padded_vocab(cfg), cfg.d_model), ("vocab", "embed"), 0.02),
        "mamba_seg": stack_specs(stack_specs(one, period), n_seg),
        "shared": {
            "ln1": PSpec((cfg.d_model,), ("embed",), init="ones"),
            "ln2": PSpec((cfg.d_model,), ("embed",), init="ones"),
            "attn": attn_specs(cfg),
            "mlp": swiglu_specs(cfg, cfg.d_ff),
        },
        "final_norm": PSpec((cfg.d_model,), ("embed",), init="ones"),
        "lm_head": PSpec((cfg.d_model, padded_vocab(cfg)), ("embed", "vocab"), 0.02),
    }
    if tail:
        specs["mamba_tail"] = stack_specs(one, tail)
    return specs


def zamba_state_specs(cfg, batch: int, max_len: int):
    """Abstract decode state: per-layer SSM + conv states, per-app KV caches."""
    n_seg, period, tail = _segments(cfg)
    d_in = cfg.ssm_expand * cfg.d_model
    n, h = cfg.ssm_state_size, cfg.ssm_num_heads
    cd = d_in + 2 * n
    kv, hd = cfg.num_kv_heads, cfg.head_dim_
    L = cfg.num_layers
    return {
        "h": jax.ShapeDtypeStruct((L, batch, h, d_in // h, n), jnp.float32),
        "conv": jax.ShapeDtypeStruct((L, batch, _CONV_K - 1, cd), cfg.adtype),
        "kv_k": jax.ShapeDtypeStruct((n_seg, batch, max_len, kv, hd), cfg.adtype),
        "kv_v": jax.ShapeDtypeStruct((n_seg, batch, max_len, kv, hd), cfg.adtype),
    }


def _zero_state(cfg, batch: int, max_len: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), zamba_state_specs(cfg, batch, max_len)
    )


def _shared_block(p, x, cfg, ctx, kv=None, cache_pos=None, write_cache=False):
    h, new_cache = attention(
        p["attn"],
        rmsnorm(x, p["ln1"], cfg.norm_eps),
        cfg,
        ctx,
        cache=kv,
        cache_pos=cache_pos,
        write_cache=write_cache,
    )
    x = x + h
    x = x + swiglu(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, ctx)
    return x, new_cache


def _run(params, tokens, cfg, ctx, state, *, mode: str, pos=None, chunked=True):
    """mode: 'forward' (no cache IO) | 'prefill' | 'decode'."""
    n_seg, period, tail = _segments(cfg)
    x = embed_tokens(params, tokens, cfg, ctx)
    L = cfg.num_layers

    def mamba_scan(x, stacked, st_slice):
        def body(x, layer_in):
            lp, st = layer_in
            y, new_st = _mamba_block(lp, x, cfg, ctx, st, chunked=chunked)
            return ctx.c(x + y, ("batch", "seq_sp", "embed")), new_st

        return jax.lax.scan(body, x, (stacked, st_slice), unroll=cfg.scan_unroll)

    new_h, new_conv = [], []
    new_k, new_v = [], []
    for seg in range(n_seg):
        seg_params = jax.tree.map(lambda t: t[seg], params["mamba_seg"])
        lo = seg * period
        st = {
            "h": state["h"][lo : lo + period],
            "conv": state["conv"][lo : lo + period],
        }
        x, st_new = mamba_scan(x, seg_params, st)
        new_h.append(st_new["h"])
        new_conv.append(st_new["conv"])
        if mode == "forward":
            x, _ = _shared_block(params["shared"], x, cfg, ctx)
        elif mode == "prefill":
            x, kvc = _shared_block(params["shared"], x, cfg, ctx, write_cache=True)
            new_k.append(kvc["k"])
            new_v.append(kvc["v"])
        else:  # decode
            kv = {"k": state["kv_k"][seg], "v": state["kv_v"][seg]}
            x, kvc = _shared_block(
                params["shared"], x, cfg, ctx, kv=kv, cache_pos=pos
            )
            new_k.append(kvc["k"])
            new_v.append(kvc["v"])
    if tail:
        st = {"h": state["h"][L - tail :], "conv": state["conv"][L - tail :]}
        x, st_new = mamba_scan(x, params["mamba_tail"], st)
        new_h.append(st_new["h"])
        new_conv.append(st_new["conv"])

    logits = unembed(params, x, cfg, ctx)
    new_state = {
        "h": jnp.concatenate(new_h, axis=0),
        "conv": jnp.concatenate(new_conv, axis=0),
    }
    if mode != "forward":
        new_state["kv_k"] = jnp.stack(new_k)
        new_state["kv_v"] = jnp.stack(new_v)
    return logits, new_state


def zamba_forward(params, tokens, cfg, ctx: ShardCtx = ShardCtx(), *, chunked=True):
    logits, _ = _run(
        params, tokens, cfg, ctx,
        _zero_state(cfg, tokens.shape[0], 1), mode="forward", chunked=chunked,
    )
    return logits, {}


def zamba_prefill(params, tokens, cfg, ctx: ShardCtx = ShardCtx(), *, chunked=True):
    return _run(
        params, tokens, cfg, ctx,
        _zero_state(cfg, tokens.shape[0], 1), mode="prefill", chunked=chunked,
    )


def zamba_decode(params, tokens, state, pos, cfg, ctx: ShardCtx = ShardCtx()):
    return _run(params, tokens, cfg, ctx, state, mode="decode", pos=pos)
