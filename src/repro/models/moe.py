"""Mixture-of-Experts block: token-choice top-k routing, shared experts, EP.

Expert compute rides the grouped-GEMM planner (DESIGN.md §10): each group
dispatches its tokens by *sort/segment permutation* — every (token, choice)
pair is ranked within its expert and scattered into a group-major capacity
buffer (expert e owns rows [e*rows_per_group, e*rows_per_group + size_e)) —
and the two expert projections run as grouped plans
(`layers.grouped_gemm`), ONE ragged kernel per projection instead of the
old one-hot dispatch/combine einsum chain over a (G, s, e, cap) tensor.
Capacity scales exactly as before (cap = cf * n * k / e at scale), so
per-device memory stays bounded; small token counts (n or per-group s <=
256: decode steps, smoke tests) use cap = n, i.e. exact drop-free routing —
on those shapes the refactor is output-identical to dense dispatch.

EP mapping: the expert dim maps to 'model' when divisible (OLMoE 64 % 16 ==
0) else the expert hidden dim is TP-sharded (Qwen2-MoE: 60 experts).  The
capacity buffer's row dim is expert-major, so the 'expert_rows' rule shards
it the same way — and the planner's `expert` collective schedule
(ShardSpec.axis_g) covers explicit EP meshes.

Aux: Switch load-balance loss + router z-loss, returned for the train loop.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import PSpec, ShardCtx, gemm, grouped_gemm

__all__ = ["moe_specs", "moe_block", "swiglu_specs", "swiglu"]

_GROUP_SIZE = 1024  # tokens per dispatch group at scale (capacity scaling)
_EXACT_GROUP = 256  # groups this small route exactly (no capacity drops)
_ROW_ALIGN = 8      # capacity rounds up so row blocks tile the ragged grid


def swiglu_specs(cfg, d_ff: int) -> Dict[str, PSpec]:
    d = cfg.d_model
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    return {
        "wi": PSpec((d, 2 * d_ff), ("embed", "mlp"), 0.02),  # fused gate+up
        "wo": PSpec((d_ff, d), ("mlp", "embed"), out_scale),
    }


def swiglu(p: Dict[str, jax.Array], x: jax.Array, cfg, ctx: ShardCtx) -> jax.Array:
    gate_up = gemm(x, p["wi"], cfg)
    gate_up = ctx.c(gate_up, ("batch", "seq", "mlp"))
    gate, up = jnp.split(gate_up, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    y = gemm(h, p["wo"], cfg)
    return ctx.c(y, ("batch", "seq", "embed"))


def moe_specs(cfg) -> Dict[str, PSpec]:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    # EP when experts divide the TP axis; else shard the expert hidden dim.
    ep_divisible = e % 16 == 0  # production 'model' axis size (DESIGN.md §4)
    eax = "experts" if ep_divisible else None
    fax = None if ep_divisible else "mlp"
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    specs = {
        "router": PSpec((d, e), ("embed", None), 0.02, dtype=jnp.float32),
        "wi": PSpec((e, d, 2 * f), (eax, "embed", fax), 0.02),
        "wo": PSpec((e, f, d), (eax, fax, "embed"), out_scale),
    }
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        specs["shared_wi"] = PSpec((d, 2 * fs), ("embed", "mlp"), 0.02)
        specs["shared_wo"] = PSpec((fs, d), ("mlp", "embed"), out_scale)
        specs["shared_gate"] = PSpec((d, 1), ("embed", None), 0.02)
    return specs


def _capacity(n: int, t: int, e: int, k: int, capacity_factor: float) -> int:
    """Per-expert row capacity, preserving the dense-dispatch scaling: tokens
    notionally split into (n // s) groups of s = min(_GROUP_SIZE, ...), each
    granting cf * s * k / e slots — except small groups, which route exactly
    (cap = n, drop-free)."""
    s = min(_GROUP_SIZE, t) if t > 1 else min(_GROUP_SIZE, n)
    while n % s:
        s //= 2
    if s <= _EXACT_GROUP:
        return n
    return (n // s) * max(1, int(capacity_factor * s * k / e))


def moe_block(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, T, D)
    cfg,
    ctx: ShardCtx,
    *,
    capacity_factor: float = 1.25,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (output, aux) with aux = {'lb_loss', 'router_z'}."""
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    n = b * t

    xf = x.reshape(n, d)
    xf = ctx.c(xf, ("batch", "embed"))
    logits = jnp.einsum(
        "nd,de->ne", xf.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)

    topv, topi = jax.lax.top_k(probs, k)  # (n, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    cap = _capacity(n, t, e, k, capacity_factor)
    rpg = -(-cap // _ROW_ALIGN) * _ROW_ALIGN  # static rows-per-group bound
    rows = e * rpg

    # Sort/segment permutation: rank each (token, choice) pair within its
    # expert (stable sort keeps token order), keep the first `cap`, and
    # scatter kept tokens into the group-major capacity buffer the grouped
    # planner consumes.  Replaces the (G, s, e, cap) one-hot dispatch einsum.
    flat_e = topi.reshape(-1)  # (n*k,) expert id per pair, token-major
    flat_t = jnp.repeat(jnp.arange(n), k)  # token id per pair
    order = jnp.argsort(flat_e)  # stable: pairs grouped by expert
    counts = jnp.bincount(flat_e, length=e)  # (e,) demand per expert
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(n * k) - starts[flat_e[order]]
    rank = jnp.zeros((n * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < cap
    gate = topv.reshape(-1) * keep.astype(topv.dtype)
    dest = jnp.where(keep, flat_e * rpg + rank, rows)  # rows => dropped

    sizes = jnp.minimum(counts, cap)
    group_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(sizes).astype(jnp.int32)]
    )

    buf = (
        jnp.zeros((rows, d), x.dtype).at[dest].set(xf[flat_t], mode="drop")
    )
    buf = ctx.c(buf, ("expert_rows", "embed"))

    gate_up = grouped_gemm(buf, group_offsets, p["wi"], cfg)  # (rows, 2f)
    gate_h, up_h = jnp.split(gate_up, 2, axis=-1)
    h = jax.nn.silu(gate_h) * up_h
    ex_out = grouped_gemm(h, group_offsets, p["wo"], cfg)  # (rows, d)
    ex_out = ctx.c(ex_out, ("expert_rows", "embed"))

    # Combine: gather each pair's expert output back and weight by its gate
    # (dropped pairs carry gate 0, so the clipped gather never contributes).
    contrib = ex_out[jnp.clip(dest, 0, rows - 1)] * gate.astype(x.dtype)[:, None]
    y = jnp.sum(
        contrib.astype(jnp.float32).reshape(n, k, d), axis=1
    ).astype(x.dtype).reshape(b, t, d)

    if cfg.num_shared_experts:
        # shared_gate rides the plan/execute API like every other projection
        # (f32 operands preserve the fp32-router numerics of the gate).
        sg = jax.nn.sigmoid(
            gemm(xf.astype(jnp.float32), p["shared_gate"].astype(jnp.float32), cfg)
        ).astype(x.dtype)
        gu = gemm(xf, p["shared_wi"], cfg)
        g_, u_ = jnp.split(gu, 2, axis=-1)
        shared = gemm(jax.nn.silu(g_) * u_, p["shared_wo"], cfg)
        y = y + (shared * sg).reshape(b, t, d)

    # Switch load-balance + router z-loss (means over all tokens).  The
    # routing `counts` from dispatch ARE the one-hot load sums (top-k indices
    # carry no gradient either way), so no (n, k, e) tensor materializes.
    load = counts.astype(jnp.float32) / n  # fraction routed per expert
    imp = jnp.mean(probs, axis=0)
    lb_loss = e * jnp.sum(load * imp) / k
    router_z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = {"lb_loss": lb_loss, "router_z": router_z}
    return ctx.c(y, ("batch", "seq", "embed")), aux
