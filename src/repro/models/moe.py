"""Mixture-of-Experts block: token-choice top-k routing, shared experts, EP.

Switch/Mesh-TF *grouped* dense-dispatch: tokens are reshaped to
(groups, group_size) with groups aligned to the data-sharded batch dim, and
每 group dispatches into per-expert capacity buffers via one-hot einsums.
Capacity scales with group_size (cap = cf * s * k / e), so the dispatch
tensor is (G, s, e, cap) with G sharded over ('pod','data') and e over
'model' — bounded per-device memory at any scale (DESIGN.md §4).  Small
groups (s <= 256: decode steps, smoke tests) use cap = s, i.e. exact
drop-free routing.

EP mapping: the expert dim maps to 'model' when divisible (OLMoE 64 % 16 == 0)
else the expert hidden dim is TP-sharded (Qwen2-MoE: 60 experts).

Aux: Switch load-balance loss + router z-loss, returned for the train loop.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import PSpec, ShardCtx, gemm

__all__ = ["moe_specs", "moe_block", "swiglu_specs", "swiglu"]

_GROUP_SIZE = 1024  # tokens per dispatch group at scale
_EXACT_GROUP = 256  # groups this small route exactly (no capacity drops)


def swiglu_specs(cfg, d_ff: int) -> Dict[str, PSpec]:
    d = cfg.d_model
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    return {
        "wi": PSpec((d, 2 * d_ff), ("embed", "mlp"), 0.02),  # fused gate+up
        "wo": PSpec((d_ff, d), ("mlp", "embed"), out_scale),
    }


def swiglu(p: Dict[str, jax.Array], x: jax.Array, cfg, ctx: ShardCtx) -> jax.Array:
    gate_up = gemm(x, p["wi"], cfg)
    gate_up = ctx.c(gate_up, ("batch", "seq", "mlp"))
    gate, up = jnp.split(gate_up, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    y = gemm(h, p["wo"], cfg)
    return ctx.c(y, ("batch", "seq", "embed"))


def moe_specs(cfg) -> Dict[str, PSpec]:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    # EP when experts divide the TP axis; else shard the expert hidden dim.
    ep_divisible = e % 16 == 0  # production 'model' axis size (DESIGN.md §4)
    eax = "experts" if ep_divisible else None
    fax = None if ep_divisible else "mlp"
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    specs = {
        "router": PSpec((d, e), ("embed", None), 0.02, dtype=jnp.float32),
        "wi": PSpec((e, d, 2 * f), (eax, "embed", fax), 0.02),
        "wo": PSpec((e, f, d), (eax, fax, "embed"), out_scale),
    }
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        specs["shared_wi"] = PSpec((d, 2 * fs), ("embed", "mlp"), 0.02)
        specs["shared_wo"] = PSpec((fs, d), ("mlp", "embed"), out_scale)
        specs["shared_gate"] = PSpec((d, 1), ("embed", None), 0.02)
    return specs


def moe_block(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, T, D)
    cfg,
    ctx: ShardCtx,
    *,
    capacity_factor: float = 1.25,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (output, aux) with aux = {'lb_loss', 'router_z'}."""
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    n = b * t

    # Group tokens along the (batch-sharded) leading dims: (G, s, d).
    s = min(_GROUP_SIZE, t) if t > 1 else min(_GROUP_SIZE, n)
    while n % s:
        s //= 2
    g = n // s
    cap = s if s <= _EXACT_GROUP else max(1, int(capacity_factor * s * k / e))

    xg = x.reshape(g, s, d)
    xg = ctx.c(xg, ("batch", None, "embed"))
    logits = jnp.einsum(
        "gsd,de->gse", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)

    topv, topi = jax.lax.top_k(probs, k)  # (g, s, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    # Position of each (token, choice) in its expert's buffer, within-group.
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)  # (g, s, k, e)
    flat = onehot.reshape(g, s * k, e)
    pos = (jnp.cumsum(flat, axis=1) - 1.0).reshape(g, s, k, e)
    pos = jnp.sum(pos * onehot, axis=-1)  # (g, s, k)
    keep = pos < cap
    gate = topv * keep.astype(topv.dtype)

    # (g, s, e, cap) dispatch tensor: token -> (expert, slot).
    cap_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=xg.dtype)  # (g, s, k, cap)
    onehot_keep = onehot.astype(xg.dtype) * keep[..., None].astype(xg.dtype)
    disp = jnp.einsum("gske,gskc->gsec", onehot_keep, cap_oh)
    ex_in = jnp.einsum("gsec,gsd->gecd", disp, xg)  # (g, e, cap, d)
    ex_in = ctx.c(ex_in, ("batch", "experts", None, "embed"))

    gate_up = jnp.einsum("gecd,edf->gecf", ex_in, p["wi"])
    gate_h, up_h = jnp.split(gate_up, 2, axis=-1)
    h = jax.nn.silu(gate_h) * up_h
    ex_out = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    ex_out = ctx.c(ex_out, ("batch", "experts", None, "embed"))

    combine = jnp.einsum(
        "gske,gskc->gsec", onehot_keep * gate.astype(xg.dtype)[..., None], cap_oh
    )
    y = jnp.einsum("gsec,gecd->gsd", combine, ex_out).reshape(b, t, d)

    if cfg.num_shared_experts:
        xf = x.reshape(n, d)
        sg = jax.nn.sigmoid(
            jnp.einsum("nd,do->no", xf.astype(jnp.float32), p["shared_gate"].astype(jnp.float32))
        ).astype(x.dtype)
        gu = gemm(xf, p["shared_wi"], cfg)
        g_, u_ = jnp.split(gu, 2, axis=-1)
        shared = gemm(jax.nn.silu(g_) * u_, p["shared_wo"], cfg)
        y = y + (shared * sg).reshape(b, t, d)

    # Switch load-balance + router z-loss (means over all groups/tokens).
    load = jnp.mean(onehot.sum(2), axis=(0, 1))  # fraction routed per expert
    imp = jnp.mean(probs, axis=(0, 1))
    lb_loss = e * jnp.sum(load * imp) / k
    router_z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = {"lb_loss": lb_loss, "router_z": router_z}
    return ctx.c(y, ("batch", "seq", "embed")), aux
