"""Model zoo: shared layers + 10 assigned architectures via a uniform API."""

from repro.models.layers import ShardCtx, softmax_xent
from repro.models.registry import Model, get_model

__all__ = ["Model", "get_model", "ShardCtx", "softmax_xent"]
