"""Whisper-medium backbone (arXiv:2212.04356): encoder-decoder transformer.

Per the assignment, the conv/mel frontend is a STUB: `input_specs()` supplies
precomputed frame embeddings (B, T_enc, D) directly to the encoder.
Assigned-shape convention (DESIGN.md §6): encoder length = shape seq_len,
decoder length = seq_len // cfg.dec_ratio.

Encoder: bidirectional self-attention blocks (no cache).
Decoder: causal self-attention (+KV cache) and cross-attention over the
encoder output; cross K/V are computed once at prefill and carried in the
decode state.  RoPE is used for positions in both stacks (framework-level
adaptation of Whisper's learned absolute embeddings — noted in DESIGN.md).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.attention import attention, attn_specs
from repro.models.layers import PSpec, ShardCtx, dense, gemm, padded_vocab, rmsnorm
from repro.models.moe import swiglu, swiglu_specs
from repro.models.transformer import stack_specs, unembed

__all__ = [
    "whisper_specs",
    "whisper_forward",
    "whisper_prefill",
    "whisper_decode",
    "whisper_cache_specs",
]


def _enc_block_specs(cfg):
    return {
        "ln1": PSpec((cfg.d_model,), ("embed",), init="ones"),
        "ln2": PSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn_specs(cfg),
        "mlp": swiglu_specs(cfg, cfg.d_ff),
    }


def _dec_block_specs(cfg):
    return {
        "ln1": PSpec((cfg.d_model,), ("embed",), init="ones"),
        "ln_x": PSpec((cfg.d_model,), ("embed",), init="ones"),
        "ln2": PSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn_specs(cfg),
        "xattn": attn_specs(cfg),
        "mlp": swiglu_specs(cfg, cfg.d_ff),
    }


def whisper_specs(cfg) -> Dict[str, Any]:
    return {
        # frontend stub: a single projection applied to precomputed frames
        "frame_proj": PSpec((cfg.d_model, cfg.d_model), ("embed", "embed"), 0.02),
        "enc_blocks": stack_specs(_enc_block_specs(cfg), cfg.enc_layers),
        "enc_norm": PSpec((cfg.d_model,), ("embed",), init="ones"),
        "embed": PSpec((padded_vocab(cfg), cfg.d_model), ("vocab", "embed"), 0.02),
        "dec_blocks": stack_specs(_dec_block_specs(cfg), cfg.dec_layers),
        "final_norm": PSpec((cfg.d_model,), ("embed",), init="ones"),
        "lm_head": PSpec((cfg.d_model, padded_vocab(cfg)), ("embed", "vocab"), 0.02),
    }


def _encode(params, frames, cfg, ctx):
    """frames: (B, T_enc, D) precomputed embeddings (stub frontend)."""
    x = gemm(frames.astype(cfg.adtype), params["frame_proj"].astype(cfg.adtype), cfg)
    x = ctx.c(x, ("batch", "frames", "embed"))

    def body(x, lp):
        h, _ = attention(
            lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg, ctx, causal=False
        )
        x = x + h
        x = x + swiglu(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg, ctx)
        return ctx.c(x, ("batch", "seq_sp", "embed")), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"], unroll=cfg.scan_unroll)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(lp, enc_out, cfg):
    """Precompute cross-attention K/V from encoder output for one layer."""
    b, t, _ = enc_out.shape
    kvh, hd = cfg.num_kv_heads, cfg.head_dim_
    k = dense(enc_out, lp["xattn"]["wk"], cfg, lp["xattn"].get("bk")).reshape(b, t, kvh, hd)
    v = dense(enc_out, lp["xattn"]["wv"], cfg, lp["xattn"].get("bv")).reshape(b, t, kvh, hd)
    return k, v


def _decode_stack(params, tokens, enc_out, cfg, ctx, *, cache=None, pos=None, write_cache=False):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
    x = ctx.c(x, ("batch", "seq", "embed"))

    def body(x, layer_in):
        if cache is not None:
            lp, kvc = layer_in
        else:
            lp, kvc = layer_in, None
        h, new_kv = attention(
            lp["attn"],
            rmsnorm(x, lp["ln1"], cfg.norm_eps),
            cfg,
            ctx,
            cache=kvc,
            cache_pos=pos,
            write_cache=write_cache,
        )
        x = x + h
        xk, xv = _cross_kv(lp, enc_out, cfg)
        h, _ = attention(
            lp["xattn"],
            rmsnorm(x, lp["ln_x"], cfg.norm_eps),
            cfg,
            ctx,
            cross_kv=(xk, xv),
        )
        x = x + h
        x = x + swiglu(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg, ctx)
        return ctx.c(x, ("batch", "seq_sp", "embed")), new_kv

    xs = (params["dec_blocks"], cache) if cache is not None else params["dec_blocks"]
    x, new_caches = jax.lax.scan(body, x, xs, unroll=cfg.scan_unroll)
    logits = unembed(params, x, cfg, ctx)
    return logits, new_caches


def whisper_forward(params, batch: Dict[str, jax.Array], cfg, ctx: ShardCtx = ShardCtx()):
    """batch: {"frames": (B, T_enc, D), "tokens": (B, T_dec)} -> (logits, aux)."""
    enc_out = _encode(params, batch["frames"], cfg, ctx)
    logits, _ = _decode_stack(params, batch["tokens"], enc_out, cfg, ctx)
    return logits, {}


def whisper_prefill(params, batch, cfg, ctx: ShardCtx = ShardCtx()):
    """Returns (logits, state) with state carrying enc_out + self-KV caches."""
    enc_out = _encode(params, batch["frames"], cfg, ctx)
    logits, caches = _decode_stack(
        params, batch["tokens"], enc_out, cfg, ctx, write_cache=True
    )
    return logits, {"enc_out": enc_out, "k": caches["k"], "v": caches["v"]}


def whisper_decode(params, tokens, state, pos, cfg, ctx: ShardCtx = ShardCtx()):
    cache = {"k": state["k"], "v": state["v"]}
    logits, new_kv = _decode_stack(
        params, tokens, state["enc_out"], cfg, ctx, cache=cache, pos=pos
    )
    new_state = {"enc_out": state["enc_out"], "k": new_kv["k"], "v": new_kv["v"]}
    return logits, new_state


def whisper_cache_specs(cfg, batch: int, enc_len: int, max_dec_len: int):
    kv, hd = cfg.num_kv_heads, cfg.head_dim_
    L = cfg.dec_layers
    return {
        "enc_out": jax.ShapeDtypeStruct((batch, enc_len, cfg.d_model), cfg.adtype),
        "k": jax.ShapeDtypeStruct((L, batch, max_dec_len, kv, hd), cfg.adtype),
        "v": jax.ShapeDtypeStruct((L, batch, max_dec_len, kv, hd), cfg.adtype),
    }
