"""Uniform model API over all families — the layer launch/train/serve talk to.

`get_model(cfg)` returns a `Model` with a family-independent interface:
  init / abstract_params / logical_axes      parameter trees (1 source: PSpec)
  forward(params, batch, ctx)                train/eval logits
  loss(params, batch, ctx)                   scalar loss + metrics
  prefill / decode + decode_state_specs      serving path
  batch_specs(shape) / decode_input_specs    ShapeDtypeStructs + logical axes
                                             for dry-run lowering (no alloc)

Batch conventions (DESIGN.md §6):
  LM (dense/moe/ssm/hybrid/vlm): {"tokens": (B,S), "labels": (B,S)}
      vlm adds {"patches": (B,P,D)}   (stub ViT frontend)
  whisper:  {"frames": (B,S,D), "tokens": (B,S//r), "labels": (B,S//r)}
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import rwkv, ssm, transformer, vlm, whisper
from repro.models.layers import (
    ShardCtx,
    abstract_params,
    init_params,
    logical_axes_tree,
    softmax_xent,
)

__all__ = ["Model", "get_model"]


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    _specs: Callable
    _forward: Callable
    _prefill: Callable
    _decode: Callable
    _state_specs: Callable  # (batch, max_len) -> abstract decode state
    # Paged-KV decode for the continuous-batching scheduler (DESIGN.md §12).
    # None for families whose decode state is not a KV cache: recurrent
    # families (ssm) carry O(1) state and are batched by stacking it per
    # slot instead; hybrid/audio are not schedulable (see launch/scheduler).
    _paged_decode: Optional[Callable] = None

    # -- parameters ---------------------------------------------------------
    def specs(self):
        return self._specs(self.cfg)

    def init(self, key: jax.Array):
        return init_params(key, self.specs(), self.cfg.pdtype)

    def abstract_params(self):
        return abstract_params(self.specs(), self.cfg.pdtype)

    def logical_axes(self):
        return logical_axes_tree(self.specs())

    # -- compute ------------------------------------------------------------
    def forward(self, params, batch: Dict[str, jax.Array], ctx: ShardCtx = ShardCtx()):
        return self._forward(params, batch, self.cfg, ctx)

    def loss(self, params, batch, ctx: ShardCtx = ShardCtx()):
        logits, aux = self.forward(params, batch, ctx)
        loss, acc = softmax_xent(logits, batch["labels"])
        if aux.get("lb_loss") is not None and self.cfg.is_moe:
            loss = loss + self.cfg.router_aux_coef * aux["lb_loss"]
            loss = loss + 1e-3 * aux["router_z"]
        metrics = {"loss": loss, "accuracy": acc, **aux}
        return loss, metrics

    def prefill(self, params, batch, ctx: ShardCtx = ShardCtx()):
        return self._prefill(params, batch, self.cfg, ctx)

    def decode(self, params, tokens, state, pos, ctx: ShardCtx = ShardCtx()):
        return self._decode(params, tokens, state, pos, self.cfg, ctx)

    def decode_state_specs(self, batch: int, max_len: int):
        return self._state_specs(self.cfg, batch, max_len)

    # -- paged serving (continuous batching) ---------------------------------
    @property
    def supports_paged(self) -> bool:
        return self._paged_decode is not None

    def paged_decode(
        self,
        params,
        tokens,  # (S, 1)
        pools,  # {"k","v"}: (L, P, page_size, KV, hd)
        block_tables,  # (S, n_pages)
        positions,  # (S,)
        ctx: ShardCtx = ShardCtx(),
        *,
        impl: Optional[str] = None,
        interpret: bool = False,
    ):
        """One continuous-batching decode step against paged KV pools."""
        if self._paged_decode is None:
            raise NotImplementedError(
                f"family {self.cfg.family!r} has no paged decode path"
            )
        return self._paged_decode(
            params,
            tokens,
            pools,
            block_tables,
            positions,
            self.cfg,
            ctx,
            impl=impl,
            interpret=interpret,
        )

    def paged_pool_specs(self, num_pages: int, page_size: int):
        if self._paged_decode is None:
            raise NotImplementedError(
                f"family {self.cfg.family!r} has no paged decode path"
            )
        return transformer.paged_pool_specs(self.cfg, num_pages, page_size)

    # -- dry-run input specs --------------------------------------------------
    def batch_specs(self, shape: ShapeSpec) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Training/prefill inputs as ShapeDtypeStructs + logical axes."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if cfg.family == "audio":
            dec = s // cfg.dec_ratio
            specs = {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.adtype),
                "tokens": jax.ShapeDtypeStruct((b, dec), i32),
                "labels": jax.ShapeDtypeStruct((b, dec), i32),
            }
            axes = {
                "frames": ("batch", "frames", "embed"),
                "tokens": ("batch", "seq"),
                "labels": ("batch", "seq"),
            }
        elif cfg.family == "vlm":
            specs = {
                "patches": jax.ShapeDtypeStruct((b, cfg.num_stub_patches, cfg.d_model), cfg.adtype),
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
            axes = {
                "patches": ("batch", "patches", "embed"),
                "tokens": ("batch", "seq"),
                "labels": ("batch", "seq"),
            }
        else:
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
            axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        return specs, axes

    def decode_input_specs(self, shape: ShapeSpec):
        """serve_step inputs: (tokens, state, pos) specs + state logical axes."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        if cfg.family == "audio":
            state = whisper.whisper_cache_specs(cfg, b, s, s // cfg.dec_ratio)
            axes = {
                "enc_out": ("kv_batch", "kv_seq", "embed"),
                "k": ("layers", "kv_batch", "kv_seq", "kv_heads", "head_dim"),
                "v": ("layers", "kv_batch", "kv_seq", "kv_heads", "head_dim"),
            }
        elif cfg.family == "ssm":
            state = rwkv.rwkv_state_specs(cfg, b)
            axes = {
                "wkv": ("layers", "batch", "heads", None, None),
                "tm_shift": ("layers", "batch", "embed"),
                "cm_shift": ("layers", "batch", "embed"),
            }
        elif cfg.family == "hybrid":
            state = ssm.zamba_state_specs(cfg, b, s)
            axes = {
                "h": ("layers", "batch", "heads", None, "state"),
                "conv": ("layers", "batch", None, "mlp"),
                "kv_k": ("layers", "kv_batch", "kv_seq", "kv_heads", "head_dim"),
                "kv_v": ("layers", "kv_batch", "kv_seq", "kv_heads", "head_dim"),
            }
        else:
            max_len = s + (cfg.num_stub_patches if cfg.family == "vlm" else 0)
            state = transformer.decode_cache_specs(cfg, b, max_len)
            axes = {
                "k": ("layers", "kv_batch", "kv_seq", "kv_heads", "head_dim"),
                "v": ("layers", "kv_batch", "kv_seq", "kv_heads", "head_dim"),
            }
        return tokens, state, pos, axes


def _lm_forward(params, batch, cfg, ctx):
    return transformer.lm_forward(params, batch["tokens"], cfg, ctx)


def _lm_prefill(params, batch, cfg, ctx):
    return transformer.lm_prefill(params, batch["tokens"], cfg, ctx)


def _rwkv_forward(params, batch, cfg, ctx):
    return rwkv.rwkv_forward(params, batch["tokens"], cfg, ctx)


def _rwkv_prefill(params, batch, cfg, ctx):
    return rwkv.rwkv_prefill(params, batch["tokens"], cfg, ctx)


def _zamba_forward(params, batch, cfg, ctx):
    return ssm.zamba_forward(params, batch["tokens"], cfg, ctx)


def _zamba_prefill(params, batch, cfg, ctx):
    return ssm.zamba_prefill(params, batch["tokens"], cfg, ctx)


def get_model(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return Model(
            cfg,
            transformer.lm_specs,
            _lm_forward,
            _lm_prefill,
            transformer.lm_decode,
            lambda c, b, m: transformer.decode_cache_specs(c, b, m),
            _paged_decode=transformer.lm_decode_paged,
        )
    if fam == "ssm":
        return Model(
            cfg,
            rwkv.rwkv_specs,
            _rwkv_forward,
            _rwkv_prefill,
            rwkv.rwkv_decode,
            lambda c, b, m: rwkv.rwkv_state_specs(c, b),
        )
    if fam == "hybrid":
        return Model(
            cfg,
            ssm.zamba_specs,
            _zamba_forward,
            _zamba_prefill,
            ssm.zamba_decode,
            ssm.zamba_state_specs,
        )
    if fam == "audio":
        return Model(
            cfg,
            whisper.whisper_specs,
            whisper.whisper_forward,
            whisper.whisper_prefill,
            whisper.whisper_decode,
            lambda c, b, m: whisper.whisper_cache_specs(c, b, m, m // c.dec_ratio),
        )
    if fam == "vlm":
        return Model(
            cfg,
            vlm.vlm_specs,
            vlm.vlm_forward,
            vlm.vlm_prefill,
            vlm.vlm_decode,
            lambda c, b, m: vlm.vlm_cache_specs(c, b, m + c.num_stub_patches),
            # vlm decode is structurally lm_decode (patches only affect
            # prefill); the scheduler offsets positions by num_stub_patches.
            _paged_decode=transformer.lm_decode_paged,
        )
    raise ValueError(f"unknown family {fam!r}")
