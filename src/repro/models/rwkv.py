"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent decay.

Per layer: time-mix (WKV linear recurrence with per-channel data-dependent
decay w_t, bonus u, data-dependent token-shift interpolation via a shared
LoRA) + channel-mix.  The WKV state is (H, K, V) per sequence — O(1) in
sequence length, which is why rwkv6 runs the long_500k decode cell.

The recurrence S_t = diag(w_t) S_{t-1} + k_t^T v_t is evaluated with
`jax.lax.scan` over time (the faithful sequential form; the per-channel decay
makes the chunked-parallel form numerically delicate — see DESIGN.md
§Arch-applicability: the mesh-array technique applies to this model's GEMMs,
not to the recurrence).

Entry points mirror transformer.py: rwkv_specs / rwkv_forward / rwkv_prefill /
rwkv_decode with stacked per-layer states {"wkv", "tm_shift", "cm_shift"}.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import PSpec, ShardCtx, gemm, rmsnorm
from repro.models.layers import padded_vocab
from repro.models.transformer import embed_tokens, stack_specs, unembed

__all__ = ["rwkv_specs", "rwkv_forward", "rwkv_prefill", "rwkv_decode", "rwkv_state_specs"]

_LORA = 32  # ddlerp LoRA rank
_DECAY_LORA = 64


def _layer_specs(cfg) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    out_scale = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5)
    return {
        "ln1": PSpec((d,), ("embed",), init="ones"),
        "ln2": PSpec((d,), ("embed",), init="ones"),
        # time-mix
        "mu_x": PSpec((d,), ("embed",), 0.5),
        "mu_rkvwg": PSpec((5, d), (None, "embed"), 0.5),
        "tm_w1": PSpec((d, 5 * _LORA), ("embed", None), 0.02),
        "tm_w2": PSpec((5, _LORA, d), (None, None, "embed"), 0.02),
        "w0": PSpec((d,), ("embed",), 0.5),
        "ww1": PSpec((d, _DECAY_LORA), ("embed", None), 0.02),
        "ww2": PSpec((_DECAY_LORA, d), (None, "embed"), 0.02),
        "u": PSpec((d,), ("embed",), 0.5),
        "wr": PSpec((d, d), ("embed", "heads"), 0.02),
        "wk": PSpec((d, d), ("embed", "heads"), 0.02),
        "wv": PSpec((d, d), ("embed", "heads"), 0.02),
        "wg": PSpec((d, d), ("embed", "heads"), 0.02),
        "wo": PSpec((d, d), ("heads", "embed"), out_scale),
        "gn_g": PSpec((d,), ("embed",), init="ones"),
        "gn_b": PSpec((d,), ("embed",), init="zeros"),
        # channel-mix
        "cm_mu_k": PSpec((d,), ("embed",), 0.5),
        "cm_mu_r": PSpec((d,), ("embed",), 0.5),
        "cm_wk": PSpec((d, f), ("embed", "mlp"), 0.02),
        "cm_wv": PSpec((f, d), ("mlp", "embed"), out_scale),
        "cm_wr": PSpec((d, d), ("embed", "embed"), 0.02),
    }


def rwkv_specs(cfg) -> Dict[str, Any]:
    return {
        "embed": PSpec((padded_vocab(cfg), cfg.d_model), ("vocab", "embed"), 0.02),
        "blocks": stack_specs(_layer_specs(cfg), cfg.num_layers),
        "final_norm": PSpec((cfg.d_model,), ("embed",), init="ones"),
        "lm_head": PSpec((cfg.d_model, padded_vocab(cfg)), ("embed", "vocab"), 0.02),
    }


def rwkv_state_specs(cfg, batch: int):
    """Abstract stacked per-layer recurrent state (ShapeDtypeStructs)."""
    h, k = cfg.num_heads, cfg.head_dim_
    L, d = cfg.num_layers, cfg.d_model
    f32 = jnp.float32
    return {
        "wkv": jax.ShapeDtypeStruct((L, batch, h, k, k), f32),
        "tm_shift": jax.ShapeDtypeStruct((L, batch, d), cfg.adtype),
        "cm_shift": jax.ShapeDtypeStruct((L, batch, d), cfg.adtype),
    }


def _zero_state(cfg, batch: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), rwkv_state_specs(cfg, batch)
    )


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift interpolation for (r, k, v, w, g)."""
    base = x + (x_prev - x) * p["mu_x"].astype(x.dtype)
    lora = jnp.einsum(
        "btd,dr->btr", base, p["tm_w1"].astype(x.dtype)
    ).reshape(*x.shape[:-1], 5, _LORA)
    adj = jnp.einsum("btir,ird->btid", jnp.tanh(lora), p["tm_w2"].astype(x.dtype))
    mus = p["mu_rkvwg"].astype(x.dtype) + adj  # (B, T, 5, D)
    return [x + (x_prev - x) * mus[..., i, :] for i in range(5)]


_WKV_CHUNK = 128


def _wkv_chunked(r, k, v, w, u, s0, chunk: int = 16, unroll: bool = False):
    """Chunk-parallel (GEMM-form) WKV — exact, beyond-paper hillclimb.

    The faithful per-token scan moves the full (B, H, K, V) state through HBM
    twice per token; this form touches the state twice per CHUNK and turns
    the per-token MACs into MXU matmuls (the TPU-native reading of the
    paper's 'feed the systolic array without bubbles').

    Derivation (per head, per channel c of K):
      S_t = diag(w_t) S_{t-1} + k_t v_t^T;   o_t = r_t (S_{t-1} + u k_t v_t^T)
      With cumulative log-decay cw_t = sum_{i<=t} log w_i inside a chunk:
        o_t = (r_t . e^{cw_{t-1}}) S_in                       [inter-chunk]
            + sum_{j<t} [ sum_c r_tc k_jc e^{cw_{t-1,c}-cw_{j,c}} ] v_j
            + (sum_c r_tc u_c k_tc) v_t                        [bonus diag]
        S_out = diag(e^{cw_C}) S_in + sum_j (e^{cw_C - cw_j} . k_j) v_j^T
      Every exponent is a difference of a *decreasing* sequence evaluated at
      j <= t-1 (or masked to -inf first), hence <= 0 — no overflow for any
      data-dependent decay.  r/k/v/w: (B, T, H, K) f32; u: (H, K);
      s0: (B, H, K, V).  T must divide by `chunk`.
    """
    b, t, h, kdim = r.shape
    vdim = s0.shape[-1]
    c = chunk
    nc = t // c
    if nc * c != t:
        raise ValueError(f"T={t} not divisible by wkv chunk={c}")

    resh = lambda a: jnp.moveaxis(a.reshape(b, nc, c, h, kdim), 1, 0)
    rc, kc, vc = resh(r), resh(k), resh(v)
    lw = jnp.log(jnp.maximum(resh(w), 1e-38))  # (nc,B,C,H,K), <= 0
    cw = jnp.cumsum(lw, axis=2)  # inclusive cumulative log decay
    cw_prev = cw - lw  # exclusive (cw_{t-1}; row 0 = 0)

    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)  # strict lower: j < t

    @jax.checkpoint
    def body(s, inp):
        rj, kj, vj, cwj, cwp = inp  # (B,C,H,K) each
        # intra-chunk attention matrix A[t,j] (strictly causal, decayed)
        diff = cwp[:, :, None] - cwj[:, None, :]  # (B,C,C,H,K): t,j
        diff = jnp.where(tri[None, :, :, None, None], diff, -1e30)
        a_mat = jnp.einsum("bthk,bjhk,btjhk->bthj", rj, kj, jnp.exp(diff))
        dg = jnp.einsum("bthk,hk,bthk->bth", rj, u, kj)  # bonus diagonal
        o = jnp.einsum("bthj,bjhv->bthv", a_mat, vj) + dg[..., None] * vj
        o = o + jnp.einsum("bthk,bhkv->bthv", rj * jnp.exp(cwp), s)
        # chunk-final state
        wj = jnp.exp(cwj[:, -1:, :, :] - cwj)  # e^{cw_C - cw_j} <= 1
        s_new = s * jnp.exp(cwj[:, -1])[..., None] + jnp.einsum(
            "bjhk,bjhv->bhkv", kj * wj, vj
        )
        return s_new, o

    s_final, o = jax.lax.scan(body, s0, (rc, kc, vc, cw, cw_prev), unroll=unroll)
    o = jnp.moveaxis(o, 0, 1).reshape(b, t, h, vdim)
    return o, s_final


def _wkv_scan(r, k, v, w, u, s0):
    """S_t = diag(w_t) S_{t-1} + k_t v_t^T;  o_t = r_t (S_{t-1} + u k_t v_t^T).

    r/k/v/w: (B, T, H, K) f32; u: (H, K); s0: (B, H, K, V).
    Returns (o (B, T, H, V), s_final).

    The time loop is a two-level scan: chunks of _WKV_CHUNK steps with the
    inner scan wrapped in jax.checkpoint, so AD saves one (B, H, K, V) state
    per *chunk* instead of per step (T/128x less remat-carrier memory —
    essential for train_4k; a flat scan would save 4096 carried states).
    """
    b, t, h, kdim = r.shape

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B, H, K) each
        kv = kt[..., None] * vt[..., None, :]  # (B, H, K, V)
        s_eff = s + u[None, :, :, None] * kv
        o = jnp.einsum("bhk,bhkv->bhv", rt, s_eff)
        s = wt[..., None] * s + kv
        return s, o

    @jax.checkpoint
    def chunk_body(s, chunk_xs):
        return jax.lax.scan(step, s, chunk_xs)

    if t % _WKV_CHUNK == 0 and t > _WKV_CHUNK:
        nc, c = t // _WKV_CHUNK, _WKV_CHUNK
        xs = jax.tree.map(
            lambda a: jnp.moveaxis(a, 1, 0).reshape(nc, c, b, h, kdim), (r, k, v, w)
        )
        s_final, o = jax.lax.scan(chunk_body, s0, xs)
        o = o.reshape(t, b, h, kdim)
    else:
        xs = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), (r, k, v, w))
        s_final, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1), s_final


def _time_mix(p, x, cfg, ctx, state_wkv, x_last):
    """x: (B, T, D); x_last: (B, D) previous-token carry.  Returns (y, wkv', last')."""
    b, t, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim_
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)

    r = gemm(xr, p["wr"].astype(x.dtype), cfg).reshape(b, t, h, hd).astype(jnp.float32)
    k = gemm(xk, p["wk"].astype(x.dtype), cfg).reshape(b, t, h, hd).astype(jnp.float32)
    v = gemm(xv, p["wv"].astype(x.dtype), cfg).reshape(b, t, h, hd).astype(jnp.float32)
    g = gemm(xg, p["wg"].astype(x.dtype), cfg, activation="silu")

    # data-dependent decay w_t in (0, 1): exp(-exp(w0 + lora(xw)))
    dec = p["w0"].astype(jnp.float32) + jnp.einsum(
        "btr,rd->btd",
        jnp.tanh(jnp.einsum("btd,dr->btr", xw.astype(jnp.float32), p["ww1"].astype(jnp.float32))),
        p["ww2"].astype(jnp.float32),
    )
    w = jnp.exp(-jnp.exp(dec)).reshape(b, t, h, hd)
    u = p["u"].astype(jnp.float32).reshape(h, hd)

    if getattr(cfg, "wkv_chunked", False) and t > 1 and t % cfg.wkv_chunk == 0:
        # NOTE: the chunk scan stays a while loop even under cost-probe
        # lowering (unrolling nc=T/chunk bodies explodes compile time); its
        # traffic is accounted analytically — dryrun.recurrence_traffic_analytic.
        o, s_final = _wkv_chunked(r, k, v, w, u, state_wkv, chunk=cfg.wkv_chunk)
    else:
        o, s_final = _wkv_scan(r, k, v, w, u, state_wkv)
    o = o.reshape(b, t, d).astype(x.dtype)
    # per-head group norm
    og = o.reshape(b, t, h, hd).astype(jnp.float32)
    mean = og.mean(-1, keepdims=True)
    var = og.var(-1, keepdims=True)
    og = ((og - mean) * jax.lax.rsqrt(var + 64e-5)).reshape(b, t, d).astype(x.dtype)
    o = og * p["gn_g"].astype(x.dtype) + p["gn_b"].astype(x.dtype)
    y = gemm(o * g, p["wo"].astype(x.dtype), cfg)
    return ctx.c(y, ("batch", "seq", "embed")), s_final, x[:, -1, :]


def _channel_mix(p, x, cfg, ctx, x_last):
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    xk = x + (x_prev - x) * p["cm_mu_k"].astype(x.dtype)
    xr = x + (x_prev - x) * p["cm_mu_r"].astype(x.dtype)
    kk = jnp.square(gemm(xk, p["cm_wk"].astype(x.dtype), cfg, activation="relu"))
    kk = ctx.c(kk, ("batch", "seq", "mlp"))
    vv = gemm(kk, p["cm_wv"].astype(x.dtype), cfg)
    rr = gemm(xr, p["cm_wr"].astype(x.dtype), cfg, activation="sigmoid")
    return ctx.c(rr * vv, ("batch", "seq", "embed")), x[:, -1, :]


def _block(p, x, cfg, ctx, st):
    y, wkv, tm_last = _time_mix(p, rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, ctx, st["wkv"], st["tm_shift"])
    x = x + y
    y2, cm_last = _channel_mix(p, rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, ctx, st["cm_shift"])
    x = x + y2
    return x, {"wkv": wkv, "tm_shift": tm_last, "cm_shift": cm_last}


def _run(params, tokens, cfg, ctx, state):
    x = embed_tokens(params, tokens, cfg, ctx)

    def body(x, layer_in):
        lp, st = layer_in
        # Note: time-mix normalizes the shift carry with this layer's ln1, so
        # the carry stores the *pre-norm* activation; we keep the normalized
        # variant for exactness between forward and decode.
        xin = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        y, wkv, tm_last = _time_mix(lp, xin, cfg, ctx, st["wkv"], st["tm_shift"])
        x = x + y
        xin2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        y2, cm_last = _channel_mix(lp, xin2, cfg, ctx, st["cm_shift"])
        x = ctx.c(x + y2, ("batch", "seq_sp", "embed"))  # SP remat carrier
        return x, {"wkv": wkv, "tm_shift": tm_last, "cm_shift": cm_last}

    x, new_state = jax.lax.scan(body, x, (params["blocks"], state), unroll=cfg.scan_unroll)
    logits = unembed(params, x, cfg, ctx)
    return logits, new_state


def rwkv_forward(params, tokens, cfg, ctx: ShardCtx = ShardCtx()):
    logits, _ = _run(params, tokens, cfg, ctx, _zero_state(cfg, tokens.shape[0]))
    return logits, {}


def rwkv_prefill(params, tokens, cfg, ctx: ShardCtx = ShardCtx()):
    return _run(params, tokens, cfg, ctx, _zero_state(cfg, tokens.shape[0]))


def rwkv_decode(params, tokens, state, pos, cfg, ctx: ShardCtx = ShardCtx()):
    """pos unused (state is position-free) — kept for API parity."""
    del pos
    return _run(params, tokens, cfg, ctx, state)
