"""Cost-model prediction quality benchmark (ISSUE 8, DESIGN.md §13).

In an 8-virtual-CPU-device subprocess: plan the BENCH 512^3 GEMM under each
K-collective schedule the planner chooses between, time it, and report
predicted-vs-measured ms plus RANKING accuracy (top-1 + pairwise) — the
number that tells us whether the model orders schedules correctly even when
its absolute scale is off (uncalibrated hosts).  The same run records the
auto-sharding decision for the unsharded spec and asserts the model ranks
reduce_scatter_k ahead of allgather_a (the gather re-runs the full-K kernel
p times for identical bytes moved) — the `BENCH_kernels.json["costmodel"]`
section is the cross-PR artifact tracking both.
"""

import json
import os
import subprocess
import sys
import tempfile
import textwrap

_PROG = textwrap.dedent(
    """
    import itertools, json, time
    import jax, jax.numpy as jnp, numpy as np
    from repro.costmodel import current_coefficients, predict, terms_from_describe
    from repro.costmodel import choose as _choose
    from repro.kernels import api
    from repro.launch.mesh import make_local_mesh
    from repro import obs

    # Tracing + bridge live for the whole bench: every eager p(a, b) below
    # emits a plan.execute span that the bridge converts into a calibration
    # record — this is the multi-device lane ROADMAP 2(a) was missing.
    obs.enable()
    obs.install()

    M = K = N = 512
    STEPS = 10
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))

    mesh = make_local_mesh((8,), ("x",))
    coeffs = current_coefficients()
    cases = [
        ("allgather_a", api.ShardSpec.from_mesh(mesh, m="x", schedule="allgather_a")),
        ("reduce_scatter_k",
         api.ShardSpec.from_mesh(mesh, k="x", schedule="reduce_scatter_k")),
        ("ring_k", api.ShardSpec.from_mesh(mesh, k="x", schedule="ring_k")),
    ]
    rows = []
    for name, shard in cases:
        spec = api.GemmSpec.from_operands(a, b, shard=shard)
        p = api.plan(spec, mesh=mesh)
        p(a, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(STEPS):
            out = p(a, b)
        out.block_until_ready()
        ms = (time.perf_counter() - t0) / STEPS * 1e3
        terms = terms_from_describe(p.describe())
        pred = predict(terms, coeffs)
        rows.append({
            "schedule": name,
            "predicted_ms": round(pred["total_s"] * 1e3, 4),
            "measured_ms": round(ms, 3),
            "ratio": round(ms / (pred["total_s"] * 1e3), 2),
        })
        # the bench's own blocked-and-timed number is the highest-quality
        # sample; submit it alongside the bridge's per-execute spans
        obs.submit_calibration([{
            "terms": terms, "ms": ms, "source": "bench_costmodel",
            "key": f"{M}x{K}x{N}|" + terms.get("backend", "?"),
        }])

    # ranking accuracy: does the model ORDER the schedules like the clock?
    by_pred = sorted(rows, key=lambda r: r["predicted_ms"])
    by_meas = sorted(rows, key=lambda r: r["measured_ms"])
    pairs = list(itertools.combinations(range(len(rows)), 2))
    agree = sum(
        1 for i, j in pairs
        if (rows[i]["predicted_ms"] < rows[j]["predicted_ms"])
        == (rows[i]["measured_ms"] < rows[j]["measured_ms"])
    )
    ranking = {
        "top1_predicted": by_pred[0]["schedule"],
        "top1_measured": by_meas[0]["schedule"],
        "top1_correct": by_pred[0]["schedule"] == by_meas[0]["schedule"],
        "pairwise_accuracy": round(agree / len(pairs), 3),
    }

    # the auto-sharding decision for the UNSHARDED spec (pure model, no
    # timing): reduce_scatter_k must outrank allgather_a on this mesh
    spec = api.GemmSpec.from_operands(a, b)
    _, dec = _choose.decide_sharding(spec, mesh)
    d = dec.as_dict()
    order = [c["name"] for c in d["candidates"] if c.get("legal")]
    rs = next(i for i, n in enumerate(order) if n.startswith("reduce_scatter_k"))
    ag = next(i for i, n in enumerate(order) if n.startswith("allgather_a"))
    assert rs < ag, f"model ranked allgather_a over reduce_scatter_k: {order}"
    auto = {
        "chosen": d["chosen"],
        "rank_reduce_scatter_k": rs,
        "rank_allgather_a": ag,
        "rs_before_ag": rs < ag,
        "calibration": d["calibration"],
    }
    # fold the buffered measurements (bench submissions + bridged
    # plan.execute spans) into the scratch calibration cache and refit:
    # link_bytes_per_s / phase_latency_s now come from THIS host's
    # multi-device timings, not shipped defaults
    pre = current_coefficients()
    ingested = obs.flush_calibration()
    post = current_coefficients()
    calibration = {
        "ingested": ingested,
        "source": post.source,
        "link_bytes_per_s": post.link_bytes_per_s,
        "phase_latency_s": post.phase_latency_s,
        "link_moved": post.link_bytes_per_s != pre.link_bytes_per_s,
        "spans": obs.stats()["finished"],
    }
    print("COSTMODEL_JSON " + json.dumps({
        "mkn": f"{M}x{K}x{N}", "rows": rows, "ranking": ranking, "auto": auto,
        "calibration": calibration,
    }))
    """
)


def _run_subprocess() -> dict:
    from repro.launch.mesh import forced_device_env

    env = forced_device_env(8)
    # scratch calibration cache: the bench must neither read a stale repo
    # fit nor leave one behind
    with tempfile.TemporaryDirectory() as td:
        env["REPRO_COSTMODEL_CACHE"] = os.path.join(td, "costmodel.json")
        out = subprocess.run(
            [sys.executable, "-c", _PROG], capture_output=True, text=True, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=560,
        )
    if out.returncode != 0:
        return {"error": out.stderr[-500:]}
    for line in out.stdout.splitlines():
        if line.startswith("COSTMODEL_JSON "):
            return json.loads(line[len("COSTMODEL_JSON "):])
    return {"error": "no COSTMODEL_JSON line in subprocess output"}


def run(as_dict: bool = False):
    print("# Cost model predicted vs measured (8 virtual CPU devices, 512^3 GEMM)")
    doc = _run_subprocess()
    if "error" in doc:
        # don't fail the whole bench suite on subprocess quirks
        print(f"subprocess failed: {doc['error']}")
        return doc if as_dict else True
    print("schedule,predicted_ms,measured_ms,ratio")
    for r in doc["rows"]:
        print(f"{r['schedule']},{r['predicted_ms']},{r['measured_ms']},{r['ratio']}")
    rk, auto = doc["ranking"], doc["auto"]
    print(
        f"ranking: top1_predicted={rk['top1_predicted']}"
        f" top1_measured={rk['top1_measured']}"
        f" top1_correct={rk['top1_correct']}"
        f" pairwise_accuracy={rk['pairwise_accuracy']}"
    )
    print(
        f"auto-shard: chosen={auto['chosen']}"
        f" rs_rank={auto['rank_reduce_scatter_k']}"
        f" ag_rank={auto['rank_allgather_a']}"
        f" source={auto['calibration']['source']}"
    )
    cal = doc.get("calibration", {})
    if cal:
        print(
            f"calibration: ingested={cal['ingested']} source={cal['source']}"
            f" link_bytes_per_s={cal['link_bytes_per_s']:.3g}"
            f" link_moved={cal['link_moved']} spans={cal['spans']}"
        )
    return doc if as_dict else True


if __name__ == "__main__":
    run()
