"""Dispatch-overhead microbench: eager `ops.matmul` vs a pre-built Plan.

The plan/execute split exists so serving pays backend resolution, capability
validation, autotune lookup, and spec construction ONCE — this section
measures what that saves per call.  Three variants over the same GEMM:

  eager     ops.matmul(a, b) each call — the legacy shim path (builds a
            GemmSpec + Epilogue, consults the plan cache, validates, executes)
  plan_hit  api.plan(spec) each call + execute — spec hashing + cache lookup
            per call, no rebuild
  planned   one Plan built up front, called directly — the serving hot path
  raw       plan.executor called directly — no per-call Python validation
            (the floor: pure jitted-dispatch latency)
  async_batch8  eight independent `Plan.dispatch` calls enqueued, then ONE
            block (api.execute_async) — vs eight sync round-trips; per-call
            µs, so the win is the amortized synchronization

plus the amortized-away cost itself:

  plan_build_cold   api.plan on an empty cache (capability validation +
                    executor construction; kernel compile happens on first
                    call, not here)

The GEMM is tiny (64³) and every call synchronizes, so rows differ by Python
dispatch work, not kernel time.  `run(as_dict=True)` returns a JSON-able
payload merged into BENCH_kernels.json by `benchmarks/run.py --json`,
tracking the plan-cache win across PRs.
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import api
from repro.kernels.ops import matmul

M = K = N = 64
ITERS = 300


def _time_per_call(fn, iters=ITERS):
    for _ in range(3):  # warm: trace/compile + prime the plan cache
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn().block_until_ready()  # sync per call: steady-state latency
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def run(as_dict=False):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    spec = api.GemmSpec.from_operands(a, b)
    plan = api.plan(spec)

    rows = {
        "eager_matmul": _time_per_call(lambda: matmul(a, b)),
        "plan_cache_hit": _time_per_call(lambda: api.plan(spec)(a, b)),
        "prebuilt_plan": _time_per_call(lambda: plan(a, b)),
        "raw_executor": _time_per_call(lambda: plan.executor(a, b, None, None)),
    }

    def _async_batch(batch=8):
        # dispatch `batch` independent calls, sync once at the end; report
        # per-call µs so the row is comparable to the sync paths above
        items = [(plan, (a, b))] * batch
        api.execute_async(items)  # warm
        t0 = time.perf_counter()
        for _ in range(ITERS // batch):
            api.execute_async(items)
        return (time.perf_counter() - t0) / (ITERS // batch * batch) * 1e6

    rows["async_batch8"] = _async_batch()

    def _build_cold():
        # snapshot + restore the whole cache/stats around the cold build so
        # plans made by other sections (and the process-wide hit/miss
        # telemetry) survive the measurement unchanged
        saved_cache = dict(api._PLAN_CACHE)
        saved_stats = dict(api._PLAN_STATS)
        api._PLAN_CACHE.clear()
        t0 = time.perf_counter()
        api.plan(spec)
        dt = time.perf_counter() - t0
        api._PLAN_CACHE.clear()
        api._PLAN_CACHE.update(saved_cache)
        api._PLAN_STATS.update(saved_stats)
        return dt

    _build_cold()  # warm autotune/module state
    rows["plan_build_cold"] = sum(_build_cold() for _ in range(20)) / 20 * 1e6

    print("# dispatch overhead: eager ops.matmul vs pre-built Plan "
          f"({M}x{K}x{N} f32, backend={plan.backend})")
    print("path,us_per_call")
    for name, us in rows.items():
        print(f"{name},{us:.1f}")
    speedup = rows["eager_matmul"] / max(rows["prebuilt_plan"], 1e-9)
    print(f"plan_speedup,{speedup:.2f}x")

    result = {
        "mkn": f"{M}x{K}x{N}",
        "backend": plan.backend,
        "us_per_call": {k: round(v, 2) for k, v in rows.items()},
        "plan_speedup": round(speedup, 2),
    }
    return result if as_dict else rows


if __name__ == "__main__":
    run()
