"""Roofline reader: renders EXPERIMENTS.md §Roofline from the dry-run
artifacts (single-pod).  Fails soft if the sweep hasn't been run."""

import os

from repro.launch.roofline import analyze_dir, render_markdown


def run(csv=False):
    base = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "artifacts", "pod16x16"
    )
    if not os.path.isdir(base):
        print("# no artifacts/pod16x16 — run: python -m repro.launch.dryrun --all")
        return []
    rows = analyze_dir(base)
    print(render_markdown(rows, title="Roofline — pod16x16 (baseline artifacts)"))
    live = [r for r in rows if not r.get("skip")]
    print(f"cells_ok,{len(live)}")
    print(f"cells_skipped,{len(rows) - len(live)}")
    if live:
        worst = min(live, key=lambda r: r["roofline_fraction"])
        best = max(live, key=lambda r: r["roofline_fraction"])
        print(f"best_fraction,{best['arch']}x{best['shape']},{best['roofline_fraction']:.3f}")
        print(f"worst_fraction,{worst['arch']}x{worst['shape']},{worst['roofline_fraction']:.3f}")
    return rows


if __name__ == "__main__":
    run()
