"""Paper Figs 1-2: mesh (2n-1) vs standard (3n-2) step counts, validated
cycle-accurately, plus simulator wall-time.

Emits one row per n: analytic counts, simulated counts, speedup ratio, and
the distributed (ICI torus) phase analogue from parallel/systolic.py.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mesh_array import simulate_mesh, simulate_standard
from repro.core.scramble import unscramble
from repro.parallel.systolic import phase_counts


def run(csv=False):
    rows = []
    rng = np.random.default_rng(0)
    for n in (2, 3, 4, 8, 16, 32, 64, 128):
        a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
        t0 = time.perf_counter()
        res_m = simulate_mesh(a, b)
        jax.block_until_ready(res_m.output)
        t_mesh = time.perf_counter() - t0
        t0 = time.perf_counter()
        res_s = simulate_standard(a, b)
        jax.block_until_ready(res_s.output)
        t_std = time.perf_counter() - t0
        ok = bool(
            np.allclose(np.asarray(unscramble(res_m.output)), np.asarray(a @ b), atol=1e-3)
            and np.allclose(np.asarray(res_s.output), np.asarray(a @ b), atol=1e-3)
        )
        pc = phase_counts(n)
        rows.append(
            dict(
                n=n,
                mesh_steps=res_m.steps,
                standard_steps=res_s.steps,
                step_ratio=round(res_s.steps / res_m.steps, 4),
                torus_switched_phases=pc["switched_phases"],
                torus_naive_phases=pc["naive_phases"],
                sim_ms_mesh=round(t_mesh * 1e3, 2),
                sim_ms_standard=round(t_std * 1e3, 2),
                correct=ok,
            )
        )
    header = list(rows[0])
    print("# paper Figs 1-2 — step counts (mesh 2n-1 vs standard 3n-2)")
    print(",".join(header))
    for r in rows:
        print(",".join(str(r[k]) for k in header))
    assert all(r["correct"] for r in rows)
    assert all(r["mesh_steps"] == 2 * r["n"] - 1 for r in rows)
    assert all(r["standard_steps"] == 3 * r["n"] - 2 for r in rows)
    return rows


if __name__ == "__main__":
    run()
