"""Observability overhead benchmark (ISSUE 9, DESIGN.md §14).

Two numbers guard the tracing contract:

  disabled_overhead_pct   cost of a disabled `with obs.span(...)` relative
                          to a realistic traced body (~tens of µs) — the
                          contract is <2% (the disabled path is one
                          attribute check returning a shared no-op span).
                          Computed as direct per-call cost over per-iter
                          body cost: differencing two long loops would
                          drown the ~200ns effect in scheduler noise.
  spans_per_s             enabled-path throughput: how many begin/end span
                          cycles per second the ring sustains (attrs, thread
                          stack, deque append).

Best-of-reps timing everywhere so load spikes don't read as overhead; the
`BENCH_kernels.json["obs"]` series tracks both numbers across PRs.
"""

import time

from repro import obs

ITERS = 10_000
REPS = 5


def _workload() -> int:
    # ~10us of real Python work — the scale of the cheapest traced
    # operations (a scheduler tick, a plan-cache hit)
    return sum(range(5000))


def _best(fn, *, reps: int = REPS) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _bare() -> None:
    for _ in range(ITERS):
        _workload()


def _span_only() -> None:
    # empty body: times the span machinery itself (disabled: the attribute
    # check + no-op span; enabled: begin/end, thread stack, ring append)
    for _ in range(ITERS):
        with obs.span("bench.obs", i=0):
            pass


def run(as_dict: bool = False):
    print(f"# obs tracing overhead ({ITERS} iters, best of {REPS})")
    was_enabled = obs.is_enabled()
    obs.disable()
    try:
        _bare(), _span_only()  # warm both paths (bytecode/caches)
        bare_s = _best(_bare)
        disabled_ns = _best(_span_only) / ITERS * 1e9
        overhead_pct = disabled_ns * 1e-9 / (bare_s / ITERS) * 100.0

        with obs.tracing(capacity=ITERS):
            _span_only()  # warm the enabled path
            obs.clear_spans()
            on_s = _best(_span_only, reps=3)
        spans_per_s = ITERS / on_s
    finally:
        if was_enabled:
            obs.enable()
    print("metric,value")
    print(f"disabled_overhead_pct,{overhead_pct:.3f}")
    print(f"disabled_ns_per_span,{disabled_ns:.0f}")
    print(f"spans_per_s,{spans_per_s:.0f}")
    print(f"bare_us_per_iter,{bare_s / ITERS * 1e6:.3f}")
    assert overhead_pct < 2.0, (
        f"disabled tracing overhead {overhead_pct:.2f}% breaks the <2% contract"
    )
    doc = {
        "iters": ITERS,
        "disabled_overhead_pct": round(overhead_pct, 3),
        "disabled_ns_per_span": round(disabled_ns),
        "spans_per_s": round(spans_per_s),
        "bare_us_per_iter": round(bare_s / ITERS * 1e6, 3),
    }
    return doc if as_dict else True


if __name__ == "__main__":
    run()
