"""ShardedPlan collective-schedule benchmark (ISSUE 4).

In an 8-virtual-CPU-device subprocess: plan one GEMM under every collective
schedule and measure wall time per step next to the plan's own bytes-moved
provenance — the cross-PR artifact (`BENCH_kernels.json` "sharded" section)
that tracks whether schedule choice and the comm model stay sane.  The
unsharded plan runs as the baseline row.
"""

import json
import os
import subprocess
import sys
import textwrap

_PROG = textwrap.dedent(
    """
    import json, time
    import jax, jax.numpy as jnp, numpy as np
    from repro.kernels import api
    from repro.launch.mesh import make_local_mesh

    M = K = N = 512
    STEPS = 20
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))

    mesh1d = make_local_mesh((8,), ("x",))
    mesh2d = make_local_mesh((4, 2), ("x", "y"))
    cases = [
        ("unsharded", None, None),
        ("replicated_mn", mesh2d, api.ShardSpec.from_mesh(mesh2d, m="x", n="y")),
        ("allgather_a", mesh1d,
         api.ShardSpec.from_mesh(mesh1d, m="x", schedule="allgather_a")),
        ("reduce_scatter_k", mesh1d,
         api.ShardSpec.from_mesh(mesh1d, k="x", schedule="reduce_scatter_k")),
        ("ring_k", mesh1d,
         api.ShardSpec.from_mesh(mesh1d, k="x", schedule="ring_k")),
    ]
    rows = []
    for name, mesh, shard in cases:
        spec = api.GemmSpec.from_operands(a, b, shard=shard)
        p = api.plan(spec, mesh=mesh)
        p(a, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(STEPS):
            out = p(a, b)
        out.block_until_ready()
        ms = (time.perf_counter() - t0) / STEPS * 1e3
        sh = p.describe().get("sharding") or {}
        rows.append({
            "case": name,
            "schedule": sh.get("schedule", "-"),
            "bytes_moved": sh.get("bytes_moved", 0),
            "collective_phases": sh.get("collective_phases", 0),
            "per_shard_flops": sh.get("per_shard_flops", p.flops),
            "ms_per_step": round(ms, 3),
        })
    print("SHARDED_JSON " + json.dumps({"mkn": f"{M}x{K}x{N}", "rows": rows}))
    """
)


def _run_subprocess() -> dict:
    from repro.launch.mesh import forced_device_env

    env = forced_device_env(8)
    out = subprocess.run(
        [sys.executable, "-c", _PROG], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=560,
    )
    if out.returncode != 0:
        return {"error": out.stderr[-500:]}
    for line in out.stdout.splitlines():
        if line.startswith("SHARDED_JSON "):
            return json.loads(line[len("SHARDED_JSON "):])
    return {"error": "no SHARDED_JSON line in subprocess output"}


def run(as_dict: bool = False):
    print("# ShardedPlan collective schedules (8 virtual CPU devices, 512^3 GEMM)")
    doc = _run_subprocess()
    if "error" in doc:
        # don't fail the whole bench suite on subprocess quirks
        print(f"subprocess failed: {doc['error']}")
        return doc if as_dict else True
    print("case,schedule,bytes_moved,phases,ms_per_step")
    for r in doc["rows"]:
        print(
            f"{r['case']},{r['schedule']},{r['bytes_moved']},"
            f"{r['collective_phases']},{r['ms_per_step']}"
        )
    return doc if as_dict else True


if __name__ == "__main__":
    run()
