"""ShardedPlan collective-schedule benchmark (ISSUE 4, overlap in ISSUE 10).

In an 8-virtual-CPU-device subprocess: plan one GEMM under every collective
schedule and measure wall time per step next to the plan's own bytes-moved
provenance — the cross-PR artifact (`BENCH_kernels.json` "sharded" section)
that tracks whether schedule choice and the comm model stay sane.  The
unsharded plan runs as the baseline row.

The double-buffered schedules ride the same table: every `*_overlap` /
`pipeline` row is asserted BITWISE-equal to its serial twin (the operands
are integer-valued f32, so accumulation-order differences cannot hide), and
`overlap_efficiency = serial_ms / overlap_ms` is recorded on the row and on
the plan itself (`ShardedPlan.note_overlap_efficiency`).  The fixed
`allgather_a` (compute-once result gather) is asserted within 2x of
`reduce_scatter_k` — the old input-rotation form ran the full-K kernel p
times and sat at ~5x.

CLI: `python -m benchmarks.bench_sharded [--schedule NAME]` — with
`--schedule` only the named schedule (plus its serial twin and the
unsharded baseline) runs: the CI distributed job's overlap smoke.
"""

import argparse
import json
import os
import subprocess
import sys
import textwrap

_PROG = textwrap.dedent(
    """
    import json, os, time
    import jax, jax.numpy as jnp, numpy as np
    from repro.kernels import api
    from repro.launch.mesh import make_local_mesh

    M = K = N = 512
    STEPS = 10
    REPS = 4  # best-of-REPS: overlap_efficiency compares two ~10ms numbers,
              # so per-rep noise must not masquerade as a schedule regression
    rng = np.random.default_rng(0)
    # Integer-valued f32 operands: products are exact (max |dot| = 16*512,
    # far below 2^24), so bitwise comparison is meaningful across schedules.
    a = jnp.asarray(rng.integers(-4, 5, size=(M, K)).astype(np.float32))
    b = jnp.asarray(rng.integers(-4, 5, size=(K, N)).astype(np.float32))

    mesh1d = make_local_mesh((8,), ("x",))
    mesh2d = make_local_mesh((4, 2), ("x", "y"))
    # overlap/pipeline rows assert bitwise equality against their serial twin
    TWIN = {
        "allgather_a_overlap": "allgather_a",
        "reduce_scatter_k_overlap": "reduce_scatter_k",
        "ring_k_overlap": "ring_k",
        "pipeline": "reduce_scatter_k",
    }
    cases = [
        ("unsharded", None, None),
        ("replicated_mn", mesh2d, api.ShardSpec.from_mesh(mesh2d, m="x", n="y")),
        ("allgather_a", mesh1d,
         api.ShardSpec.from_mesh(mesh1d, m="x", schedule="allgather_a")),
        ("allgather_a_overlap", mesh1d,
         api.ShardSpec.from_mesh(mesh1d, m="x", schedule="allgather_a_overlap")),
        ("reduce_scatter_k", mesh1d,
         api.ShardSpec.from_mesh(mesh1d, k="x", schedule="reduce_scatter_k")),
        ("reduce_scatter_k_overlap", mesh1d,
         api.ShardSpec.from_mesh(mesh1d, k="x",
                                 schedule="reduce_scatter_k_overlap")),
        ("ring_k", mesh1d,
         api.ShardSpec.from_mesh(mesh1d, k="x", schedule="ring_k")),
        ("ring_k_overlap", mesh1d,
         api.ShardSpec.from_mesh(mesh1d, k="x", schedule="ring_k_overlap")),
        ("pipeline", mesh1d,
         api.ShardSpec.from_mesh(mesh1d, k="x", schedule="pipeline")),
    ]
    only = os.environ.get("REPRO_BENCH_SCHEDULE")
    if only:
        keep = {"unsharded", only, TWIN.get(only, only)}
        cases = [c for c in cases if c[0] in keep]

    rows, outs, times, plans = [], {}, {}, {}
    for name, mesh, shard in cases:
        spec = api.GemmSpec.from_operands(a, b, shard=shard)
        p = api.plan(spec, mesh=mesh)
        out = p(a, b)
        out.block_until_ready()
        ms = float("inf")
        for _ in range(REPS):
            t0 = time.perf_counter()
            for _ in range(STEPS):
                out = p(a, b)
            out.block_until_ready()
            ms = min(ms, (time.perf_counter() - t0) / STEPS * 1e3)
        outs[name], times[name], plans[name] = np.asarray(out), ms, p
        sh = p.describe().get("sharding") or {}
        rows.append({
            "case": name,
            "schedule": sh.get("schedule", "-"),
            "overlap": bool(sh.get("overlap", False)),
            "bytes_moved": sh.get("bytes_moved", 0),
            "collective_phases": sh.get("collective_phases", 0),
            "per_shard_flops": sh.get("per_shard_flops", p.flops),
            "ms_per_step": round(ms, 3),
        })

    for r in rows:
        twin = TWIN.get(r["case"])
        if twin is None or twin not in outs:
            continue
        # the serial path is the oracle: outputs must match bit for bit
        assert np.array_equal(outs[r["case"]], outs[twin]), (
            f"{r['case']} output differs from serial twin {twin}")
        eff = times[twin] / times[r["case"]]
        r["overlap_efficiency"] = round(eff, 3)
        plans[r["case"]].note_overlap_efficiency(eff)
    if "allgather_a" in times and "reduce_scatter_k" in times:
        # the compute-once gather must stay in reduce_scatter_k's league
        # (the input-rotation pathology was ~5x)
        assert times["allgather_a"] < 2 * times["reduce_scatter_k"], (
            f"allgather_a {times['allgather_a']:.2f}ms >= 2x reduce_scatter_k "
            f"{times['reduce_scatter_k']:.2f}ms")
    print("SHARDED_JSON " + json.dumps({"mkn": f"{M}x{K}x{N}", "rows": rows}))
    """
)


def _run_subprocess(schedule: str = None) -> dict:
    from repro.launch.mesh import forced_device_env

    env = forced_device_env(8)
    if schedule:
        env["REPRO_BENCH_SCHEDULE"] = schedule
    out = subprocess.run(
        [sys.executable, "-c", _PROG], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=560,
    )
    if out.returncode != 0:
        return {"error": out.stderr[-500:]}
    for line in out.stdout.splitlines():
        if line.startswith("SHARDED_JSON "):
            return json.loads(line[len("SHARDED_JSON "):])
    return {"error": "no SHARDED_JSON line in subprocess output"}


def run(as_dict: bool = False, schedule: str = None):
    scope = f", --schedule {schedule}" if schedule else ""
    print(
        "# ShardedPlan collective schedules "
        f"(8 virtual CPU devices, 512^3 GEMM{scope})"
    )
    doc = _run_subprocess(schedule)
    if "error" in doc:
        if schedule:
            # the targeted smoke (CI) must FAIL loudly, not shrug
            raise RuntimeError(f"sharded bench subprocess failed: {doc['error']}")
        # don't fail the whole bench suite on subprocess quirks
        print(f"subprocess failed: {doc['error']}")
        return doc if as_dict else True
    print("case,schedule,bytes_moved,phases,ms_per_step,overlap_efficiency")
    for r in doc["rows"]:
        eff = r.get("overlap_efficiency")
        print(
            f"{r['case']},{r['schedule']},{r['bytes_moved']},"
            f"{r['collective_phases']},{r['ms_per_step']},"
            f"{eff if eff is not None else '-'}"
        )
    return doc if as_dict else True


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--schedule",
        default=None,
        help="run only this schedule (plus its serial twin and the unsharded"
        " baseline); bitwise parity is still asserted",
    )
    args = ap.parse_args()
    run(schedule=args.schedule)
